//! Quickstart: map one SNN onto neuromorphic hardware in ~20 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Generates a small LeNet-derived SNN, maps it with the paper's headline
//! pipeline (hyperedge-overlap partitioning → spectral placement →
//! force-directed refinement), and prints the Table I metrics. Uses the
//! AOT JAX/Pallas artifacts via PJRT when `artifacts/` exists.

use snnmap::prelude::*;
use snnmap::runtime::PjrtRuntime;

fn main() {
    // 1. A network: LeNet topology at 25% scale, biological spike rates.
    let net = snnmap::snn::by_name("lenet", 0.25, 42).expect("suite network");
    println!(
        "network: {} — {} neurons, {} axons, {} synapses",
        net.name,
        net.graph.num_nodes(),
        net.graph.num_edges(),
        net.graph.num_connections()
    );

    // 2. Hardware: Loihi-like "small" preset, constraints scaled down so
    //    the example produces a multi-core mapping.
    let hw = NmhConfig::small().scaled(0.05);

    // 3. The pipeline. Engine: PJRT artifacts when built, else native.
    let runtime = PjrtRuntime::discover();
    let result = MapperPipeline::new(hw)
        .partitioner(PartitionerKind::HyperedgeOverlap)
        .placer(PlacerKind::Spectral)
        .refiner(RefinerKind::ForceDirected)
        .run_with(&net.graph, net.layer_ranges.as_deref(), runtime.as_ref())
        .expect("mapping failed");

    println!(
        "engine: {}",
        if runtime.is_some() { "PJRT (AOT JAX/Pallas artifacts)" } else { "native" }
    );
    print!("{}", result.report());

    // 4. The mapping artifacts themselves are plain data:
    let p0_core = result.placement.coords[0];
    println!(
        "partition of neuron 0: {} -> core ({}, {})",
        result.rho.assign[0], p0_core.0, p0_core.1
    );
}
