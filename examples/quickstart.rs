//! Quickstart: map one SNN onto neuromorphic hardware in ~20 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Generates a small LeNet-derived SNN, maps it with the paper's headline
//! pipeline (hyperedge-overlap partitioning → spectral placement →
//! force-directed refinement), and prints the Table I metrics — first
//! through the enum-builder shims, then re-running the identical mapping
//! from a serializable PipelineSpec. Uses the AOT JAX/Pallas artifacts
//! via PJRT when `artifacts/` exists.

use snnmap::prelude::*;
use snnmap::runtime::PjrtRuntime;

fn main() {
    // 1. A network: LeNet topology at 25% scale, biological spike rates.
    let net = snnmap::snn::by_name("lenet", 0.25, 42).expect("suite network");
    println!(
        "network: {} — {} neurons, {} axons, {} synapses",
        net.name,
        net.graph.num_nodes(),
        net.graph.num_edges(),
        net.graph.num_connections()
    );

    // 2. Hardware: Loihi-like "small" preset, constraints scaled down so
    //    the example produces a multi-core mapping.
    let hw = NmhConfig::small().scaled(0.05);

    // 3. The pipeline, via the enum-builder shims (each shim resolves a
    //    built-in stage through the StageRegistry). Engine: PJRT
    //    artifacts when built, else native.
    let runtime = PjrtRuntime::discover();
    let result = MapperPipeline::new(hw)
        .partitioner(PartitionerKind::HyperedgeOverlap)
        .placer(PlacerKind::Spectral)
        .refiner(RefinerKind::ForceDirected)
        .seed(42)
        .run_with(&net.graph, net.layer_ranges.as_deref(), runtime.as_ref())
        .expect("mapping failed");

    println!(
        "engine: {}",
        if runtime.is_some() { "PJRT (AOT JAX/Pallas artifacts)" } else { "native" }
    );
    print!("{}", result.report());

    // 4. The same run as plain data: a PipelineSpec is a JSON document
    //    (stage names + params + hw + seed) that reproduces the mapping
    //    bit for bit — archive it, diff it, ship it to a grid runner.
    let spec = PipelineSpec::from_json_str(
        r#"{
            "partitioner": "overlap",
            "placer": "spectral",
            "refiner": "force",
            "hw": {"preset": "small", "scale": 0.05},
            "seed": 42
        }"#,
    )
    .expect("spec parses");
    let replay = MapperPipeline::from_spec(&spec)
        .expect("all stage names registered")
        .run_with(&net.graph, net.layer_ranges.as_deref(), runtime.as_ref())
        .expect("mapping failed");
    assert_eq!(result.rho.assign, replay.rho.assign, "spec replay is bit-for-bit");
    println!("spec replay: identical partitioning ({} partitions)", replay.rho.num_parts);
    println!("spec JSON:\n{}", spec.to_json().to_pretty());

    // 5. The mapping artifacts themselves are plain data:
    let p0_core = result.placement.coords[0];
    println!(
        "partition of neuron 0: {} -> core ({}, {})",
        result.rho.assign[0], p0_core.0, p0_core.1
    );
}
