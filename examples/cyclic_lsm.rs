//! Cyclic / biological scenario: liquid-state-machine-style recurrent
//! SNNs have no layer order to exploit (paper §II-A, §V-A) — exactly
//! where hypergraph affinity methods earn their keep. This example maps
//! an x_rand network and an Allen-V1-like cortical model, comparing the
//! graph-based control (EdgeMap) against the hypergraph methods.
//!
//!     cargo run --release --example cyclic_lsm

use snnmap::coordinator::{MapperPipeline, PartitionerKind, PlacerKind, RefinerKind};
use snnmap::hw::NmhConfig;
use snnmap::hypergraph::stats;

fn main() {
    for (name, scale) in [("16k_rand", 0.12), ("allen_v1", 0.04)] {
        let net = snnmap::snn::by_name(name, scale, 3).expect("suite network");
        let apl = stats::avg_path_length(&net.graph, 8, 7);
        let overlap = stats::mean_hedge_overlap(&net.graph, 10_000, 7);
        println!(
            "\n=== {} — {} neurons, {} synapses | small-world: path length {:.2}, h-edge overlap {:.3}",
            net.name,
            net.graph.num_nodes(),
            net.graph.num_connections(),
            apl,
            overlap
        );
        let hw = NmhConfig::small().scaled(0.08);
        println!(
            "{:<15} {:>7} {:>14} {:>11} {:>10}",
            "partitioner", "parts", "connectivity", "ELP", "time"
        );
        for pk in [
            PartitionerKind::EdgeMap,
            PartitionerKind::SequentialUnordered,
            PartitionerKind::Sequential,
            PartitionerKind::HyperedgeOverlap,
            PartitionerKind::Hierarchical,
        ] {
            let t0 = std::time::Instant::now();
            let res = MapperPipeline::new(hw)
                .partitioner(pk)
                .placer(PlacerKind::Spectral)
                .refiner(RefinerKind::ForceDirected)
                .run(&net.graph, None)
                .expect("mapping failed");
            println!(
                "{:<15} {:>7} {:>14.4e} {:>11.3e} {:>9.2}s",
                pk.name(),
                res.rho.num_parts,
                res.metrics.connectivity,
                res.metrics.elp,
                t0.elapsed().as_secs_f64()
            );
        }
    }
    println!(
        "\nno layer order exists here, so unordered sequential degrades badly and \
EdgeMap's\nfirst-order-only guidance leaves reuse on the table; overlap partitioning \
plus spectral\nplacement is the paper's recommendation for this regime (§V-B2: 'for \
the Allen V1 ... unilaterally\nfinds the best mappings in the least time')."
    );
}
