//! Layered-SNN scenario: ANN-derived feedforward networks are the bread
//! and butter of neuromorphic deployment (paper §II-A). This example maps
//! a VGG-style x_model with all partitioners and shows how layer-major
//! order helps sequential partitioning — and where hypergraph methods
//! still win.
//!
//!     cargo run --release --example layered_pipeline

use snnmap::coordinator::{MapperPipeline, PartitionerKind, PlacerKind, RefinerKind};
use snnmap::hw::NmhConfig;
use snnmap::metrics::properties::{self, Mean};

fn main() {
    let net = snnmap::snn::by_name("16k_model", 0.2, 7).expect("16k_model");
    println!(
        "{}: {} neurons in {} layers, {} synapses, mean h-edge cardinality {:.1}",
        net.name,
        net.graph.num_nodes(),
        net.layer_ranges.as_ref().map(|r| r.len()).unwrap_or(0),
        net.graph.num_connections(),
        net.graph.mean_cardinality()
    );
    let hw = NmhConfig::small().scaled(0.08);

    println!(
        "\n{:<15} {:>7} {:>14} {:>9} {:>9} {:>10}",
        "partitioner", "parts", "connectivity", "sr_geo", "ELP", "time"
    );
    for pk in PartitionerKind::ALL {
        let t0 = std::time::Instant::now();
        let res = MapperPipeline::new(hw)
            .partitioner(pk)
            .placer(PlacerKind::Hilbert)
            .refiner(RefinerKind::ForceDirected)
            .run(&net.graph, net.layer_ranges.as_deref())
            .expect("mapping failed");
        let sr_geo = properties::synaptic_reuse(&net.graph, &res.rho, Mean::Geometric);
        println!(
            "{:<15} {:>7} {:>14.4e} {:>9.3} {:>9.3e} {:>9.2}s",
            pk.name(),
            res.rho.num_parts,
            res.metrics.connectivity,
            sr_geo,
            res.metrics.elp,
            t0.elapsed().as_secs_f64()
        );
    }

    println!(
        "\nreading the table: neighboring neurons in a conv layer share most of their \
receptive field,\nso the layer-major order already clusters co-members — sequential \
partitioning rides that.\nOverlap/hierarchical exploit the same structure explicitly \
through second-order affinity\nand keep winning when the layout order is less kind \
(see the cyclic_lsm example)."
    );
}
