//! End-to-end driver: proves all layers compose on a real small workload.
//!
//!     cargo run --release --example e2e_mapping_study
//!
//! Pipeline exercised per network (one per Table III class):
//!   1. generate the SNN workload (topology + biological spike rates);
//!   2. partition with the baseline (sequential+Hilbert+force, the [7]
//!      pipeline) and with the paper's hypergraph pipeline
//!      (overlap + spectral + force), the latter running its numeric hot
//!      spots through the AOT JAX/Pallas artifacts via PJRT;
//!   3. score both with the analytic Table I model;
//!   4. EXECUTE both mappings on the NoC simulator for several hundred
//!      timesteps — spikes drawn per-axon, XY-routed, per-link
//!      serialization — logging energy/step and makespan latency;
//!   5. report the headline ratio (paper: hypergraph mappings up to ~2x
//!      more efficient than graph-driven state of the art).
//!
//! Results are also written to e2e_results.json for EXPERIMENTS.md.

use snnmap::coordinator::{MapperPipeline, PartitionerKind, PlacerKind, RefinerKind};
use snnmap::metrics::evaluate;
use snnmap::runtime::PjrtRuntime;
use snnmap::sim::{simulate, SimParams};
use snnmap::util::json::Json;

struct Outcome {
    label: &'static str,
    elp: f64,
    energy: f64,
    latency: f64,
    sim_energy_step: f64,
    sim_makespan: f64,
    parts: usize,
    wall: f64,
}

fn main() {
    let runtime = PjrtRuntime::discover();
    println!(
        "engine: {}",
        runtime
            .as_ref()
            .map(|r| format!("PJRT ({}) + AOT JAX/Pallas artifacts", r.platform()))
            .unwrap_or_else(|| "native (run `make artifacts` for the PJRT path)".into())
    );

    let steps = 300;
    let mut all = Vec::new();
    for (name, scale) in [("16k_model", 0.25), ("allen_v1", 0.06), ("16k_rand", 0.15)] {
        let net = snnmap::snn::by_name(name, scale, 42).expect("network");
        let hw = snnmap::coordinator::experiment::hw_for(&net, scale);
        println!(
            "\n=== {} — {} neurons / {} synapses on {}x{} cores (C_npc {}) ===",
            net.name,
            net.graph.num_nodes(),
            net.graph.num_connections(),
            hw.width,
            hw.height,
            hw.c_npc
        );

        let mut outcomes = Vec::new();
        for (label, pk, pl) in [
            ("baseline[7]: seq+hilbert+force", PartitionerKind::Sequential, PlacerKind::Hilbert),
            (
                "hypergraph: overlap+spectral+force",
                PartitionerKind::HyperedgeOverlap,
                PlacerKind::Spectral,
            ),
        ] {
            let t0 = std::time::Instant::now();
            let res = MapperPipeline::new(hw)
                .partitioner(pk)
                .placer(pl)
                .refiner(RefinerKind::ForceDirected)
                .run_with(&net.graph, net.layer_ranges.as_deref(), runtime.as_ref())
                .expect("mapping failed");
            let wall = t0.elapsed().as_secs_f64();
            let analytic = evaluate(&res.gp, &res.placement, &hw);
            let sim = simulate(
                &res.gp,
                &res.placement,
                &hw,
                SimParams { timesteps: steps, seed: 9, poisson_spikes: true },
            );
            println!(
                "{label}\n  partitions {}  connectivity {:.4e}  built in {:.2}s",
                res.rho.num_parts, analytic.connectivity, wall
            );
            println!(
                "  analytic: energy {:.4e} pJ/step  latency {:.4e} ns  ELP {:.4e}",
                analytic.energy, analytic.latency, analytic.elp
            );
            println!(
                "  simulated {steps} steps: {:.4e} pJ/step (ratio {:.3}), makespan mean {:.1} ns max {:.1} ns, peak router {} spikes",
                sim.energy_per_step(),
                sim.energy_per_step() / analytic.energy,
                sim.mean_makespan,
                sim.max_makespan,
                sim.peak_router_load
            );
            outcomes.push(Outcome {
                label,
                elp: analytic.elp,
                energy: analytic.energy,
                latency: analytic.latency,
                sim_energy_step: sim.energy_per_step(),
                sim_makespan: sim.mean_makespan,
                parts: res.rho.num_parts,
                wall,
            });
        }
        let ratio = outcomes[0].elp / outcomes[1].elp;
        println!(
            ">>> hypergraph pipeline ELP improvement over baseline: {ratio:.2}x  [paper: up to ~2x]"
        );
        all.push((net.name.clone(), outcomes, ratio));
    }

    // headline + JSON archive
    println!("\n================ e2e summary ================");
    let mut json_nets = Vec::new();
    for (name, outcomes, ratio) in &all {
        println!("{name:<12} baseline/hypergraph ELP ratio = {ratio:.2}x");
        json_nets.push(Json::obj(vec![
            ("network", Json::Str(name.clone())),
            ("elp_improvement", Json::Num(*ratio)),
            (
                "pipelines",
                Json::Arr(
                    outcomes
                        .iter()
                        .map(|o| {
                            Json::obj(vec![
                                ("label", Json::Str(o.label.into())),
                                ("partitions", Json::Num(o.parts as f64)),
                                ("energy_pj_step", Json::Num(o.energy)),
                                ("latency_ns", Json::Num(o.latency)),
                                ("elp", Json::Num(o.elp)),
                                ("sim_energy_pj_step", Json::Num(o.sim_energy_step)),
                                ("sim_makespan_ns", Json::Num(o.sim_makespan)),
                                ("build_seconds", Json::Num(o.wall)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    let doc = Json::obj(vec![
        ("steps_simulated", Json::Num(steps as f64)),
        ("networks", Json::Arr(json_nets)),
    ]);
    std::fs::write("e2e_results.json", doc.to_pretty()).expect("write results");
    println!("wrote e2e_results.json");
}
