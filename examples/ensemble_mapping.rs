//! Ensemble scenario (paper §V-B2): "placement has more margin due to its
//! smaller problem size, and running an ensemble of different techniques
//! on a time limit — then selecting the best final mapping — is
//! practicable." This example partitions once and races every placement
//! candidate inside a wall-clock budget.
//!
//!     cargo run --release --example ensemble_mapping

use snnmap::coordinator::ensemble;
use snnmap::hw::NmhConfig;
use snnmap::runtime::PjrtRuntime;
use std::time::Duration;

fn main() {
    let net = snnmap::snn::by_name("allen_v1", 0.05, 11).expect("allen_v1");
    println!(
        "{}: {} neurons, {} synapses",
        net.name,
        net.graph.num_nodes(),
        net.graph.num_connections()
    );
    let hw = NmhConfig::small().scaled(0.08);
    let runtime = PjrtRuntime::discover();
    if runtime.is_some() {
        println!("engine: PJRT artifacts");
    }

    let budget = Duration::from_secs(120);
    // candidates are registry stage names — any registered placer or
    // refiner can race, not just the built-in enums
    let res = ensemble::run_named(
        &net.graph,
        None,
        hw,
        "overlap",
        budget,
        11,
        runtime.as_ref(),
    )
    .expect("ensemble failed");

    println!("\ncandidates (budget {budget:?}):");
    for (pl, rf, elp, dt) in &res.scoreboard {
        let winner = (pl, rf) == (&res.best_combo.0, &res.best_combo.1);
        let marker = if winner { "  << winner" } else { "" };
        println!(
            "  {:<10} + {:<6}  ELP {:>12.4e}  in {:>6.2}s{marker}",
            pl,
            rf,
            elp,
            dt.as_secs_f64()
        );
    }
    if res.budget_exhausted {
        println!("  (budget exhausted before trying every candidate)");
    }
    println!();
    print!("{}", res.best.report());
}
