//! E6 — Fig. 11: synaptic reuse / connections locality measures and their
//! Spearman rank correlation with connectivity / ELP, standardized
//! per-network (z-score) exactly as §V-C describes.
//!
//! Paper result: ρ(SR_geo, connectivity) ≈ −0.86, ρ(CL, ELP) ≈ +0.69.

mod common;

use snnmap::coordinator::experiment::{run_grid, GridSpec};
use snnmap::metrics::stats::grouped_spearman;
use std::collections::BTreeMap;

fn main() {
    let scale = common::scale();
    println!("Fig. 11 — property measures and correlations (scale {scale})");
    common::hr();
    let mut spec = GridSpec::fig10(scale); // full combo grid gives the spread
    spec.networks = common::bench_suite().into_iter().map(String::from).collect();
    let rows = run_grid(&spec);

    println!(
        "{:<14} {:<13} {:<16} {:>9} {:>9} {:>9} {:>9}",
        "network", "partitioner", "placer+refiner", "sr_arith", "sr_geo", "cl_arith", "cl_geo"
    );
    common::hr();
    for r in rows.iter().filter(|r| r.error.is_none()) {
        println!(
            "{:<14} {:<13} {:<16} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
            r.network,
            r.partitioner,
            format!("{}+{}", r.placer, r.refiner),
            r.sr_arith,
            r.sr_geo,
            r.cl_arith,
            r.cl_geo
        );
    }
    common::hr();

    // group per network, z-score within group, pooled Spearman (paper's method)
    let mut by_net: BTreeMap<&str, Vec<&_>> = BTreeMap::new();
    for r in rows.iter().filter(|r| r.error.is_none()) {
        by_net.entry(r.network.as_str()).or_default().push(r);
    }
    let groups_of = |fx: &dyn Fn(&&snnmap::coordinator::experiment::ExperimentRow) -> f64,
                     fy: &dyn Fn(&&snnmap::coordinator::experiment::ExperimentRow) -> f64|
     -> Vec<(Vec<f64>, Vec<f64>)> {
        by_net
            .values()
            .map(|rs| (rs.iter().map(fx).collect(), rs.iter().map(fy).collect()))
            .collect()
    };

    let sr_conn = grouped_spearman(&groups_of(&|r| r.sr_geo, &|r| r.connectivity));
    let sr_arith_conn = grouped_spearman(&groups_of(&|r| r.sr_arith, &|r| r.connectivity));
    let cl_elp = grouped_spearman(&groups_of(&|r| r.cl_geo, &|r| r.elp));
    let cl_arith_elp = grouped_spearman(&groups_of(&|r| r.cl_arith, &|r| r.elp));
    let cl_energy = grouped_spearman(&groups_of(&|r| r.cl_geo, &|r| r.energy));

    println!("Spearman rank correlations (per-network z-scored, pooled):");
    println!(
        "  rho(SR_geo,  connectivity) = {:>6.3}   [paper: ~ -0.86]",
        sr_conn.unwrap_or(f64::NAN)
    );
    println!(
        "  rho(SR_arith, connectivity) = {:>6.3}   [paper: diverges from geo]",
        sr_arith_conn.unwrap_or(f64::NAN)
    );
    println!(
        "  rho(CL_geo,  ELP)          = {:>6.3}   [paper: ~ +0.69]",
        cl_elp.unwrap_or(f64::NAN)
    );
    println!(
        "  rho(CL_arith, ELP)         = {:>6.3}   [paper: close to geo]",
        cl_arith_elp.unwrap_or(f64::NAN)
    );
    println!(
        "  rho(CL_geo,  energy)       = {:>6.3}",
        cl_energy.unwrap_or(f64::NAN)
    );
}
