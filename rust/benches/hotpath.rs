//! E10 — hot-path microbenchmarks for the §Perf optimization loop:
//! overlap partitioning throughput (connections/s) plus its serial-vs-
//! parallel growth pair, force-refinement sweep rate plus its serial-vs-
//! parallel refine pair, metric-engine throughput (serial vs parallel),
//! quotient construction plus the pooled push-forward's serial-vs-
//! parallel sweep pair, greedy ordering plus its serial-vs-parallel
//! fan-out pair (over the quotient graph, whose hub fan-outs clear the
//! dispatch threshold), the PJRT-vs-native spectral engine, the
//! multilevel hierarchical engine (serial vs two-phase parallel
//! coarsen/refine/end2end rows with peak hierarchy memory_bytes), and
//! the NoC simulator (serial vs two-phase parallel step pair plus the
//! batched trace replay, all with pooled-scratch memory_bytes). Every
//! serial/parallel pair asserts bit-identical outputs before recording.
//!
//! `--json <path>` additionally writes the numbers machine-readably so the
//! BENCH trajectory (BENCH_hotpath.json at the repo root) can track
//! regressions across PRs:
//!
//!     cargo bench --bench hotpath -- --json BENCH_hotpath.json

mod common;

use snnmap::coordinator::experiment::hw_for;
use snnmap::hypergraph::quotient::{
    push_forward, push_forward_pooled_with_stats, QuotientScratch,
};
use snnmap::mapping::hierarchical::{self, HierParams};
use snnmap::mapping::{self, sequential::SeqOrder};
use snnmap::metrics::{evaluate, evaluate_serial};
use snnmap::placement::{eigen, force, hilbert, spectral};
use snnmap::runtime::PjrtRuntime;
use snnmap::sim::{
    simulate_batch_with_stats, simulate_serial, simulate_with_stats, SimConfig, SimParams,
    SimReport, SimScratch, PAR_MIN_STREAMS,
};
use snnmap::util::cli::Args;
use snnmap::util::json::Json;
use snnmap::util::par;
use snnmap::util::timer::{bench, time_once};
use std::time::Duration;

/// Append one `{secs_per_iter, <rate_key>}` kernel row (a plain fn, not
/// a closure, so sections can also push richer rows directly).
fn record(kernels: &mut Vec<(String, Json)>, name: &str, secs: f64, rate_key: &str, rate: f64) {
    kernels.push((
        name.to_string(),
        Json::obj(vec![
            ("secs_per_iter", Json::Num(secs)),
            (rate_key, Json::Num(rate)),
        ]),
    ));
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]);
    let mut kernels: Vec<(String, Json)> = Vec::new();

    let net = common::load("16k_rand");
    let g = &net.graph;
    let hw = hw_for(&net, common::scale());
    let conns = g.num_connections() as f64;
    let min_t = Duration::from_millis(800);
    println!("hot-path microbenchmarks ({} nodes, {:.2e} connections)", g.num_nodes(), conns);
    common::hr();

    // 1. overlap partitioning (the paper's novel hot path)
    let (rho, st) = bench(2, min_t, || mapping::overlap::partition(g, &hw).unwrap());
    println!(
        "overlap partitioning   {:>10.3}s/iter  {:>10.2e} connections/s",
        st.mean_secs(),
        conns / st.mean_secs()
    );
    record(&mut kernels, "overlap_partition", st.mean_secs(), "conn_per_s", conns / st.mean_secs());

    // 1b. overlap growth: serial reference vs two-phase parallel frontier
    // scoring. The pair must agree bit-for-bit (asserted); memory_bytes
    // is the partitioner's scratch high-water mark.
    let run_overlap = |threads: usize| {
        mapping::overlap::partition_with_stats(g, &hw, Default::default(), threads).unwrap()
    };
    let ((ov_ser, os_ser), st_ov_ser) = bench(2, min_t, || run_overlap(1));
    let ((ov_par, os_par), st_ov_par) = bench(2, min_t, || run_overlap(par::max_threads()));
    assert_eq!(
        ov_ser.assign, ov_par.assign,
        "parallel overlap growth diverged from serial"
    );
    for (mode, st_m, os) in
        [("serial", &st_ov_ser, &os_ser), ("parallel", &st_ov_par, &os_par)]
    {
        kernels.push((
            format!("overlap_grow_{mode}"),
            Json::obj(vec![
                ("secs_per_iter", Json::Num(st_m.mean_secs())),
                ("conn_per_s", Json::Num(conns / st_m.mean_secs())),
                ("memory_bytes", Json::Num(os.peak_scratch_bytes as f64)),
            ]),
        ));
    }
    println!(
        "overlap grow (serial)  {:>10.3}s/iter  (score {:.3}s, commit {:.3}s, {} par steps)",
        st_ov_ser.mean_secs(),
        os_ser.score_secs,
        os_ser.commit_secs,
        os_ser.par_growth_steps
    );
    println!(
        "overlap grow ({} thr)   {:>9.3}s/iter  ({:.2}x, {} par steps, bit-identical to serial)",
        par::max_threads(),
        st_ov_par.mean_secs(),
        st_ov_ser.mean_secs() / st_ov_par.mean_secs(),
        os_par.par_growth_steps
    );

    // 2. greedy ordering (Alg. 2)
    let (_, st) = bench(2, min_t, || mapping::ordering::greedy_order(g));
    println!(
        "greedy ordering        {:>10.3}s/iter  {:>10.2e} connections/s",
        st.mean_secs(),
        conns / st.mean_secs()
    );
    record(&mut kernels, "greedy_ordering", st.mean_secs(), "conn_per_s", conns / st.mean_secs());

    // 3. sequential partitioning over a precomputed order
    let order = mapping::ordering::greedy_order(g);
    let (_, st) = bench(2, min_t, || {
        mapping::sequential::partition_with_order(g, &hw, &order).unwrap()
    });
    println!(
        "sequential (ordered)   {:>10.3}s/iter  {:>10.2e} connections/s",
        st.mean_secs(),
        conns / st.mean_secs()
    );
    record(
        &mut kernels,
        "sequential_ordered",
        st.mean_secs(),
        "conn_per_s",
        conns / st.mean_secs(),
    );
    let _ = SeqOrder::Natural;

    // 4. quotient construction
    let (q, st) = bench(2, min_t, || push_forward(g, &rho));
    println!(
        "quotient push-forward  {:>10.3}s/iter  {:>10.2e} connections/s",
        st.mean_secs(),
        conns / st.mean_secs()
    );
    record(
        &mut kernels,
        "quotient_push_forward",
        st.mean_secs(),
        "conn_per_s",
        conns / st.mean_secs(),
    );
    let gp = q.graph;
    println!("  quotient: {} partitions, {} h-edges", gp.num_nodes(), gp.num_edges());

    // 4b. pooled quotient push-forward: serial sweep vs the two-phase
    // parallel scan, through ONE recycled scratch per the production
    // (multilevel) usage — so the rows gate the steady-state sweep, not
    // first-use arena growth. The pair must agree bit-for-bit
    // (asserted); memory_bytes is the sweep's scratch high-water mark
    // (shared arenas + per-chunk scan buffers).
    let fine_mult = vec![1u32; g.num_edges()];
    let mut quot_scratch = QuotientScratch::new();
    let mut run_quot = |threads: usize| {
        push_forward_pooled_with_stats(g, &rho, &fine_mult, &mut quot_scratch, threads)
    };
    let ((qg_ser, qm_ser, qs_ser), st_q_ser) = bench(2, min_t, || run_quot(1));
    let ((qg_par, qm_par, qs_par), st_q_par) = bench(2, min_t, || run_quot(par::max_threads()));
    assert_eq!(
        qg_ser.num_edges(),
        qg_par.num_edges(),
        "parallel quotient sweep diverged from serial"
    );
    for e in qg_ser.edge_ids() {
        assert_eq!(qg_ser.source(e), qg_par.source(e), "edge {e}");
        assert_eq!(qg_ser.dsts(e), qg_par.dsts(e), "edge {e}");
        assert_eq!(qg_ser.weight(e).to_bits(), qg_par.weight(e).to_bits(), "edge {e}");
    }
    assert_eq!(qm_ser, qm_par, "parallel quotient multiplicity diverged");
    for (mode, st_m, qs) in [("serial", &st_q_ser, &qs_ser), ("parallel", &st_q_par, &qs_par)] {
        kernels.push((
            format!("quotient_push_{mode}"),
            Json::obj(vec![
                ("secs_per_iter", Json::Num(st_m.mean_secs())),
                ("conn_per_s", Json::Num(conns / st_m.mean_secs())),
                ("memory_bytes", Json::Num(qs.peak_scratch_bytes as f64)),
            ]),
        ));
    }
    println!(
        "quotient push (serial) {:>10.3}s/iter  (scan {:.3}s)",
        st_q_ser.mean_secs(),
        qs_ser.scan_secs
    );
    println!(
        "quotient push ({} thr)  {:>9.3}s/iter  ({:.2}x, scan {:.3}s, commit {:.3}s, \
         {} par sweeps, bit-identical to serial)",
        par::max_threads(),
        st_q_par.mean_secs(),
        st_q_ser.mean_secs() / st_q_par.mean_secs(),
        qs_par.scan_secs,
        qs_par.commit_secs,
        qs_par.par_sweeps
    );

    // 4c. greedy ordering over the *quotient* graph: the addressable
    // heap serial vs the parallel fan-out propagation engine. Quotient
    // hub fan-outs are the kind that cross PAR_MIN_FANOUT; at smoke
    // scales they mostly sit below it (par_steps printed below), so the
    // pair primarily tracks the addressable-heap engine — the hub tests
    // in ordering.rs/properties.rs prove the parallel dispatch itself.
    let qconns = gp.num_connections() as f64;
    let run_order = |threads: usize| mapping::ordering::greedy_order_with_stats(&gp, threads);
    let ((ord_ser, gs_ser), st_o_ser) = bench(2, min_t, || run_order(1));
    let ((ord_par, gs_par), st_o_par) = bench(2, min_t, || run_order(par::max_threads()));
    assert_eq!(ord_ser, ord_par, "parallel greedy ordering diverged from serial");
    for (mode, st_m, gs) in [("serial", &st_o_ser, &gs_ser), ("parallel", &st_o_par, &gs_par)] {
        kernels.push((
            format!("greedy_order_{mode}"),
            Json::obj(vec![
                ("secs_per_iter", Json::Num(st_m.mean_secs())),
                ("conn_per_s", Json::Num(qconns / st_m.mean_secs())),
                ("memory_bytes", Json::Num(gs.peak_scratch_bytes as f64)),
            ]),
        ));
    }
    println!(
        "greedy order (serial)  {:>10.3}s/iter  {:>10.2e} connections/s",
        st_o_ser.mean_secs(),
        qconns / st_o_ser.mean_secs()
    );
    println!(
        "greedy order ({} thr)   {:>9.3}s/iter  ({:.2}x, {} par steps, bit-identical to serial)",
        par::max_threads(),
        st_o_par.mean_secs(),
        st_o_ser.mean_secs() / st_o_par.mean_secs(),
        gs_par.par_steps
    );

    // 5. metric engine: serial reference vs the parallel default.
    // Throughput is synapse-visits/s (one visit per quotient connection);
    // the two paths must agree bit-for-bit (ordered reduction).
    let pl = hilbert::place(&gp, &hw);
    let visits = gp.num_connections() as f64;
    let (ms, st_ser) = bench(3, min_t, || evaluate_serial(&gp, &pl, &hw));
    println!(
        "metric eval (serial)   {:>10.3}s/iter  {:>10.2e} synapse-visits/s",
        st_ser.mean_secs(),
        visits / st_ser.mean_secs()
    );
    record(
        &mut kernels,
        "metrics_evaluate_serial",
        st_ser.mean_secs(),
        "synapse_visits_per_s",
        visits / st_ser.mean_secs(),
    );
    let (m, st_par) = bench(3, min_t, || evaluate(&gp, &pl, &hw));
    assert_eq!(ms, m, "parallel evaluate diverged from serial");
    println!(
        "metric eval ({} thr)    {:>9.3}s/iter  {:>10.2e} synapse-visits/s  ({:.2}x, conn {:.3e}, elp {:.3e})",
        par::max_threads(),
        st_par.mean_secs(),
        visits / st_par.mean_secs(),
        st_ser.mean_secs() / st_par.mean_secs(),
        m.connectivity,
        m.elp
    );
    record(
        &mut kernels,
        "metrics_evaluate_parallel",
        st_par.mean_secs(),
        "synapse_visits_per_s",
        visits / st_par.mean_secs(),
    );

    // 6. force refinement: serial reference vs two-phase parallel
    // candidate scan, from the same Hilbert start. The pair must agree
    // bit-for-bit (asserted); memory_bytes is the refiner's scratch
    // high-water mark (flat adjacency + proposal slots). Averaged over
    // >= min_t like every other gated row — a single sample on a noisy
    // runner would trip the 25% bench gate spuriously. The legacy
    // force_refinement row is derived from the serial measurement (same
    // workload) rather than re-run single-sample.
    let pl_start = hilbert::place(&gp, &hw);
    let run_force = |threads: usize| {
        let mut p = pl_start.clone();
        let fs = force::refine_with_threads(&gp, &hw, &mut p, Default::default(), None, threads);
        (p, fs)
    };
    let ((pl_f_ser, fs_ser), st_f_ser) = bench(1, min_t, || run_force(1));
    let ((pl_f_par, fs_par), st_f_par) = bench(1, min_t, || run_force(par::max_threads()));
    assert_eq!(
        pl_f_ser.coords, pl_f_par.coords,
        "parallel force refinement diverged from serial"
    );
    record(
        &mut kernels,
        "force_refinement",
        st_f_ser.mean_secs(),
        "sweeps",
        fs_ser.sweeps as f64,
    );
    for (mode, st_m, fs) in [("serial", &st_f_ser, &fs_ser), ("parallel", &st_f_par, &fs_par)] {
        kernels.push((
            format!("force_refine_{mode}"),
            Json::obj(vec![
                ("secs_per_iter", Json::Num(st_m.mean_secs())),
                ("sweeps_per_s", Json::Num(fs.sweeps as f64 / st_m.mean_secs().max(1e-12))),
                ("memory_bytes", Json::Num(fs.peak_scratch_bytes as f64)),
            ]),
        ));
    }
    println!(
        "force refinement       {:>10.3}s/iter  ({} sweeps, {} swaps, wl {:.3e} -> {:.3e})",
        st_f_ser.mean_secs(),
        fs_ser.sweeps,
        fs_ser.swaps + fs_ser.moves_to_empty,
        fs_ser.initial_wirelength,
        fs_ser.final_wirelength
    );
    println!(
        "force refine (serial)  {:>10.3}s/iter  (scan {:.3}s, commit {:.3}s)",
        st_f_ser.mean_secs(),
        fs_ser.scan_secs,
        fs_ser.commit_secs
    );
    println!(
        "force refine ({} thr)   {:>9.3}s/iter  ({:.2}x, bit-identical to serial)",
        par::max_threads(),
        st_f_par.mean_secs(),
        st_f_ser.mean_secs() / st_f_par.mean_secs()
    );

    // 7. spectral engines: native vs PJRT artifact
    let prob = eigen::build_laplacian(&gp);
    let (_, st) = bench(1, min_t, || {
        eigen::smallest_nontrivial_eigs(&prob, 400, 8)
    });
    println!(
        "spectral native        {:>10.3}s/iter  (n={}, nnz={})",
        st.mean_secs(),
        prob.lap.n,
        prob.lap.nnz()
    );
    record(&mut kernels, "spectral_native", st.mean_secs(), "n", prob.lap.n as f64);
    match PjrtRuntime::discover() {
        Some(rt) => {
            let n = prob.lap.n;
            if n <= rt.spectral_capacity() {
                let mut dense = vec![0f32; n * n];
                for r in 0..n {
                    for i in prob.lap.row_off[r]..prob.lap.row_off[r + 1] {
                        dense[r * n + prob.lap.cols[i] as usize] = prob.lap.vals[i] as f32;
                    }
                }
                // first call compiles; time both
                let (_, compile_t) =
                    time_once(|| rt.spectral_embed(&dense, n, &prob.wdeg).unwrap());
                let (_, st) = bench(2, min_t, || rt.spectral_embed(&dense, n, &prob.wdeg).unwrap());
                println!(
                    "spectral PJRT          {:>10.3}s/iter  (+{:.2}s one-time compile)",
                    st.mean_secs(),
                    compile_t.as_secs_f64() - st.mean_secs()
                );
                record(&mut kernels, "spectral_pjrt", st.mean_secs(), "n", n as f64);
            } else {
                println!(
                    "spectral PJRT          skipped: {} partitions > capacity {}",
                    n,
                    rt.spectral_capacity()
                );
            }
        }
        None => println!("spectral PJRT          skipped: artifacts/ not built"),
    }

    // 8. full spectral placement
    let (_, st) = bench(1, min_t, || spectral::place(&gp, &hw));
    println!("spectral placement     {:>10.3}s/iter  (embed + discretize)", st.mean_secs());
    record(&mut kernels, "spectral_placement", st.mean_secs(), "n", gp.num_nodes() as f64);

    // 9. hierarchical multilevel engine: serial vs two-phase parallel.
    // The paths must agree bit-for-bit; peak memory_bytes is the owned
    // hierarchy high-water mark (level 0 borrows the input graph).
    let run_hier = |threads: usize| {
        let hp = HierParams { threads, ..HierParams::default() };
        hierarchical::partition_with_stats(g, &hw, hp).unwrap()
    };
    let ((rho_ser, hs_ser), st_ser) = bench(1, min_t, || run_hier(1));
    let ((rho_par, hs_par), st_par) = bench(1, min_t, || run_hier(par::max_threads()));
    assert_eq!(
        rho_ser.assign, rho_par.assign,
        "parallel hierarchical diverged from serial"
    );
    let mut record_hier = |mode: &str, end2end: f64, hs: &hierarchical::HierStats| {
        for (stage, secs) in
            [("coarsen", hs.coarsen_secs), ("refine", hs.refine_secs), ("end2end", end2end)]
        {
            kernels.push((
                format!("hier_{stage}_{mode}"),
                Json::obj(vec![
                    ("secs_per_iter", Json::Num(secs)),
                    ("conn_per_s", Json::Num(conns / secs.max(1e-12))),
                    ("memory_bytes", Json::Num(hs.peak_hierarchy_bytes as f64)),
                ]),
            ));
        }
    };
    record_hier("serial", st_ser.mean_secs(), &hs_ser);
    record_hier("parallel", st_par.mean_secs(), &hs_par);
    println!(
        "hier end2end (serial)  {:>10.3}s/iter  (coarsen {:.3}s, refine {:.3}s, {} levels, peak {:.2e} B)",
        st_ser.mean_secs(),
        hs_ser.coarsen_secs,
        hs_ser.refine_secs,
        hs_ser.levels,
        hs_ser.peak_hierarchy_bytes as f64
    );
    println!(
        "hier end2end ({} thr)   {:>9.3}s/iter  ({:.2}x, {} partitions, bit-identical to serial)",
        par::max_threads(),
        st_par.mean_secs(),
        st_ser.mean_secs() / st_par.mean_secs(),
        rho_par.num_parts
    );

    // 10. NoC simulator: serial reference step vs two-phase parallel
    // accumulation, plus the batched trace replay, over the quotient
    // mapping from sections 4-5. Every pair is asserted bit-identical on
    // the full report before recording (DESIGN.md §16); memory_bytes is
    // the pooled SimScratch high-water mark.
    fn assert_sim_eq(a: &SimReport, b: &SimReport, what: &str) {
        assert_eq!(a.spikes, b.spikes, "{what}: spikes");
        assert_eq!(a.copies, b.copies, "{what}: copies");
        assert_eq!(a.hops, b.hops, "{what}: hops");
        assert_eq!(a.dropped_spikes, b.dropped_spikes, "{what}: dropped_spikes");
        assert_eq!(a.detour_hops, b.detour_hops, "{what}: detour_hops");
        assert_eq!(a.peak_router_load, b.peak_router_load, "{what}: peak_router_load");
        assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "{what}: energy");
        assert_eq!(
            a.mean_makespan.to_bits(),
            b.mean_makespan.to_bits(),
            "{what}: mean_makespan"
        );
        assert_eq!(a.max_makespan.to_bits(), b.max_makespan.to_bits(), "{what}: max_makespan");
        assert_eq!(
            a.mean_peak_link_load.to_bits(),
            b.mean_peak_link_load.to_bits(),
            "{what}: mean_peak_link_load"
        );
        assert_eq!(a.timesteps, b.timesteps, "{what}: timesteps");
    }
    let sim_params = SimParams { timesteps: 200, seed: 17, poisson_spikes: true };
    let sim_streams = gp.num_connections();
    let mut sim_scratch = SimScratch::new();
    let mut run_sim = |threads: usize| {
        simulate_with_stats(&gp, &pl, &hw, sim_params, None, threads, &mut sim_scratch)
    };
    let ((rep_s_ser, ss_ser), st_s_ser) = bench(2, min_t, || run_sim(1));
    let ((rep_s_par, ss_par), st_s_par) = bench(2, min_t, || run_sim(par::max_threads()));
    let ref_rep = simulate_serial(&gp, &pl, &hw, sim_params, None);
    assert_sim_eq(&ref_rep, &rep_s_ser, "pooled serial sim vs simulate_serial");
    assert_sim_eq(&rep_s_ser, &rep_s_par, "parallel sim vs serial");
    // At smoke scales the quotient may sit below the dispatch threshold;
    // only then is the parallel row allowed to fall back to the serial step.
    if sim_streams >= PAR_MIN_STREAMS && par::max_threads() > 1 {
        assert!(
            ss_par.par_steps > 0,
            "parallel sim row never dispatched the two-phase step \
             ({sim_streams} streams >= {PAR_MIN_STREAMS})"
        );
    }
    for (name, st, ss) in [
        ("sim_step_serial", &st_s_ser, &ss_ser),
        ("sim_step_parallel", &st_s_par, &ss_par),
    ] {
        kernels.push((
            name.to_string(),
            Json::obj(vec![
                ("secs_per_iter", Json::Num(st.mean_secs())),
                (
                    "steps_per_s",
                    Json::Num(sim_params.timesteps as f64 / st.mean_secs().max(1e-12)),
                ),
                ("memory_bytes", Json::Num(ss.peak_scratch_bytes as f64)),
            ]),
        ));
    }
    println!(
        "sim step (serial)      {:>10.3}s/iter  ({} streams, {} steps)",
        st_s_ser.mean_secs(),
        sim_streams,
        sim_params.timesteps
    );
    println!(
        "sim step ({} thr)       {:>9.3}s/iter  ({:.2}x, {} par steps, bit-identical to serial)",
        par::max_threads(),
        st_s_par.mean_secs(),
        st_s_ser.mean_secs() / st_s_par.mean_secs(),
        ss_par.par_steps
    );
    let batch_cfgs: Vec<SimConfig> = (0..4u64)
        .map(|i| SimConfig {
            params: SimParams { timesteps: 50, seed: 100 + i, poisson_spikes: true },
            rate_scale: 1.0,
            faults: None,
        })
        .collect();
    let ((batch_reps, bs), st_b) = bench(2, min_t, || {
        simulate_batch_with_stats(&gp, &pl, &hw, &batch_cfgs, par::max_threads(), &mut sim_scratch)
    });
    for (i, cfg) in batch_cfgs.iter().enumerate() {
        let solo = snnmap::sim::simulate_with_threads(
            &gp,
            &pl,
            &hw,
            cfg.params,
            cfg.faults,
            par::max_threads(),
        );
        assert_sim_eq(&solo, &batch_reps[i], "batched replay vs one-by-one");
    }
    kernels.push((
        "sim_batch".to_string(),
        Json::obj(vec![
            ("secs_per_iter", Json::Num(st_b.mean_secs())),
            (
                "configs_per_s",
                Json::Num(batch_cfgs.len() as f64 / st_b.mean_secs().max(1e-12)),
            ),
            ("memory_bytes", Json::Num(bs.peak_scratch_bytes as f64)),
        ]),
    ));
    println!(
        "sim batch ({} cfgs)     {:>9.3}s/iter  (bit-identical to one-by-one replay)",
        batch_cfgs.len(),
        st_b.mean_secs()
    );
    common::hr();
    println!("targets (DESIGN.md §8): overlap >= 5e6 conn/s; metrics >= 1e7 synapse-visits/s.");

    if let Some(path) = args.get("json") {
        let doc = Json::obj(vec![
            ("bench", Json::Str("hotpath".into())),
            ("network", Json::Str(net.name.clone())),
            ("scale", Json::Num(common::scale())),
            ("threads", Json::Num(par::max_threads() as f64)),
            ("nodes", Json::Num(g.num_nodes() as f64)),
            ("connections", Json::Num(conns)),
            ("quotient_partitions", Json::Num(gp.num_nodes() as f64)),
            ("quotient_edges", Json::Num(gp.num_edges() as f64)),
            ("kernels", Json::Obj(kernels.into_iter().collect())),
            (
                "targets",
                Json::obj(vec![
                    ("overlap_conn_per_s", Json::Num(5e6)),
                    ("metrics_synapse_visits_per_s", Json::Num(1e7)),
                ]),
            ),
        ]);
        let body = doc.to_pretty() + "\n";
        snnmap::runtime::checkpoint::atomic_write(std::path::Path::new(path), body.as_bytes())
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote machine-readable results to {path}");
    }
}
