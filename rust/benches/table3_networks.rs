//! E1 — Table III: the evaluation-suite network statistics (node count,
//! connections, mean h-edge cardinality, target constraints), regenerated
//! from our generators at the bench scale.

mod common;

use snnmap::hw::NmhConfig;
use snnmap::hypergraph::stats;
use snnmap::util::timer::time_once;

fn main() {
    let scale = common::scale();
    println!("Table III — network suite (scale {scale}; paper sizes at scale 1.0)");
    common::hr();
    println!(
        "{:<14} {:>10} {:>12} {:>14} {:>10} {:>8}  gen_time",
        "network", "nodes", "h-edges", "connections", "mean |D|", "target"
    );
    common::hr();
    for name in common::bench_suite() {
        let (net, dt) = time_once(|| common::load(name));
        let s = stats::summarize(&net.graph);
        let target = if NmhConfig::for_connections(s.connections) == NmhConfig::small() {
            "small"
        } else {
            "large"
        };
        println!(
            "{:<14} {:>10} {:>12} {:>14} {:>10.1} {:>8}  {:.2}s",
            net.name,
            s.nodes,
            s.edges,
            s.connections,
            s.mean_cardinality,
            target,
            dt.as_secs_f64()
        );
    }
    common::hr();
    println!("paper row shapes: feedforward/layered nets have |D| in the tens-to-hundreds,");
    println!("cyclic nets mean |D| ~ its Poisson target; target preset flips to 'large' past 2^26 connections.");
}
