//! E9 — simulator cross-check: the analytic Table I cost model vs the
//! executable NoC simulator (Poisson spike draws, XY routing) on real
//! mappings. Expected-energy equality is exact in the limit; congestion
//! and makespan expose what the expectation model cannot.

mod common;

use snnmap::coordinator::{MapperPipeline, PartitionerKind, PlacerKind, RefinerKind};
use snnmap::metrics::evaluate;
use snnmap::sim::{simulate, SimParams};
use snnmap::util::timer::time_once;

fn main() {
    println!("Simulator validation — analytic Table I vs executed NoC traffic");
    common::hr();
    println!(
        "{:<14} {:<12} {:>12} {:>12} {:>7} {:>12} {:>12} {:>9}",
        "network", "pipeline", "E_analytic", "E_sim/step", "ratio", "congestion", "peak router",
        "sim time"
    );
    common::hr();
    for name in ["lenet", "allen_v1", "16k_rand"] {
        let net = common::load(name);
        let hw = common::hw_for(&net);
        for (pk, label) in [
            (PartitionerKind::HyperedgeOverlap, "overlap"),
            (PartitionerKind::Sequential, "sequential"),
        ] {
            let res = MapperPipeline::new(hw)
                .partitioner(pk)
                .placer(PlacerKind::Spectral)
                .refiner(RefinerKind::ForceDirected)
                .run(&net.graph, net.layer_ranges.as_deref())
                .expect("mapping failed");
            let analytic = evaluate(&res.gp, &res.placement, &hw);
            let (sim, dt) = time_once(|| {
                simulate(
                    &res.gp,
                    &res.placement,
                    &hw,
                    SimParams { timesteps: 400, seed: 11, poisson_spikes: true },
                )
            });
            println!(
                "{:<14} {:<12} {:>12.4e} {:>12.4e} {:>7.3} {:>12.3e} {:>12} {:>8.2}s",
                net.name,
                label,
                analytic.energy,
                sim.energy_per_step(),
                sim.energy_per_step() / analytic.energy,
                analytic.congestion,
                sim.peak_router_load,
                dt.as_secs_f64()
            );
        }
    }
    common::hr();
    println!("expected: ratio -> 1.0 as timesteps grow; peak router load tracks analytic congestion's order of magnitude.");
}
