//! E5 — Fig. 10: full-mapping performance (energy, latency, congestion,
//! ELP) and construction time for every partitioning × placement combo.

mod common;

use snnmap::coordinator::experiment::{run_grid, GridSpec};
use snnmap::coordinator::report::ratio_summary;

fn main() {
    let scale = common::scale();
    println!(
        "Fig. 10 — mapping performance across partitioner x placement combos (scale {scale})"
    );
    common::hr();
    let mut spec = GridSpec::fig10(scale);
    spec.networks = common::bench_suite().into_iter().map(String::from).collect();
    let rows = run_grid(&spec);

    println!(
        "{:<14} {:<13} {:<16} {:>10.6} {:>11.6} {:>11.6} {:>11.6} {:>10.6} {:>8} {:>8}",
        "network", "partitioner", "placer+refiner", "energy", "latency", "congestion", "ELP",
        "cl_geo", "t_part", "t_place"
    );
    common::hr();
    for r in &rows {
        if let Some(e) = &r.error {
            println!("{:<14} {:<13} {:<16} FAILED: {e}", r.network, r.partitioner, r.placer);
            continue;
        }
        println!(
            "{:<14} {:<13} {:<16} {:>10.3e} {:>11.3e} {:>11.3e} {:>11.3e} {:>10.2} {:>8.2} {:>8.2}",
            r.network,
            r.partitioner,
            format!("{}+{}", r.placer, r.refiner),
            r.energy,
            r.latency,
            r.congestion,
            r.elp,
            r.cl_geo,
            r.partition_time.as_secs_f64(),
            r.placement_time.as_secs_f64()
        );
    }
    common::hr();

    // paper shape summaries (§V-B2)
    println!("shape checks vs paper:");
    if let Some(r) = ratio_summary(&rows, "hierarchical", "overlap", |r| r.elp) {
        println!("  ELP(hierarchical)/ELP(overlap) geomean = {r:.2}  [paper: 0.98x]");
    }
    if let Some(r) = ratio_summary(&rows, "overlap", "sequential", |r| r.elp) {
        println!("  ELP(overlap)/ELP(sequential)   geomean = {r:.2}  [paper: 0.63x]");
    }
    // spectral vs hilbert after force refinement
    let spectral_force: Vec<&_> = rows
        .iter()
        .filter(|r| r.placer == "spectral" && r.refiner == "force" && r.error.is_none())
        .collect();
    let mut elp_ratio_logs = Vec::new();
    let mut cong_ratio_logs = Vec::new();
    for s in &spectral_force {
        if let Some(h) = rows.iter().find(|r| {
            r.placer == "hilbert"
                && r.refiner == "force"
                && r.network == s.network
                && r.partitioner == s.partitioner
                && r.error.is_none()
        }) {
            elp_ratio_logs.push((s.elp / h.elp).ln());
            cong_ratio_logs.push((h.congestion / s.congestion).ln());
        }
    }
    if !elp_ratio_logs.is_empty() {
        let g = (elp_ratio_logs.iter().sum::<f64>() / elp_ratio_logs.len() as f64).exp();
        println!("  ELP(spectral+force)/ELP(hilbert+force) geomean = {g:.2}  [paper: 0.96x]");
        let c = (cong_ratio_logs.iter().sum::<f64>() / cong_ratio_logs.len() as f64).exp();
        println!("  congestion(hilbert)/congestion(spectral) geomean = {c:.2}  [paper: 0.92x]");
    }
    // refinement improvement band
    let mut impr = Vec::new();
    for s in rows.iter().filter(|r| r.refiner == "force" && r.error.is_none()) {
        if let Some(raw) = rows.iter().find(|r| {
            r.refiner == "none"
                && r.placer == s.placer
                && r.network == s.network
                && r.partitioner == s.partitioner
                && r.error.is_none()
        }) {
            impr.push(s.elp / raw.elp);
        }
    }
    if !impr.is_empty() {
        let min = impr.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = impr.iter().cloned().fold(0.0, f64::max);
        println!("  force refinement ELP ratio range = {min:.2}..{max:.2}  [paper: 0.51-0.87x]");
    }
    // mindist speed/quality envelope
    let mut mindist_ratio = Vec::new();
    for m in rows.iter().filter(|r| r.placer == "mindist" && r.error.is_none()) {
        let best = rows
            .iter()
            .filter(|r| {
                r.network == m.network && r.partitioner == m.partitioner && r.error.is_none()
            })
            .map(|r| r.elp)
            .fold(f64::INFINITY, f64::min);
        mindist_ratio.push(m.elp / best);
    }
    if !mindist_ratio.is_empty() {
        let worst = mindist_ratio.iter().cloned().fold(0.0, f64::max);
        println!("  mindist ELP within {worst:.2}x of the best combo  [paper: within 2.18x]");
    }
}
