//! Shared bench harness helpers (criterion is not in the offline
//! registry; benches are `harness = false` binaries using util::timer).

#![allow(dead_code)]

use snnmap::snn::{self, Network};

/// Bench scale from `SNNMAP_SCALE` (default keeps `cargo bench` at
/// minutes, not hours; raise towards 1.0 to approach paper sizes).
pub fn scale() -> f64 {
    std::env::var("SNNMAP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.12)
}

/// Networks covered by the default bench tier: one per Table III class.
pub fn bench_suite() -> Vec<&'static str> {
    match std::env::var("SNNMAP_SUITE").as_deref() {
        Ok("full") => snn::SUITE.to_vec(),
        Ok("mid") => vec![
            "16k_model", "64k_model", "lenet", "alexnet", "vgg11", "mobilenet", "allen_v1",
            "16k_rand", "64k_rand",
        ],
        _ => vec!["16k_model", "lenet", "mobilenet", "allen_v1", "16k_rand"],
    }
}

pub fn load(name: &str) -> Network {
    let net = snn::by_name(name, scale(), 42).unwrap_or_else(|| panic!("unknown network {name}"));
    eprintln!(
        "[gen] {:<12} nodes={:<8} h-edges={:<8} connections={:<10} mean|D|={:.1}",
        net.name,
        net.graph.num_nodes(),
        net.graph.num_edges(),
        net.graph.num_connections(),
        net.graph.mean_cardinality()
    );
    net
}

/// Hardware config scaled in step with the networks so partition counts
/// stay representative of the paper's regimes (DESIGN.md §5).
pub fn hw_for(net: &Network) -> snnmap::hw::NmhConfig {
    snnmap::coordinator::experiment::hw_for(net, scale())
}

pub fn hr() {
    println!("{}", "-".repeat(100));
}
