//! E4 + E8 — Fig. 9: connectivity and execution time of every
//! partitioning heuristic over the network suite, plus the §V-B1 headline
//! ratio summaries (overlap vs hierarchical / sequential / EdgeMap).

mod common;

use snnmap::coordinator::experiment::{run_grid, ExperimentRow, GridSpec};
use snnmap::coordinator::report::ratio_summary;
use snnmap::coordinator::PartitionerKind;

fn main() {
    let scale = common::scale();
    println!("Fig. 9 — partitioning heuristics: connectivity + execution time (scale {scale})");
    common::hr();
    let mut spec = GridSpec::fig9(scale);
    spec.networks = common::bench_suite().into_iter().map(String::from).collect();
    let rows = run_grid(&spec);

    println!(
        "{:<14} {:<14} {:>8} {:>14} {:>12} {:>10}",
        "network", "partitioner", "parts", "connectivity", "sr_geo", "time (s)"
    );
    common::hr();
    for r in &rows {
        if let Some(e) = &r.error {
            println!("{:<14} {:<14} FAILED: {e}", r.network, r.partitioner);
            continue;
        }
        println!(
            "{:<14} {:<14} {:>8} {:>14.4e} {:>12.3} {:>10.3}",
            r.network,
            r.partitioner,
            r.partitions,
            r.connectivity,
            r.sr_geo,
            r.partition_time.as_secs_f64()
        );
    }
    common::hr();

    // §V-B1 headline ratios (geometric means across networks)
    let conn = |r: &ExperimentRow| r.connectivity;
    let time = |r: &ExperimentRow| r.partition_time.as_secs_f64().max(1e-6);
    let pairs = [
        ("hierarchical", "sequential", "0.47x (paper)"),
        ("hierarchical", "overlap", "0.95x (paper)"),
        ("overlap", "sequential", "0.32-0.91x (paper)"),
        ("edgemap", "overlap", "8.5x worse (paper)"),
        ("seq-unordered", "sequential", "up to 11.4x worse (paper)"),
    ];
    println!("headline connectivity ratios (geomean across networks):");
    for (a, b, paper) in pairs {
        if let Some(r) = ratio_summary(&rows, a, b, conn) {
            println!("  conn({a}) / conn({b}) = {r:.2}   [{paper}]");
        }
    }
    println!("execution-time ratios (geomean):");
    for (a, b) in [("hierarchical", "overlap"), ("overlap", "seq-unordered")] {
        if let Some(r) = ratio_summary(&rows, a, b, time) {
            println!("  time({a}) / time({b}) = {r:.1}");
        }
    }
    // complexity bands (paper: three trends — unordered O(n) at the
    // bottom; overlap/edgemap/ordered-seq O(e·d) in the middle;
    // hierarchical O(e·d²) on top). Verified per network:
    println!(
        "\ncomplexity bands (expect time: seq-unordered <= overlap ~ edgemap <= hierarchical):"
    );
    let nets: std::collections::BTreeSet<&str> = rows.iter().map(|r| r.network.as_str()).collect();
    for net in nets {
        let t = |p: &str| {
            rows.iter()
                .find(|r| r.network == net && r.partitioner == p)
                .map(|r| r.partition_time.as_secs_f64())
                .unwrap_or(f64::NAN)
        };
        println!(
            "  {:<14} unordered {:>8.3}s | overlap {:>8.3}s | edgemap {:>8.3}s | hierarchical {:>8.3}s",
            net,
            t(PartitionerKind::SequentialUnordered.name()),
            t(PartitionerKind::HyperedgeOverlap.name()),
            t(PartitionerKind::EdgeMap.name()),
            t(PartitionerKind::Hierarchical.name()),
        );
    }
}
