//! E2 — Fig. 7: spike-frequency distributions of four selected SNNs with
//! their log-normal fits. The paper's panels show all networks' measured
//! rates collapsing onto a log-normal (median ≈ 0.23, CV ≈ 1.58); our
//! generators sample from that fit, so the bench verifies the round-trip:
//! sampled rates re-fit to the same parameters, and the histogram tracks
//! the fitted pdf.

mod common;

use snnmap::snn::spikefreq::{self, fit_lognormal, histogram};

fn main() {
    println!("Fig. 7 — spike-frequency distributions + log-normal fits");
    common::hr();
    for name in ["16k_model", "lenet", "allen_v1", "16k_rand"] {
        let net = common::load(name);
        let freqs: Vec<f32> = net.graph.edge_ids().map(|e| net.graph.weight(e)).collect();
        let fit = fit_lognormal(&freqs).expect("fit failed");
        println!(
            "{:<12} samples={:<8} fitted median={:.3} (paper .23)  cv={:.2} (paper 1.58)",
            net.name,
            freqs.len(),
            fit.median(),
            fit.cv()
        );
        // density curve: histogram vs fitted pdf over the bulk (Fig. 7 panel)
        let (centers, density) = histogram(&freqs, 40);
        let mut l1 = 0.0;
        let mut mass = 0.0;
        let width = centers[1] - centers[0];
        print!("  density  ");
        for (i, (&c, &d)) in centers.iter().zip(&density).enumerate() {
            l1 += (d - fit.pdf(c)).abs() * width;
            mass += d * width;
            if i < 12 {
                print!("{:.2} ", d);
            }
        }
        println!("...");
        print!("  fit pdf  ");
        for &c in centers.iter().take(12) {
            print!("{:.2} ", fit.pdf(c));
        }
        println!("...");
        println!("  histogram mass={mass:.3}  L1(fit, hist)={l1:.3}");
    }
    common::hr();
    println!(
        "reference parameters: median {}  cv {} [39]",
        spikefreq::BIO_MEDIAN,
        spikefreq::BIO_CV
    );
}
