//! E3 — Fig. 8: average path length and mean h-edge overlap of the
//! considered SNNs. The paper uses these to establish that both layered
//! and cyclic SNNs are small-world networks with pervasive h-edge
//! overlap — the raw material for synaptic reuse.

mod common;

use snnmap::hypergraph::stats;
use snnmap::util::timer::time_once;

fn main() {
    println!("Fig. 8 — average path length and h-edge overlap");
    common::hr();
    println!(
        "{:<14} {:>10} {:>16} {:>16}  time",
        "network", "nodes", "avg path length", "h-edge overlap"
    );
    common::hr();
    let mut rows = Vec::new();
    for name in common::bench_suite() {
        let net = common::load(name);
        let bfs_sources = (40_000 / net.graph.num_nodes().max(1)).clamp(3, 64);
        let ((apl, overlap), dt) = time_once(|| {
            (
                stats::avg_path_length(&net.graph, bfs_sources, 7),
                stats::mean_hedge_overlap(&net.graph, 20_000, 7),
            )
        });
        println!(
            "{:<14} {:>10} {:>16.2} {:>16.3}  {:.2}s",
            net.name,
            net.graph.num_nodes(),
            apl,
            overlap,
            dt.as_secs_f64()
        );
        rows.push((net.name.clone(), apl, overlap));
    }
    common::hr();
    // paper shape checks
    let max_apl = rows.iter().map(|r| r.1).fold(0.0, f64::max);
    println!(
        "small-world check: max avg path length {:.2} (paper: slow-growing, single digits)",
        max_apl
    );
    if let Some(mb) = rows.iter().find(|r| r.0.contains("Mobile")) {
        let others: Vec<f64> =
            rows.iter().filter(|r| !r.0.contains("Mobile")).map(|r| r.2).collect();
        let mean_others = others.iter().sum::<f64>() / others.len().max(1) as f64;
        println!(
            "MobileNet outlier check: overlap {:.3} vs suite mean {:.3} (paper: MobileNet is the low-overlap outlier)",
            mb.2, mean_others
        );
    }
}
