//! Ablation studies for the design choices DESIGN.md calls out:
//!
//!   A1  overlap partitioning: priority queue on/off, node policy on/off
//!   A2  force refinement: empty-core moves on/off, max(dist,1) clamp
//!   A3  spectral discretization: heavy-hubs-first vs id order
//!   A4  synapse pruning threshold sweep (quality-vs-cost tradeoff)
//!   A5  unicast vs hierarchical-multicast energy per placement scheme
//!   A6  multi-chip: chip-aware two-level vs chip-oblivious placement

mod common;

use snnmap::coordinator::experiment::hw_for;
use snnmap::hypergraph::quotient::push_forward;
use snnmap::mapping::{self, connectivity, overlap::OverlapParams, pruning};
use snnmap::metrics::multicast;
use snnmap::multichip::{self, MultiChipConfig};
use snnmap::stage::StageCtx;
use snnmap::placement::{eigen, force, hilbert, spectral};
use snnmap::util::timer::time_once;

fn main() {
    let net = common::load("16k_rand");
    let allen = common::load("allen_v1");
    let g = &net.graph;
    let hw = hw_for(&net, common::scale());

    // ---- A1: overlap components ----
    println!("A1. hyperedge-overlap partitioning components (16k_rand)");
    for (label, p) in [
        ("full Alg.1", OverlapParams { use_queue: true, select_min_new_axons: true }),
        ("no queue", OverlapParams { use_queue: false, select_min_new_axons: true }),
        ("no node policy", OverlapParams { use_queue: true, select_min_new_axons: false }),
        ("neither", OverlapParams { use_queue: false, select_min_new_axons: false }),
    ] {
        let (rho, dt) = time_once(|| mapping::overlap::partition_with_params(g, &hw, p).unwrap());
        println!(
            "  {:<16} parts={:<5} connectivity={:.4e}  {:.3}s",
            label,
            rho.num_parts,
            connectivity(g, &rho),
            dt.as_secs_f64()
        );
    }

    // quotient used by the placement ablations
    let rho = mapping::overlap::partition(g, &hw).unwrap();
    let gp = push_forward(g, &rho).graph;

    // ---- A2: force refinement components ----
    println!("\nA2. force-directed refinement components (16k_rand quotient, Hilbert start)");
    for (label, empty, clamp) in [
        ("full (paper)", true, true),
        ("no empty-core moves", false, true),
        ("no unit clamp", true, false),
    ] {
        let mut pl = hilbert::place(&gp, &hw);
        let params = force::ForceParams {
            allow_empty_moves: empty,
            clamp_unit: clamp,
            ..Default::default()
        };
        let (stats, dt) = time_once(|| force::refine(&gp, &hw, &mut pl, params, None));
        println!(
            "  {:<20} wl {:.4e} -> {:.4e}  ({} sweeps, {:.2}s)",
            label,
            stats.initial_wirelength,
            stats.final_wirelength,
            stats.sweeps,
            dt.as_secs_f64()
        );
    }

    // ---- A3: spectral discretization order ----
    println!("\nA3. spectral discretization visit order (16k_rand quotient)");
    let prob = eigen::build_laplacian(&gp);
    let emb = eigen::smallest_nontrivial_eigs(&prob, 400, 8).0;
    for (label, heavy) in [("heavy-hubs first", true), ("id order", false)] {
        let pl = spectral::discretize_with(&emb, &prob.wdeg, &hw, heavy);
        println!("  {:<18} wirelength {:.4e}", label, pl.wirelength(&gp));
    }

    // ---- A4: pruning sweep ----
    println!("\nA4. synapse pruning sweep (AllenV1; quality vs mapping cost)");
    let ahw = hw_for(&allen, common::scale());
    for frac in [0.0, 0.05, 0.1, 0.2, 0.4] {
        let (pruned, rep) = pruning::prune_fraction(&allen.graph, frac);
        let rho = mapping::overlap::partition(&pruned, &ahw).unwrap();
        println!(
            "  mass-removed<= {:>4.2}  edges {:>6} -> {:>6}  parts {:>5}  connectivity {:.4e}",
            rep.mass_removed,
            rep.edges_before,
            rep.edges_after,
            rho.num_parts,
            connectivity(&pruned, &rho)
        );
    }

    // ---- A5: unicast vs multicast ----
    println!("\nA5. unicast vs hierarchical-multicast energy (16k_rand quotient)");
    for (label, pl) in [
        ("hilbert", hilbert::place(&gp, &hw)),
        ("spectral", spectral::place(&gp, &hw)),
        ("spectral+force", {
            let mut p = spectral::place(&gp, &hw);
            force::refine(&gp, &hw, &mut p, Default::default(), None);
            p
        }),
    ] {
        let m = multicast::evaluate_multicast(&gp, &pl, &hw);
        println!(
            "  {:<16} unicast {:.4e} pJ  multicast {:.4e} pJ  saving {:.2}x  (hpwl bound {:.4e})",
            label,
            m.unicast_energy,
            m.tree_energy,
            1.0 / m.saving_ratio.max(1e-12),
            m.hpwl_bound
        );
    }

    // ---- A6: multi-chip aware vs oblivious ----
    println!("\nA6. multi-chip: chip-aware two-level vs chip-oblivious placement");
    let mut chip = snnmap::hw::NmhConfig::small();
    chip.width = 16;
    chip.height = 16;
    let mc = MultiChipConfig {
        chip,
        chips_x: 2,
        chips_y: 2,
        off_chip_energy_factor: 10.0,
        off_chip_latency_factor: 10.0,
    };
    if gp.num_nodes() <= mc.num_cores() {
        let (aware, _) = multichip::placement::place(
            &gp,
            &mc,
            &spectral::SpectralPlacer::new(),
            Some(&force::ForceRefiner::new()),
            &StageCtx::new(42),
        )
        .unwrap();
        let oblivious = hilbert::place(&gp, &mc.global_lattice());
        let ma = multichip::metrics::evaluate(&gp, &aware, &mc);
        let mo = multichip::metrics::evaluate(&gp, &oblivious, &mc);
        println!(
            "  chip-aware     energy {:.4e}  off-chip hops {:.3e}  boundary traffic {:.3e}",
            ma.energy, ma.off_chip_hops, ma.boundary_traffic
        );
        println!(
            "  chip-oblivious energy {:.4e}  off-chip hops {:.3e}  boundary traffic {:.3e}",
            mo.energy, mo.off_chip_hops, mo.boundary_traffic
        );
        println!("  energy ratio (oblivious/aware) = {:.2}x", mo.energy / ma.energy);
    } else {
        println!("  skipped: {} partitions exceed the 2x2x16x16 array", gp.num_nodes());
    }
}
