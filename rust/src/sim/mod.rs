//! NoC spike-traffic simulator substrate.
//!
//! The paper (like [7]) scores mappings with the *analytic* Table I model.
//! This module provides the executable counterpart: a discrete-timestep
//! simulator that draws spikes per h-edge, routes each copy over the 2D
//! mesh with dimension-ordered (XY) routing — or the YX / BFS-detour
//! fault fallbacks of DESIGN.md §15 — and accounts energy, per-link and
//! per-router traffic, and makespan latency. It validates the analytic
//! metrics (expected simulated energy equals Table I energy exactly) and
//! exposes congestion behaviour an expectation model can't (hot links,
//! tail timesteps).
//!
//! Since DESIGN.md §16 the per-step accumulation is parallel under the
//! repo's two-phase propose/commit discipline and bit-for-bit
//! thread-invariant: [`simulate_with_threads`] honors an explicit worker
//! count, [`simulate_serial`] is the tested single-worker reference, and
//! [`simulate_batch`] replays many (seed, rate-scale, fault-mask)
//! configurations through one pooled [`SimScratch`] with shared route
//! classification.

pub mod noc;

pub use noc::{
    simulate, simulate_batch, simulate_batch_with_stats, simulate_faulty, simulate_serial,
    simulate_with_stats, simulate_with_threads, SimConfig, SimParams, SimReport, SimScratch,
    SimStats, PAR_MIN_STREAMS,
};
