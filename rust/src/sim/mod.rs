//! NoC spike-traffic simulator substrate.
//!
//! The paper (like [7]) scores mappings with the *analytic* Table I model.
//! This module provides the executable counterpart: a discrete-timestep
//! simulator that draws spikes per h-edge, routes each copy over the 2D
//! mesh with dimension-ordered (XY) routing, and accounts energy, per-link
//! and per-router traffic, and makespan latency. It validates the analytic
//! metrics (expected simulated energy equals Table I energy exactly) and
//! exposes congestion behaviour an expectation model can't (hot links,
//! tail timesteps).

pub mod noc;

pub use noc::{simulate, simulate_faulty, SimParams, SimReport};
