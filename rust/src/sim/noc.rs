//! Discrete-timestep mesh NoC simulator with XY routing — parallel
//! two-phase edition (DESIGN.md §16).
//!
//! # Model
//!
//! Each simulated timestep draws a spike count per h-edge (Poisson with
//! the edge weight as its mean, or Bernoulli for sub-unit rates), then
//! walks every (h-edge, destination) *copy stream* over the mesh,
//! accumulating per-link and per-router flit loads plus event totals.
//! Energy prices those totals with the Table I constants (per-routing
//! event `e_r`, per-wire-hop `e_t`); makespan serializes the hottest
//! link per step (`peak_link * (l_r + l_t) + l_r`, in ns).
//!
//! # Two-phase parallel stepping
//!
//! The per-step accumulation follows the repo's propose/commit
//! discipline (DESIGN.md §10-§12, §16): copy streams are split into
//! fixed chunks by [`crate::util::par::fixed_chunk`] — a pure function
//! of `(stream count, threads)`, never of scheduling — and each chunk
//! fills a private **integer** accumulator ([`ChunkAcc`]) against
//! step-start state. The serial commit then merges chunk accumulators
//! in ascending link-id / router-id / chunk order. Because the propose
//! phase is integer-only (exact, associative), and every `f64` in the
//! report is derived from those exactly-summed integers in one fixed
//! serial expression, the output is bit-for-bit identical for any
//! worker count. [`simulate_serial`] is the tested reference
//! (`sim_parallel_equals_serial_exactly`).
//!
//! # Batched trace replay
//!
//! [`simulate_batch`] replays many [`SimConfig`] variations — seed,
//! spike-rate scale, fault mask — through one pooled [`SimScratch`]:
//! copy streams are built once per (graph, placement) and fault-route
//! classification is shared between consecutive configs that reference
//! the same mask, so grid sweeps stop re-deriving routes per cell.
//!
//! # Fault injection
//!
//! Fault routing (DESIGN.md §15) classifies every copy stream once,
//! statically, with the precedence **XY → YX → BFS detour → drop**:
//! healthy XY path first, deterministic YX fallback second, shortest
//! alive BFS detour (neighbor order E, W, N, S) third, dropped when no
//! alive path exists. Dead links and dead cores carry zero traffic;
//! [`SimReport::dropped_spikes`] and [`SimReport::detour_hops`]
//! quantify the degradation. `faults: None` and an all-healthy mask
//! reproduce the fault-free simulation bit for bit (every stream
//! classifies as the verbatim XY path, and the spike RNG is consumed
//! per h-edge regardless of routing).

use std::time::Instant;

use crate::hw::faults::{FaultMask, DIR_STEPS};
use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use crate::placement::Placement;
use crate::util::par;
use crate::util::rng::Pcg64;

/// Minimum copy-stream count before a step dispatches to the parallel
/// propose phase; below it, chunk bookkeeping costs more than the walk.
/// The dispatch (like every two-phase stage) depends only on this
/// constant and the requested worker count — never on scheduling.
pub const PAR_MIN_STREAMS: usize = 1024;

/// Simulation parameters for one trace replay.
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    /// Number of discrete timesteps to simulate. Each step draws fresh
    /// spike counts and re-accumulates link/router loads from zero.
    pub timesteps: usize,
    /// Spike-RNG seed (stream 41 of [`Pcg64`]); two runs with equal
    /// seeds draw identical spike trains regardless of fault mask.
    pub seed: u64,
    /// Spike count per h-edge per timestep ~ Poisson(w) so the expected
    /// traffic matches the analytic model exactly (w is a frequency, not
    /// a probability — biological rates exceed 1 spike/step in the tail).
    /// When `false`, draws Bernoulli(min(w, 1)) instead: at most one
    /// spike per edge per step.
    pub poisson_spikes: bool,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { timesteps: 100, seed: 99, poisson_spikes: true }
    }
}

/// One batched-replay configuration: parameters plus the two axes the
/// experiment grid sweeps, spike-rate scale and fault mask.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig<'a> {
    /// Timesteps / seed / spike-distribution knobs.
    pub params: SimParams,
    /// Multiplier applied to every edge weight before the spike draw
    /// (a whole-network firing-rate profile). `1.0` is bit-identical to
    /// the unscaled simulator (IEEE `x * 1.0 == x`).
    pub rate_scale: f64,
    /// Optional hardware fault mask. Consecutive batch configs that
    /// borrow the *same* mask share one route classification.
    pub faults: Option<&'a FaultMask>,
}

impl SimConfig<'_> {
    /// A fault-free, unscaled configuration.
    pub fn new(params: SimParams) -> Self {
        SimConfig { params, rate_scale: 1.0, faults: None }
    }
}

/// Aggregated simulation results.
///
/// All `f64` fields are derived from exactly-summed integer event
/// counts in a fixed serial expression order, so reports are
/// bit-for-bit comparable across worker counts (DESIGN.md §16).
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    /// Timesteps simulated (copied from [`SimParams::timesteps`]).
    pub timesteps: usize,
    /// Total spikes generated (axon firings), summed over h-edges.
    pub spikes: u64,
    /// Total inter/intra-core spike copies delivered (one per alive
    /// (h-edge, destination) stream per firing).
    pub copies: u64,
    /// Total link traversals (hops) across all delivered copies.
    pub hops: u64,
    /// Total energy in pJ: `copies * e_r + hops * (e_r + e_t)` with the
    /// Table I per-event costs (`e_r` per routing event — every copy
    /// pays one at the destination router and one per transit router;
    /// `e_t` per wire hop).
    pub energy: f64,
    /// Mean per-timestep makespan latency, ns: the hottest link
    /// serializes its flits (`peak_link * (l_r + l_t)`) plus one router
    /// pass `l_r`, per Table I latency costs.
    pub mean_makespan: f64,
    /// Worst per-timestep makespan, ns.
    pub max_makespan: f64,
    /// Peak router load (spike transits through a single core, one step).
    pub peak_router_load: u64,
    /// Mean (over timesteps) of the per-step max link load.
    pub mean_peak_link_load: f64,
    /// Spike copies that could not be delivered under the fault mask
    /// (dead endpoint, or no alive path). Always 0 without faults.
    pub dropped_spikes: u64,
    /// Hops in excess of the Manhattan distance, summed over detoured
    /// copies. Always 0 without faults (and for YX fallbacks, which stay
    /// minimal).
    pub detour_hops: u64,
}

impl SimReport {
    /// Energy per timestep — directly comparable to the analytic
    /// Table I energy expectation.
    pub fn energy_per_step(&self) -> f64 {
        self.energy / self.timesteps.max(1) as f64
    }

    /// Serialize every report column (the CLI's `--out-report` artifact).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("timesteps", Json::Num(self.timesteps as f64)),
            ("spikes", Json::Num(self.spikes as f64)),
            ("copies", Json::Num(self.copies as f64)),
            ("hops", Json::Num(self.hops as f64)),
            ("energy", Json::Num(self.energy)),
            ("mean_makespan", Json::Num(self.mean_makespan)),
            ("max_makespan", Json::Num(self.max_makespan)),
            ("peak_router_load", Json::Num(self.peak_router_load as f64)),
            ("mean_peak_link_load", Json::Num(self.mean_peak_link_load)),
            ("dropped_spikes", Json::Num(self.dropped_spikes as f64)),
            ("detour_hops", Json::Num(self.detour_hops as f64)),
        ])
    }
}

/// Directed mesh link id: 4 outgoing links per core (E, W, N, S).
#[inline]
fn link_id(hw: &NmhConfig, x: u16, y: u16, dir: usize) -> usize {
    hw.index(x, y) * 4 + dir
}

/// Route one hop of XY routing: move along x first, then y.
/// Returns (next coordinate, link direction).
#[inline]
fn xy_step(cur: (u16, u16), dst: (u16, u16)) -> ((u16, u16), usize) {
    if cur.0 != dst.0 {
        if dst.0 > cur.0 {
            ((cur.0 + 1, cur.1), 0) // E
        } else {
            ((cur.0 - 1, cur.1), 1) // W
        }
    } else if dst.1 > cur.1 {
        ((cur.0, cur.1 + 1), 2) // N (towards +y)
    } else {
        ((cur.0, cur.1 - 1), 3) // S
    }
}

/// One hop of YX routing (y first, then x) — the first-choice fault
/// fallback because it turns at the opposite corner of the XY rectangle.
#[inline]
fn yx_step(cur: (u16, u16), dst: (u16, u16)) -> ((u16, u16), usize) {
    if cur.1 != dst.1 {
        if dst.1 > cur.1 {
            ((cur.0, cur.1 + 1), 2) // N
        } else {
            ((cur.0, cur.1 - 1), 3) // S
        }
    } else if dst.0 > cur.0 {
        ((cur.0 + 1, cur.1), 0) // E
    } else {
        ((cur.0 - 1, cur.1), 1) // W
    }
}

/// Static route of one (h-edge, destination) copy stream under a fault
/// mask, per the XY → YX → BFS detour → drop precedence. Faults are
/// static, so classification happens once per stream, outside the
/// timestep loop — and in batched replay, once per distinct mask.
enum Route {
    /// Healthy XY path — simulated with the pre-fault accounting code,
    /// verbatim (bit-identity for all-healthy masks).
    Xy,
    /// Precomputed alive path: one (from-cell, link direction) per hop,
    /// plus the hop excess over the Manhattan distance.
    Path(Vec<((u16, u16), usize)>, u64),
    /// Dead endpoint or no alive path: every copy drops.
    Drop,
}

/// Walk `step` from `src` to `dst`, collecting (cell, dir) hops; `None`
/// as soon as a dead link or dead intermediate core is hit.
fn walk_alive(
    m: &FaultMask,
    src: (u16, u16),
    dst: (u16, u16),
    step: fn((u16, u16), (u16, u16)) -> ((u16, u16), usize),
) -> Option<Vec<((u16, u16), usize)>> {
    let mut hops = Vec::new();
    let mut cur = src;
    while cur != dst {
        let (next, dir) = step(cur, dst);
        if m.is_link_dead(cur.0, cur.1, dir) {
            return None;
        }
        if next != dst && m.is_core_dead(next.0, next.1) {
            return None;
        }
        hops.push((cur, dir));
        cur = next;
    }
    Some(hops)
}

/// Shortest alive path by BFS over alive cores and links, neighbor order
/// E, W, N, S (deterministic; ties resolve to the first-discovered
/// parent, so identical masks give identical detours).
fn bfs_route(
    hw: &NmhConfig,
    m: &FaultMask,
    src: (u16, u16),
    dst: (u16, u16),
) -> Option<Vec<((u16, u16), usize)>> {
    let s = hw.index(src.0, src.1);
    let d = hw.index(dst.0, dst.1);
    let mut prev = vec![u32::MAX; hw.num_cores()];
    let mut prev_dir = vec![0u8; hw.num_cores()];
    let mut queue = std::collections::VecDeque::new();
    prev[s] = s as u32;
    queue.push_back(s);
    while let Some(c) = queue.pop_front() {
        if c == d {
            break;
        }
        let (x, y) = hw.coord(c);
        for (dir, &(dx, dy)) in DIR_STEPS.iter().enumerate() {
            let nx = x as i32 + dx;
            let ny = y as i32 + dy;
            if !hw.contains(nx, ny) || m.is_link_dead(x, y, dir) {
                continue;
            }
            let ni = hw.index(nx as u16, ny as u16);
            if prev[ni] != u32::MAX || m.is_core_dead(nx as u16, ny as u16) {
                continue;
            }
            prev[ni] = c as u32;
            prev_dir[ni] = dir as u8;
            queue.push_back(ni);
        }
    }
    if prev[d] == u32::MAX {
        return None;
    }
    let mut hops = Vec::new();
    let mut c = d;
    while c != s {
        let p = prev[c] as usize;
        hops.push((hw.coord(p), prev_dir[c] as usize));
        c = p;
    }
    hops.reverse();
    Some(hops)
}

/// Classify one copy stream: XY when fully alive, else YX, else the
/// shortest alive detour, else drop.
fn classify_route(hw: &NmhConfig, m: &FaultMask, src: (u16, u16), dst: (u16, u16)) -> Route {
    if m.is_core_dead(src.0, src.1) || m.is_core_dead(dst.0, dst.1) {
        return Route::Drop;
    }
    if src == dst || walk_alive(m, src, dst, xy_step).is_some() {
        return Route::Xy;
    }
    if let Some(hops) = walk_alive(m, src, dst, yx_step) {
        return Route::Path(hops, 0); // YX is Manhattan-minimal too
    }
    match bfs_route(hw, m, src, dst) {
        Some(hops) => {
            let extra = hops.len() as u64 - NmhConfig::manhattan(src, dst) as u64;
            Route::Path(hops, extra)
        }
        None => Route::Drop,
    }
}

/// One (h-edge, destination) copy stream, flattened from the nested
/// edge → dsts walk in that exact order so `streams[i]` pairs with the
/// `i`-th classified [`Route`].
#[derive(Clone, Copy)]
struct Stream {
    /// Source h-edge (indexes the per-step spike-draw table).
    edge: u32,
    src: (u16, u16),
    dst: (u16, u16),
}

/// Flatten the (edge, dst) streams of a mapped graph, in edge order then
/// dsts order — the accounting order of the serial reference.
fn build_streams(gp: &Hypergraph, placement: &Placement, out: &mut Vec<Stream>) {
    out.clear();
    for e in gp.edge_ids() {
        let src = placement.coords[gp.source(e) as usize];
        for &d in gp.dsts(e) {
            out.push(Stream { edge: e, src, dst: placement.coords[d as usize] });
        }
    }
}

/// Classify every stream under `m` into `out` (index-aligned with
/// `streams`). Classification is pure per stream, so the parallel path
/// via [`par::par_map`] — which returns results in index order — is
/// trivially identical to the serial loop.
fn classify_routes(
    hw: &NmhConfig,
    m: &FaultMask,
    streams: &[Stream],
    threads: usize,
    out: &mut Vec<Route>,
) {
    out.clear();
    if threads > 1 && streams.len() >= PAR_MIN_STREAMS {
        out.extend(par::par_map(streams.len(), threads, |i| {
            classify_route(hw, m, streams[i].src, streams[i].dst)
        }));
    } else {
        out.reserve(streams.len());
        for s in streams {
            out.push(classify_route(hw, m, s.src, s.dst));
        }
    }
}

/// Integer event totals of one simulated step — exact, so any summation
/// order (chunk merge vs serial walk) yields identical values.
#[derive(Clone, Copy, Default)]
struct StepTotals {
    copies: u64,
    hops: u64,
    dropped: u64,
    detour: u64,
}

/// Per-chunk propose-phase accumulator: link/router flit loads plus the
/// step totals of this chunk's streams. Integer-only by design — the
/// commit merge is exact regardless of chunk count (DESIGN.md §16).
#[derive(Default)]
struct ChunkAcc {
    link: Vec<u32>,
    router: Vec<u32>,
    totals: StepTotals,
}

impl ChunkAcc {
    fn reset(&mut self, num_links: usize, num_cores: usize) {
        self.link.clear();
        self.link.resize(num_links, 0);
        self.router.clear();
        self.router.resize(num_cores, 0);
        self.totals = StepTotals::default();
    }
}

/// Pooled per-run working state: spike draws, merged per-step loads,
/// chunk accumulators, and the makespan trace. Split out of
/// [`SimScratch`] so the route table can stay borrowed while the core
/// is mutated.
#[derive(Default)]
struct CoreScratch {
    fires: Vec<u32>,
    link_load: Vec<u32>,
    router_load: Vec<u32>,
    chunks: Vec<ChunkAcc>,
    makespans: Vec<f64>,
}

impl CoreScratch {
    fn memory_bytes(&self) -> usize {
        use std::mem::size_of;
        let mut b = self.fires.capacity() * size_of::<u32>()
            + self.link_load.capacity() * size_of::<u32>()
            + self.router_load.capacity() * size_of::<u32>()
            + self.makespans.capacity() * size_of::<f64>();
        for c in &self.chunks {
            b += c.link.capacity() * size_of::<u32>() + c.router.capacity() * size_of::<u32>();
        }
        b
    }
}

/// Reusable simulator scratch: copy streams, the fault-route table, and
/// the per-step working state. One `SimScratch` serves an entire
/// [`simulate_batch`] sweep — allocations are made once and recycled.
#[derive(Default)]
pub struct SimScratch {
    streams: Vec<Stream>,
    routes: Vec<Route>,
    core: CoreScratch,
}

impl SimScratch {
    /// An empty scratch; buffers grow on first use and are then reused.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current heap footprint of every pooled buffer (capacities, not
    /// lengths) — the bench rows' `memory_bytes` high-water mark.
    pub fn memory_bytes(&self) -> usize {
        use std::mem::size_of;
        let mut b = self.streams.capacity() * size_of::<Stream>()
            + self.routes.capacity() * size_of::<Route>();
        for r in &self.routes {
            if let Route::Path(hops, _) = r {
                b += hops.capacity() * size_of::<((u16, u16), usize)>();
            }
        }
        b + self.core.memory_bytes()
    }
}

/// Instrumentation for one simulator invocation (reset per call, summed
/// across a batch): phase timings, the parallel-dispatch counter the
/// equality tests assert non-vacuous, and the scratch high-water mark.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    /// Seconds in the serial spike-draw pre-pass (RNG order is part of
    /// the determinism contract, so draws never run in parallel).
    pub draw_secs: f64,
    /// Seconds in the accumulation scan (parallel propose or the serial
    /// walk, whichever the dispatch chose).
    pub scan_secs: f64,
    /// Seconds in the serial commit merge (parallel steps only).
    pub commit_secs: f64,
    /// Steps that dispatched to the parallel propose phase.
    pub par_steps: u64,
    /// High-water heap footprint of the pooled [`SimScratch`].
    pub peak_scratch_bytes: usize,
}

/// Account one firing copy stream into link/router loads and step
/// totals. Shared verbatim by [`sim_step_serial`] and the per-chunk
/// propose phase of [`sim_step_parallel`] — the two paths cannot
/// diverge on per-stream arithmetic.
#[inline]
fn account_stream(
    hw: &NmhConfig,
    s: &Stream,
    fires: u32,
    route: Option<&Route>,
    link: &mut [u32],
    router: &mut [u32],
    t: &mut StepTotals,
) {
    match route {
        None | Some(Route::Xy) => {
            t.copies += fires as u64;
            // destination router always pays one routing event
            router[hw.index(s.dst.0, s.dst.1)] += fires;
            let mut cur = s.src;
            while cur != s.dst {
                let (next, dir) = xy_step(cur, s.dst);
                link[link_id(hw, cur.0, cur.1, dir)] += fires;
                router[hw.index(cur.0, cur.1)] += fires;
                t.hops += fires as u64;
                cur = next;
            }
        }
        Some(Route::Path(hops, extra)) => {
            t.copies += fires as u64;
            router[hw.index(s.dst.0, s.dst.1)] += fires;
            for &((cx, cy), dir) in hops {
                link[link_id(hw, cx, cy, dir)] += fires;
                router[hw.index(cx, cy)] += fires;
                t.hops += fires as u64;
            }
            t.detour += *extra * fires as u64;
        }
        Some(Route::Drop) => t.dropped += fires as u64,
    }
}

/// Serial reference step: zero the load arrays, walk every stream in
/// order. The twin kept honest by `sim_parallel_equals_serial_exactly`.
fn sim_step_serial(
    hw: &NmhConfig,
    streams: &[Stream],
    routes: Option<&[Route]>,
    fires: &[u32],
    link_load: &mut [u32],
    router_load: &mut [u32],
) -> StepTotals {
    link_load.iter_mut().for_each(|l| *l = 0);
    router_load.iter_mut().for_each(|l| *l = 0);
    let mut t = StepTotals::default();
    for (i, s) in streams.iter().enumerate() {
        let f = fires[s.edge as usize];
        if f == 0 {
            continue;
        }
        account_stream(hw, s, f, routes.map(|r| &r[i]), link_load, router_load, &mut t);
    }
    t
}

/// Parallel two-phase step. Propose: each fixed stream chunk fills its
/// private integer [`ChunkAcc`] (one chunk per [`par_chunks_mut`] slot,
/// dynamic scheduling over disjoint slots). Commit: merge per link id,
/// then per router id, then scalar totals, always in ascending chunk
/// order. Integer addition is associative and commutative, so the merge
/// equals the serial walk exactly — bit-identity holds without any
/// float ever entering the propose phase.
///
/// [`par_chunks_mut`]: par::par_chunks_mut
// snn-lint: allow(parallel-serial-pairing) — sim_step_serial runs via the threads<=1 /
// below-PAR_MIN_STREAMS dispatch in run_sim; sim_parallel_equals_serial_exactly asserts
// bit-identical reports across thread counts through the public API
fn sim_step_parallel(
    hw: &NmhConfig,
    streams: &[Stream],
    routes: Option<&[Route]>,
    chunk: usize,
    threads: usize,
    core: &mut CoreScratch,
    stats: &mut SimStats,
) -> StepTotals {
    let CoreScratch { fires, link_load, router_load, chunks, .. } = core;
    let n_chunks = crate::util::div_ceil(streams.len(), chunk);
    chunks.resize_with(n_chunks, ChunkAcc::default);
    let num_links = link_load.len();
    let num_cores = router_load.len();
    let fires: &[u32] = fires;

    let t0 = Instant::now();
    par::par_chunks_mut(&mut chunks[..n_chunks], 1, threads, |ci, slot| {
        let acc = &mut slot[0];
        acc.reset(num_links, num_cores);
        let lo = ci * chunk;
        let hi = (lo + chunk).min(streams.len());
        let mut t = StepTotals::default();
        for (i, s) in streams[lo..hi].iter().enumerate() {
            let f = fires[s.edge as usize];
            if f == 0 {
                continue;
            }
            let route = routes.map(|r| &r[lo + i]);
            account_stream(hw, s, f, route, &mut acc.link, &mut acc.router, &mut t);
        }
        acc.totals = t;
    });
    stats.scan_secs += t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let active = &chunks[..n_chunks];
    for (l, slot) in link_load.iter_mut().enumerate() {
        let mut v = 0u32;
        for c in active {
            v += c.link[l];
        }
        *slot = v;
    }
    for (r, slot) in router_load.iter_mut().enumerate() {
        let mut v = 0u32;
        for c in active {
            v += c.router[r];
        }
        *slot = v;
    }
    let mut totals = StepTotals::default();
    for c in active {
        totals.copies += c.totals.copies;
        totals.hops += c.totals.hops;
        totals.dropped += c.totals.dropped;
        totals.detour += c.totals.detour;
    }
    stats.commit_secs += t1.elapsed().as_secs_f64();
    totals
}

/// Core replay loop shared by every public entry point: serial spike
/// draw (RNG order is the contract), dispatched serial/parallel step
/// accumulation, and a serial epilogue that derives every `f64` from
/// the step's exact integer totals in one fixed expression order.
#[allow(clippy::too_many_arguments)]
fn run_sim(
    gp: &Hypergraph,
    hw: &NmhConfig,
    params: SimParams,
    rate_scale: f64,
    streams: &[Stream],
    routes: Option<&[Route]>,
    core: &mut CoreScratch,
    threads: usize,
    stats: &mut SimStats,
) -> SimReport {
    let costs = hw.costs;
    let mut rng = Pcg64::new(params.seed, 41);
    let mut report = SimReport { timesteps: params.timesteps, ..Default::default() };

    let num_links = hw.num_cores() * 4;
    core.fires.clear();
    core.fires.resize(gp.num_edges(), 0);
    core.link_load.clear();
    core.link_load.resize(num_links, 0);
    core.router_load.clear();
    core.router_load.resize(hw.num_cores(), 0);
    core.makespans.clear();
    core.makespans.reserve(params.timesteps);

    let parallel = threads > 1 && streams.len() >= PAR_MIN_STREAMS;
    let chunk = par::fixed_chunk(streams.len(), threads);

    for _step in 0..params.timesteps {
        // spike draws stay serial: the RNG is consumed once per h-edge
        // in edge order, independent of routing or worker count
        let t0 = Instant::now();
        for e in gp.edge_ids() {
            let w = gp.weight(e) as f64 * rate_scale;
            let fires = if params.poisson_spikes {
                rng.poisson(w)
            } else {
                usize::from(rng.bernoulli(w.min(1.0)))
            };
            core.fires[e as usize] = fires as u32;
            report.spikes += fires as u64;
        }
        stats.draw_secs += t0.elapsed().as_secs_f64();

        let totals = if parallel {
            stats.par_steps += 1;
            sim_step_parallel(hw, streams, routes, chunk, threads, core, stats)
        } else {
            let t1 = Instant::now();
            let t = sim_step_serial(
                hw,
                streams,
                routes,
                &core.fires,
                &mut core.link_load,
                &mut core.router_load,
            );
            stats.scan_secs += t1.elapsed().as_secs_f64();
            t
        };

        report.copies += totals.copies;
        report.hops += totals.hops;
        report.dropped_spikes += totals.dropped;
        report.detour_hops += totals.detour;
        // Table I pricing over the step's exact integer totals: one
        // routing event per delivered copy (destination router) plus one
        // routing event and one wire traversal per hop
        report.energy +=
            totals.copies as f64 * costs.e_r + totals.hops as f64 * (costs.e_r + costs.e_t);

        let peak_link = core.link_load.iter().copied().max().unwrap_or(0);
        let peak_router = core.router_load.iter().copied().max().unwrap_or(0);
        report.peak_router_load = report.peak_router_load.max(peak_router as u64);
        // makespan: hottest link serializes its flits, plus one router pass
        let makespan = peak_link as f64 * (costs.l_r + costs.l_t) + costs.l_r;
        core.makespans.push(makespan);
        report.mean_peak_link_load += peak_link as f64;
    }

    report.mean_peak_link_load /= params.timesteps.max(1) as f64;
    report.mean_makespan =
        core.makespans.iter().sum::<f64>() / core.makespans.len().max(1) as f64;
    report.max_makespan = core.makespans.iter().copied().fold(0.0, f64::max);
    report
}

/// Run the simulator over a mapped SNN at the process-default worker
/// count ([`par::max_threads`]).
///
/// `gp` is the quotient h-graph (one node per partition — its edges carry
/// the merged spike frequencies), `placement` its γ.
pub fn simulate(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    params: SimParams,
) -> SimReport {
    simulate_faulty(gp, placement, hw, params, None)
}

/// [`simulate`] under an optional hardware fault mask (DESIGN.md §15).
///
/// With `faults: None` (or an all-healthy mask) this is bit-identical to
/// the fault-free simulator. Under faults, each (h-edge, destination)
/// stream routes per its static [`Route`] classification; dead links and
/// dead cores carry zero traffic.
pub fn simulate_faulty(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    params: SimParams,
    faults: Option<&FaultMask>,
) -> SimReport {
    simulate_with_threads(gp, placement, hw, params, faults, par::max_threads())
}

/// [`simulate_faulty`] with an explicit worker count — the entry point
/// `StageCtx.threads` consumers (pipeline, experiment grid) use. The
/// report is bit-for-bit identical for every `threads` value
/// (DESIGN.md §16); [`simulate_serial`] is the tested reference.
pub fn simulate_with_threads(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    params: SimParams,
    faults: Option<&FaultMask>,
    threads: usize,
) -> SimReport {
    let mut scratch = SimScratch::new();
    simulate_with_stats(gp, placement, hw, params, faults, threads, &mut scratch).0
}

/// Serial reference simulator: the exact single-worker walk, kept as
/// the oracle the thread-invariance tests compare against.
pub fn simulate_serial(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    params: SimParams,
    faults: Option<&FaultMask>,
) -> SimReport {
    let mut scratch = SimScratch::new();
    simulate_with_stats(gp, placement, hw, params, faults, 1, &mut scratch).0
}

/// Full-control entry point: explicit worker count, caller-pooled
/// [`SimScratch`], and [`SimStats`] instrumentation (phase timings, the
/// `par_steps` dispatch counter, scratch high-water mark).
#[allow(clippy::too_many_arguments)]
pub fn simulate_with_stats(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    params: SimParams,
    faults: Option<&FaultMask>,
    threads: usize,
    scratch: &mut SimScratch,
) -> (SimReport, SimStats) {
    assert_eq!(gp.num_nodes(), placement.len());
    let mut stats = SimStats::default();
    build_streams(gp, placement, &mut scratch.streams);
    let routes = match faults {
        Some(m) => {
            classify_routes(hw, m, &scratch.streams, threads, &mut scratch.routes);
            Some(&scratch.routes[..])
        }
        None => None,
    };
    let report = run_sim(
        gp,
        hw,
        params,
        1.0,
        &scratch.streams,
        routes,
        &mut scratch.core,
        threads,
        &mut stats,
    );
    stats.peak_scratch_bytes = stats.peak_scratch_bytes.max(scratch.memory_bytes());
    (report, stats)
}

/// Batched trace replay: run every [`SimConfig`] over one mapped graph
/// through a single pooled scratch. Streams are built once; consecutive
/// configs borrowing the same [`FaultMask`] share one route
/// classification. Each returned report is bit-identical to the
/// corresponding standalone [`simulate_with_threads`] call.
pub fn simulate_batch(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    configs: &[SimConfig<'_>],
    threads: usize,
) -> Vec<SimReport> {
    let mut scratch = SimScratch::new();
    simulate_batch_with_stats(gp, placement, hw, configs, threads, &mut scratch).0
}

/// [`simulate_batch`] with a caller-pooled scratch and accumulated
/// [`SimStats`] across the whole batch.
///
/// Route-classification sharing is keyed by mask address, which is
/// sound here because every mask in `configs` stays borrowed for the
/// whole call — no allocation can reuse a key'd address mid-batch.
pub fn simulate_batch_with_stats(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    configs: &[SimConfig<'_>],
    threads: usize,
    scratch: &mut SimScratch,
) -> (Vec<SimReport>, SimStats) {
    assert_eq!(gp.num_nodes(), placement.len());
    let mut stats = SimStats::default();
    build_streams(gp, placement, &mut scratch.streams);
    let mut reports = Vec::with_capacity(configs.len());
    let mut cached_mask: Option<*const FaultMask> = None;
    for cfg in configs {
        let routes = match cfg.faults {
            None => None,
            Some(m) => {
                let key: *const FaultMask = m;
                if cached_mask != Some(key) {
                    classify_routes(hw, m, &scratch.streams, threads, &mut scratch.routes);
                    cached_mask = Some(key);
                }
                Some(&scratch.routes[..])
            }
        };
        reports.push(run_sim(
            gp,
            hw,
            cfg.params,
            cfg.rate_scale,
            &scratch.streams,
            routes,
            &mut scratch.core,
            threads,
            &mut stats,
        ));
    }
    stats.peak_scratch_bytes = stats.peak_scratch_bytes.max(scratch.memory_bytes());
    (reports, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::metrics::evaluate;

    fn line_mapping() -> (Hypergraph, Placement) {
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 0.8);
        (
            b.build(),
            Placement { coords: vec![(0, 0), (4, 0)] },
        )
    }

    /// A graph wide enough to cross [`PAR_MIN_STREAMS`]: 2 h-edges with
    /// 512 destinations each → 1024 copy streams.
    fn wide_mapping(hw: &NmhConfig) -> (Hypergraph, Placement) {
        let n = 2 + 1024;
        let mut b = HypergraphBuilder::new(n);
        b.add_edge(0, (2..514).collect(), 1.3);
        b.add_edge(1, (514..1026).collect(), 0.7);
        let gp = b.build();
        let coords = (0..n)
            .map(|i| {
                let c = (i * 7) % hw.num_cores();
                hw.coord(c)
            })
            .collect();
        (gp, Placement { coords })
    }

    fn assert_reports_bit_identical(a: &SimReport, b: &SimReport, what: &str) {
        assert_eq!(a.timesteps, b.timesteps, "{what}: timesteps");
        assert_eq!(a.spikes, b.spikes, "{what}: spikes");
        assert_eq!(a.copies, b.copies, "{what}: copies");
        assert_eq!(a.hops, b.hops, "{what}: hops");
        assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "{what}: energy");
        assert_eq!(a.mean_makespan.to_bits(), b.mean_makespan.to_bits(), "{what}: mean_makespan");
        assert_eq!(a.max_makespan.to_bits(), b.max_makespan.to_bits(), "{what}: max_makespan");
        assert_eq!(a.peak_router_load, b.peak_router_load, "{what}: peak_router_load");
        assert_eq!(
            a.mean_peak_link_load.to_bits(),
            b.mean_peak_link_load.to_bits(),
            "{what}: mean_peak_link_load"
        );
        assert_eq!(a.dropped_spikes, b.dropped_spikes, "{what}: dropped_spikes");
        assert_eq!(a.detour_hops, b.detour_hops, "{what}: detour_hops");
    }

    #[test]
    fn deterministic_given_seed() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let a = simulate(&gp, &pl, &hw, SimParams::default());
        let b = simulate(&gp, &pl, &hw, SimParams::default());
        assert_eq!(a.spikes, b.spikes);
        assert_eq!(a.energy, b.energy);
    }

    #[test]
    fn energy_matches_analytic_expectation() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let analytic = evaluate(&gp, &pl, &hw);
        let sim = simulate(
            &gp,
            &pl,
            &hw,
            SimParams { timesteps: 20_000, seed: 7, poisson_spikes: true },
        );
        let per_step = sim.energy_per_step();
        let rel = (per_step - analytic.energy).abs() / analytic.energy;
        assert!(rel < 0.03, "sim {per_step} vs analytic {} (rel {rel})", analytic.energy);
    }

    #[test]
    fn hop_counts_follow_manhattan() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let sim = simulate(&gp, &pl, &hw, SimParams::default());
        // every copy walks exactly 4 hops
        assert_eq!(sim.hops, sim.copies * 4);
    }

    #[test]
    fn xy_routing_turns_once() {
        // (0,0) -> (2,3): 2 east then 3 north; verify router visits
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 50.0); // fires a lot
        let gp = b.build();
        let pl = Placement { coords: vec![(0, 0), (2, 3)] };
        let hw = NmhConfig::small();
        let sim =
            simulate(&gp, &pl, &hw, SimParams { timesteps: 2, seed: 1, poisson_spikes: true });
        assert_eq!(sim.hops, sim.copies * 5);
    }

    #[test]
    fn colocated_partitions_move_no_flits() {
        let mut b = HypergraphBuilder::new(1);
        b.add_edge(0, vec![0], 1.0);
        let gp = b.build();
        let pl = Placement { coords: vec![(3, 3)] };
        let hw = NmhConfig::small();
        let sim = simulate(&gp, &pl, &hw, SimParams::default());
        assert_eq!(sim.hops, 0);
        assert!(sim.copies > 0);
        // only router energy
        assert!((sim.energy - sim.copies as f64 * hw.costs.e_r).abs() < 1e-9);
    }

    #[test]
    fn healthy_mask_is_bit_identical_to_none() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let mask = FaultMask::healthy(&hw);
        let plain = simulate(&gp, &pl, &hw, SimParams::default());
        let masked = simulate_faulty(&gp, &pl, &hw, SimParams::default(), Some(&mask));
        assert_reports_bit_identical(&plain, &masked, "healthy mask vs none");
        assert_eq!(masked.dropped_spikes, 0);
        assert_eq!(masked.detour_hops, 0);
    }

    #[test]
    fn dead_link_forces_deterministic_detour() {
        // (0,0) -> (4,0): killing the east link out of (1,0) blocks both
        // XY and YX (same row), so every copy takes a minimal BFS detour
        // of 6 hops (Manhattan 4 + 2 extra).
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let mut mask = FaultMask::healthy(&hw);
        mask.kill_link(1, 0, 0); // E out of (1,0)
        let a = simulate_faulty(&gp, &pl, &hw, SimParams::default(), Some(&mask));
        assert!(a.copies > 0);
        assert_eq!(a.dropped_spikes, 0);
        assert_eq!(a.hops, a.copies * 6, "detour path length");
        assert_eq!(a.detour_hops, a.copies * 2, "excess over Manhattan");
        // detours are statically classified: rerun is bit-identical
        let b = simulate_faulty(&gp, &pl, &hw, SimParams::default(), Some(&mask));
        assert_eq!(a.hops, b.hops);
        assert_eq!(a.energy.to_bits(), b.energy.to_bits());
    }

    #[test]
    fn dead_destination_core_drops_all_copies() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let mut mask = FaultMask::healthy(&hw);
        mask.kill_core(4, 0);
        let sim = simulate_faulty(&gp, &pl, &hw, SimParams::default(), Some(&mask));
        assert!(sim.dropped_spikes > 0);
        assert_eq!(sim.copies, 0);
        assert_eq!(sim.hops, 0);
        assert_eq!(sim.energy, 0.0);
        // spike generation itself is unaffected (same RNG draw order)
        let plain = simulate(&gp, &pl, &hw, SimParams::default());
        assert_eq!(sim.spikes, plain.spikes);
    }

    #[test]
    fn makespan_scales_with_congestion() {
        // two flows sharing a corridor vs separated: shared is slower
        let hw = NmhConfig::small();
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1], 3.0);
        b.add_edge(2, vec![3], 3.0);
        let gp = b.build();
        let shared = Placement {
            coords: vec![(0, 0), (10, 0), (1, 0), (9, 0)], // same row corridor
        };
        let apart = Placement {
            coords: vec![(0, 0), (10, 0), (0, 20), (10, 20)],
        };
        let p = SimParams { timesteps: 300, seed: 5, poisson_spikes: true };
        let s_shared = simulate(&gp, &shared, &hw, p);
        let s_apart = simulate(&gp, &apart, &hw, p);
        assert!(
            s_shared.mean_makespan > s_apart.mean_makespan,
            "shared {} vs apart {}",
            s_shared.mean_makespan,
            s_apart.mean_makespan
        );
    }

    #[test]
    fn parallel_step_dispatches_and_matches_serial() {
        // wide graph crosses PAR_MIN_STREAMS, so threads>1 must take the
        // two-phase path (par_steps non-vacuous) and stay bit-identical
        let hw = NmhConfig::small();
        let (gp, pl) = wide_mapping(&hw);
        let params = SimParams { timesteps: 6, seed: 21, poisson_spikes: true };
        let reference = simulate_serial(&gp, &pl, &hw, params, None);
        let mut scratch = SimScratch::new();
        let (par_rep, stats) =
            simulate_with_stats(&gp, &pl, &hw, params, None, 4, &mut scratch);
        assert_eq!(stats.par_steps, params.timesteps as u64, "parallel path not taken");
        assert!(stats.peak_scratch_bytes > 0);
        assert_reports_bit_identical(&reference, &par_rep, "threads=4 vs serial");
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // the pooled scratch must carry no state between runs
        let hw = NmhConfig::small();
        let (gp, pl) = wide_mapping(&hw);
        let params = SimParams { timesteps: 4, seed: 3, poisson_spikes: true };
        let mut scratch = SimScratch::new();
        let (first, _) = simulate_with_stats(&gp, &pl, &hw, params, None, 2, &mut scratch);
        let (second, _) = simulate_with_stats(&gp, &pl, &hw, params, None, 2, &mut scratch);
        assert_reports_bit_identical(&first, &second, "fresh vs reused scratch");
    }

    #[test]
    fn batch_matches_one_by_one() {
        let hw = NmhConfig::small();
        let (gp, pl) = line_mapping();
        let mut mask = FaultMask::healthy(&hw);
        mask.kill_link(1, 0, 0);
        let configs = [
            SimConfig::new(SimParams { timesteps: 50, seed: 1, poisson_spikes: true }),
            SimConfig {
                params: SimParams { timesteps: 50, seed: 2, poisson_spikes: true },
                rate_scale: 1.0,
                faults: Some(&mask),
            },
            SimConfig {
                params: SimParams { timesteps: 50, seed: 2, poisson_spikes: true },
                rate_scale: 1.0,
                faults: Some(&mask), // same mask: shares one classification
            },
        ];
        let batch = simulate_batch(&gp, &pl, &hw, &configs, 1);
        assert_eq!(batch.len(), configs.len());
        for (i, cfg) in configs.iter().enumerate() {
            let solo = simulate_with_threads(&gp, &pl, &hw, cfg.params, cfg.faults, 1);
            assert_reports_bit_identical(&solo, &batch[i], "batch config");
        }
        // identical (seed, mask) configs must produce identical reports
        assert_reports_bit_identical(&batch[1], &batch[2], "route-cache reuse");
    }

    #[test]
    fn rate_scale_one_is_identity_and_scaling_raises_traffic() {
        let hw = NmhConfig::small();
        let (gp, pl) = line_mapping();
        let params = SimParams { timesteps: 400, seed: 11, poisson_spikes: true };
        let base = simulate(&gp, &pl, &hw, params);
        let cfgs = [
            SimConfig { params, rate_scale: 1.0, faults: None },
            SimConfig { params, rate_scale: 3.0, faults: None },
        ];
        let batch = simulate_batch(&gp, &pl, &hw, &cfgs, 1);
        assert_reports_bit_identical(&base, &batch[0], "rate_scale=1.0");
        assert!(
            batch[1].spikes > batch[0].spikes * 2,
            "3x rate should roughly triple traffic: {} vs {}",
            batch[1].spikes,
            batch[0].spikes
        );
    }
}
