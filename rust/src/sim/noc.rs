//! Discrete-timestep mesh NoC simulator with XY routing.
//!
//! Fault injection (DESIGN.md §15): under a
//! [`crate::hw::faults::FaultMask`] every (h-edge, destination) copy
//! stream is classified once — healthy XY path, deterministic YX
//! fallback, shortest alive BFS detour (neighbor order E, W, N, S), or
//! dropped when no alive path exists. Dead links and dead cores carry
//! zero traffic; [`SimReport::dropped_spikes`] and
//! [`SimReport::detour_hops`] quantify the degradation. `faults: None`
//! and an all-healthy mask reproduce the pre-fault simulation bit for
//! bit (every stream classifies as the verbatim XY path, and the spike
//! RNG is consumed per h-edge regardless of routing).

use crate::hw::faults::{FaultMask, DIR_STEPS};
use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use crate::placement::Placement;
use crate::util::rng::Pcg64;

/// Simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    pub timesteps: usize,
    pub seed: u64,
    /// Spike count per h-edge per timestep ~ Poisson(w) so the expected
    /// traffic matches the analytic model exactly (w is a frequency, not
    /// a probability — biological rates exceed 1 spike/step in the tail).
    pub poisson_spikes: bool,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { timesteps: 100, seed: 99, poisson_spikes: true }
    }
}

/// Aggregated simulation results.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub timesteps: usize,
    /// Total spikes generated (axon firings).
    pub spikes: u64,
    /// Total inter/intra-core spike copies delivered.
    pub copies: u64,
    /// Total hop count across all copies.
    pub hops: u64,
    /// Total energy, pJ (per Table I per-copy pricing).
    pub energy: f64,
    /// Mean per-timestep makespan latency, ns (serialized hottest link).
    pub mean_makespan: f64,
    /// Worst per-timestep makespan, ns.
    pub max_makespan: f64,
    /// Peak router load (spike transits through a single core, one step).
    pub peak_router_load: u64,
    /// Mean (over timesteps) of the per-step max link load.
    pub mean_peak_link_load: f64,
    /// Spike copies that could not be delivered under the fault mask
    /// (dead endpoint, or no alive path). Always 0 without faults.
    pub dropped_spikes: u64,
    /// Hops in excess of the Manhattan distance, summed over detoured
    /// copies. Always 0 without faults (and for YX fallbacks, which stay
    /// minimal).
    pub detour_hops: u64,
}

impl SimReport {
    /// Energy per timestep — directly comparable to the analytic
    /// Table I energy expectation.
    pub fn energy_per_step(&self) -> f64 {
        self.energy / self.timesteps.max(1) as f64
    }

    /// Serialize every report column (the CLI's `--out-report` artifact).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("timesteps", Json::Num(self.timesteps as f64)),
            ("spikes", Json::Num(self.spikes as f64)),
            ("copies", Json::Num(self.copies as f64)),
            ("hops", Json::Num(self.hops as f64)),
            ("energy", Json::Num(self.energy)),
            ("mean_makespan", Json::Num(self.mean_makespan)),
            ("max_makespan", Json::Num(self.max_makespan)),
            ("peak_router_load", Json::Num(self.peak_router_load as f64)),
            ("mean_peak_link_load", Json::Num(self.mean_peak_link_load)),
            ("dropped_spikes", Json::Num(self.dropped_spikes as f64)),
            ("detour_hops", Json::Num(self.detour_hops as f64)),
        ])
    }
}

/// Directed mesh link id: 4 outgoing links per core (E, W, N, S).
#[inline]
fn link_id(hw: &NmhConfig, x: u16, y: u16, dir: usize) -> usize {
    hw.index(x, y) * 4 + dir
}

/// Route one hop of XY routing: move along x first, then y.
/// Returns (next coordinate, link direction).
#[inline]
fn xy_step(cur: (u16, u16), dst: (u16, u16)) -> ((u16, u16), usize) {
    if cur.0 != dst.0 {
        if dst.0 > cur.0 {
            ((cur.0 + 1, cur.1), 0) // E
        } else {
            ((cur.0 - 1, cur.1), 1) // W
        }
    } else if dst.1 > cur.1 {
        ((cur.0, cur.1 + 1), 2) // N (towards +y)
    } else {
        ((cur.0, cur.1 - 1), 3) // S
    }
}

/// One hop of YX routing (y first, then x) — the first-choice fault
/// fallback because it turns at the opposite corner of the XY rectangle.
#[inline]
fn yx_step(cur: (u16, u16), dst: (u16, u16)) -> ((u16, u16), usize) {
    if cur.1 != dst.1 {
        if dst.1 > cur.1 {
            ((cur.0, cur.1 + 1), 2) // N
        } else {
            ((cur.0, cur.1 - 1), 3) // S
        }
    } else if dst.0 > cur.0 {
        ((cur.0 + 1, cur.1), 0) // E
    } else {
        ((cur.0 - 1, cur.1), 1) // W
    }
}

/// Static route of one (h-edge, destination) copy stream under a fault
/// mask. Faults are static, so classification happens once per stream,
/// outside the timestep loop.
enum Route {
    /// Healthy XY path — simulated with the pre-fault accounting code,
    /// verbatim (bit-identity for all-healthy masks).
    Xy,
    /// Precomputed alive path: one (from-cell, link direction) per hop,
    /// plus the hop excess over the Manhattan distance.
    Path(Vec<((u16, u16), usize)>, u64),
    /// Dead endpoint or no alive path: every copy drops.
    Drop,
}

/// Walk `step` from `src` to `dst`, collecting (cell, dir) hops; `None`
/// as soon as a dead link or dead intermediate core is hit.
fn walk_alive(
    m: &FaultMask,
    src: (u16, u16),
    dst: (u16, u16),
    step: fn((u16, u16), (u16, u16)) -> ((u16, u16), usize),
) -> Option<Vec<((u16, u16), usize)>> {
    let mut hops = Vec::new();
    let mut cur = src;
    while cur != dst {
        let (next, dir) = step(cur, dst);
        if m.is_link_dead(cur.0, cur.1, dir) {
            return None;
        }
        if next != dst && m.is_core_dead(next.0, next.1) {
            return None;
        }
        hops.push((cur, dir));
        cur = next;
    }
    Some(hops)
}

/// Shortest alive path by BFS over alive cores and links, neighbor order
/// E, W, N, S (deterministic; ties resolve to the first-discovered
/// parent, so identical masks give identical detours).
fn bfs_route(
    hw: &NmhConfig,
    m: &FaultMask,
    src: (u16, u16),
    dst: (u16, u16),
) -> Option<Vec<((u16, u16), usize)>> {
    let s = hw.index(src.0, src.1);
    let d = hw.index(dst.0, dst.1);
    let mut prev = vec![u32::MAX; hw.num_cores()];
    let mut prev_dir = vec![0u8; hw.num_cores()];
    let mut queue = std::collections::VecDeque::new();
    prev[s] = s as u32;
    queue.push_back(s);
    while let Some(c) = queue.pop_front() {
        if c == d {
            break;
        }
        let (x, y) = hw.coord(c);
        for (dir, &(dx, dy)) in DIR_STEPS.iter().enumerate() {
            let nx = x as i32 + dx;
            let ny = y as i32 + dy;
            if !hw.contains(nx, ny) || m.is_link_dead(x, y, dir) {
                continue;
            }
            let ni = hw.index(nx as u16, ny as u16);
            if prev[ni] != u32::MAX || m.is_core_dead(nx as u16, ny as u16) {
                continue;
            }
            prev[ni] = c as u32;
            prev_dir[ni] = dir as u8;
            queue.push_back(ni);
        }
    }
    if prev[d] == u32::MAX {
        return None;
    }
    let mut hops = Vec::new();
    let mut c = d;
    while c != s {
        let p = prev[c] as usize;
        hops.push((hw.coord(p), prev_dir[c] as usize));
        c = p;
    }
    hops.reverse();
    Some(hops)
}

/// Classify one copy stream: XY when fully alive, else YX, else the
/// shortest alive detour, else drop.
fn classify_route(hw: &NmhConfig, m: &FaultMask, src: (u16, u16), dst: (u16, u16)) -> Route {
    if m.is_core_dead(src.0, src.1) || m.is_core_dead(dst.0, dst.1) {
        return Route::Drop;
    }
    if src == dst || walk_alive(m, src, dst, xy_step).is_some() {
        return Route::Xy;
    }
    if let Some(hops) = walk_alive(m, src, dst, yx_step) {
        return Route::Path(hops, 0); // YX is Manhattan-minimal too
    }
    match bfs_route(hw, m, src, dst) {
        Some(hops) => {
            let extra = hops.len() as u64 - NmhConfig::manhattan(src, dst) as u64;
            Route::Path(hops, extra)
        }
        None => Route::Drop,
    }
}

/// Run the simulator over a mapped SNN.
///
/// `gp` is the quotient h-graph (one node per partition — its edges carry
/// the merged spike frequencies), `placement` its γ.
pub fn simulate(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    params: SimParams,
) -> SimReport {
    simulate_faulty(gp, placement, hw, params, None)
}

/// [`simulate`] under an optional hardware fault mask (DESIGN.md §15).
///
/// With `faults: None` (or an all-healthy mask) this is bit-identical to
/// the fault-free simulator. Under faults, each (h-edge, destination)
/// stream routes per its static [`Route`] classification; dead links and
/// dead cores carry zero traffic.
pub fn simulate_faulty(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    params: SimParams,
    faults: Option<&FaultMask>,
) -> SimReport {
    assert_eq!(gp.num_nodes(), placement.len());
    let costs = hw.costs;
    let mut rng = Pcg64::new(params.seed, 41);
    let mut report = SimReport {
        timesteps: params.timesteps,
        ..Default::default()
    };

    // static fault classification, once per (edge, dst) stream in edge
    // order then dsts order — indexed by the same walk in the step loop
    let routes: Option<Vec<Route>> = faults.map(|m| {
        let mut r = Vec::new();
        for e in gp.edge_ids() {
            let src = placement.coords[gp.source(e) as usize];
            for &d in gp.dsts(e) {
                let dst = placement.coords[d as usize];
                r.push(classify_route(hw, m, src, dst));
            }
        }
        r
    });

    let num_links = hw.num_cores() * 4;
    let mut link_load = vec![0u32; num_links];
    let mut router_load = vec![0u32; hw.num_cores()];
    let mut makespans = Vec::with_capacity(params.timesteps);

    for _step in 0..params.timesteps {
        link_load.iter_mut().for_each(|l| *l = 0);
        router_load.iter_mut().for_each(|l| *l = 0);

        let mut route_idx = 0usize;
        for e in gp.edge_ids() {
            let w = gp.weight(e) as f64;
            let fires = if params.poisson_spikes {
                rng.poisson(w)
            } else {
                usize::from(rng.bernoulli(w.min(1.0)))
            };
            if fires == 0 {
                route_idx += gp.dsts(e).len();
                continue;
            }
            report.spikes += fires as u64;
            let src = placement.coords[gp.source(e) as usize];
            for &d in gp.dsts(e) {
                let dst = placement.coords[d as usize];
                let route = routes.as_ref().map(|r| &r[route_idx]);
                route_idx += 1;
                match route {
                    None | Some(Route::Xy) => {
                        report.copies += fires as u64;
                        // destination router always pays one routing event
                        router_load[hw.index(dst.0, dst.1)] += fires as u32;
                        report.energy += fires as f64 * costs.e_r;
                        let mut cur = src;
                        while cur != dst {
                            let (next, dir) = xy_step(cur, dst);
                            link_load[link_id(hw, cur.0, cur.1, dir)] += fires as u32;
                            router_load[hw.index(cur.0, cur.1)] += fires as u32;
                            report.energy += fires as f64 * (costs.e_r + costs.e_t);
                            report.hops += fires as u64;
                            cur = next;
                        }
                    }
                    Some(Route::Path(hops, extra)) => {
                        report.copies += fires as u64;
                        router_load[hw.index(dst.0, dst.1)] += fires as u32;
                        report.energy += fires as f64 * costs.e_r;
                        for &((cx, cy), dir) in hops {
                            link_load[link_id(hw, cx, cy, dir)] += fires as u32;
                            router_load[hw.index(cx, cy)] += fires as u32;
                            report.energy += fires as f64 * (costs.e_r + costs.e_t);
                            report.hops += fires as u64;
                        }
                        report.detour_hops += extra * fires as u64;
                    }
                    Some(Route::Drop) => {
                        report.dropped_spikes += fires as u64;
                    }
                }
            }
        }

        let peak_link = link_load.iter().cloned().max().unwrap_or(0);
        let peak_router = router_load.iter().cloned().max().unwrap_or(0);
        report.peak_router_load = report.peak_router_load.max(peak_router as u64);
        // makespan: hottest link serializes its flits, plus one router pass
        let makespan = peak_link as f64 * (costs.l_r + costs.l_t) + costs.l_r;
        makespans.push(makespan);
        report.mean_peak_link_load += peak_link as f64;
    }

    report.mean_peak_link_load /= params.timesteps.max(1) as f64;
    report.mean_makespan = makespans.iter().sum::<f64>() / makespans.len().max(1) as f64;
    report.max_makespan = makespans.iter().cloned().fold(0.0, f64::max);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::metrics::evaluate;

    fn line_mapping() -> (Hypergraph, Placement) {
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 0.8);
        (
            b.build(),
            Placement { coords: vec![(0, 0), (4, 0)] },
        )
    }

    #[test]
    fn deterministic_given_seed() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let a = simulate(&gp, &pl, &hw, SimParams::default());
        let b = simulate(&gp, &pl, &hw, SimParams::default());
        assert_eq!(a.spikes, b.spikes);
        assert_eq!(a.energy, b.energy);
    }

    #[test]
    fn energy_matches_analytic_expectation() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let analytic = evaluate(&gp, &pl, &hw);
        let sim = simulate(
            &gp,
            &pl,
            &hw,
            SimParams { timesteps: 20_000, seed: 7, poisson_spikes: true },
        );
        let per_step = sim.energy_per_step();
        let rel = (per_step - analytic.energy).abs() / analytic.energy;
        assert!(rel < 0.03, "sim {per_step} vs analytic {} (rel {rel})", analytic.energy);
    }

    #[test]
    fn hop_counts_follow_manhattan() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let sim = simulate(&gp, &pl, &hw, SimParams::default());
        // every copy walks exactly 4 hops
        assert_eq!(sim.hops, sim.copies * 4);
    }

    #[test]
    fn xy_routing_turns_once() {
        // (0,0) -> (2,3): 2 east then 3 north; verify router visits
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 50.0); // fires a lot
        let gp = b.build();
        let pl = Placement { coords: vec![(0, 0), (2, 3)] };
        let hw = NmhConfig::small();
        let sim =
            simulate(&gp, &pl, &hw, SimParams { timesteps: 2, seed: 1, poisson_spikes: true });
        assert_eq!(sim.hops, sim.copies * 5);
    }

    #[test]
    fn colocated_partitions_move_no_flits() {
        let mut b = HypergraphBuilder::new(1);
        b.add_edge(0, vec![0], 1.0);
        let gp = b.build();
        let pl = Placement { coords: vec![(3, 3)] };
        let hw = NmhConfig::small();
        let sim = simulate(&gp, &pl, &hw, SimParams::default());
        assert_eq!(sim.hops, 0);
        assert!(sim.copies > 0);
        // only router energy
        assert!((sim.energy - sim.copies as f64 * hw.costs.e_r).abs() < 1e-9);
    }

    #[test]
    fn healthy_mask_is_bit_identical_to_none() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let mask = FaultMask::healthy(&hw);
        let plain = simulate(&gp, &pl, &hw, SimParams::default());
        let masked = simulate_faulty(&gp, &pl, &hw, SimParams::default(), Some(&mask));
        assert_eq!(plain.spikes, masked.spikes);
        assert_eq!(plain.copies, masked.copies);
        assert_eq!(plain.hops, masked.hops);
        assert_eq!(plain.energy.to_bits(), masked.energy.to_bits());
        assert_eq!(plain.mean_makespan.to_bits(), masked.mean_makespan.to_bits());
        assert_eq!(plain.max_makespan.to_bits(), masked.max_makespan.to_bits());
        assert_eq!(plain.peak_router_load, masked.peak_router_load);
        assert_eq!(plain.mean_peak_link_load.to_bits(), masked.mean_peak_link_load.to_bits());
        assert_eq!(masked.dropped_spikes, 0);
        assert_eq!(masked.detour_hops, 0);
    }

    #[test]
    fn dead_link_forces_deterministic_detour() {
        // (0,0) -> (4,0): killing the east link out of (1,0) blocks both
        // XY and YX (same row), so every copy takes a minimal BFS detour
        // of 6 hops (Manhattan 4 + 2 extra).
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let mut mask = FaultMask::healthy(&hw);
        mask.kill_link(1, 0, 0); // E out of (1,0)
        let a = simulate_faulty(&gp, &pl, &hw, SimParams::default(), Some(&mask));
        assert!(a.copies > 0);
        assert_eq!(a.dropped_spikes, 0);
        assert_eq!(a.hops, a.copies * 6, "detour path length");
        assert_eq!(a.detour_hops, a.copies * 2, "excess over Manhattan");
        // detours are statically classified: rerun is bit-identical
        let b = simulate_faulty(&gp, &pl, &hw, SimParams::default(), Some(&mask));
        assert_eq!(a.hops, b.hops);
        assert_eq!(a.energy.to_bits(), b.energy.to_bits());
    }

    #[test]
    fn dead_destination_core_drops_all_copies() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let mut mask = FaultMask::healthy(&hw);
        mask.kill_core(4, 0);
        let sim = simulate_faulty(&gp, &pl, &hw, SimParams::default(), Some(&mask));
        assert!(sim.dropped_spikes > 0);
        assert_eq!(sim.copies, 0);
        assert_eq!(sim.hops, 0);
        assert_eq!(sim.energy, 0.0);
        // spike generation itself is unaffected (same RNG draw order)
        let plain = simulate(&gp, &pl, &hw, SimParams::default());
        assert_eq!(sim.spikes, plain.spikes);
    }

    #[test]
    fn makespan_scales_with_congestion() {
        // two flows sharing a corridor vs separated: shared is slower
        let hw = NmhConfig::small();
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1], 3.0);
        b.add_edge(2, vec![3], 3.0);
        let gp = b.build();
        let shared = Placement {
            coords: vec![(0, 0), (10, 0), (1, 0), (9, 0)], // same row corridor
        };
        let apart = Placement {
            coords: vec![(0, 0), (10, 0), (0, 20), (10, 20)],
        };
        let p = SimParams { timesteps: 300, seed: 5, poisson_spikes: true };
        let s_shared = simulate(&gp, &shared, &hw, p);
        let s_apart = simulate(&gp, &apart, &hw, p);
        assert!(
            s_shared.mean_makespan > s_apart.mean_makespan,
            "shared {} vs apart {}",
            s_shared.mean_makespan,
            s_apart.mean_makespan
        );
    }
}
