//! Discrete-timestep mesh NoC simulator with XY routing.

use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use crate::placement::Placement;
use crate::util::rng::Pcg64;

/// Simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    pub timesteps: usize,
    pub seed: u64,
    /// Spike count per h-edge per timestep ~ Poisson(w) so the expected
    /// traffic matches the analytic model exactly (w is a frequency, not
    /// a probability — biological rates exceed 1 spike/step in the tail).
    pub poisson_spikes: bool,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { timesteps: 100, seed: 99, poisson_spikes: true }
    }
}

/// Aggregated simulation results.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub timesteps: usize,
    /// Total spikes generated (axon firings).
    pub spikes: u64,
    /// Total inter/intra-core spike copies delivered.
    pub copies: u64,
    /// Total hop count across all copies.
    pub hops: u64,
    /// Total energy, pJ (per Table I per-copy pricing).
    pub energy: f64,
    /// Mean per-timestep makespan latency, ns (serialized hottest link).
    pub mean_makespan: f64,
    /// Worst per-timestep makespan, ns.
    pub max_makespan: f64,
    /// Peak router load (spike transits through a single core, one step).
    pub peak_router_load: u64,
    /// Mean (over timesteps) of the per-step max link load.
    pub mean_peak_link_load: f64,
}

impl SimReport {
    /// Energy per timestep — directly comparable to the analytic
    /// Table I energy expectation.
    pub fn energy_per_step(&self) -> f64 {
        self.energy / self.timesteps.max(1) as f64
    }
}

/// Directed mesh link id: 4 outgoing links per core (E, W, N, S).
#[inline]
fn link_id(hw: &NmhConfig, x: u16, y: u16, dir: usize) -> usize {
    hw.index(x, y) * 4 + dir
}

/// Route one hop of XY routing: move along x first, then y.
/// Returns (next coordinate, link direction).
#[inline]
fn xy_step(cur: (u16, u16), dst: (u16, u16)) -> ((u16, u16), usize) {
    if cur.0 != dst.0 {
        if dst.0 > cur.0 {
            ((cur.0 + 1, cur.1), 0) // E
        } else {
            ((cur.0 - 1, cur.1), 1) // W
        }
    } else if dst.1 > cur.1 {
        ((cur.0, cur.1 + 1), 2) // N (towards +y)
    } else {
        ((cur.0, cur.1 - 1), 3) // S
    }
}

/// Run the simulator over a mapped SNN.
///
/// `gp` is the quotient h-graph (one node per partition — its edges carry
/// the merged spike frequencies), `placement` its γ.
pub fn simulate(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    params: SimParams,
) -> SimReport {
    assert_eq!(gp.num_nodes(), placement.len());
    let costs = hw.costs;
    let mut rng = Pcg64::new(params.seed, 41);
    let mut report = SimReport {
        timesteps: params.timesteps,
        ..Default::default()
    };

    let num_links = hw.num_cores() * 4;
    let mut link_load = vec![0u32; num_links];
    let mut router_load = vec![0u32; hw.num_cores()];
    let mut makespans = Vec::with_capacity(params.timesteps);

    for _step in 0..params.timesteps {
        link_load.iter_mut().for_each(|l| *l = 0);
        router_load.iter_mut().for_each(|l| *l = 0);

        for e in gp.edge_ids() {
            let w = gp.weight(e) as f64;
            let fires = if params.poisson_spikes {
                rng.poisson(w)
            } else {
                usize::from(rng.bernoulli(w.min(1.0)))
            };
            if fires == 0 {
                continue;
            }
            report.spikes += fires as u64;
            let src = placement.coords[gp.source(e) as usize];
            for &d in gp.dsts(e) {
                let dst = placement.coords[d as usize];
                report.copies += fires as u64;
                // destination router always pays one routing event
                router_load[hw.index(dst.0, dst.1)] += fires as u32;
                report.energy += fires as f64 * costs.e_r;
                let mut cur = src;
                while cur != dst {
                    let (next, dir) = xy_step(cur, dst);
                    link_load[link_id(hw, cur.0, cur.1, dir)] += fires as u32;
                    router_load[hw.index(cur.0, cur.1)] += fires as u32;
                    report.energy += fires as f64 * (costs.e_r + costs.e_t);
                    report.hops += fires as u64;
                    cur = next;
                }
            }
        }

        let peak_link = link_load.iter().cloned().max().unwrap_or(0);
        let peak_router = router_load.iter().cloned().max().unwrap_or(0);
        report.peak_router_load = report.peak_router_load.max(peak_router as u64);
        // makespan: hottest link serializes its flits, plus one router pass
        let makespan = peak_link as f64 * (costs.l_r + costs.l_t) + costs.l_r;
        makespans.push(makespan);
        report.mean_peak_link_load += peak_link as f64;
    }

    report.mean_peak_link_load /= params.timesteps.max(1) as f64;
    report.mean_makespan = makespans.iter().sum::<f64>() / makespans.len().max(1) as f64;
    report.max_makespan = makespans.iter().cloned().fold(0.0, f64::max);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::metrics::evaluate;

    fn line_mapping() -> (Hypergraph, Placement) {
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 0.8);
        (
            b.build(),
            Placement { coords: vec![(0, 0), (4, 0)] },
        )
    }

    #[test]
    fn deterministic_given_seed() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let a = simulate(&gp, &pl, &hw, SimParams::default());
        let b = simulate(&gp, &pl, &hw, SimParams::default());
        assert_eq!(a.spikes, b.spikes);
        assert_eq!(a.energy, b.energy);
    }

    #[test]
    fn energy_matches_analytic_expectation() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let analytic = evaluate(&gp, &pl, &hw);
        let sim = simulate(
            &gp,
            &pl,
            &hw,
            SimParams { timesteps: 20_000, seed: 7, poisson_spikes: true },
        );
        let per_step = sim.energy_per_step();
        let rel = (per_step - analytic.energy).abs() / analytic.energy;
        assert!(rel < 0.03, "sim {per_step} vs analytic {} (rel {rel})", analytic.energy);
    }

    #[test]
    fn hop_counts_follow_manhattan() {
        let (gp, pl) = line_mapping();
        let hw = NmhConfig::small();
        let sim = simulate(&gp, &pl, &hw, SimParams::default());
        // every copy walks exactly 4 hops
        assert_eq!(sim.hops, sim.copies * 4);
    }

    #[test]
    fn xy_routing_turns_once() {
        // (0,0) -> (2,3): 2 east then 3 north; verify router visits
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 50.0); // fires a lot
        let gp = b.build();
        let pl = Placement { coords: vec![(0, 0), (2, 3)] };
        let hw = NmhConfig::small();
        let sim =
            simulate(&gp, &pl, &hw, SimParams { timesteps: 2, seed: 1, poisson_spikes: true });
        assert_eq!(sim.hops, sim.copies * 5);
    }

    #[test]
    fn colocated_partitions_move_no_flits() {
        let mut b = HypergraphBuilder::new(1);
        b.add_edge(0, vec![0], 1.0);
        let gp = b.build();
        let pl = Placement { coords: vec![(3, 3)] };
        let hw = NmhConfig::small();
        let sim = simulate(&gp, &pl, &hw, SimParams::default());
        assert_eq!(sim.hops, 0);
        assert!(sim.copies > 0);
        // only router energy
        assert!((sim.energy - sim.copies as f64 * hw.costs.e_r).abs() < 1e-9);
    }

    #[test]
    fn makespan_scales_with_congestion() {
        // two flows sharing a corridor vs separated: shared is slower
        let hw = NmhConfig::small();
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1], 3.0);
        b.add_edge(2, vec![3], 3.0);
        let gp = b.build();
        let shared = Placement {
            coords: vec![(0, 0), (10, 0), (1, 0), (9, 0)], // same row corridor
        };
        let apart = Placement {
            coords: vec![(0, 0), (10, 0), (0, 20), (10, 20)],
        };
        let p = SimParams { timesteps: 300, seed: 5, poisson_spikes: true };
        let s_shared = simulate(&gp, &shared, &hw, p);
        let s_apart = simulate(&gp, &apart, &hw, p);
        assert!(
            s_shared.mean_makespan > s_apart.mean_makespan,
            "shared {} vs apart {}",
            s_shared.mean_makespan,
            s_apart.mean_makespan
        );
    }
}
