//! `snn_lint` — run the repo's invariant lint (DESIGN.md §14) over the
//! crate tree and exit nonzero on unwaived findings.
//!
//! Usage: `cargo run --release --bin snn_lint [-- --root <crate-dir>]`
//!
//! The root defaults to `CARGO_MANIFEST_DIR` (set by cargo), falling
//! back to the current directory, so both `cargo run` and a bare binary
//! invocation from `rust/` work. Exit codes: 0 clean, 1 unwaived
//! findings, 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root: Option<PathBuf> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(p) => root = Some(PathBuf::from(p)),
                    None => {
                        eprintln!("snn_lint: --root expects a path");
                        return ExitCode::from(2);
                    }
                }
            }
            other => {
                eprintln!("snn_lint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
        i += 1;
    }
    let root = root
        .or_else(|| std::env::var_os("CARGO_MANIFEST_DIR").map(PathBuf::from))
        .unwrap_or_else(|| PathBuf::from("."));

    match snnmap::lint::lint_tree(&root) {
        Ok(report) => {
            print!("{}", report.render());
            if report.is_clean() {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("snn_lint: {e}");
            ExitCode::from(2)
        }
    }
}
