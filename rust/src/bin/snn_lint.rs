//! `snn_lint` — run the repo's invariant lint (DESIGN.md §14) over the
//! crate tree and exit nonzero when the gate fails.
//!
//! Usage: `cargo run --release --bin snn_lint [-- --root <crate-dir>]
//!         [--format text|json|sarif]`
//!
//! The root defaults to `CARGO_MANIFEST_DIR` (set by cargo), falling
//! back to the current directory, so both `cargo run` and a bare binary
//! invocation from `rust/` work. `--format sarif` emits a SARIF 2.1.0
//! log (for CI artifact upload / code-scanning ingestion), `--format
//! json` a compact machine-readable report; both still gate. Exit
//! codes: 0 gate passes, 1 unwaived findings or unused waivers, 2 usage
//! or I/O error. Unused waivers are hard errors: a stale waiver is a
//! standing invitation to reintroduce the violation it once covered.

use std::path::PathBuf;
use std::process::ExitCode;

enum Format {
    Text,
    Json,
    Sarif,
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root: Option<PathBuf> = None;
    let mut format = Format::Text;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(p) => root = Some(PathBuf::from(p)),
                    None => {
                        eprintln!("snn_lint: --root expects a path");
                        return ExitCode::from(2);
                    }
                }
            }
            "--format" => {
                i += 1;
                match args.get(i).map(|s| s.as_str()) {
                    Some("text") => format = Format::Text,
                    Some("json") => format = Format::Json,
                    Some("sarif") => format = Format::Sarif,
                    Some(other) => {
                        eprintln!("snn_lint: unknown format `{other}` (text|json|sarif)");
                        return ExitCode::from(2);
                    }
                    None => {
                        eprintln!("snn_lint: --format expects text|json|sarif");
                        return ExitCode::from(2);
                    }
                }
            }
            other => {
                eprintln!("snn_lint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
        i += 1;
    }
    let root = root
        .or_else(|| std::env::var_os("CARGO_MANIFEST_DIR").map(PathBuf::from))
        .unwrap_or_else(|| PathBuf::from("."));

    match snnmap::lint::lint_tree(&root) {
        Ok(report) => {
            match format {
                Format::Text => print!("{}", report.render()),
                Format::Json => {
                    println!("{}", snnmap::lint::sarif::to_json(&report).to_pretty())
                }
                Format::Sarif => {
                    println!("{}", snnmap::lint::sarif::to_sarif(&report).to_pretty())
                }
            }
            if report.gate_ok() {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("snn_lint: {e}");
            ExitCode::from(2)
        }
    }
}
