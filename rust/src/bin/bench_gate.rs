//! CI bench-regression gate for the BENCH trajectory (DESIGN.md §8).
//!
//! Validates a freshly measured `hotpath --json` document against the
//! committed `BENCH_hotpath.json` baseline:
//!
//! 1. **Schema** — every kernel row and metric key the baseline declares
//!    must be present in the measured document with a finite numeric
//!    value (so renamed/dropped kernels fail loudly instead of silently
//!    leaving the trajectory empty). Extra measured rows are reported as
//!    new, never fatal.
//! 2. **Regression** — wherever the baseline value is non-null, the
//!    measured value must not regress by more than the tolerance
//!    (default 25%, the CI bound; DESIGN.md §8's 20% is the human
//!    review bound). Rate-like metrics (`*_per_s`) gate downward,
//!    time/space-like metrics (`secs_per_iter`, `memory_bytes`) gate
//!    upward; count-like metrics (`sweeps`, `n`, ...) are
//!    informational. Null baselines are reported as *ungated* — with
//!    today's all-null trajectory the gate passes while printing every
//!    row it is not yet guarding.
//!
//! Usage (CI runs this from `rust/`):
//!
//!     cargo run --release --bin bench_gate -- \
//!         --measured bench_out.json --baseline ../BENCH_hotpath.json
//!
//! Exit code 0 = pass, 1 = schema or regression failure.

use snnmap::util::cli::Args;
use snnmap::util::json::Json;

/// Relative regression tolerance (0.25 = fail beyond 25%).
const DEFAULT_TOLERANCE: f64 = 0.25;

/// Every kernel row `benches/hotpath.rs` must emit — the committed
/// `BENCH_hotpath.json` schema minus host-optional rows (spectral_pjrt).
/// The bench-smoke job fails when any of these is missing from the
/// committed baseline *or* from the measured artifact, so a silently
/// dropped schema row can never shrink the trajectory.
const EXPECTED_ROWS: &[&str] = &[
    "force_refine_parallel",
    "force_refine_serial",
    "force_refinement",
    "greedy_order_parallel",
    "greedy_order_serial",
    "greedy_ordering",
    "hier_coarsen_parallel",
    "hier_coarsen_serial",
    "hier_end2end_parallel",
    "hier_end2end_serial",
    "hier_refine_parallel",
    "hier_refine_serial",
    "metrics_evaluate_parallel",
    "metrics_evaluate_serial",
    "overlap_grow_parallel",
    "overlap_grow_serial",
    "overlap_partition",
    "quotient_push_forward",
    "quotient_push_parallel",
    "quotient_push_serial",
    "sequential_ordered",
    "sim_batch",
    "sim_step_parallel",
    "sim_step_serial",
    "spectral_native",
    "spectral_placement",
];

/// Gating direction of one metric key.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Direction {
    /// Throughput: regression = measured falls below baseline.
    HigherIsBetter,
    /// Time or space: regression = measured rises above baseline.
    LowerIsBetter,
    /// Descriptive (sweep counts, problem sizes): never gated.
    Informational,
}

fn direction_of(metric: &str) -> Direction {
    if metric.ends_with("_per_s") {
        Direction::HigherIsBetter
    } else if metric == "secs_per_iter" || metric == "memory_bytes" {
        Direction::LowerIsBetter
    } else {
        Direction::Informational
    }
}

/// Outcome of gating one (kernel, metric) cell.
#[derive(Debug)]
enum Cell {
    /// Baseline null: nothing to gate yet.
    Ungated { kernel: String, metric: String },
    /// Gated and within tolerance.
    Ok,
    /// Gated and out of tolerance.
    Regressed {
        kernel: String,
        metric: String,
        baseline: f64,
        measured: f64,
    },
    Informational,
}

/// Run the full gate. `Ok(report)` = pass (the report lists ungated
/// rows); `Err(failures)` = schema violations and/or regressions.
/// `required` rows (normally [`EXPECTED_ROWS`]; tests pass their own)
/// must be present in both documents.
fn gate(
    measured: &Json,
    baseline: &Json,
    tolerance: f64,
    required: &[&str],
) -> Result<Vec<String>, Vec<String>> {
    let mut failures: Vec<String> = Vec::new();
    let mut report: Vec<String> = Vec::new();

    if let Some(name) = baseline.get("bench").as_str() {
        if measured.get("bench").as_str() != Some(name) {
            failures.push(format!(
                "schema: measured 'bench' is {:?}, baseline expects {name:?}",
                measured.get("bench").as_str()
            ));
        }
    }
    // Scale must match once the baseline records one: cross-scale
    // throughput comparisons are meaningless.
    if let Some(scale) = baseline.get("scale").as_f64() {
        match measured.get("scale").as_f64() {
            Some(m) if (m - scale).abs() < 1e-12 => {}
            other => failures.push(format!(
                "schema: measured scale {other:?} != baseline scale {scale}"
            )),
        }
    }

    let base_kernels = match baseline.get("kernels").as_obj() {
        Some(m) => m,
        None => {
            failures.push("schema: baseline has no 'kernels' object".into());
            return Err(failures);
        }
    };
    let meas_kernels = match measured.get("kernels").as_obj() {
        Some(m) => m,
        None => {
            failures.push("schema: measured document has no 'kernels' object".into());
            return Err(failures);
        }
    };

    // Row presence: a committed schema row must exist in both documents.
    // Rows the baseline declares are presence-checked against the
    // measured run by the loop below; rows missing from the baseline
    // itself are reported here (with the measured side too, since the
    // baseline loop can no longer see them).
    for &row in required {
        if !base_kernels.contains_key(row) {
            failures.push(format!(
                "schema: expected row '{row}' missing from the committed baseline"
            ));
            if !meas_kernels.contains_key(row) {
                failures.push(format!("schema: kernel '{row}' missing from measured run"));
            }
        }
    }

    let mut ungated = 0usize;
    let mut gated = 0usize;
    for (kernel, base_row) in base_kernels {
        // The optional PJRT row only appears when artifacts exist on the
        // measuring host; it never blocks the gate.
        let optional = kernel == "spectral_pjrt";
        let meas_row = match meas_kernels.get(kernel) {
            Some(r) => r,
            None if optional => continue,
            None => {
                failures.push(format!("schema: kernel '{kernel}' missing from measured run"));
                continue;
            }
        };
        let base_metrics = match base_row.as_obj() {
            Some(m) => m,
            None => {
                failures.push(format!("schema: baseline kernel '{kernel}' is not an object"));
                continue;
            }
        };
        for (metric, base_val) in base_metrics {
            let meas_val = meas_row.get(metric).as_f64();
            let meas_val = match meas_val {
                Some(v) if v.is_finite() => v,
                _ => {
                    failures.push(format!(
                        "schema: '{kernel}.{metric}' missing or non-numeric in measured run"
                    ));
                    continue;
                }
            };
            match check_cell(kernel, metric, base_val, meas_val, tolerance) {
                Cell::Ungated { kernel, metric } => {
                    ungated += 1;
                    report.push(format!("ungated (null baseline): {kernel}.{metric}"));
                }
                Cell::Ok => gated += 1,
                Cell::Regressed { kernel, metric, baseline, measured } => {
                    gated += 1;
                    failures.push(format!(
                        "regression: {kernel}.{metric} measured {measured:.6e} vs baseline \
                         {baseline:.6e} (tolerance {:.0}%)",
                        tolerance * 100.0
                    ));
                }
                Cell::Informational => {}
            }
        }
    }
    for kernel in meas_kernels.keys() {
        if !base_kernels.contains_key(kernel) {
            report.push(format!("new kernel (not in baseline): {kernel}"));
        }
    }
    report.push(format!("{gated} cells gated, {ungated} ungated"));

    if failures.is_empty() {
        Ok(report)
    } else {
        Err(failures)
    }
}

fn check_cell(kernel: &str, metric: &str, base: &Json, measured: f64, tol: f64) -> Cell {
    let dir = direction_of(metric);
    if dir == Direction::Informational {
        return Cell::Informational;
    }
    let base = match base.as_f64() {
        None => {
            // Json::Null (or a non-number, which the emitter never
            // writes): the trajectory has no baseline here yet.
            return Cell::Ungated { kernel: kernel.into(), metric: metric.into() };
        }
        Some(b) => b,
    };
    let regressed = match dir {
        Direction::HigherIsBetter => measured < base * (1.0 - tol),
        Direction::LowerIsBetter => measured > base * (1.0 + tol),
        Direction::Informational => false,
    };
    if regressed {
        Cell::Regressed {
            kernel: kernel.into(),
            metric: metric.into(),
            baseline: base,
            measured,
        }
    } else {
        Cell::Ok
    }
}

fn load(path: &str) -> Json {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("bench_gate: cannot read {path}: {e}");
        std::process::exit(1);
    });
    Json::parse(&text).unwrap_or_else(|e| {
        eprintln!("bench_gate: {path} is not valid JSON: {e}");
        std::process::exit(1);
    })
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]);
    let measured_path = args.get("measured").unwrap_or_else(|| {
        eprintln!(
            "usage: bench_gate --measured <run.json> --baseline <BENCH_hotpath.json> \
             [--tolerance 0.25]"
        );
        std::process::exit(1);
    });
    let baseline_path = args.get_or("baseline", "../BENCH_hotpath.json");
    let tolerance = args
        .get("tolerance")
        .map(|t| {
            t.parse::<f64>()
                .unwrap_or_else(|_| panic!("--tolerance expects a number, got '{t}'"))
        })
        .unwrap_or(DEFAULT_TOLERANCE);

    let measured = load(measured_path);
    let baseline = load(baseline_path);
    match gate(&measured, &baseline, tolerance, EXPECTED_ROWS) {
        Ok(report) => {
            for line in &report {
                println!("bench_gate: {line}");
            }
            println!("bench_gate: PASS ({measured_path} vs {baseline_path})");
        }
        Err(failures) => {
            for line in &failures {
                eprintln!("bench_gate: {line}");
            }
            eprintln!("bench_gate: FAIL ({} problem(s))", failures.len());
            std::process::exit(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(pairs: Vec<(&str, Json)>) -> Json {
        Json::obj(pairs)
    }

    fn doc(scale: Json, kernels: Vec<(&str, Json)>) -> Json {
        Json::obj(vec![
            ("bench", Json::Str("hotpath".into())),
            ("scale", scale),
            ("kernels", Json::obj(kernels)),
        ])
    }

    #[test]
    fn null_baseline_passes_and_reports_ungated() {
        let base = doc(
            Json::Null,
            vec![(
                "overlap_partition",
                row(vec![("secs_per_iter", Json::Null), ("conn_per_s", Json::Null)]),
            )],
        );
        let meas = doc(
            Json::Num(0.12),
            vec![(
                "overlap_partition",
                row(vec![("secs_per_iter", Json::Num(0.5)), ("conn_per_s", Json::Num(1e7))]),
            )],
        );
        let report = gate(&meas, &base, 0.25, &[]).expect("null baselines must pass");
        assert!(report.iter().any(|l| l.contains("ungated") && l.contains("conn_per_s")));
    }

    #[test]
    fn throughput_regression_fails_and_improvement_passes() {
        let base = doc(
            Json::Num(0.12),
            vec![("k", row(vec![("conn_per_s", Json::Num(1e7))]))],
        );
        let slow = doc(
            Json::Num(0.12),
            vec![("k", row(vec![("conn_per_s", Json::Num(7.0e6))]))],
        );
        let errs = gate(&slow, &base, 0.25, &[]).unwrap_err();
        assert!(errs.iter().any(|l| l.contains("regression: k.conn_per_s")));
        // within tolerance
        let ok = doc(
            Json::Num(0.12),
            vec![("k", row(vec![("conn_per_s", Json::Num(7.6e6))]))],
        );
        assert!(gate(&ok, &base, 0.25, &[]).is_ok());
        // faster is never a regression
        let fast = doc(
            Json::Num(0.12),
            vec![("k", row(vec![("conn_per_s", Json::Num(5e7))]))],
        );
        assert!(gate(&fast, &base, 0.25, &[]).is_ok());
    }

    #[test]
    fn time_and_memory_gate_upward() {
        let base = doc(
            Json::Num(0.12),
            vec![(
                "k",
                row(vec![("secs_per_iter", Json::Num(1.0)), ("memory_bytes", Json::Num(1e6))]),
            )],
        );
        let bloated = doc(
            Json::Num(0.12),
            vec![(
                "k",
                row(vec![("secs_per_iter", Json::Num(1.1)), ("memory_bytes", Json::Num(2e6))]),
            )],
        );
        let errs = gate(&bloated, &base, 0.25, &[]).unwrap_err();
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(errs[0].contains("k.memory_bytes"));
    }

    #[test]
    fn missing_kernel_or_metric_is_schema_failure() {
        let base = doc(
            Json::Num(0.12),
            vec![("k", row(vec![("conn_per_s", Json::Null)]))],
        );
        let empty = doc(Json::Num(0.12), vec![]);
        let errs = gate(&empty, &base, 0.25, &[]).unwrap_err();
        assert!(errs.iter().any(|l| l.contains("kernel 'k' missing")));
        let wrong_metric = doc(
            Json::Num(0.12),
            vec![("k", row(vec![("synapse_visits_per_s", Json::Num(1.0))]))],
        );
        let errs = gate(&wrong_metric, &base, 0.25, &[]).unwrap_err();
        assert!(errs.iter().any(|l| l.contains("'k.conn_per_s' missing")));
    }

    #[test]
    fn informational_metrics_and_new_kernels_never_fail() {
        let base = doc(
            Json::Num(0.12),
            vec![("k", row(vec![("sweeps", Json::Num(100.0)), ("n", Json::Num(64.0))]))],
        );
        let meas = doc(
            Json::Num(0.12),
            vec![
                ("k", row(vec![("sweeps", Json::Num(900.0)), ("n", Json::Num(1.0))])),
                ("brand_new", row(vec![("conn_per_s", Json::Num(1.0))])),
            ],
        );
        let report = gate(&meas, &base, 0.25, &[]).expect("informational cells must not gate");
        assert!(report.iter().any(|l| l.contains("new kernel") && l.contains("brand_new")));
    }

    #[test]
    fn scale_mismatch_fails_once_baseline_records_one() {
        let base = doc(
            Json::Num(0.12),
            vec![("k", row(vec![("conn_per_s", Json::Null)]))],
        );
        let meas = doc(
            Json::Num(0.06),
            vec![("k", row(vec![("conn_per_s", Json::Num(1.0))]))],
        );
        let errs = gate(&meas, &base, 0.25, &[]).unwrap_err();
        assert!(errs.iter().any(|l| l.contains("scale")));
        // null baseline scale: any measured scale accepted
        let base_null = doc(Json::Null, vec![("k", row(vec![("conn_per_s", Json::Null)]))]);
        assert!(gate(&meas, &base_null, 0.25, &[]).is_ok());
    }

    #[test]
    fn missing_optional_pjrt_row_is_fine() {
        let base = doc(
            Json::Num(0.12),
            vec![("spectral_pjrt", row(vec![("secs_per_iter", Json::Null)]))],
        );
        let meas = doc(Json::Num(0.12), vec![]);
        assert!(gate(&meas, &base, 0.25, &[]).is_ok());
    }

    #[test]
    fn required_row_missing_from_baseline_or_artifact_fails() {
        // a committed schema row absent from the baseline is a schema
        // failure (and is also reported against the artifact when absent
        // there), so trajectory rows can never be dropped silently
        let base = doc(
            Json::Num(0.12),
            vec![("quotient_push_serial", row(vec![("conn_per_s", Json::Null)]))],
        );
        let meas = doc(
            Json::Num(0.12),
            vec![("quotient_push_serial", row(vec![("conn_per_s", Json::Num(1.0))]))],
        );
        let required = &["quotient_push_serial", "quotient_push_parallel"];
        let errs = gate(&meas, &base, 0.25, required).unwrap_err();
        assert!(
            errs.iter().any(|l| l.contains("'quotient_push_parallel'")
                && l.contains("committed baseline")),
            "{errs:?}"
        );
        assert!(
            errs.iter()
                .any(|l| l.contains("'quotient_push_parallel'") && l.contains("measured run")),
            "{errs:?}"
        );
        // present in both -> passes
        let base_ok = doc(
            Json::Num(0.12),
            vec![("quotient_push_serial", row(vec![("conn_per_s", Json::Null)]))],
        );
        assert!(gate(&meas, &base_ok, 0.25, &["quotient_push_serial"]).is_ok());
    }

    #[test]
    fn committed_baseline_declares_every_expected_row() {
        // the committed trajectory file itself must carry the full
        // expected-row schema (including the PR-5 two-phase rows) — this
        // is the row-presence check the bench-smoke job relies on
        let baseline = Json::parse(include_str!("../../../BENCH_hotpath.json"))
            .expect("committed BENCH_hotpath.json must parse");
        let kernels = baseline.get("kernels").as_obj().expect("kernels object");
        for &row in EXPECTED_ROWS {
            assert!(kernels.contains_key(row), "BENCH_hotpath.json lost row '{row}'");
        }
    }
}
