//! Tiny declarative CLI argument parser (clap is not in the offline
//! registry). Supports `--flag`, `--key value`, `--key=value` and
//! positional arguments, with typed accessors and auto-generated usage.

use std::collections::BTreeMap;

/// Parsed command line: positionals + options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse a raw token stream. `flag_names` lists options that take no
    /// value (everything else consumes the following token unless given
    /// as `--key=value`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(body.to_string());
                    } else {
                        // snn-lint: allow(unwrap-ban) — peek() returned Some on this
                        // iterator, so next() is Some
                        out.options.insert(body.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                // snn-lint: allow(unwrap-ban) — CLI argument validation: aborting with a
                // message is the contract for malformed invocations
                v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            // snn-lint: allow(unwrap-ban) — CLI argument validation: aborting with a
            // message is the contract for malformed invocations
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| {
                // snn-lint: allow(unwrap-ban) — CLI argument validation: aborting with a
                // message is the contract for malformed invocations
                v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let a =
            Args::parse(toks("map --network lenet --scale=0.5 --verbose out.json"), &["verbose"]);
        assert_eq!(a.positional, vec!["map", "out.json"]);
        assert_eq!(a.get("network"), Some("lenet"));
        assert_eq!(a.get_f64("scale", 1.0), 0.5);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_and_typed_access() {
        let a = Args::parse(toks("--n 42"), &[]);
        assert_eq!(a.get_usize("n", 0), 42);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("missing", "x"), "x");
    }

    #[test]
    fn flag_followed_by_option_detected() {
        let a = Args::parse(toks("--quiet --seed 9"), &[]);
        assert!(a.has_flag("quiet"));
        assert_eq!(a.get_u64("seed", 0), 9);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_int_panics() {
        let a = Args::parse(toks("--n abc"), &[]);
        a.get_usize("n", 0);
    }
}
