//! Deterministic scoped-thread parallelism for the mapping hot paths.
//!
//! The offline registry ships no rayon, so this module is the crate's
//! stand-in: a work-stealing indexed map over `std::thread::scope` plus a
//! chunked fold with an *ordered* reduction. Two properties matter more
//! than raw speed here and are load-bearing for the metric engine:
//!
//! 1. **Placement determinism** — [`par_map`] writes each job's result
//!    into its own index slot, so output order never depends on thread
//!    scheduling.
//! 2. **Reduction determinism** — [`chunked_fold`] splits `0..n` into
//!    *fixed-size* chunks (independent of the worker count) and merges the
//!    per-chunk accumulators in ascending chunk order. The floating-point
//!    merge tree is therefore identical whether 1 or 64 workers execute
//!    the chunks, which is what lets `metrics::evaluate` promise
//!    bit-for-bit `parallel == serial` (see DESIGN.md §6-§7).
//!
//! Worker count resolution: explicit argument > `set_max_threads` >
//! `SNNMAP_THREADS` env var > `available_parallelism()`.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// Process-wide override installed by [`set_max_threads`]; 0 = unset.
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Pin the default worker count for all subsequent parallel calls
/// (coordinator config and tests). `0` restores auto-detection.
// snn-lint: allow(parallel-serial-pairing) — pool-size accessor, not a parallel algorithm;
// the `_threads` suffix names the quantity, there is no serial counterpart to pair
pub fn set_max_threads(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

/// Default worker count: override > `SNNMAP_THREADS` > hardware threads.
// snn-lint: allow(parallel-serial-pairing) — pool-size accessor, not a parallel algorithm;
// the `_threads` suffix names the quantity, there is no serial counterpart to pair
pub fn max_threads() -> usize {
    let o = OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    static DETECTED: OnceLock<usize> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        std::env::var("SNNMAP_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Fixed chunk size for an `n`-element two-phase propose sweep: the
/// smallest chunk that covers `0..n` with at most `threads` chunks.
///
/// Every two-phase stage (hierarchical coarsen/refine, force candidate
/// scan, overlap frontier scoring) derives its [`par_chunks_mut`] chunk
/// from this one expression so the chunk structure — and therefore any
/// per-chunk work — is a pure function of `(n, threads)`, never of
/// scheduling.
#[inline]
pub fn fixed_chunk(n: usize, threads: usize) -> usize {
    crate::util::div_ceil(n, threads.max(1)).max(1)
}

/// Parallel indexed map: evaluates `f(0..n)` on up to `threads` workers
/// (an atomic cursor hands out jobs) and returns the results in index
/// order regardless of completion order. `threads <= 1` runs inline.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.min(n).max(1);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let next = AtomicUsize::new(0);
    let slots = Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // snn-lint: allow(shared-mut-in-propose) — scheduler contract: the shared
                // atomic only hands out work indices; each claimed `i` is unique, results
                // land in index-disjoint slots, so commit order never depends on workers
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i); // compute outside the lock
                // snn-lint: allow(unwrap-ban) — mutex poisoning only follows a panic in a
                // worker; propagating it as a panic is the intended failure mode
                slots.lock().unwrap()[i] = Some(v);
            });
        }
    });
    out.into_iter()
        // snn-lint: allow(unwrap-ban) — every index < n is claimed exactly once via
        // fetch_add, so each slot is written before the scope joins
        .map(|v| v.expect("par_map worker filled every slot"))
        .collect()
}

/// Chunked parallel fold with an ordered reduction.
///
/// `0..n` is split into fixed chunks of `chunk` indices — the chunk
/// structure does NOT depend on `threads` — each folded by `fold`, then
/// the per-chunk accumulators are merged left-to-right in chunk order.
/// Returns `None` for `n == 0`.
pub fn chunked_fold<A, Fold, Merge>(
    n: usize,
    chunk: usize,
    threads: usize,
    fold: Fold,
    merge: Merge,
) -> Option<A>
where
    A: Send,
    Fold: Fn(Range<usize>) -> A + Sync,
    Merge: FnMut(A, A) -> A,
{
    if n == 0 {
        return None;
    }
    let chunk = chunk.max(1);
    let chunks = crate::util::div_ceil(n, chunk);
    let parts = par_map(chunks, threads, |c| {
        let lo = c * chunk;
        fold(lo..(lo + chunk).min(n))
    });
    parts.into_iter().reduce(merge)
}

/// Parallel mutable-chunk sweep: splits `data` into *fixed-size* chunks
/// and calls `f(chunk_index, chunk)` for each, distributing chunks over
/// up to `threads` workers with dynamic scheduling (a mutex-guarded
/// `chunks_mut` iterator hands out disjoint slices — no unsafe).
///
/// Each element is written by exactly one invocation, so as long as `f`
/// computes chunk contents independently of scheduling (the contract all
/// callers in this crate obey), the result is bit-for-bit identical for
/// any worker count. `threads <= 1` runs inline.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let chunk = chunk.max(1);
    let n_chunks = crate::util::div_ceil(data.len(), chunk);
    let threads = threads.min(n_chunks).max(1);
    if threads <= 1 {
        for (i, s) in data.chunks_mut(chunk).enumerate() {
            f(i, s);
        }
        return;
    }
    let jobs = Mutex::new(data.chunks_mut(chunk).enumerate());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // snn-lint: allow(unwrap-ban) — mutex poisoning only follows a panic in a
                // worker; propagating it as a panic is the intended failure mode
                // snn-lint: allow(shared-mut-in-propose) — scheduler contract: the jobs
                // iterator under the mutex only hands out disjoint (chunk id, &mut slice)
                // pairs; all result state is written through those disjoint slices
                let next = jobs.lock().unwrap().next();
                match next {
                    Some((i, s)) => f(i, s),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_index_order() {
        for threads in [1, 2, 7] {
            let out = par_map(100, threads, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        assert_eq!(par_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, 4, |i| i + 10), vec![10]);
    }

    #[test]
    fn chunked_fold_matches_serial_sum() {
        let xs: Vec<u64> = (0..1000).map(|i| i * 3 + 1).collect();
        let serial: u64 = xs.iter().sum();
        for threads in [1, 3, 8] {
            let total = chunked_fold(
                xs.len(),
                64,
                threads,
                |r| xs[r].iter().sum::<u64>(),
                |a, b| a + b,
            )
            .unwrap();
            assert_eq!(total, serial);
        }
    }

    #[test]
    fn chunked_fold_float_merge_tree_is_thread_invariant() {
        // adversarial magnitudes: a naive reduction in completion order
        // would give run-dependent rounding; fixed chunks + ordered merge
        // must be bit-identical across worker counts
        let xs: Vec<f64> = (0..4096)
            .map(|i| if i % 3 == 0 { 1e16 } else { 1.0 + i as f64 * 1e-3 })
            .collect();
        let fold = |r: std::ops::Range<usize>| xs[r].iter().sum::<f64>();
        let one = chunked_fold(xs.len(), 128, 1, fold, |a, b| a + b).unwrap();
        for threads in [2, 5, 16] {
            let many = chunked_fold(xs.len(), 128, threads, fold, |a, b| a + b).unwrap();
            assert_eq!(one.to_bits(), many.to_bits());
        }
    }

    #[test]
    fn chunked_fold_empty_is_none() {
        assert!(chunked_fold(0, 8, 4, |_| 0u32, |a, b| a + b).is_none());
    }

    #[test]
    fn par_chunks_mut_writes_every_slot_once() {
        for threads in [1, 2, 7] {
            let mut data = vec![0usize; 103];
            par_chunks_mut(&mut data, 8, threads, |ci, s| {
                for (k, v) in s.iter_mut().enumerate() {
                    *v = ci * 8 + k + 1;
                }
            });
            let want: Vec<usize> = (1..=103).collect();
            assert_eq!(data, want, "threads={threads}");
        }
    }

    #[test]
    fn par_chunks_mut_empty_and_tiny() {
        let mut empty: Vec<u32> = vec![];
        par_chunks_mut(&mut empty, 4, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![0u32];
        par_chunks_mut(&mut one, 4, 4, |ci, s| {
            assert_eq!(ci, 0);
            s[0] = 9;
        });
        assert_eq!(one, vec![9]);
    }

    #[test]
    fn fixed_chunk_covers_with_at_most_threads_chunks() {
        for n in [1usize, 7, 103, 512] {
            for threads in [1usize, 2, 5, 16] {
                let c = fixed_chunk(n, threads);
                let chunks = crate::util::div_ceil(n, c);
                assert!(chunks <= threads.max(1), "n={n} threads={threads}");
                assert!(c * chunks >= n, "n={n} threads={threads}");
            }
        }
        assert_eq!(fixed_chunk(10, 0), 10); // zero workers clamps to one chunk
    }

    #[test]
    fn ragged_tail_chunk_covered() {
        // n not divisible by chunk: the tail range must still be folded
        let hits = chunked_fold(10, 4, 2, |r| r.len(), |a, b| a + b).unwrap();
        assert_eq!(hits, 10);
    }
}
