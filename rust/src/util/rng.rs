//! Deterministic PRNG + sampling distributions.
//!
//! PCG64 (O'Neill's PCG-XSL-RR 128/64) — small state, excellent statistical
//! quality, fully reproducible across platforms. Distributions implemented
//! on top: uniform, normal (Box–Muller), log-normal parameterized the way
//! the paper specifies spike frequencies (median + coefficient of
//! variation), Poisson (Knuth / PTRS for large mean), exponential, and
//! weighted index sampling.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// Raw generator state, split into u64 words so it can cross a
/// serialization boundary (the `SNNCK1` checkpoint format,
/// `runtime/checkpoint.rs`) without a u128 wire type. Restoring a
/// snapshot continues the exact output stream, Box–Muller spare
/// included.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pcg64State {
    pub state_hi: u64,
    pub state_lo: u64,
    pub inc_hi: u64,
    pub inc_lo: u64,
    pub spare_normal: Option<f64>,
}

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            spare_normal: None,
        };
        rng.state = rng.inc.wrapping_add(seed as u128);
        rng.next_u64();
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Snapshot the full generator state for checkpointing.
    pub fn state(&self) -> Pcg64State {
        Pcg64State {
            state_hi: (self.state >> 64) as u64,
            state_lo: self.state as u64,
            inc_hi: (self.inc >> 64) as u64,
            inc_lo: self.inc as u64,
            spare_normal: self.spare_normal,
        }
    }

    /// Rebuild a generator from a snapshot; the restored generator
    /// produces exactly the stream the snapshotted one would have.
    pub fn from_state(s: Pcg64State) -> Self {
        Pcg64 {
            state: ((s.state_hi as u128) << 64) | s.state_lo as u128,
            inc: ((s.inc_hi as u128) << 64) | s.inc_lo as u128,
            spare_normal: s.spare_normal,
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) — Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (caches the spare variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Log-normal sample given the *median* and *coefficient of variation*
    /// of the distribution — the paper's Fig. 7 parameterization
    /// (median 0.23, CV 1.58 for biological spike frequencies).
    ///
    /// For LogNormal(mu, sigma): median = e^mu, CV = sqrt(e^{sigma^2} - 1).
    pub fn lognormal_median_cv(&mut self, median: f64, cv: f64) -> f64 {
        let mu = median.ln();
        let sigma = (cv * cv + 1.0).ln().sqrt();
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson sample. Knuth's product method for small means, normal
    /// approximation (clamped at 0) beyond 30 where Knuth underflows.
    pub fn poisson(&mut self, mean: f64) -> usize {
        if mean <= 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0usize;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction.
            let x = mean + mean.sqrt() * self.normal() + 0.5;
            if x < 0.0 {
                0
            } else {
                x as usize
            }
        }
    }

    /// Exponential sample with given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index proportionally to non-negative `weights`.
    /// Returns None if all weights are zero/empty.
    pub fn weighted_index(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return None;
        }
        let mut t = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t < 0.0 {
                return Some(i);
            }
        }
        Some(weights.len() - 1)
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), Floyd's algorithm.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(42, 2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Pcg64::new(99, 5);
        // Burn some outputs, including a normal() so the Box–Muller spare
        // is populated at snapshot time.
        for _ in 0..17 {
            a.next_u64();
        }
        a.normal();
        let snap = a.state();
        let mut b = Pcg64::from_state(snap);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // The spare variate must survive the roundtrip bit-for-bit.
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        assert_eq!(a.state(), b.state());
    }

    #[test]
    fn uniform_range_bounds() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..10_000 {
            let x = rng.below(7);
            assert!(x < 7);
            let y = rng.range(3, 5);
            assert!((3..=5).contains(&y));
            let f = rng.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn uniform_mean_variance() {
        let mut rng = Pcg64::seeded(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.03, "var={v}");
    }

    #[test]
    fn lognormal_median_and_cv() {
        let mut rng = Pcg64::seeded(4);
        let n = 200_000;
        let mut xs: Vec<f64> = (0..n).map(|_| rng.lognormal_median_cv(0.23, 1.58)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        assert!((median - 0.23).abs() < 0.01, "median={median}");
        let m = xs.iter().sum::<f64>() / n as f64;
        let sd = (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64).sqrt();
        let cv = sd / m;
        assert!((cv - 1.58).abs() < 0.12, "cv={cv}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut rng = Pcg64::seeded(5);
        for &lambda in &[0.5, 3.0, 12.0, 80.0] {
            let n = 50_000;
            let m: f64 =
                (0..n).map(|_| rng.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (m - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} m={m}"
            );
        }
        assert_eq!(rng.poisson(0.0), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(6);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Pcg64::seeded(7);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio={ratio}");
        assert_eq!(rng.weighted_index(&[0.0, 0.0]), None);
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Pcg64::seeded(8);
        for _ in 0..100 {
            let got = rng.sample_distinct(50, 10);
            assert_eq!(got.len(), 10);
            let set: std::collections::HashSet<_> = got.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(got.iter().all(|&x| x < 50));
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::seeded(9);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean={m}");
    }
}
