//! Minimal JSON value model, parser and writer.
//!
//! Used for the artifact manifest (read), experiment configs (read) and
//! report emission (write). Supports the full JSON grammar except for
//! `\u` surrogate pairs outside the BMP (sufficient for our ASCII-only
//! manifests); numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use BTreeMap for deterministic output ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup; Null for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Parse a JSON document (entire input must be consumed).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{}", x);
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| "bad utf8")?;
                    s.push_str(chunk);
                    self.i += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {:?}", other.map(|b| b as char))),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {:?}", other.map(|b| b as char))),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic_values() {
        for text in [
            "null",
            "true",
            "false",
            "0",
            "-12.5",
            "\"hi\\nthere\"",
            "[1,2,3]",
            "{\"a\":1,\"b\":[true,null]}",
        ] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "text={text}");
        }
    }

    #[test]
    fn parse_nested_manifest_like() {
        let text = r#"{
          "format": "hlo-text",
          "artifacts": [
            {"kind": "spectral", "n": 128, "iters": 300, "path": "spectral_128.hlo.txt",
             "inputs": [["f32", [128, 128]], ["f32", [128]]]}
          ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format").as_str(), Some("hlo-text"));
        let arts = v.get("artifacts").as_arr().unwrap();
        assert_eq!(arts[0].get("n").as_usize(), Some(128));
        assert_eq!(
            arts[0].get("inputs").as_arr().unwrap()[0].as_arr().unwrap()[0].as_str(),
            Some("f32")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_control_chars() {
        let s = Json::Str("a\"b\\c\n\u{1}".into()).to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\n\\u0001\"");
        assert_eq!(Json::parse(&s).unwrap(), Json::Str("a\"b\\c\n\u{1}".into()));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = Json::obj(vec![
            ("x", Json::Num(1.0)),
            ("y", Json::Arr(vec![Json::Num(2.5), Json::Null])),
        ]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }
}
