//! Timing helpers for the bench harness (criterion is not in the offline
//! registry): wall-clock scopes, repeated-measurement statistics, and a
//! simple stage profiler used by the coordinator.

use std::time::{Duration, Instant};

/// Measure one closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Statistics over repeated timed runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Run `f` repeatedly: at least `min_iters` times and at least `min_time`
/// total, then report stats. The result of the last invocation is returned
/// so benches can validate outputs.
pub fn bench<T>(min_iters: usize, min_time: Duration, mut f: impl FnMut() -> T) -> (T, BenchStats) {
    let mut durs = Vec::new();
    let start = Instant::now();
    let mut last = None;
    while durs.len() < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        last = Some(f());
        durs.push(t0.elapsed());
        if durs.len() > 10_000 {
            break;
        }
    }
    let total: Duration = durs.iter().sum();
    let stats = BenchStats {
        iters: durs.len(),
        mean: total / durs.len() as u32,
        // snn-lint: allow(unwrap-ban) — the measurement loop always runs >= 1 iteration
        min: *durs.iter().min().unwrap(),
        // snn-lint: allow(unwrap-ban) — the measurement loop always runs >= 1 iteration
        max: *durs.iter().max().unwrap(),
    };
    // snn-lint: allow(unwrap-ban) — `last` was set on every loop iteration and >= 1 ran
    (last.unwrap(), stats)
}

/// Accumulating multi-stage profiler: `stage(name, f)` times a closure and
/// files it under `name`; `report()` renders a sorted table.
#[derive(Debug, Default)]
pub struct StageProfiler {
    stages: Vec<(String, Duration)>,
}

impl StageProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stage<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, dt) = time_once(f);
        self.stages.push((name.to_string(), dt));
        out
    }

    pub fn record(&mut self, name: &str, dt: Duration) {
        self.stages.push((name.to_string(), dt));
    }

    pub fn total(&self) -> Duration {
        self.stages.iter().map(|(_, d)| *d).sum()
    }

    pub fn stages(&self) -> &[(String, Duration)] {
        &self.stages
    }

    pub fn report(&self) -> String {
        let total = self.total().as_secs_f64().max(1e-12);
        let mut out = String::new();
        for (name, d) in &self.stages {
            out.push_str(&format!(
                "  {:<28} {:>10.3}s  {:>5.1}%\n",
                name,
                d.as_secs_f64(),
                100.0 * d.as_secs_f64() / total
            ));
        }
        out.push_str(&format!("  {:<28} {:>10.3}s\n", "TOTAL", total));
        out
    }
}

/// Render a Duration compactly for logs.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_enough_iters() {
        let (out, stats) = bench(5, Duration::from_millis(1), || 42);
        assert_eq!(out, 42);
        assert!(stats.iters >= 5);
        assert!(stats.min <= stats.mean && stats.mean <= stats.max);
    }

    #[test]
    fn profiler_accumulates() {
        let mut p = StageProfiler::new();
        let x = p.stage("a", || 1 + 1);
        assert_eq!(x, 2);
        p.record("b", Duration::from_millis(2));
        assert_eq!(p.stages().len(), 2);
        assert!(p.total() >= Duration::from_millis(2));
        let rep = p.report();
        assert!(rep.contains("a") && rep.contains("b") && rep.contains("TOTAL"));
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("us"));
        assert!(fmt_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
        assert!(fmt_duration(Duration::from_secs(300)).ends_with("min"));
    }
}
