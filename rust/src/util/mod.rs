//! Utility substrate: the offline registry ships no rand/serde/clap/rayon,
//! so the toolchain carries its own deterministic RNG, JSON codec, CLI
//! parser, timing helpers and scoped-thread parallel engine. All are fully
//! unit-tested and dependency-free.

pub mod cli;
pub mod json;
pub mod par;
pub mod rng;
pub mod timer;

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Whether stage-timing diagnostics (`SNNMAP_TIMING`) are enabled.
///
/// The env var is read once per process — hot loops (the multilevel
/// partitioner checks this per coarsening round) must not pay a
/// `std::env::var` syscall + UTF-8 validation each time.
pub fn timing_enabled() -> bool {
    static ENABLED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var("SNNMAP_TIMING").is_ok())
}

/// Total order over values the caller guarantees are non-NaN (scores,
/// weights, gains — all finite by construction in this codebase).
///
/// Replaces the `partial_cmp().unwrap()` idiom: same result for every
/// non-NaN pair — including `-0.0 == 0.0`, which `f64::total_cmp` would
/// order and thereby reorder existing sorts — but structurally panic-free
/// (incomparable pairs collapse to `Equal` instead of aborting).
#[inline]
pub fn cmp_non_nan<T: PartialOrd>(a: &T, b: &T) -> std::cmp::Ordering {
    if a < b {
        std::cmp::Ordering::Less
    } else if a > b {
        std::cmp::Ordering::Greater
    } else {
        std::cmp::Ordering::Equal
    }
}

/// Arithmetic mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean of a slice of positive values; zeros are clamped to
/// `floor` so a single empty bucket doesn't annihilate the statistic
/// (matches the paper's use of geometric means over per-partition ratios).
pub fn geometric_mean(xs: &[f64], floor: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|&x| x.max(floor).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 1024), 1);
        assert_eq!(div_ceil(0, 7), 0);
    }

    #[test]
    fn cmp_non_nan_matches_partial_cmp_for_non_nan() {
        use std::cmp::Ordering::*;
        for (a, b) in [(1.0f64, 2.0), (2.0, 1.0), (3.5, 3.5), (-0.0, 0.0), (0.0, -0.0)] {
            assert_eq!(cmp_non_nan(&a, &b), a.partial_cmp(&b).unwrap(), "({a}, {b})");
        }
        // tuples (the (cost, cell) lexicographic pattern) work too
        assert_eq!(cmp_non_nan(&(1.0, 5usize), &(1.0, 3usize)), Greater);
        // incomparable pairs collapse to Equal instead of panicking
        assert_eq!(cmp_non_nan(&f64::NAN, &1.0), Equal);
    }

    #[test]
    fn mean_and_geo_mean() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
        let g = geometric_mean(&[1.0, 4.0], 1e-12);
        assert!((g - 2.0).abs() < 1e-12);
        // floor keeps zeros from collapsing the product
        let g = geometric_mean(&[0.0, 4.0], 1.0);
        assert!((g - 2.0).abs() < 1e-12);
    }
}
