//! The nine repo-invariant rules (R1–R9), run over the per-file models
//! plus the crate-wide symbol index. Every rule is purely
//! lexical/structural — see DESIGN.md §14 for each rule's rationale and
//! the exact scope table. R1 (twin resolution), R8 (float-merge-order)
//! and R9 (shared-mut-in-propose) are cross-module/flow-aware and lean
//! on [`super::crate_model::CrateModel`].

use std::collections::BTreeSet;

use super::crate_model::{CrateModel, FileCtx};
use super::lexer::{ident_at, path2_at, punct_at, TokKind, Token};
use super::model::FileModel;
use super::parse::{
    closure_start, compound_ops, direct_calls, is_keyword, is_mut_method, parallel_regions,
    region_bindings, stmt_span, PAR_COMBINATORS,
};
use super::{classify, FileClass, Finding, LintReport, BAD_WAIVER};

/// Methods whose hash-ordered iteration order can leak into results.
const ITER_METHODS: [&str; 11] = [
    "iter", "iter_mut", "into_iter", "keys", "into_keys", "values", "values_mut", "into_values",
    "drain", "retain", "extract_if",
];

/// Suffixes marking a parallel entry point needing a serial twin (R1),
/// tried longest-first so `*_with_threads` is not mis-stemmed.
const PAR_SUFFIXES: [&str; 3] = ["_with_threads", "_threads", "_parallel"];

fn par_stem(name: &str) -> Option<&str> {
    PAR_SUFFIXES
        .iter()
        .find_map(|suf| name.strip_suffix(suf))
        .filter(|stem| !stem.is_empty())
}

/// The outermost type name a declaration resolves to: skips `&`, `mut`,
/// `dyn`, `impl` and lifetimes, then follows a `::` path to its last
/// segment. `Vec<HashSet<u32>>` resolves to `Vec` — containers *of* hash
/// collections are not themselves hash-ordered.
fn type_head(toks: &[Token], mut k: usize) -> Option<String> {
    loop {
        let lifetime = matches!(toks.get(k).map(|t| &t.kind), Some(TokKind::Lifetime));
        if punct_at(toks, k, '&') || lifetime {
            k += 1;
            continue;
        }
        match ident_at(toks, k) {
            Some("mut") | Some("dyn") | Some("impl") => {
                k += 1;
                continue;
            }
            _ => break,
        }
    }
    let mut last = ident_at(toks, k)?.to_string();
    while punct_at(toks, k + 1, ':') && punct_at(toks, k + 2, ':') {
        match ident_at(toks, k + 3) {
            Some(id) => {
                last = id.to_string();
                k += 3;
            }
            None => break,
        }
    }
    Some(last)
}

/// Run all rules over `files` (path → source). Paths are relative to the
/// crate root with `/` separators (`src/…`, `tests/…`, `benches/…`).
pub fn run(files: &[(String, String)]) -> LintReport {
    let parsed: Vec<FileCtx> = files
        .iter()
        .map(|(path, src)| {
            let (toks, comments) = super::lexer::lex(src);
            let model = FileModel::build(&toks, &comments);
            FileCtx { path: path.clone(), class: classify(path), toks, model }
        })
        .collect();
    let cm = CrateModel::build(&parsed);

    let mut findings: Vec<Finding> = Vec::new();
    let mut push = |rule: &str, path: &str, line: u32, msg: String| {
        findings.push(Finding {
            rule: rule.to_string(),
            path: path.to_string(),
            line,
            msg,
            waived: None,
        });
    };

    // ---- R1 parallel-serial-pairing (cross-module) -----------------
    // Pass 1: every `*_parallel`/`*_threads` lib fn needs a `*_serial`
    // twin — anywhere in the crate, resolved through the fn index.
    // Pass 2: the twin must be referenced from test/bench context
    // somewhere in the tree (the equality test that keeps it honest).
    for f in parsed.iter() {
        if f.class != FileClass::Lib {
            continue;
        }
        for func in &f.model.fns {
            if f.model.in_test(func.kw_idx) {
                continue;
            }
            let Some(stem) = par_stem(&func.name) else { continue };
            let twin = format!("{stem}_serial");
            match cm.fn_index.get(&twin).and_then(|v| v.first()) {
                None => push(
                    "parallel-serial-pairing",
                    &f.path,
                    func.line,
                    format!("`{}` has no `{twin}` twin anywhere in the crate", func.name),
                ),
                Some(loc) => {
                    if !cm.test_referenced.contains(&twin) {
                        push(
                            "parallel-serial-pairing",
                            &f.path,
                            func.line,
                            format!(
                                "serial twin `{twin}` of `{}` (in {}) is never referenced \
                                 from a test or bench",
                                func.name, parsed[loc.file].path
                            ),
                        );
                    }
                }
            }
        }
    }

    for f in &parsed {
        let toks = &f.toks;
        let n = toks.len();

        // ---- R3 no-raw-writes (all contexts) -----------------------
        if f.path != "src/hypergraph/io.rs" && f.path != "src/runtime/checkpoint.rs" {
            for i in 0..n {
                if path2_at(toks, i, "fs", "write") {
                    push(
                        "no-raw-writes",
                        &f.path,
                        toks[i].line,
                        "raw `fs::write` — route through `runtime::checkpoint::atomic_write`"
                            .to_string(),
                    );
                } else if path2_at(toks, i, "File", "create")
                    || path2_at(toks, i, "File", "create_new")
                    || path2_at(toks, i, "OpenOptions", "new")
                {
                    push(
                        "no-raw-writes",
                        &f.path,
                        toks[i].line,
                        "raw file creation — route through `runtime::checkpoint::atomic_write`"
                            .to_string(),
                    );
                }
            }
        }

        // ---- R4 unwrap-ban (library code, non-test) ----------------
        if f.class == FileClass::Lib {
            for i in 0..n {
                if f.model.in_test(i) {
                    continue;
                }
                if punct_at(toks, i, '.') && punct_at(toks, i + 2, '(') {
                    if let Some(m @ ("unwrap" | "expect")) = ident_at(toks, i + 1) {
                        push(
                            "unwrap-ban",
                            &f.path,
                            toks[i + 1].line,
                            format!("`.{m}()` in library code — convert to `MapError` or waive"),
                        );
                    }
                }
                if ident_at(toks, i) == Some("panic") && punct_at(toks, i + 1, '!') {
                    push(
                        "unwrap-ban",
                        &f.path,
                        toks[i].line,
                        "`panic!` in library code — convert to `MapError` or waive".to_string(),
                    );
                }
            }
        }

        // ---- R5 env-discipline (src/, non-test) --------------------
        let r5_exempt = f.path == "src/main.rs"
            || f.path.starts_with("src/bin/")
            || f.path == "src/runtime/artifacts.rs";
        if matches!(f.class, FileClass::Lib | FileClass::Bin) && !r5_exempt {
            for i in 0..n {
                if f.model.in_test(i) {
                    continue;
                }
                if path2_at(toks, i, "env", "var") || path2_at(toks, i, "env", "var_os") {
                    let gated = f.path.starts_with("src/util/")
                        && f.model.enclosing_fn(i).and_then(|x| x.body).is_some_and(|(s, e)| {
                            toks[s..=e.min(n - 1)].iter().any(
                                |t| matches!(&t.kind, TokKind::Ident(id) if id == "OnceLock"),
                            )
                        });
                    if !gated {
                        push(
                            "env-discipline",
                            &f.path,
                            toks[i].line,
                            "`env::var` needs a util/ `OnceLock` gate, main.rs or artifacts.rs"
                                .to_string(),
                        );
                    }
                }
            }
        }

        // ---- R6 timing-gate (stage code, non-test) -----------------
        if f.class == FileClass::Lib && !f.path.starts_with("src/util/") {
            for i in 0..n {
                if f.model.in_test(i) {
                    continue;
                }
                if path2_at(toks, i, "Instant", "now") {
                    let sunk = f.model.enclosing_fn(i).and_then(|x| x.body).is_some_and(|(s, e)| {
                        toks[s..=e.min(n - 1)].iter().any(|t| {
                            matches!(&t.kind, TokKind::Ident(id)
                                if id == "timing_enabled"
                                    || id.to_ascii_lowercase().ends_with("stats")
                                    || id.ends_with("_secs"))
                        })
                    });
                    if !sunk {
                        push(
                            "timing-gate",
                            &f.path,
                            toks[i].line,
                            "`Instant::now()` without a `*Stats` sink or `timing_enabled()` gate"
                                .to_string(),
                        );
                    }
                }
            }
        }

        // ---- R7 threads-wiring (stage impls) -----------------------
        if f.class == FileClass::Lib {
            for im in &f.model.impls {
                let Some(tr) = im.trait_name.as_deref() else { continue };
                if !matches!(tr, "Partitioner" | "Placer" | "Refiner") || f.model.in_test(im.kw_idx)
                {
                    continue;
                }
                let (s, e) = im.body;
                let reads = (s..e.min(n)).any(|i| {
                    matches!(&toks[i].kind, TokKind::Ident(id) if id.ends_with("ctx"))
                        && punct_at(toks, i + 1, '.')
                        && ident_at(toks, i + 2) == Some("threads")
                });
                if !reads {
                    push(
                        "threads-wiring",
                        &f.path,
                        im.line,
                        format!("`impl {tr}` never reads `ctx.threads` — thread budget ignored"),
                    );
                }
            }
        }

        // ---- R2 unordered-iteration (src/, non-test) ---------------
        if matches!(f.class, FileClass::Lib | FileClass::Bin) {
            let tracked = tracked_hash_names(toks, &f.model);
            if !tracked.is_empty() {
                for i in 0..n {
                    if f.model.in_test(i) {
                        continue;
                    }
                    let mut hit: Option<(String, u32)> = None;
                    if let Some(name) = ident_at(toks, i) {
                        if tracked.contains(name)
                            && punct_at(toks, i + 1, '.')
                            && ident_at(toks, i + 2).is_some_and(|m| ITER_METHODS.contains(&m))
                        {
                            hit = Some((name.to_string(), toks[i].line));
                        }
                    }
                    if ident_at(toks, i) == Some("in") {
                        let mut k = i + 1;
                        if punct_at(toks, k, '&') {
                            k += 1;
                        }
                        if ident_at(toks, k) == Some("mut") {
                            k += 1;
                        }
                        if ident_at(toks, k) == Some("self") && punct_at(toks, k + 1, '.') {
                            k += 2;
                        }
                        if let Some(name) = ident_at(toks, k) {
                            if tracked.contains(name) && punct_at(toks, k + 1, '{') {
                                hit = Some((name.to_string(), toks[k].line));
                            }
                        }
                    }
                    if let Some((name, line)) = hit {
                        // downstream sort in the same fn restores order
                        let sorted =
                            f.model.enclosing_fn(i).and_then(|x| x.body).is_some_and(|(_, e)| {
                                toks[i + 1..=e.min(n - 1)].iter().any(|t| {
                                    matches!(&t.kind, TokKind::Ident(id)
                                        if id.starts_with("sort"))
                                })
                            });
                        if !sorted {
                            push(
                                "unordered-iteration",
                                &f.path,
                                line,
                                format!(
                                    "hash-ordered `{name}` iteration can leak into results — \
                                     collect into a Vec and sort before iterating, or switch \
                                     to a BTreeMap/BTreeSet"
                                ),
                            );
                        }
                    }
                }
            }
        }

        // ---- R8 float-merge-order / R9 shared-mut-in-propose -------
        // Flow-aware propose/commit discipline over parallel regions.
        // One R8 finding per region (the fix is per-region: route the
        // reduction through the integer-accumulator/ordered-merge
        // discipline); R9 dedupes per (region, captured name).
        if f.class == FileClass::Lib {
            for region in parallel_regions(toks) {
                if f.model.in_test(region.call_idx) {
                    continue;
                }
                let (s, e) = region.args;
                let comb = region.combinator.as_str();
                let fn_float = f
                    .model
                    .enclosing_fn(region.call_idx)
                    .map(|func| cm.fn_float_names(f, func))
                    .unwrap_or_default();
                // only the closure body runs concurrently — leading
                // args (`&mut data`, chunk sizes) are pre-spawn
                let body_s = closure_start(toks, s, e).unwrap_or(s);
                let binds = region_bindings(toks, s, e);

                // R8 direct: a compound op whose statement is
                // float-evidenced inside the closure itself
                let mut r8: Option<String> = None;
                if let Some((tgt, line, ev)) = region_r8_direct(toks, body_s, e, &fn_float, &cm) {
                    r8 = Some(format!(
                        "float accumulation inside `{comb}` closure (`{}` at line {line}; {ev})",
                        tgt.as_deref().unwrap_or("?")
                    ));
                }
                // R8 one-hop: a bare call to a crate fn whose own body
                // accumulates floats (scored with the callee's scope)
                if r8.is_none() {
                    'calls: for (callee, _) in direct_calls(toks, body_s, e) {
                        if PAR_COMBINATORS.contains(&callee.as_str())
                            || binds.contains(callee.as_str())
                        {
                            continue;
                        }
                        let Some(refs) = cm.fn_index.get(&callee) else { continue };
                        for r in refs {
                            let cf = &parsed[r.file];
                            let Some(cfn) = cf.model.fns.get(r.fn_idx) else { continue };
                            let Some((cs, ce)) = cfn.body else { continue };
                            let cfloat = cm.fn_float_names(cf, cfn);
                            if let Some((_, _, ev)) =
                                region_r8_direct(&cf.toks, cs, ce, &cfloat, &cm)
                            {
                                r8 = Some(format!(
                                    "`{comb}` closure calls `{callee}` ({}:{}) which \
                                     accumulates floats ({ev})",
                                    cf.path, cfn.line
                                ));
                                break 'calls;
                            }
                        }
                    }
                }
                if let Some(msg) = r8 {
                    push("float-merge-order", &f.path, region.line, msg);
                }

                // R9: walk each head ident's postfix chain in the
                // closure body; flag writes and mutating calls on
                // captured (non-closure-local) names, exempting
                // index-disjoint slot writes (`slots[i] = …` where `i`
                // is closure-bound)
                let mut seen_r9: BTreeSet<String> = BTreeSet::new();
                let mut k = body_s;
                while k <= e {
                    let head = match ident_at(toks, k) {
                        Some(id) if !is_keyword(id) => id.to_string(),
                        _ => {
                            k += 1;
                            continue;
                        }
                    };
                    let prev_blocks = k > 0
                        && (punct_at(toks, k - 1, '.')
                            || punct_at(toks, k - 1, ':')
                            || matches!(
                                ident_at(toks, k - 1),
                                Some("let") | Some("mut") | Some("fn")
                            ));
                    if prev_blocks {
                        k += 1;
                        continue;
                    }
                    let mut j = k + 1;
                    let mut last_index: Option<(usize, usize)> = None;
                    let mut first_mut: Option<String> = None;
                    while j <= e {
                        if punct_at(toks, j, '.') {
                            let Some(m) = ident_at(toks, j + 1) else { break };
                            if punct_at(toks, j + 2, '(') {
                                if first_mut.is_none() && is_mut_method(m) {
                                    first_mut = Some(m.to_string());
                                }
                                j = super::lexer::match_delim(toks, j + 2, '(', ')') + 1;
                            } else {
                                j += 2;
                            }
                        } else if punct_at(toks, j, '[') {
                            let close = super::lexer::match_delim(toks, j, '[', ']');
                            last_index = Some((j + 1, close.saturating_sub(1)));
                            j = close + 1;
                        } else if punct_at(toks, j, '?') {
                            j += 1;
                        } else if punct_at(toks, j, '(') && j == k + 1 {
                            j = super::lexer::match_delim(toks, j, '(', ')') + 1;
                        } else {
                            break;
                        }
                    }
                    let is_assign = punct_at(toks, j, '=')
                        && !punct_at(toks, j + 1, '=')
                        && !punct_at(toks, j + 1, '>');
                    let is_comp = matches!(
                        toks.get(j).map(|t| &t.kind),
                        Some(TokKind::Punct(c)) if "+-*/%^&|".contains(*c)
                    ) && punct_at(toks, j + 1, '=');
                    let captured = !binds.contains(head.as_str());
                    if is_assign || is_comp {
                        let idx_ok = last_index.is_some_and(|(a, b)| {
                            (a..=b).any(|m| {
                                ident_at(toks, m).is_some_and(|id| binds.contains(id))
                            })
                        });
                        if captured && !idx_ok && seen_r9.insert(head.clone()) {
                            push(
                                "shared-mut-in-propose",
                                &f.path,
                                toks[k].line,
                                format!("write to captured `{head}` inside `{comb}` closure"),
                            );
                        }
                    } else if let Some(m) = first_mut {
                        if captured && seen_r9.insert(head.clone()) {
                            push(
                                "shared-mut-in-propose",
                                &f.path,
                                toks[k].line,
                                format!(
                                    "mutating call `.{m}()` on captured `{head}` inside \
                                     `{comb}` closure"
                                ),
                            );
                        }
                    }
                    k += 1;
                }
                // `&mut name` handing captured state to a callee
                for k in body_s..e.min(n.saturating_sub(1)) {
                    if !punct_at(toks, k, '&') || ident_at(toks, k + 1) != Some("mut") {
                        continue;
                    }
                    let Some(nm) = ident_at(toks, k + 2) else { continue };
                    if !is_keyword(nm)
                        && !binds.contains(nm)
                        && seen_r9.insert(nm.to_string())
                    {
                        push(
                            "shared-mut-in-propose",
                            &f.path,
                            toks[k].line,
                            format!("captured `{nm}` passed as `&mut` inside `{comb}` closure"),
                        );
                    }
                }
            }
        }
    }

    // ---- waiver application ----------------------------------------
    let mut used: BTreeSet<(String, u32)> = BTreeSet::new();
    for fnd in &mut findings {
        if let Some(f) = parsed.iter().find(|p| p.path == fnd.path) {
            for w in &f.model.waivers {
                if w.rules.iter().any(|r| r == &fnd.rule) && w.covered.contains(&fnd.line) {
                    fnd.waived = Some(w.reason.clone());
                    used.insert((f.path.clone(), w.line));
                    break;
                }
            }
        }
    }
    for f in &parsed {
        for b in &f.model.bad_waivers {
            findings.push(Finding {
                rule: BAD_WAIVER.to_string(),
                path: f.path.clone(),
                line: b.line,
                msg: b.msg.clone(),
                waived: None,
            });
        }
    }
    let mut unused_waivers: Vec<(String, u32)> = Vec::new();
    for f in &parsed {
        for w in &f.model.waivers {
            if !used.contains(&(f.path.clone(), w.line)) {
                unused_waivers.push((f.path.clone(), w.line));
            }
        }
    }

    let rule_order = |rule: &str| -> usize {
        super::RULES.iter().position(|r| r.id == rule).unwrap_or(super::RULES.len())
    };
    findings.sort_by(|a, b| {
        rule_order(&a.rule)
            .cmp(&rule_order(&b.rule))
            .then_with(|| a.path.cmp(&b.path))
            .then_with(|| a.line.cmp(&b.line))
    });

    LintReport { findings, unused_waivers, files_scanned: files.len() }
}

/// Float evidence inside one statement span `[a, b]`, as a short
/// human-readable reason: a float literal, an `f32`/`f64` mention, a
/// name that is float-typed in the enclosing fn's scope, a crate-known
/// float struct field, or a call-position crate fn returning floats.
fn stmt_float_evidence(
    toks: &[Token],
    a: usize,
    b: usize,
    fn_float: &BTreeSet<String>,
    cm: &CrateModel,
) -> Option<String> {
    let hi = b.min(toks.len().saturating_sub(1));
    for m in a..=hi {
        if super::lexer::float_lit_at(toks, m) {
            return Some("float literal".to_string());
        }
        let Some(id) = ident_at(toks, m) else { continue };
        if id == "f32" || id == "f64" {
            return Some(id.to_string());
        }
        if fn_float.contains(id) {
            return Some(format!("`{id}` is float-typed"));
        }
        if m > 0 && punct_at(toks, m - 1, '.') && cm.float_fields.contains(id) {
            return Some(format!("float field `.{id}`"));
        }
        if punct_at(toks, m + 1, '(')
            && !(m > 0 && punct_at(toks, m - 1, '.'))
            && cm.float_fns.contains(id)
        {
            return Some(format!("float-returning `{id}()`"));
        }
    }
    None
}

/// The first compound-assignment in `[s, e]` whose *statement* carries
/// float evidence (or whose target name is float-typed):
/// `(target, line, evidence)`. Statement scoping is what keeps integer
/// accumulators (`epoch += 1`) clean inside regions that also mention
/// floats elsewhere.
fn region_r8_direct(
    toks: &[Token],
    s: usize,
    e: usize,
    fn_float: &BTreeSet<String>,
    cm: &CrateModel,
) -> Option<(Option<String>, u32, String)> {
    for op in compound_ops(toks, s, e) {
        let (a, b) = stmt_span(toks, op.op_idx, s, e);
        let mut ev = stmt_float_evidence(toks, a, b, fn_float, cm);
        if ev.is_none() {
            if let Some(t) = &op.target {
                if fn_float.contains(t) || cm.float_fields.contains(t) {
                    ev = Some(format!("target `{t}`"));
                }
            }
        }
        if let Some(ev) = ev {
            return Some((op.target, op.line, ev));
        }
    }
    None
}

/// File-local names (let bindings, struct fields, fn params) whose type
/// head is `HashMap`/`HashSet`.
fn tracked_hash_names(toks: &[Token], model: &FileModel) -> BTreeSet<String> {
    let n = toks.len();
    let mut tracked: BTreeSet<String> = BTreeSet::new();
    let is_hash = |h: &Option<String>| {
        matches!(h.as_deref(), Some("HashMap") | Some("HashSet"))
    };
    for i in 0..n {
        // `let [mut] name: HashMap<…>` / `let [mut] name = HashMap::new()`
        if ident_at(toks, i) == Some("let") {
            let mut k = i + 1;
            if ident_at(toks, k) == Some("mut") {
                k += 1;
            }
            let Some(name) = ident_at(toks, k) else { continue };
            if punct_at(toks, k + 1, ':')
                && !punct_at(toks, k + 2, ':')
                && is_hash(&type_head(toks, k + 2))
            {
                tracked.insert(name.to_string());
            } else if punct_at(toks, k + 1, '=') {
                for j in k + 2..(k + 9).min(n) {
                    if matches!(ident_at(toks, j), Some("HashMap") | Some("HashSet")) {
                        tracked.insert(name.to_string());
                        break;
                    }
                    if punct_at(toks, j, ';') || punct_at(toks, j, '(') || punct_at(toks, j, '{') {
                        break;
                    }
                }
            }
        }
        // `struct S { field: HashMap<…>, … }` (depth-1 fields only)
        if ident_at(toks, i) == Some("struct") && ident_at(toks, i + 1).is_some() {
            let mut k = i + 2;
            while k < n
                && !punct_at(toks, k, '{')
                && !punct_at(toks, k, ';')
                && !punct_at(toks, k, '(')
            {
                k += 1;
            }
            if punct_at(toks, k, '{') {
                let end = super::lexer::match_delim(toks, k, '{', '}');
                let mut depth = 0isize;
                for j in k..end {
                    if punct_at(toks, j, '{') {
                        depth += 1;
                    } else if punct_at(toks, j, '}') {
                        depth -= 1;
                    } else if depth == 1
                        && punct_at(toks, j + 1, ':')
                        && !punct_at(toks, j + 2, ':')
                    {
                        if let Some(name) = ident_at(toks, j) {
                            if is_hash(&type_head(toks, j + 2)) {
                                tracked.insert(name.to_string());
                            }
                        }
                    }
                }
            }
        }
    }
    // fn params: `fn f(name: HashMap<…>)`
    for func in &model.fns {
        let Some((body_start, _)) = func.body else { continue };
        for j in func.kw_idx..body_start {
            if punct_at(toks, j + 1, ':') && !punct_at(toks, j + 2, ':') {
                if let Some(name) = ident_at(toks, j) {
                    if is_hash(&type_head(toks, j + 2)) {
                        tracked.insert(name.to_string());
                    }
                }
            }
        }
    }
    tracked
}

#[cfg(test)]
mod tests {
    use super::super::{lint_sources, LintReport};

    fn lint_one(path: &str, src: &str) -> LintReport {
        lint_sources(&[(path.to_string(), src.to_string())])
    }

    fn unwaived_rules(r: &LintReport) -> Vec<String> {
        r.unwaived().map(|f| f.rule.clone()).collect()
    }

    // ---- R1 parallel-serial-pairing --------------------------------

    #[test]
    fn r1_fires_on_missing_twin() {
        let r = lint_one("src/a.rs", "pub fn foo_parallel(x: u32) -> u32 { x }\n");
        assert_eq!(unwaived_rules(&r), vec!["parallel-serial-pairing"]);
    }

    #[test]
    fn r1_fires_on_twin_unreferenced_from_tests() {
        let src = r#"
pub fn foo_parallel(x: u32) -> u32 { foo_serial(x) }
pub fn foo_serial(x: u32) -> u32 { x }
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["parallel-serial-pairing"]);
    }

    #[test]
    fn r1_clean_when_twin_is_tested() {
        let files = vec![
            (
                "src/a.rs".to_string(),
                "pub fn foo_parallel(x: u32) -> u32 { foo_serial(x) }\n\
                 pub fn foo_serial(x: u32) -> u32 { x }\n"
                    .to_string(),
            ),
            (
                "tests/eq.rs".to_string(),
                "#[test]\nfn twins_agree() { assert_eq!(a::foo_parallel(3), a::foo_serial(3)); }\n"
                    .to_string(),
            ),
        ];
        let r = lint_sources(&files);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn r1_waived() {
        let src = "// snn-lint: allow(parallel-serial-pairing) — wrapper, no parallel body\n\
                   pub fn foo_parallel(x: u32) -> u32 { x }\n";
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.waived().count(), 1);
    }

    #[test]
    fn r1_ignores_test_only_fns_and_with_threads_suffix() {
        let src = "#[cfg(test)]\nmod tests {\n    fn helper_parallel() {}\n}\n";
        assert!(lint_one("src/a.rs", src).is_clean());
        let r = lint_one("src/b.rs", "pub fn go_with_threads(t: usize) -> usize { t }\n");
        // stem is `go`, so the expected twin is go_serial, not go_with_serial
        assert!(r.findings[0].msg.contains("go_serial"), "{}", r.findings[0].msg);
    }

    // ---- R2 unordered-iteration ------------------------------------

    const R2_FIRING: &str = r#"
use std::collections::HashMap;
pub fn f() -> u32 {
    let m: HashMap<u32, u32> = HashMap::new();
    let mut s = 0;
    for k in m.keys() {
        s += k;
    }
    s
}
"#;

    #[test]
    fn r2_fires_on_hash_iteration() {
        assert_eq!(unwaived_rules(&lint_one("src/a.rs", R2_FIRING)), vec!["unordered-iteration"]);
    }

    #[test]
    fn r2_clean_when_sorted_downstream() {
        let src = r#"
use std::collections::HashMap;
pub fn f() -> Vec<u32> {
    let m: HashMap<u32, u32> = HashMap::new();
    let mut ks: Vec<u32> = m.keys().copied().collect();
    ks.sort();
    ks
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn r2_clean_in_tests_and_for_non_hash_containers() {
        assert!(lint_one("tests/t.rs", R2_FIRING).is_clean());
        let src =
            "pub fn f(v: Vec<u32>) -> u32 { let mut s = 0; for x in v.iter() { s += x; } s }\n";
        assert!(lint_one("src/a.rs", src).is_clean());
    }

    #[test]
    fn r2_waived() {
        let src = r#"
use std::collections::HashSet;
pub fn f(s: HashSet<u32>) -> u32 {
    // snn-lint: allow(unordered-iteration) — summation is order-independent
    s.iter().sum()
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.waived().count(), 1);
    }

    // ---- R3 no-raw-writes ------------------------------------------

    #[test]
    fn r3_fires_on_fs_write_and_file_create_everywhere() {
        let w = r#"pub fn f(p: &std::path::Path) { let _ = std::fs::write(p, b"x"); }"#;
        assert_eq!(unwaived_rules(&lint_one("src/a.rs", w)), vec!["no-raw-writes"]);
        // benches and tests are NOT exempt: crash-consistency is global
        assert_eq!(unwaived_rules(&lint_one("benches/b.rs", w)), vec!["no-raw-writes"]);
        let c = r#"pub fn f(p: &std::path::Path) { let _ = std::fs::File::create(p); }"#;
        assert_eq!(unwaived_rules(&lint_one("tests/t.rs", c)), vec!["no-raw-writes"]);
    }

    #[test]
    fn r3_clean_in_allowlisted_io_modules() {
        let w = r#"pub fn f(p: &std::path::Path) { let _ = std::fs::write(p, b"x"); }"#;
        assert!(lint_one("src/runtime/checkpoint.rs", w).is_clean());
        assert!(lint_one("src/hypergraph/io.rs", w).is_clean());
    }

    #[test]
    fn r3_waived() {
        let src = r#"
pub fn corrupt(p: &std::path::Path) {
    // snn-lint: allow(no-raw-writes) — corruption harness, atomicity is under test
    let _ = std::fs::write(p, b"x");
}
"#;
        let r = lint_one("tests/t.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    // ---- R4 unwrap-ban ---------------------------------------------

    #[test]
    fn r4_fires_on_unwrap_expect_panic_in_lib() {
        let src = r#"
pub fn f(x: Option<u32>) -> u32 { x.unwrap() }
pub fn g(x: Option<u32>) -> u32 { x.expect("set") }
pub fn h() { panic!("no"); }
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["unwrap-ban"; 3]);
    }

    #[test]
    fn r4_clean_in_tests_bins_and_benches() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert!(lint_one("tests/t.rs", src).is_clean());
        assert!(lint_one("benches/b.rs", src).is_clean());
        assert!(lint_one("src/bin/tool.rs", src).is_clean());
        assert!(lint_one("src/main.rs", src).is_clean());
        let in_test_mod =
            "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u32>) -> u32 { x.unwrap() }\n}\n";
        assert!(lint_one("src/a.rs", in_test_mod).is_clean());
    }

    #[test]
    fn r4_waived_with_reason() {
        let src = r#"
pub fn f(x: Option<u32>) -> u32 {
    // snn-lint: allow(unwrap-ban) — caller guarantees Some by construction
    x.unwrap()
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
        let reason = r.waived().next().and_then(|f| f.waived.clone());
        assert_eq!(reason.as_deref(), Some("caller guarantees Some by construction"));
    }

    #[test]
    fn r4_not_fooled_by_strings_comments_or_lookalikes() {
        let src = r#"
pub fn f() -> &'static str {
    // a comment mentioning x.unwrap() and panic!() changes nothing
    "x.unwrap() and panic!(msg) in a string are inert"
}
pub fn g(x: Option<u32>) -> u32 { x.unwrap_or(0) }
"#;
        assert!(lint_one("src/a.rs", src).is_clean());
    }

    // ---- R5 env-discipline -----------------------------------------

    #[test]
    fn r5_fires_outside_util() {
        let src = r#"pub fn f() -> String { std::env::var("X").unwrap_or_default() }"#;
        assert_eq!(unwaived_rules(&lint_one("src/mapping/a.rs", src)), vec!["env-discipline"]);
    }

    #[test]
    fn r5_clean_in_main_bins_artifacts_and_gated_util() {
        let src = r#"pub fn f() -> String { std::env::var("X").unwrap_or_default() }"#;
        assert!(lint_one("src/main.rs", src).is_clean());
        assert!(lint_one("src/bin/tool.rs", src).is_clean());
        assert!(lint_one("src/runtime/artifacts.rs", src).is_clean());
        let gated = r#"
use std::sync::OnceLock;
pub fn threads() -> usize {
    static V: OnceLock<usize> = OnceLock::new();
    *V.get_or_init(|| std::env::var("T").ok().and_then(|s| s.parse().ok()).unwrap_or(1))
}
"#;
        assert!(lint_one("src/util/par.rs", gated).is_clean());
    }

    #[test]
    fn r5_fires_in_util_without_oncelock_unless_waived() {
        let src = r#"pub fn f() -> String { std::env::var("X").unwrap_or_default() }"#;
        assert_eq!(unwaived_rules(&lint_one("src/util/x.rs", src)), vec!["env-discipline"]);
        let waived = r#"
pub fn f() -> String {
    // snn-lint: allow(env-discipline) — read once at startup by the coordinator
    std::env::var("X").unwrap_or_default()
}
"#;
        assert!(lint_one("src/util/x.rs", waived).is_clean());
    }

    // ---- R6 timing-gate --------------------------------------------

    #[test]
    fn r6_fires_on_unsunk_instant() {
        let src = r#"
pub fn f() -> u64 {
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
"#;
        assert_eq!(unwaived_rules(&lint_one("src/mapping/a.rs", src)), vec!["timing-gate"]);
    }

    #[test]
    fn r6_clean_when_feeding_stats_or_gated() {
        let sunk = r#"
pub struct RunStats { pub coarsen_secs: f64 }
pub fn f(stats: &mut RunStats) {
    let t = std::time::Instant::now();
    stats.coarsen_secs = t.elapsed().as_secs_f64();
}
"#;
        assert!(lint_one("src/mapping/a.rs", sunk).is_clean());
        let gated = r#"
pub fn f() {
    if crate::util::timing_enabled() {
        let t = std::time::Instant::now();
        eprintln!("{:?}", t.elapsed());
    }
}
"#;
        assert!(lint_one("src/mapping/a.rs", gated).is_clean());
        // util/ itself (the timer module) is out of scope
        let raw = "pub fn f() -> std::time::Instant { std::time::Instant::now() }\n";
        assert!(lint_one("src/util/timer.rs", raw).is_clean());
    }

    #[test]
    fn r6_waived() {
        let src = r#"
pub fn f() -> bool {
    // snn-lint: allow(timing-gate) — wall-clock budget is product semantics here
    let t = std::time::Instant::now();
    t.elapsed().as_secs() > 1
}
"#;
        assert!(lint_one("src/coordinator/a.rs", src).is_clean());
    }

    // ---- R7 threads-wiring -----------------------------------------

    #[test]
    fn r7_fires_when_ctx_threads_unread() {
        let src = r#"
pub struct P;
impl crate::stage::Partitioner for P {
    fn partition(&self) -> u32 { 0 }
}
"#;
        assert_eq!(unwaived_rules(&lint_one("src/mapping/a.rs", src)), vec!["threads-wiring"]);
    }

    #[test]
    fn r7_clean_when_ctx_threads_read_and_for_other_impls() {
        let src = r#"
pub struct P;
impl crate::stage::Partitioner for P {
    fn partition(&self, ctx: &StageCtx) -> u32 { ctx.threads as u32 }
}
impl Clone for P {
    fn clone(&self) -> P { P }
}
"#;
        assert!(lint_one("src/mapping/a.rs", src).is_clean());
    }

    #[test]
    fn r7_waived() {
        let src = r#"
pub struct P;
// snn-lint: allow(threads-wiring) — inherently sequential stage
impl crate::stage::Placer for P {
    fn place(&self) -> u32 { 0 }
}
"#;
        assert!(lint_one("src/mapping/a.rs", src).is_clean());
    }

    // ---- waiver parser ---------------------------------------------

    #[test]
    fn waiver_without_reason_is_rejected_and_does_not_waive() {
        let src = r#"
pub fn f(x: Option<u32>) -> u32 {
    // snn-lint: allow(unwrap-ban)
    x.unwrap()
}
"#;
        let r = lint_one("src/a.rs", src);
        let mut rules = unwaived_rules(&r);
        rules.sort();
        assert_eq!(rules, vec!["bad-waiver", "unwrap-ban"]);
    }

    #[test]
    fn waiver_with_separator_but_empty_reason_is_rejected() {
        let src = "// snn-lint: allow(unwrap-ban) —\npub fn f() {}\n";
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["bad-waiver"]);
    }

    #[test]
    fn waiver_with_unknown_rule_id_is_rejected() {
        let src = "// snn-lint: allow(no-such-rule) — because reasons\npub fn f() {}\n";
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["bad-waiver"]);
        assert!(r.findings[0].msg.contains("no-such-rule"), "{}", r.findings[0].msg);
    }

    #[test]
    fn malformed_waiver_marker_is_rejected() {
        let src = "// snn-lint: disallow(unwrap-ban) — nope\npub fn f() {}\n";
        assert_eq!(unwaived_rules(&lint_one("src/a.rs", src)), vec!["bad-waiver"]);
    }

    #[test]
    fn bad_waiver_cannot_itself_be_waived() {
        // `bad-waiver` is not a waivable rule id, so naming it is itself bad
        let src =
            "// snn-lint: allow(bad-waiver) — trying to silence the silencer\npub fn f() {}\n";
        assert_eq!(unwaived_rules(&lint_one("src/a.rs", src)), vec!["bad-waiver"]);
    }

    #[test]
    fn multi_rule_waiver_and_alternate_separators() {
        let src = r#"
pub fn f(x: Option<u32>) -> u32 {
    // snn-lint: allow(unwrap-ban, timing-gate) - plain-dash separator, both ids valid
    x.unwrap()
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn trailing_waiver_covers_its_own_line_only() {
        let src = r#"
pub fn f(x: Option<u32>, y: Option<u32>) -> u32 {
    let a = x.unwrap(); // snn-lint: allow(unwrap-ban) — covered inline
    let b = y.unwrap();
    a + b
}
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["unwrap-ban"]);
        assert_eq!(r.waived().count(), 1);
    }

    #[test]
    fn standalone_waiver_does_not_leak_past_next_line() {
        let src = r#"
pub fn f(x: Option<u32>, y: Option<u32>) -> u32 {
    // snn-lint: allow(unwrap-ban) — only the next line
    let a = x.unwrap();
    let b = y.unwrap();
    a + b
}
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["unwrap-ban"]);
    }

    #[test]
    fn unused_waiver_fails_the_gate() {
        let src = "// snn-lint: allow(unwrap-ban) — nothing here needs it\npub fn f() {}\n";
        let r = lint_one("src/a.rs", src);
        // no unwaived findings, but the stale waiver is a hard error
        assert!(r.is_clean());
        assert!(!r.gate_ok());
        assert_eq!(r.unused_waivers.len(), 1);
        assert!(r.render().contains("error: unused waiver at src/a.rs:1"), "{}", r.render());
    }

    #[test]
    fn doc_prose_mentioning_the_marker_is_not_a_waiver() {
        let src = "/// Waivers look like `// snn-lint: allow(rule)` in this repo.\npub fn f() {}\n";
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
        assert!(r.unused_waivers.is_empty());
    }

    // ---- report shape ----------------------------------------------

    #[test]
    fn report_groups_by_rule_and_counts() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let r = lint_one("src/a.rs", src);
        let text = r.render();
        assert!(text.contains("[unwrap-ban]"), "{text}");
        assert!(text.contains("src/a.rs:1"), "{text}");
        assert!(text.contains("1 unwaived finding(s)"), "{text}");
    }

    // ---- R1 cross-module twin resolution ---------------------------

    #[test]
    fn r1_resolves_twin_in_another_module() {
        let files = vec![
            ("src/a.rs".to_string(), "pub fn foo_parallel(x: u32) -> u32 { x }\n".to_string()),
            ("src/b.rs".to_string(), "pub fn foo_serial(x: u32) -> u32 { x }\n".to_string()),
            (
                "tests/eq.rs".to_string(),
                "#[test]\nfn eq() { assert_eq!(foo_parallel(3), foo_serial(3)); }\n".to_string(),
            ),
        ];
        let r = lint_sources(&files);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn r1_cross_module_untested_twin_names_its_module() {
        let files = vec![
            ("src/a.rs".to_string(), "pub fn foo_parallel(x: u32) -> u32 { x }\n".to_string()),
            ("src/b.rs".to_string(), "pub fn foo_serial(x: u32) -> u32 { x }\n".to_string()),
        ];
        let r = lint_sources(&files);
        assert_eq!(unwaived_rules(&r), vec!["parallel-serial-pairing"]);
        let msg = &r.findings[0].msg;
        assert!(msg.contains("(in src/b.rs)"), "{msg}");
    }

    #[test]
    fn r1_missing_twin_message_says_anywhere_in_the_crate() {
        let r = lint_one("src/a.rs", "pub fn foo_parallel(x: u32) -> u32 { x }\n");
        assert!(r.findings[0].msg.contains("anywhere in the crate"), "{}", r.findings[0].msg);
    }

    // ---- R8 float-merge-order --------------------------------------

    const R8_FIRING: &str = r#"
pub fn total(xs: &[f64], threads: usize) -> f64 {
    crate::util::par::chunked_fold(xs.len(), 64, threads, |chunk| {
        let mut sum = 0.0f64;
        for i in chunk {
            sum += xs[i];
        }
        sum
    })
}
"#;

    #[test]
    fn r8_fires_on_float_accumulation_in_parallel_closure() {
        let r = lint_one("src/metrics/a.rs", R8_FIRING);
        assert_eq!(unwaived_rules(&r), vec!["float-merge-order"]);
        assert!(r.findings[0].msg.contains("chunked_fold"), "{}", r.findings[0].msg);
    }

    #[test]
    fn r8_clean_on_integer_accumulation() {
        // the §16 discipline: accumulate in integers inside the region,
        // convert to floats only after the ordered merge
        let src = r#"
pub fn count(xs: &[u32], threads: usize) -> u64 {
    crate::util::par::chunked_fold(xs.len(), 64, threads, |chunk| {
        let mut n = 0u64;
        for i in chunk {
            n += u64::from(xs[i]);
        }
        n
    })
}
"#;
        let r = lint_one("src/metrics/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn r8_integer_op_stays_clean_beside_float_code_in_same_file() {
        // per-fn float scoping: `w` is floaty in `weigh`, but the
        // parallel closure in `count` only touches integers
        let src = r#"
pub fn weigh(x: u32) -> f64 {
    let w = 0.5f64;
    w * x as f64
}
pub fn count(xs: &[u32], threads: usize) -> u64 {
    crate::util::par::chunked_fold(xs.len(), 64, threads, |chunk| {
        let mut n = 0u64;
        for i in chunk {
            n += u64::from(xs[i]);
        }
        n
    })
}
"#;
        let r = lint_one("src/metrics/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn r8_one_hop_resolves_callee_across_modules() {
        let files = vec![
            (
                "src/a.rs".to_string(),
                "pub fn scan(props: &mut [f64], threads: usize) {\n\
                 \x20   crate::util::par::par_chunks_mut(props, 8, threads, |ci, slice| {\n\
                 \x20       score(ci, slice);\n\
                 \x20   });\n\
                 }\n"
                    .to_string(),
            ),
            (
                "src/b.rs".to_string(),
                "pub fn score(ci: usize, out: &mut [f64]) {\n\
                 \x20   let mut acc = 0.0;\n\
                 \x20   for v in out.iter() {\n\
                 \x20       acc += v;\n\
                 \x20   }\n\
                 \x20   let _ = (ci, acc);\n\
                 }\n"
                    .to_string(),
            ),
        ];
        let r = lint_sources(&files);
        assert_eq!(unwaived_rules(&r), vec!["float-merge-order"]);
        let msg = &r.findings[0].msg;
        assert!(msg.contains("calls `score` (src/b.rs:1)"), "{msg}");
    }

    #[test]
    fn r8_waived_with_discipline_reason() {
        let src = r#"
pub fn total(xs: &[f64], threads: usize) -> f64 {
    // snn-lint: allow(float-merge-order) — fixed chunking, serial in-order merge
    crate::util::par::chunked_fold(xs.len(), 64, threads, |chunk| {
        let mut sum = 0.0f64;
        for i in chunk {
            sum += xs[i];
        }
        sum
    })
}
"#;
        let r = lint_one("src/metrics/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.waived().count(), 1);
    }

    #[test]
    fn r8_and_r9_skip_test_regions_and_non_lib_files() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t(threads: usize) -> f64 {\n        \
                   crate::util::par::par_map(4, threads, |i| {\n            \
                   let mut s = 0.0f64;\n            s += i as f64;\n            s\n        })\n    \
                   }\n}\n";
        assert!(lint_one("src/a.rs", src).is_clean());
    }

    // ---- R9 shared-mut-in-propose ----------------------------------

    #[test]
    fn r9_fires_on_write_to_captured_state() {
        let src = r#"
pub fn bad(xs: &[u32], threads: usize) -> u32 {
    let mut total = 0u32;
    crate::util::par::par_map(xs.len(), threads, |i| {
        total += xs[i];
        i as u32
    });
    total
}
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["shared-mut-in-propose"]);
        assert!(r.findings[0].msg.contains("captured `total`"), "{}", r.findings[0].msg);
    }

    #[test]
    fn r9_exempts_index_disjoint_slot_writes() {
        let src = r#"
pub fn good(xs: &[u32], slots: &mut [u32], threads: usize) {
    crate::util::par::par_map(xs.len(), threads, |i| {
        slots[i] = xs[i];
        i as u32
    });
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn r9_fires_on_mutating_method_on_captured_state() {
        let src = r#"
pub fn bad(xs: &[u32], log: &std::sync::Mutex<Vec<u32>>, threads: usize) {
    crate::util::par::par_map(xs.len(), threads, |i| {
        log.lock();
        xs[i]
    });
}
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["shared-mut-in-propose"]);
        assert!(r.findings[0].msg.contains(".lock()"), "{}", r.findings[0].msg);
    }

    #[test]
    fn r9_fires_on_captured_mut_borrow() {
        let src = r#"
pub fn bad(xs: &[u32], scratch: &mut Vec<u32>, threads: usize) {
    crate::util::par::par_map(xs.len(), threads, |i| {
        refill(&mut scratch, i);
        xs[i]
    });
}
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["shared-mut-in-propose"]);
        assert!(r.findings[0].msg.contains("`&mut`"), "{}", r.findings[0].msg);
    }

    #[test]
    fn r9_ignores_pre_closure_combinator_arguments() {
        // the `&mut data` handed TO par_chunks_mut is pre-spawn plumbing,
        // not a write from inside the concurrent closure
        let src = r#"
pub fn good(data: &mut [u32], threads: usize) {
    crate::util::par::par_chunks_mut(&mut data[..], 8, threads, |ci, chunk| {
        let _ = (ci, chunk);
    });
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn r9_waived_with_scheduler_contract_reason() {
        let src = r#"
pub fn sched(xs: &[u32], next: &std::sync::atomic::AtomicUsize, threads: usize) {
    crate::util::par::par_map(xs.len(), threads, |i| {
        // snn-lint: allow(shared-mut-in-propose) — work-stealing counter only hands out unique indices
        next.fetch_add(1, Relaxed);
        i as u32
    });
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.waived().count(), 1);
    }

    // ---- lexer hardening: rules cannot be dodged through literals ---

    #[test]
    fn rules_not_dodged_by_raw_strings_with_hashes() {
        let src = "pub fn f() -> &'static str {\n    r##\"std::fs::write(p, x.unwrap())\"##\n}\n";
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn rules_not_dodged_by_nested_block_comments() {
        let src = "/* outer /* std::fs::write(p, b) */ still a comment */\npub fn f() {}\n";
        assert!(lint_one("src/a.rs", src).is_clean());
    }

    #[test]
    fn escaped_quote_char_literal_does_not_swallow_code() {
        // before the lexer fix, '\'' scanned to the NEXT quote and
        // silently ate the unwrap() that follows
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    let _q = '\\'';\n    x.unwrap()\n}\n";
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["unwrap-ban"]);
        assert_eq!(r.findings[0].line, 3);
    }

    #[test]
    fn byte_char_literal_does_not_desync_lines() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    let _m = b'a';\n    x.unwrap()\n}\n";
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["unwrap-ban"]);
        assert_eq!(r.findings[0].line, 3);
    }

    #[test]
    fn escaped_newline_in_string_keeps_finding_lines_accurate() {
        // a `\`-continued string spans two physical lines; the finding
        // after it must land on the right line for waivers to match
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    let _s = \"a\\\nb\";\n    x.unwrap()\n}\n";
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["unwrap-ban"]);
        assert_eq!(r.findings[0].line, 4);
    }

    #[test]
    fn float_literal_in_string_is_inert_for_r8() {
        let src = r#"
pub fn tag(xs: &[u32], threads: usize) -> u32 {
    crate::util::par::chunked_fold(xs.len(), 64, threads, |chunk| {
        let mut n = 0u32;
        let _label = "weight 0.5f64";
        for i in chunk {
            n += xs[i];
        }
        n
    })
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }
}
