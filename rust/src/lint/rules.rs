//! The seven repo-invariant rules (R1–R7), run over the per-file models.
//! Every rule is purely lexical/structural — see DESIGN.md §14 for each
//! rule's rationale and the exact scope table.

use std::collections::BTreeSet;

use super::lexer::{ident_at, path2_at, punct_at, TokKind, Token};
use super::model::FileModel;
use super::{classify, FileClass, Finding, LintReport, BAD_WAIVER};

struct ParsedFile {
    path: String,
    class: FileClass,
    toks: Vec<Token>,
    model: FileModel,
}

/// Methods whose hash-ordered iteration order can leak into results.
const ITER_METHODS: [&str; 11] = [
    "iter", "iter_mut", "into_iter", "keys", "into_keys", "values", "values_mut", "into_values",
    "drain", "retain", "extract_if",
];

/// Suffixes marking a parallel entry point needing a serial twin (R1),
/// tried longest-first so `*_with_threads` is not mis-stemmed.
const PAR_SUFFIXES: [&str; 3] = ["_with_threads", "_threads", "_parallel"];

fn par_stem(name: &str) -> Option<&str> {
    PAR_SUFFIXES
        .iter()
        .find_map(|suf| name.strip_suffix(suf))
        .filter(|stem| !stem.is_empty())
}

/// The outermost type name a declaration resolves to: skips `&`, `mut`,
/// `dyn`, `impl` and lifetimes, then follows a `::` path to its last
/// segment. `Vec<HashSet<u32>>` resolves to `Vec` — containers *of* hash
/// collections are not themselves hash-ordered.
fn type_head(toks: &[Token], mut k: usize) -> Option<String> {
    loop {
        let lifetime = matches!(toks.get(k).map(|t| &t.kind), Some(TokKind::Lifetime));
        if punct_at(toks, k, '&') || lifetime {
            k += 1;
            continue;
        }
        match ident_at(toks, k) {
            Some("mut") | Some("dyn") | Some("impl") => {
                k += 1;
                continue;
            }
            _ => break,
        }
    }
    let mut last = ident_at(toks, k)?.to_string();
    while punct_at(toks, k + 1, ':') && punct_at(toks, k + 2, ':') {
        match ident_at(toks, k + 3) {
            Some(id) => {
                last = id.to_string();
                k += 3;
            }
            None => break,
        }
    }
    Some(last)
}

/// Run all rules over `files` (path → source). Paths are relative to the
/// crate root with `/` separators (`src/…`, `tests/…`, `benches/…`).
pub fn run(files: &[(String, String)]) -> LintReport {
    let parsed: Vec<ParsedFile> = files
        .iter()
        .map(|(path, src)| {
            let (toks, comments) = super::lexer::lex(src);
            let model = FileModel::build(&toks, &comments);
            ParsedFile { path: path.clone(), class: classify(path), toks, model }
        })
        .collect();

    let mut findings: Vec<Finding> = Vec::new();
    let mut push = |rule: &str, path: &str, line: u32, msg: String| {
        findings.push(Finding {
            rule: rule.to_string(),
            path: path.to_string(),
            line,
            msg,
            waived: None,
        });
    };

    // ---- R1 parallel-serial-pairing --------------------------------
    // Pass 1: every `*_parallel`/`*_threads` lib fn needs a local twin.
    let mut twins_needed: Vec<(usize, u32, String, String)> = Vec::new();
    for (fi, f) in parsed.iter().enumerate() {
        if f.class != FileClass::Lib {
            continue;
        }
        let local: BTreeSet<&str> = f.model.fns.iter().map(|x| x.name.as_str()).collect();
        for func in &f.model.fns {
            if f.model.in_test(func.kw_idx) {
                continue;
            }
            let Some(stem) = par_stem(&func.name) else { continue };
            let twin = format!("{stem}_serial");
            if local.contains(twin.as_str()) {
                twins_needed.push((fi, func.line, func.name.clone(), twin));
            } else {
                push(
                    "parallel-serial-pairing",
                    &f.path,
                    func.line,
                    format!("`{}` has no `{twin}` twin in this module", func.name),
                );
            }
        }
    }
    // Pass 2: the twin must be referenced from test/bench context
    // somewhere in the tree (the equality test that keeps it honest).
    let mut referenced: BTreeSet<String> = BTreeSet::new();
    for f in &parsed {
        let whole_file_is_test = matches!(f.class, FileClass::Test | FileClass::Bench);
        for (i, t) in f.toks.iter().enumerate() {
            if let TokKind::Ident(id) = &t.kind {
                if whole_file_is_test || f.model.in_test(i) {
                    referenced.insert(id.clone());
                }
            }
        }
    }
    for (fi, line, name, twin) in &twins_needed {
        if !referenced.contains(twin) {
            push(
                "parallel-serial-pairing",
                &parsed[*fi].path,
                *line,
                format!(
                    "serial twin `{twin}` of `{name}` is never referenced from a test or bench"
                ),
            );
        }
    }

    for f in &parsed {
        let toks = &f.toks;
        let n = toks.len();

        // ---- R3 no-raw-writes (all contexts) -----------------------
        if f.path != "src/hypergraph/io.rs" && f.path != "src/runtime/checkpoint.rs" {
            for i in 0..n {
                if path2_at(toks, i, "fs", "write") {
                    push(
                        "no-raw-writes",
                        &f.path,
                        toks[i].line,
                        "raw `fs::write` — route through `runtime::checkpoint::atomic_write`"
                            .to_string(),
                    );
                } else if path2_at(toks, i, "File", "create")
                    || path2_at(toks, i, "File", "create_new")
                    || path2_at(toks, i, "OpenOptions", "new")
                {
                    push(
                        "no-raw-writes",
                        &f.path,
                        toks[i].line,
                        "raw file creation — route through `runtime::checkpoint::atomic_write`"
                            .to_string(),
                    );
                }
            }
        }

        // ---- R4 unwrap-ban (library code, non-test) ----------------
        if f.class == FileClass::Lib {
            for i in 0..n {
                if f.model.in_test(i) {
                    continue;
                }
                if punct_at(toks, i, '.') && punct_at(toks, i + 2, '(') {
                    if let Some(m @ ("unwrap" | "expect")) = ident_at(toks, i + 1) {
                        push(
                            "unwrap-ban",
                            &f.path,
                            toks[i + 1].line,
                            format!("`.{m}()` in library code — convert to `MapError` or waive"),
                        );
                    }
                }
                if ident_at(toks, i) == Some("panic") && punct_at(toks, i + 1, '!') {
                    push(
                        "unwrap-ban",
                        &f.path,
                        toks[i].line,
                        "`panic!` in library code — convert to `MapError` or waive".to_string(),
                    );
                }
            }
        }

        // ---- R5 env-discipline (src/, non-test) --------------------
        let r5_exempt = f.path == "src/main.rs"
            || f.path.starts_with("src/bin/")
            || f.path == "src/runtime/artifacts.rs";
        if matches!(f.class, FileClass::Lib | FileClass::Bin) && !r5_exempt {
            for i in 0..n {
                if f.model.in_test(i) {
                    continue;
                }
                if path2_at(toks, i, "env", "var") || path2_at(toks, i, "env", "var_os") {
                    let gated = f.path.starts_with("src/util/")
                        && f.model.enclosing_fn(i).and_then(|x| x.body).is_some_and(|(s, e)| {
                            toks[s..=e.min(n - 1)].iter().any(
                                |t| matches!(&t.kind, TokKind::Ident(id) if id == "OnceLock"),
                            )
                        });
                    if !gated {
                        push(
                            "env-discipline",
                            &f.path,
                            toks[i].line,
                            "`env::var` needs a util/ `OnceLock` gate, main.rs or artifacts.rs"
                                .to_string(),
                        );
                    }
                }
            }
        }

        // ---- R6 timing-gate (stage code, non-test) -----------------
        if f.class == FileClass::Lib && !f.path.starts_with("src/util/") {
            for i in 0..n {
                if f.model.in_test(i) {
                    continue;
                }
                if path2_at(toks, i, "Instant", "now") {
                    let sunk = f.model.enclosing_fn(i).and_then(|x| x.body).is_some_and(|(s, e)| {
                        toks[s..=e.min(n - 1)].iter().any(|t| {
                            matches!(&t.kind, TokKind::Ident(id)
                                if id == "timing_enabled"
                                    || id.to_ascii_lowercase().ends_with("stats")
                                    || id.ends_with("_secs"))
                        })
                    });
                    if !sunk {
                        push(
                            "timing-gate",
                            &f.path,
                            toks[i].line,
                            "`Instant::now()` without a `*Stats` sink or `timing_enabled()` gate"
                                .to_string(),
                        );
                    }
                }
            }
        }

        // ---- R7 threads-wiring (stage impls) -----------------------
        if f.class == FileClass::Lib {
            for im in &f.model.impls {
                let Some(tr) = im.trait_name.as_deref() else { continue };
                if !matches!(tr, "Partitioner" | "Placer" | "Refiner") || f.model.in_test(im.kw_idx)
                {
                    continue;
                }
                let (s, e) = im.body;
                let reads = (s..e.min(n)).any(|i| {
                    matches!(&toks[i].kind, TokKind::Ident(id) if id.ends_with("ctx"))
                        && punct_at(toks, i + 1, '.')
                        && ident_at(toks, i + 2) == Some("threads")
                });
                if !reads {
                    push(
                        "threads-wiring",
                        &f.path,
                        im.line,
                        format!("`impl {tr}` never reads `ctx.threads` — thread budget ignored"),
                    );
                }
            }
        }

        // ---- R2 unordered-iteration (src/, non-test) ---------------
        if matches!(f.class, FileClass::Lib | FileClass::Bin) {
            let tracked = tracked_hash_names(toks, &f.model);
            if !tracked.is_empty() {
                for i in 0..n {
                    if f.model.in_test(i) {
                        continue;
                    }
                    let mut hit: Option<(String, u32)> = None;
                    if let Some(name) = ident_at(toks, i) {
                        if tracked.contains(name)
                            && punct_at(toks, i + 1, '.')
                            && ident_at(toks, i + 2).is_some_and(|m| ITER_METHODS.contains(&m))
                        {
                            hit = Some((name.to_string(), toks[i].line));
                        }
                    }
                    if ident_at(toks, i) == Some("in") {
                        let mut k = i + 1;
                        if punct_at(toks, k, '&') {
                            k += 1;
                        }
                        if ident_at(toks, k) == Some("mut") {
                            k += 1;
                        }
                        if ident_at(toks, k) == Some("self") && punct_at(toks, k + 1, '.') {
                            k += 2;
                        }
                        if let Some(name) = ident_at(toks, k) {
                            if tracked.contains(name) && punct_at(toks, k + 1, '{') {
                                hit = Some((name.to_string(), toks[k].line));
                            }
                        }
                    }
                    if let Some((name, line)) = hit {
                        // downstream sort in the same fn restores order
                        let sorted =
                            f.model.enclosing_fn(i).and_then(|x| x.body).is_some_and(|(_, e)| {
                                toks[i + 1..=e.min(n - 1)].iter().any(|t| {
                                    matches!(&t.kind, TokKind::Ident(id)
                                        if id.starts_with("sort"))
                                })
                            });
                        if !sorted {
                            push(
                                "unordered-iteration",
                                &f.path,
                                line,
                                format!(
                                    "hash-ordered `{name}` iteration can leak into results — \
                                     collect into a Vec and sort before iterating, or switch \
                                     to a BTreeMap/BTreeSet"
                                ),
                            );
                        }
                    }
                }
            }
        }
    }

    // ---- waiver application ----------------------------------------
    let mut used: BTreeSet<(String, u32)> = BTreeSet::new();
    for fnd in &mut findings {
        if let Some(f) = parsed.iter().find(|p| p.path == fnd.path) {
            for w in &f.model.waivers {
                if w.rules.iter().any(|r| r == &fnd.rule) && w.covered.contains(&fnd.line) {
                    fnd.waived = Some(w.reason.clone());
                    used.insert((f.path.clone(), w.line));
                    break;
                }
            }
        }
    }
    for f in &parsed {
        for b in &f.model.bad_waivers {
            findings.push(Finding {
                rule: BAD_WAIVER.to_string(),
                path: f.path.clone(),
                line: b.line,
                msg: b.msg.clone(),
                waived: None,
            });
        }
    }
    let mut unused_waivers: Vec<(String, u32)> = Vec::new();
    for f in &parsed {
        for w in &f.model.waivers {
            if !used.contains(&(f.path.clone(), w.line)) {
                unused_waivers.push((f.path.clone(), w.line));
            }
        }
    }

    let rule_order = |rule: &str| -> usize {
        super::RULES.iter().position(|r| r.id == rule).unwrap_or(super::RULES.len())
    };
    findings.sort_by(|a, b| {
        rule_order(&a.rule)
            .cmp(&rule_order(&b.rule))
            .then_with(|| a.path.cmp(&b.path))
            .then_with(|| a.line.cmp(&b.line))
    });

    LintReport { findings, unused_waivers, files_scanned: files.len() }
}

/// File-local names (let bindings, struct fields, fn params) whose type
/// head is `HashMap`/`HashSet`.
fn tracked_hash_names(toks: &[Token], model: &FileModel) -> BTreeSet<String> {
    let n = toks.len();
    let mut tracked: BTreeSet<String> = BTreeSet::new();
    let is_hash = |h: &Option<String>| {
        matches!(h.as_deref(), Some("HashMap") | Some("HashSet"))
    };
    for i in 0..n {
        // `let [mut] name: HashMap<…>` / `let [mut] name = HashMap::new()`
        if ident_at(toks, i) == Some("let") {
            let mut k = i + 1;
            if ident_at(toks, k) == Some("mut") {
                k += 1;
            }
            let Some(name) = ident_at(toks, k) else { continue };
            if punct_at(toks, k + 1, ':')
                && !punct_at(toks, k + 2, ':')
                && is_hash(&type_head(toks, k + 2))
            {
                tracked.insert(name.to_string());
            } else if punct_at(toks, k + 1, '=') {
                for j in k + 2..(k + 9).min(n) {
                    if matches!(ident_at(toks, j), Some("HashMap") | Some("HashSet")) {
                        tracked.insert(name.to_string());
                        break;
                    }
                    if punct_at(toks, j, ';') || punct_at(toks, j, '(') || punct_at(toks, j, '{') {
                        break;
                    }
                }
            }
        }
        // `struct S { field: HashMap<…>, … }` (depth-1 fields only)
        if ident_at(toks, i) == Some("struct") && ident_at(toks, i + 1).is_some() {
            let mut k = i + 2;
            while k < n
                && !punct_at(toks, k, '{')
                && !punct_at(toks, k, ';')
                && !punct_at(toks, k, '(')
            {
                k += 1;
            }
            if punct_at(toks, k, '{') {
                let end = super::lexer::match_delim(toks, k, '{', '}');
                let mut depth = 0isize;
                for j in k..end {
                    if punct_at(toks, j, '{') {
                        depth += 1;
                    } else if punct_at(toks, j, '}') {
                        depth -= 1;
                    } else if depth == 1
                        && punct_at(toks, j + 1, ':')
                        && !punct_at(toks, j + 2, ':')
                    {
                        if let Some(name) = ident_at(toks, j) {
                            if is_hash(&type_head(toks, j + 2)) {
                                tracked.insert(name.to_string());
                            }
                        }
                    }
                }
            }
        }
    }
    // fn params: `fn f(name: HashMap<…>)`
    for func in &model.fns {
        let Some((body_start, _)) = func.body else { continue };
        for j in func.kw_idx..body_start {
            if punct_at(toks, j + 1, ':') && !punct_at(toks, j + 2, ':') {
                if let Some(name) = ident_at(toks, j) {
                    if is_hash(&type_head(toks, j + 2)) {
                        tracked.insert(name.to_string());
                    }
                }
            }
        }
    }
    tracked
}

#[cfg(test)]
mod tests {
    use super::super::{lint_sources, LintReport};

    fn lint_one(path: &str, src: &str) -> LintReport {
        lint_sources(&[(path.to_string(), src.to_string())])
    }

    fn unwaived_rules(r: &LintReport) -> Vec<String> {
        r.unwaived().map(|f| f.rule.clone()).collect()
    }

    // ---- R1 parallel-serial-pairing --------------------------------

    #[test]
    fn r1_fires_on_missing_twin() {
        let r = lint_one("src/a.rs", "pub fn foo_parallel(x: u32) -> u32 { x }\n");
        assert_eq!(unwaived_rules(&r), vec!["parallel-serial-pairing"]);
    }

    #[test]
    fn r1_fires_on_twin_unreferenced_from_tests() {
        let src = r#"
pub fn foo_parallel(x: u32) -> u32 { foo_serial(x) }
pub fn foo_serial(x: u32) -> u32 { x }
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["parallel-serial-pairing"]);
    }

    #[test]
    fn r1_clean_when_twin_is_tested() {
        let files = vec![
            (
                "src/a.rs".to_string(),
                "pub fn foo_parallel(x: u32) -> u32 { foo_serial(x) }\n\
                 pub fn foo_serial(x: u32) -> u32 { x }\n"
                    .to_string(),
            ),
            (
                "tests/eq.rs".to_string(),
                "#[test]\nfn twins_agree() { assert_eq!(a::foo_parallel(3), a::foo_serial(3)); }\n"
                    .to_string(),
            ),
        ];
        let r = lint_sources(&files);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn r1_waived() {
        let src = "// snn-lint: allow(parallel-serial-pairing) — wrapper, no parallel body\n\
                   pub fn foo_parallel(x: u32) -> u32 { x }\n";
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.waived().count(), 1);
    }

    #[test]
    fn r1_ignores_test_only_fns_and_with_threads_suffix() {
        let src = "#[cfg(test)]\nmod tests {\n    fn helper_parallel() {}\n}\n";
        assert!(lint_one("src/a.rs", src).is_clean());
        let r = lint_one("src/b.rs", "pub fn go_with_threads(t: usize) -> usize { t }\n");
        // stem is `go`, so the expected twin is go_serial, not go_with_serial
        assert!(r.findings[0].msg.contains("go_serial"), "{}", r.findings[0].msg);
    }

    // ---- R2 unordered-iteration ------------------------------------

    const R2_FIRING: &str = r#"
use std::collections::HashMap;
pub fn f() -> u32 {
    let m: HashMap<u32, u32> = HashMap::new();
    let mut s = 0;
    for k in m.keys() {
        s += k;
    }
    s
}
"#;

    #[test]
    fn r2_fires_on_hash_iteration() {
        assert_eq!(unwaived_rules(&lint_one("src/a.rs", R2_FIRING)), vec!["unordered-iteration"]);
    }

    #[test]
    fn r2_clean_when_sorted_downstream() {
        let src = r#"
use std::collections::HashMap;
pub fn f() -> Vec<u32> {
    let m: HashMap<u32, u32> = HashMap::new();
    let mut ks: Vec<u32> = m.keys().copied().collect();
    ks.sort();
    ks
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn r2_clean_in_tests_and_for_non_hash_containers() {
        assert!(lint_one("tests/t.rs", R2_FIRING).is_clean());
        let src =
            "pub fn f(v: Vec<u32>) -> u32 { let mut s = 0; for x in v.iter() { s += x; } s }\n";
        assert!(lint_one("src/a.rs", src).is_clean());
    }

    #[test]
    fn r2_waived() {
        let src = r#"
use std::collections::HashSet;
pub fn f(s: HashSet<u32>) -> u32 {
    // snn-lint: allow(unordered-iteration) — summation is order-independent
    s.iter().sum()
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.waived().count(), 1);
    }

    // ---- R3 no-raw-writes ------------------------------------------

    #[test]
    fn r3_fires_on_fs_write_and_file_create_everywhere() {
        let w = r#"pub fn f(p: &std::path::Path) { let _ = std::fs::write(p, b"x"); }"#;
        assert_eq!(unwaived_rules(&lint_one("src/a.rs", w)), vec!["no-raw-writes"]);
        // benches and tests are NOT exempt: crash-consistency is global
        assert_eq!(unwaived_rules(&lint_one("benches/b.rs", w)), vec!["no-raw-writes"]);
        let c = r#"pub fn f(p: &std::path::Path) { let _ = std::fs::File::create(p); }"#;
        assert_eq!(unwaived_rules(&lint_one("tests/t.rs", c)), vec!["no-raw-writes"]);
    }

    #[test]
    fn r3_clean_in_allowlisted_io_modules() {
        let w = r#"pub fn f(p: &std::path::Path) { let _ = std::fs::write(p, b"x"); }"#;
        assert!(lint_one("src/runtime/checkpoint.rs", w).is_clean());
        assert!(lint_one("src/hypergraph/io.rs", w).is_clean());
    }

    #[test]
    fn r3_waived() {
        let src = r#"
pub fn corrupt(p: &std::path::Path) {
    // snn-lint: allow(no-raw-writes) — corruption harness, atomicity is under test
    let _ = std::fs::write(p, b"x");
}
"#;
        let r = lint_one("tests/t.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    // ---- R4 unwrap-ban ---------------------------------------------

    #[test]
    fn r4_fires_on_unwrap_expect_panic_in_lib() {
        let src = r#"
pub fn f(x: Option<u32>) -> u32 { x.unwrap() }
pub fn g(x: Option<u32>) -> u32 { x.expect("set") }
pub fn h() { panic!("no"); }
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["unwrap-ban"; 3]);
    }

    #[test]
    fn r4_clean_in_tests_bins_and_benches() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert!(lint_one("tests/t.rs", src).is_clean());
        assert!(lint_one("benches/b.rs", src).is_clean());
        assert!(lint_one("src/bin/tool.rs", src).is_clean());
        assert!(lint_one("src/main.rs", src).is_clean());
        let in_test_mod =
            "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u32>) -> u32 { x.unwrap() }\n}\n";
        assert!(lint_one("src/a.rs", in_test_mod).is_clean());
    }

    #[test]
    fn r4_waived_with_reason() {
        let src = r#"
pub fn f(x: Option<u32>) -> u32 {
    // snn-lint: allow(unwrap-ban) — caller guarantees Some by construction
    x.unwrap()
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
        let reason = r.waived().next().and_then(|f| f.waived.clone());
        assert_eq!(reason.as_deref(), Some("caller guarantees Some by construction"));
    }

    #[test]
    fn r4_not_fooled_by_strings_comments_or_lookalikes() {
        let src = r#"
pub fn f() -> &'static str {
    // a comment mentioning x.unwrap() and panic!() changes nothing
    "x.unwrap() and panic!(msg) in a string are inert"
}
pub fn g(x: Option<u32>) -> u32 { x.unwrap_or(0) }
"#;
        assert!(lint_one("src/a.rs", src).is_clean());
    }

    // ---- R5 env-discipline -----------------------------------------

    #[test]
    fn r5_fires_outside_util() {
        let src = r#"pub fn f() -> String { std::env::var("X").unwrap_or_default() }"#;
        assert_eq!(unwaived_rules(&lint_one("src/mapping/a.rs", src)), vec!["env-discipline"]);
    }

    #[test]
    fn r5_clean_in_main_bins_artifacts_and_gated_util() {
        let src = r#"pub fn f() -> String { std::env::var("X").unwrap_or_default() }"#;
        assert!(lint_one("src/main.rs", src).is_clean());
        assert!(lint_one("src/bin/tool.rs", src).is_clean());
        assert!(lint_one("src/runtime/artifacts.rs", src).is_clean());
        let gated = r#"
use std::sync::OnceLock;
pub fn threads() -> usize {
    static V: OnceLock<usize> = OnceLock::new();
    *V.get_or_init(|| std::env::var("T").ok().and_then(|s| s.parse().ok()).unwrap_or(1))
}
"#;
        assert!(lint_one("src/util/par.rs", gated).is_clean());
    }

    #[test]
    fn r5_fires_in_util_without_oncelock_unless_waived() {
        let src = r#"pub fn f() -> String { std::env::var("X").unwrap_or_default() }"#;
        assert_eq!(unwaived_rules(&lint_one("src/util/x.rs", src)), vec!["env-discipline"]);
        let waived = r#"
pub fn f() -> String {
    // snn-lint: allow(env-discipline) — read once at startup by the coordinator
    std::env::var("X").unwrap_or_default()
}
"#;
        assert!(lint_one("src/util/x.rs", waived).is_clean());
    }

    // ---- R6 timing-gate --------------------------------------------

    #[test]
    fn r6_fires_on_unsunk_instant() {
        let src = r#"
pub fn f() -> u64 {
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
"#;
        assert_eq!(unwaived_rules(&lint_one("src/mapping/a.rs", src)), vec!["timing-gate"]);
    }

    #[test]
    fn r6_clean_when_feeding_stats_or_gated() {
        let sunk = r#"
pub struct RunStats { pub coarsen_secs: f64 }
pub fn f(stats: &mut RunStats) {
    let t = std::time::Instant::now();
    stats.coarsen_secs = t.elapsed().as_secs_f64();
}
"#;
        assert!(lint_one("src/mapping/a.rs", sunk).is_clean());
        let gated = r#"
pub fn f() {
    if crate::util::timing_enabled() {
        let t = std::time::Instant::now();
        eprintln!("{:?}", t.elapsed());
    }
}
"#;
        assert!(lint_one("src/mapping/a.rs", gated).is_clean());
        // util/ itself (the timer module) is out of scope
        let raw = "pub fn f() -> std::time::Instant { std::time::Instant::now() }\n";
        assert!(lint_one("src/util/timer.rs", raw).is_clean());
    }

    #[test]
    fn r6_waived() {
        let src = r#"
pub fn f() -> bool {
    // snn-lint: allow(timing-gate) — wall-clock budget is product semantics here
    let t = std::time::Instant::now();
    t.elapsed().as_secs() > 1
}
"#;
        assert!(lint_one("src/coordinator/a.rs", src).is_clean());
    }

    // ---- R7 threads-wiring -----------------------------------------

    #[test]
    fn r7_fires_when_ctx_threads_unread() {
        let src = r#"
pub struct P;
impl crate::stage::Partitioner for P {
    fn partition(&self) -> u32 { 0 }
}
"#;
        assert_eq!(unwaived_rules(&lint_one("src/mapping/a.rs", src)), vec!["threads-wiring"]);
    }

    #[test]
    fn r7_clean_when_ctx_threads_read_and_for_other_impls() {
        let src = r#"
pub struct P;
impl crate::stage::Partitioner for P {
    fn partition(&self, ctx: &StageCtx) -> u32 { ctx.threads as u32 }
}
impl Clone for P {
    fn clone(&self) -> P { P }
}
"#;
        assert!(lint_one("src/mapping/a.rs", src).is_clean());
    }

    #[test]
    fn r7_waived() {
        let src = r#"
pub struct P;
// snn-lint: allow(threads-wiring) — inherently sequential stage
impl crate::stage::Placer for P {
    fn place(&self) -> u32 { 0 }
}
"#;
        assert!(lint_one("src/mapping/a.rs", src).is_clean());
    }

    // ---- waiver parser ---------------------------------------------

    #[test]
    fn waiver_without_reason_is_rejected_and_does_not_waive() {
        let src = r#"
pub fn f(x: Option<u32>) -> u32 {
    // snn-lint: allow(unwrap-ban)
    x.unwrap()
}
"#;
        let r = lint_one("src/a.rs", src);
        let mut rules = unwaived_rules(&r);
        rules.sort();
        assert_eq!(rules, vec!["bad-waiver", "unwrap-ban"]);
    }

    #[test]
    fn waiver_with_separator_but_empty_reason_is_rejected() {
        let src = "// snn-lint: allow(unwrap-ban) —\npub fn f() {}\n";
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["bad-waiver"]);
    }

    #[test]
    fn waiver_with_unknown_rule_id_is_rejected() {
        let src = "// snn-lint: allow(no-such-rule) — because reasons\npub fn f() {}\n";
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["bad-waiver"]);
        assert!(r.findings[0].msg.contains("no-such-rule"), "{}", r.findings[0].msg);
    }

    #[test]
    fn malformed_waiver_marker_is_rejected() {
        let src = "// snn-lint: disallow(unwrap-ban) — nope\npub fn f() {}\n";
        assert_eq!(unwaived_rules(&lint_one("src/a.rs", src)), vec!["bad-waiver"]);
    }

    #[test]
    fn bad_waiver_cannot_itself_be_waived() {
        // `bad-waiver` is not a waivable rule id, so naming it is itself bad
        let src =
            "// snn-lint: allow(bad-waiver) — trying to silence the silencer\npub fn f() {}\n";
        assert_eq!(unwaived_rules(&lint_one("src/a.rs", src)), vec!["bad-waiver"]);
    }

    #[test]
    fn multi_rule_waiver_and_alternate_separators() {
        let src = r#"
pub fn f(x: Option<u32>) -> u32 {
    // snn-lint: allow(unwrap-ban, timing-gate) - plain-dash separator, both ids valid
    x.unwrap()
}
"#;
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn trailing_waiver_covers_its_own_line_only() {
        let src = r#"
pub fn f(x: Option<u32>, y: Option<u32>) -> u32 {
    let a = x.unwrap(); // snn-lint: allow(unwrap-ban) — covered inline
    let b = y.unwrap();
    a + b
}
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["unwrap-ban"]);
        assert_eq!(r.waived().count(), 1);
    }

    #[test]
    fn standalone_waiver_does_not_leak_past_next_line() {
        let src = r#"
pub fn f(x: Option<u32>, y: Option<u32>) -> u32 {
    // snn-lint: allow(unwrap-ban) — only the next line
    let a = x.unwrap();
    let b = y.unwrap();
    a + b
}
"#;
        let r = lint_one("src/a.rs", src);
        assert_eq!(unwaived_rules(&r), vec!["unwrap-ban"]);
    }

    #[test]
    fn unused_waiver_is_advisory_not_failing() {
        let src = "// snn-lint: allow(unwrap-ban) — nothing here needs it\npub fn f() {}\n";
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean());
        assert_eq!(r.unused_waivers.len(), 1);
    }

    #[test]
    fn doc_prose_mentioning_the_marker_is_not_a_waiver() {
        let src = "/// Waivers look like `// snn-lint: allow(rule)` in this repo.\npub fn f() {}\n";
        let r = lint_one("src/a.rs", src);
        assert!(r.is_clean(), "{}", r.render());
        assert!(r.unused_waivers.is_empty());
    }

    // ---- report shape ----------------------------------------------

    #[test]
    fn report_groups_by_rule_and_counts() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let r = lint_one("src/a.rs", src);
        let text = r.render();
        assert!(text.contains("[unwrap-ban]"), "{text}");
        assert!(text.contains("src/a.rs:1"), "{text}");
        assert!(text.contains("1 unwaived finding(s)"), "{text}");
    }
}
