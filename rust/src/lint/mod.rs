//! # snn-lint — the repo's determinism & invariant static-analysis pass
//!
//! The crate's value proposition — reproducible mappings, bit-for-bit
//! across thread counts, crash-consistent on disk — rests on disciplines
//! that used to live only in DESIGN.md §10–§13 and in tests. This module
//! turns them into machine-checked rules over the source tree itself
//! (`rust/src` + `rust/tests` + `rust/benches`), enforced by the
//! `snn_lint` binary in CI. The registry is offline, so there is no
//! `syn`: [`lexer`] is a small hand-rolled Rust lexer and every rule is
//! lexical/structural. See DESIGN.md §14 for the rule catalogue.
//!
//! A finding is suppressed by an inline waiver comment of the form
//! `// snn-lint: allow(rule-id) — reason`, where the reason is
//! mandatory: a waiver is a claim that an invariant makes the flagged
//! pattern safe, and the claim has to be written down. A waiver on its
//! own line covers the next code line; a trailing waiver covers its own
//! line. Malformed waivers (missing reason, unknown rule id) are
//! themselves findings — rule id `bad-waiver` — and cannot be waived.
//! A waiver that suppresses nothing is a hard error too: the gate
//! ([`LintReport::gate_ok`]) requires zero unwaived findings *and* zero
//! unused waivers, so stale waivers get deleted instead of rotting.
//!
//! The flow-aware rules (R8 `float-merge-order`, R9
//! `shared-mut-in-propose`) stand on [`parse`] (item-level structure:
//! parallel regions, closures, bindings, compound ops) and
//! [`crate_model`] (whole-crate symbol index: fn definitions,
//! float-returning fns, float fields, test-referenced idents), which
//! also lets R1 resolve serial twins across modules. [`sarif`] renders
//! a report as SARIF 2.1.0 or compact JSON for machine consumers.

pub mod crate_model;
pub mod lexer;
pub mod model;
pub mod parse;
pub mod rules;
pub mod sarif;

use std::path::Path;

/// One lint rule: stable id (used in waivers) plus a one-line summary.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    pub id: &'static str,
    pub summary: &'static str,
}

/// The rule catalogue, in reporting order (DESIGN.md §14).
pub const RULES: [Rule; 9] = [
    Rule {
        id: "parallel-serial-pairing",
        summary: "every *_parallel/*_threads fn needs a *_serial twin referenced from tests",
    },
    Rule {
        id: "unordered-iteration",
        summary: "no HashMap/HashSet iteration in non-test src/ unless sorted downstream",
    },
    Rule {
        id: "no-raw-writes",
        summary: "file writes go through checkpoint::atomic_write (or hypergraph/io.rs)",
    },
    Rule {
        id: "unwrap-ban",
        summary: "no unwrap()/expect()/panic! in library code without a reasoned waiver",
    },
    Rule {
        id: "env-discipline",
        summary: "env::var only in util/ behind OnceLock, main.rs, src/bin/ or artifacts.rs",
    },
    Rule {
        id: "timing-gate",
        summary: "Instant::now() in stage code must feed a *Stats field or timing_enabled()",
    },
    Rule {
        id: "threads-wiring",
        summary: "every impl Partitioner/Placer/Refiner must read ctx.threads",
    },
    Rule {
        id: "float-merge-order",
        summary: "no raw f32/f64 accumulation in parallel closures — use fixed-chunk ordered merge",
    },
    Rule {
        id: "shared-mut-in-propose",
        summary: "parallel closures write captured state only via index-disjoint slot writes",
    },
];

/// Pseudo-rule id for malformed waivers; never waivable.
pub const BAD_WAIVER: &str = "bad-waiver";

/// Where a file sits in the crate, which decides rule scope.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileClass {
    /// Library code under `src/` — every rule applies.
    Lib,
    /// `src/main.rs` and `src/bin/**` — R2/R3/R5 apply.
    Bin,
    /// `tests/**` — only R3 applies (plus waiver hygiene).
    Test,
    /// `benches/**` — only R3 applies (plus waiver hygiene).
    Bench,
}

/// Classify a crate-relative path (`/`-separated).
pub fn classify(path: &str) -> FileClass {
    if path.starts_with("tests/") {
        FileClass::Test
    } else if path.starts_with("benches/") {
        FileClass::Bench
    } else if path.starts_with("src/bin/") || path == "src/main.rs" {
        FileClass::Bin
    } else {
        FileClass::Lib
    }
}

/// One diagnostic: rule id, crate-relative path, 1-indexed line, message
/// and — when an inline waiver covers it — the waiver's reason.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    pub path: String,
    pub line: u32,
    pub msg: String,
    pub waived: Option<String>,
}

/// The result of a lint run over a file set.
#[derive(Debug, Default)]
pub struct LintReport {
    /// All findings (waived and unwaived), sorted by rule, path, line.
    pub findings: Vec<Finding>,
    /// Waivers that suppressed nothing. These fail the gate: a stale
    /// waiver is a standing invitation to reintroduce the violation it
    /// once covered, so it must be deleted (or re-aimed) immediately.
    pub unused_waivers: Vec<(String, u32)>,
    pub files_scanned: usize,
}

impl LintReport {
    /// Findings not covered by a waiver — these fail the build.
    pub fn unwaived(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.waived.is_none())
    }

    /// Findings suppressed by a reasoned waiver.
    pub fn waived(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.waived.is_some())
    }

    pub fn is_clean(&self) -> bool {
        self.unwaived().next().is_none()
    }

    /// The CI gate: no unwaived findings AND no unused waivers.
    pub fn gate_ok(&self) -> bool {
        self.is_clean() && self.unused_waivers.is_empty()
    }

    /// Human-readable report: unwaived findings grouped by rule with
    /// `path:line`, then a summary line, then unused-waiver errors.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut total_unwaived = 0usize;
        let rule_ids: Vec<&str> =
            RULES.iter().map(|r| r.id).chain(std::iter::once(BAD_WAIVER)).collect();
        for rid in rule_ids {
            let hits: Vec<&Finding> =
                self.unwaived().filter(|f| f.rule == rid).collect();
            if hits.is_empty() {
                continue;
            }
            total_unwaived += hits.len();
            let summary = RULES
                .iter()
                .find(|r| r.id == rid)
                .map(|r| r.summary)
                .unwrap_or("malformed `snn-lint:` waiver comment");
            out.push_str(&format!("[{rid}] {summary}\n"));
            for f in hits {
                out.push_str(&format!("  {}:{}  {}\n", f.path, f.line, f.msg));
            }
        }
        let waived = self.waived().count();
        out.push_str(&format!(
            "{} file(s) scanned: {} unwaived finding(s), {} waived, {} unused waiver(s)\n",
            self.files_scanned,
            total_unwaived,
            waived,
            self.unused_waivers.len()
        ));
        for (path, line) in &self.unused_waivers {
            out.push_str(&format!(
                "error: unused waiver at {path}:{line} — delete it or re-aim it at a real finding\n"
            ));
        }
        out
    }
}

/// Lint an in-memory file set of `(crate-relative path, source)` pairs.
pub fn lint_sources(files: &[(String, String)]) -> LintReport {
    rules::run(files)
}

/// Lint the crate tree rooted at `root` (the directory holding
/// `Cargo.toml`): walks `src/`, `tests/` and `benches/` in sorted order
/// so reports are deterministic across platforms.
pub fn lint_tree(root: &Path) -> Result<LintReport, String> {
    let mut files: Vec<(String, String)> = Vec::new();
    for sub in ["src", "tests", "benches"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs_files(&dir, root, &mut files)?;
        }
    }
    if files.is_empty() {
        return Err(format!("no .rs files under {}", root.display()));
    }
    Ok(lint_sources(&files))
}

fn collect_rs_files(
    dir: &Path,
    root: &Path,
    out: &mut Vec<(String, String)>,
) -> Result<(), String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
    let mut entries: Vec<std::path::PathBuf> = Vec::new();
    for ent in rd {
        let ent = ent.map_err(|e| format!("reading {}: {e}", dir.display()))?;
        entries.push(ent.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs_files(&p, root, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            let bytes =
                std::fs::read(&p).map_err(|e| format!("reading {}: {e}", p.display()))?;
            let src = String::from_utf8_lossy(&bytes).into_owned();
            let rel = p
                .strip_prefix(root)
                .map_err(|_| format!("{} escapes {}", p.display(), root.display()))?
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, src));
        }
    }
    Ok(())
}
