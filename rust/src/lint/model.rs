//! Per-file structural model built on the token stream: test regions,
//! function spans, `impl` blocks, and parsed waivers. This is the layer
//! between the lexer and the rules — rules only ever ask "is this token
//! inside a test?", "which fn encloses this?", "is this line waived?".

use super::lexer::{ident_at, match_delim, punct_at, Comment, TokKind, Token};
use super::RULES;

/// A `fn` item: its name, the line of the `fn` keyword, the token index
/// of the `fn` keyword, and the token span of its body (absent for
/// trait-method declarations ending in `;`).
#[derive(Debug, Clone)]
pub struct FnInfo {
    pub name: String,
    pub line: u32,
    pub kw_idx: usize,
    pub body: Option<(usize, usize)>,
}

/// An `impl` block: the trait name when it is a trait impl (`impl T for
/// U`), the line of the `impl` keyword, its body token span, and the
/// token index of the `impl` keyword.
#[derive(Debug, Clone)]
pub struct ImplInfo {
    pub trait_name: Option<String>,
    pub line: u32,
    pub body: (usize, usize),
    pub kw_idx: usize,
}

/// A parsed, well-formed waiver: the rules it waives, its mandatory
/// reason, and the source lines it covers (its own line, plus the next
/// token's line when the comment stands alone on its line).
#[derive(Debug, Clone)]
pub struct Waiver {
    pub line: u32,
    pub rules: Vec<String>,
    pub reason: String,
    pub covered: Vec<u32>,
}

/// A malformed waiver — reported as an unwaivable `bad-waiver` finding
/// (a waiver that silently failed to parse would silently stop waiving).
#[derive(Debug, Clone)]
pub struct BadWaiver {
    pub line: u32,
    pub msg: String,
}

/// Everything the rules need to know about one file.
#[derive(Debug, Default)]
pub struct FileModel {
    pub test_regions: Vec<(usize, usize)>,
    pub fns: Vec<FnInfo>,
    pub impls: Vec<ImplInfo>,
    pub waivers: Vec<Waiver>,
    pub bad_waivers: Vec<BadWaiver>,
}

impl FileModel {
    pub fn build(toks: &[Token], comments: &[Comment]) -> FileModel {
        let mut m = FileModel::default();
        m.scan_test_regions(toks);
        m.scan_fns(toks);
        m.scan_impls(toks);
        for c in comments {
            match parse_waiver(c, toks) {
                WaiverParse::NotAWaiver => {}
                WaiverParse::Ok(w) => m.waivers.push(w),
                WaiverParse::Bad(b) => m.bad_waivers.push(b),
            }
        }
        m
    }

    /// True iff token `idx` sits inside a `#[test]` / `#[cfg(test)]`
    /// region (attribute arguments containing the ident `test` but not
    /// `not`, so `#[cfg(not(test))]` does not count).
    pub fn in_test(&self, idx: usize) -> bool {
        self.test_regions.iter().any(|&(s, e)| s <= idx && idx <= e)
    }

    /// The innermost fn whose body contains token `idx`.
    pub fn enclosing_fn(&self, idx: usize) -> Option<&FnInfo> {
        self.fns
            .iter()
            .filter(|f| f.body.is_some_and(|(s, e)| s <= idx && idx <= e))
            .max_by_key(|f| f.body.map(|(s, _)| s))
    }

    fn scan_test_regions(&mut self, toks: &[Token]) {
        let n = toks.len();
        let mut i = 0usize;
        while i < n {
            if !(punct_at(toks, i, '#') && punct_at(toks, i + 1, '[')) {
                i += 1;
                continue;
            }
            let close = match_delim(toks, i + 1, '[', ']');
            let mut has_test = false;
            let mut has_not = false;
            for t in &toks[i + 1..=close.min(n - 1)] {
                if let TokKind::Ident(s) = &t.kind {
                    has_test |= s == "test";
                    has_not |= s == "not";
                }
            }
            if has_test && !has_not {
                // skip any further attributes, then span the next item
                let mut j = close + 1;
                while punct_at(toks, j, '#') && punct_at(toks, j + 1, '[') {
                    j = match_delim(toks, j + 1, '[', ']') + 1;
                }
                let mut k = j;
                while k < n {
                    if punct_at(toks, k, '{') {
                        self.test_regions.push((i, match_delim(toks, k, '{', '}')));
                        break;
                    }
                    if punct_at(toks, k, ';') {
                        self.test_regions.push((i, k));
                        break;
                    }
                    k += 1;
                }
            }
            i = close + 1;
        }
    }

    fn scan_fns(&mut self, toks: &[Token]) {
        let n = toks.len();
        for i in 0..n {
            if ident_at(toks, i) != Some("fn") {
                continue;
            }
            let Some(name) = ident_at(toks, i + 1) else { continue };
            let mut body = None;
            let mut k = i + 2;
            while k < n {
                if punct_at(toks, k, '{') {
                    body = Some((k, match_delim(toks, k, '{', '}')));
                    break;
                }
                if punct_at(toks, k, ';') {
                    break;
                }
                k += 1;
            }
            self.fns.push(FnInfo { name: name.to_string(), line: toks[i].line, kw_idx: i, body });
        }
    }

    fn scan_impls(&mut self, toks: &[Token]) {
        let n = toks.len();
        let mut i = 0usize;
        while i < n {
            if ident_at(toks, i) != Some("impl") {
                i += 1;
                continue;
            }
            // walk the header: at angle-depth 0, the ident before `for`
            // is the trait name (`impl<T> Trait<X> for Type { … }`)
            let mut angle = 0isize;
            let mut last_ident: Option<String> = None;
            let mut trait_name: Option<String> = None;
            let mut k = i + 1;
            while k < n {
                if punct_at(toks, k, '<') {
                    angle += 1;
                } else if punct_at(toks, k, '>') {
                    // `->` in the header (fn-pointer types) is not a closer
                    if !punct_at(toks, k.wrapping_sub(1), '-') {
                        angle = (angle - 1).max(0);
                    }
                } else if angle == 0 && (punct_at(toks, k, '{') || punct_at(toks, k, ';')) {
                    if punct_at(toks, k, '{') {
                        self.impls.push(ImplInfo {
                            trait_name: trait_name.clone(),
                            line: toks[i].line,
                            body: (k, match_delim(toks, k, '{', '}')),
                            kw_idx: i,
                        });
                    }
                    break;
                } else if angle == 0 {
                    if let Some(id) = ident_at(toks, k) {
                        if id == "for" {
                            trait_name = last_ident.take();
                        } else {
                            last_ident = Some(id.to_string());
                        }
                    }
                }
                k += 1;
            }
            i = k.max(i + 1);
        }
    }
}

enum WaiverParse {
    NotAWaiver,
    Ok(Waiver),
    Bad(BadWaiver),
}

/// Strip comment-decoration (`/`, `!`, whitespace) from the front; a
/// waiver marker must be the first thing left. Doc prose that *mentions*
/// the marker mid-sentence or in backticks therefore never parses.
fn comment_payload(text: &str) -> &str {
    text.trim_start_matches(|c: char| c == '/' || c == '!' || c.is_whitespace())
}

fn parse_waiver(c: &Comment, toks: &[Token]) -> WaiverParse {
    let t = comment_payload(&c.text);
    let Some(rest) = t.strip_prefix("snn-lint:") else {
        return WaiverParse::NotAWaiver;
    };
    let bad = |msg: &str| {
        WaiverParse::Bad(BadWaiver { line: c.line, msg: msg.to_string() })
    };
    let rest = rest.trim_start();
    let Some(after_allow) = rest.strip_prefix("allow") else {
        return bad("malformed waiver: expected `allow(<rule-id>)`");
    };
    let after_allow = after_allow.trim_start();
    let Some(inner) = after_allow.strip_prefix('(') else {
        return bad("malformed waiver: expected `allow(<rule-id>)`");
    };
    let Some(close) = inner.find(')') else {
        return bad("malformed waiver: unclosed `allow(`");
    };
    let ids: Vec<String> = inner[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if ids.is_empty() {
        return bad("waiver names no rule");
    }
    if let Some(unknown) = ids.iter().find(|id| !RULES.iter().any(|r| r.id == id.as_str())) {
        return WaiverParse::Bad(BadWaiver {
            line: c.line,
            msg: format!("unknown rule id `{unknown}`"),
        });
    }
    let reason = inner[close + 1..]
        .trim_start_matches(|ch: char| {
            ch == '-' || ch == '\u{2014}' || ch == '\u{2013}' || ch == ':' || ch.is_whitespace()
        })
        .trim();
    if reason.is_empty() {
        return bad("waiver must carry a reason after the rule list");
    }
    let mut covered = vec![c.line];
    if c.standalone {
        if let Some(next) = toks.iter().map(|t| t.line).find(|&l| l > c.line) {
            covered.push(next);
        }
    }
    WaiverParse::Ok(Waiver { line: c.line, rules: ids, reason: reason.to_string(), covered })
}
