//! A minimal Rust lexer for the lint pass: enough token structure to tell
//! identifiers, punctuation, literals and lifetimes apart, with comments
//! captured out-of-band. The registry is offline, so `syn` is not an
//! option — and the rules only need lexical shape, not a parse tree.
//!
//! Guarantees the rules rely on:
//! * string/char/byte/raw-string literal *contents* never surface as
//!   tokens (a `"fs::write"` inside a fixture string cannot fire R3);
//! * comments never surface as tokens, but are kept with their line and
//!   a `standalone` flag so the waiver parser can decide coverage;
//! * nested block comments and `r#"…"#`-style raw strings are honored;
//! * `'a` (lifetime) and `'a'` (char) are distinguished so a lifetime
//!   never swallows the token after it.

/// Token kind. Literal payloads are dropped deliberately — no rule may
/// depend on literal contents, which keeps fixtures-in-strings inert.
/// The one exception is a single *shape* bit on numeric literals: R8
/// (float-merge-order) needs to know that `0.0f64` is a float without
/// ever seeing its value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `impl`, `unwrap`, …).
    Ident(String),
    /// Single punctuation byte (`.`, `:`, `{`, …).
    Punct(char),
    /// Any literal: string, raw string, byte string, char, number.
    /// `float` is true only for numeric literals with a decimal point or
    /// an `f32`/`f64` suffix (hex/binary/octal never count).
    Lit { float: bool },
    /// A lifetime such as `'a` or `'static`.
    Lifetime,
}

/// One token with its 1-indexed source line.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub line: u32,
}

/// One comment (line or block), with the line it starts on and whether
/// it is the first thing on that line (`standalone`) — waivers in
/// standalone comments extend their coverage to the next token's line.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub standalone: bool,
    pub text: String,
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Index just past the closing quote of a char literal whose opening `'`
/// is at `start`. Escape-aware, so `'\''` and `'\\'` terminate at the
/// real closing quote instead of the escaped one. Stops at end-of-line
/// on malformed input rather than swallowing the rest of the file.
fn scan_char_lit(b: &[u8], start: usize) -> usize {
    let n = b.len();
    let mut j = start + 1;
    if j < n && b[j] == b'\\' {
        j += 2; // consume the escape introducer and the escaped byte
    } else if j < n {
        j += 1;
    }
    while j < n && b[j] != b'\'' && b[j] != b'\n' {
        j += 1;
    }
    if j < n && b[j] == b'\'' {
        j + 1
    } else {
        j
    }
}

/// Lex `src` into tokens plus out-of-band comments. Never fails: bytes
/// the lexer does not understand become single-byte `Punct` tokens.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Token> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut tokens_on_line = false;

    let text_of = |range: &[u8]| String::from_utf8_lossy(range).into_owned();

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            tokens_on_line = false;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i + 2;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            comments.push(Comment {
                line,
                standalone: !tokens_on_line,
                text: text_of(&b[i + 2..j]),
            });
            i = j;
            continue;
        }
        // block comment (nested)
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start_line = line;
            let standalone = !tokens_on_line;
            let mut depth = 1usize;
            let mut j = i + 2;
            let text_start = j;
            let mut text_end = j;
            while j < n && depth > 0 {
                if b[j] == b'\n' {
                    line += 1;
                }
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                    continue;
                }
                if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                    if depth == 0 {
                        text_end = j - 2;
                    }
                    continue;
                }
                j += 1;
                text_end = j;
            }
            comments.push(Comment {
                line: start_line,
                standalone,
                text: text_of(&b[text_start..text_end.min(n)]),
            });
            i = j;
            continue;
        }
        // raw / byte strings and raw identifiers: r"…", r#"…"#, b"…",
        // br#"…"#, r#ident
        if c == b'r' || c == b'b' {
            let mut j = i;
            if b[j] == b'b' {
                j += 1;
            }
            let raw = j < n && b[j] == b'r';
            if raw {
                j += 1;
            }
            let mut hashes = 0usize;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            let bytestr_prefix_len = if c == b'b' { 1 } else { 0 };
            let plain_byte_string = hashes == 0 && j == i + bytestr_prefix_len;
            if j < n && b[j] == b'"' && (raw || plain_byte_string) {
                let start_line = line;
                j += 1;
                if raw {
                    // scan for `"` followed by `hashes` hashes
                    loop {
                        if j >= n {
                            break;
                        }
                        if b[j] == b'\n' {
                            line += 1;
                            j += 1;
                            continue;
                        }
                        if b[j] == b'"' {
                            let mut k = j + 1;
                            let mut seen = 0usize;
                            while k < n && seen < hashes && b[k] == b'#' {
                                seen += 1;
                                k += 1;
                            }
                            if seen == hashes {
                                j = k;
                                break;
                            }
                        }
                        j += 1;
                    }
                } else {
                    // escape-aware byte string
                    while j < n {
                        if b[j] == b'\\' {
                            // an escaped newline (line continuation) still
                            // advances the source line counter
                            if j + 1 < n && b[j + 1] == b'\n' {
                                line += 1;
                            }
                            j += 2;
                            continue;
                        }
                        if b[j] == b'\n' {
                            line += 1;
                        }
                        if b[j] == b'"' {
                            j += 1;
                            break;
                        }
                        j += 1;
                    }
                }
                toks.push(Token { kind: TokKind::Lit { float: false }, line: start_line });
                tokens_on_line = true;
                i = j;
                continue;
            }
            // byte-char literal b'x' / b'\n' — without this, `b'a'` would
            // lex as Ident("b") + char literal and desync waiver lines
            if c == b'b' && i + 1 < n && b[i + 1] == b'\'' {
                let j = scan_char_lit(b, i + 1);
                toks.push(Token { kind: TokKind::Lit { float: false }, line });
                tokens_on_line = true;
                i = j;
                continue;
            }
            // raw identifier r#ident
            if c == b'r'
                && i + 2 < n
                && b[i + 1] == b'#'
                && (b[i + 2].is_ascii_alphabetic() || b[i + 2] == b'_')
            {
                let mut j = i + 2;
                while j < n && is_ident_byte(b[j]) {
                    j += 1;
                }
                toks.push(Token { kind: TokKind::Ident(text_of(&b[i + 2..j])), line });
                tokens_on_line = true;
                i = j;
                continue;
            }
        }
        // identifier / keyword
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i;
            while j < n && is_ident_byte(b[j]) {
                j += 1;
            }
            toks.push(Token { kind: TokKind::Ident(text_of(&b[i..j])), line });
            tokens_on_line = true;
            i = j;
            continue;
        }
        // string literal
        if c == b'"' {
            let start_line = line;
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    if j + 1 < n && b[j + 1] == b'\n' {
                        line += 1;
                    }
                    j += 2;
                    continue;
                }
                if b[j] == b'\n' {
                    line += 1;
                }
                if b[j] == b'"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            toks.push(Token { kind: TokKind::Lit { float: false }, line: start_line });
            tokens_on_line = true;
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            if (i + 1 < n && b[i + 1] == b'\\') || (i + 2 < n && b[i + 2] == b'\'') {
                let j = scan_char_lit(b, i);
                toks.push(Token { kind: TokKind::Lit { float: false }, line });
                tokens_on_line = true;
                i = j;
                continue;
            }
            let mut j = i + 1;
            while j < n && is_ident_byte(b[j]) {
                j += 1;
            }
            toks.push(Token { kind: TokKind::Lifetime, line });
            tokens_on_line = true;
            i = j;
            continue;
        }
        // number literal (digits, `1_000u32`, `1.5e-3`, `0xff`)
        if c.is_ascii_digit() {
            let mut j = i;
            loop {
                while j < n && is_ident_byte(b[j]) {
                    j += 1;
                }
                if j < n && b[j] == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                    j += 1;
                    continue;
                }
                if j < n && (b[j] == b'+' || b[j] == b'-') && j > i && (b[j - 1] | 0x20) == b'e' {
                    j += 1;
                    continue;
                }
                break;
            }
            let text = &b[i..j];
            let prefixed = text.len() > 1
                && text[0] == b'0'
                && matches!(text[1] | 0x20, b'x' | b'b' | b'o');
            let float = !prefixed
                && (text.contains(&b'.') || text.ends_with(b"f32") || text.ends_with(b"f64"));
            toks.push(Token { kind: TokKind::Lit { float }, line });
            tokens_on_line = true;
            i = j;
            continue;
        }
        toks.push(Token { kind: TokKind::Punct(c as char), line });
        tokens_on_line = true;
        i += 1;
    }
    (toks, comments)
}

/// The identifier text at `i`, if that token is an identifier.
pub fn ident_at(toks: &[Token], i: usize) -> Option<&str> {
    match toks.get(i) {
        Some(Token { kind: TokKind::Ident(s), .. }) => Some(s.as_str()),
        _ => None,
    }
}

/// True iff token `i` is the punctuation byte `c`.
pub fn punct_at(toks: &[Token], i: usize, c: char) -> bool {
    matches!(toks.get(i), Some(Token { kind: TokKind::Punct(p), .. }) if *p == c)
}

/// True iff token `i` is a float-shaped numeric literal (`1.5`, `0.0f64`,
/// `2f32` — never hex/binary/octal or integer literals).
pub fn float_lit_at(toks: &[Token], i: usize) -> bool {
    matches!(toks.get(i), Some(Token { kind: TokKind::Lit { float: true }, .. }))
}

/// True iff tokens at `i..` spell the path segment pair `a::b`.
pub fn path2_at(toks: &[Token], i: usize, a: &str, b: &str) -> bool {
    ident_at(toks, i) == Some(a)
        && punct_at(toks, i + 1, ':')
        && punct_at(toks, i + 2, ':')
        && ident_at(toks, i + 3) == Some(b)
}

/// Index of the brace that closes the `open`/`close` pair whose opening
/// token sits at `open_idx`. Falls back to the last token on imbalance
/// (truncated input) — rules degrade to over-scanning, never panic.
pub fn match_delim(toks: &[Token], open_idx: usize, open: char, close: char) -> usize {
    let mut depth = 0isize;
    let mut k = open_idx;
    while k < toks.len() {
        if punct_at(toks, k, open) {
            depth += 1;
        } else if punct_at(toks, k, close) {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
        k += 1;
    }
    toks.len().saturating_sub(1)
}
