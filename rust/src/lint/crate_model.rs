//! Whole-crate symbol index for cross-module rules. Per-file models
//! ([`super::model::FileModel`]) only see one file; R1's twin resolution
//! and R8's float-flow reasoning need crate-wide facts: which fn names
//! exist (and where), which fns return floats, which struct fields are
//! float-typed, and which idents are referenced from test/bench context.
//!
//! Resolution is *lexical*, by bare name: a call `score(x)` resolves to
//! every non-test lib fn named `score`, wherever it lives. That is
//! deliberately conservative — with no type checker, a name match is
//! the strongest link available, and the rules that consume it (R8
//! one-hop) only use it to *add* evidence, never to exonerate.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{float_lit_at, ident_at, match_delim, punct_at, Token, TokKind};
use super::model::{FileModel, FnInfo};
use super::parse::{scan_use_paths, UseImport};
use super::FileClass;

/// One lexed + modeled file, the unit the crate model is built from.
pub struct FileCtx {
    pub path: String,
    pub class: FileClass,
    pub toks: Vec<Token>,
    pub model: FileModel,
}

/// A reference into `files[file].model.fns[fn_idx]`.
#[derive(Debug, Clone, Copy)]
pub struct FnRef {
    pub file: usize,
    pub fn_idx: usize,
}

/// Crate-wide lexical index over a file set.
pub struct CrateModel {
    /// Non-test lib fns by bare name, in file order. Multiple entries
    /// mean the name is ambiguous; consumers take the first or all.
    pub fn_index: BTreeMap<String, Vec<FnRef>>,
    /// Names of lib fns whose return type mentions `f32`/`f64`.
    pub float_fns: BTreeSet<String>,
    /// Names of struct fields whose declared type mentions `f32`/`f64`.
    pub float_fields: BTreeSet<String>,
    /// Every ident that appears in test/bench context anywhere.
    pub test_referenced: BTreeSet<String>,
    /// Per-file `use` imports (parallel to the input file slice).
    pub uses: Vec<Vec<UseImport>>,
}

impl CrateModel {
    pub fn build(files: &[FileCtx]) -> CrateModel {
        let mut fn_index: BTreeMap<String, Vec<FnRef>> = BTreeMap::new();
        let mut float_fns = BTreeSet::new();
        let mut float_fields = BTreeSet::new();
        let mut test_referenced = BTreeSet::new();
        let mut uses = Vec::with_capacity(files.len());

        for (fi, f) in files.iter().enumerate() {
            uses.push(scan_use_paths(&f.toks));
            let whole_file_is_test = matches!(f.class, FileClass::Test | FileClass::Bench);
            for (i, t) in f.toks.iter().enumerate() {
                if let TokKind::Ident(id) = &t.kind {
                    if whole_file_is_test || f.model.in_test(i) {
                        test_referenced.insert(id.clone());
                    }
                }
            }
            if f.class != FileClass::Lib {
                continue;
            }
            let n = f.toks.len();
            for (xi, func) in f.model.fns.iter().enumerate() {
                if !f.model.in_test(func.kw_idx) {
                    fn_index
                        .entry(func.name.clone())
                        .or_default()
                        .push(FnRef { file: fi, fn_idx: xi });
                }
                // return type: the span between `->` and the body open
                let sig_end = func.body.map(|(s, _)| s).unwrap_or(n);
                let mut j = func.kw_idx;
                while j + 1 < sig_end {
                    if punct_at(&f.toks, j, '-') && punct_at(&f.toks, j + 1, '>') {
                        let floaty = (j + 2..sig_end).any(|m| {
                            matches!(ident_at(&f.toks, m), Some("f32") | Some("f64"))
                        });
                        if floaty {
                            float_fns.insert(func.name.clone());
                        }
                        break;
                    }
                    j += 1;
                }
            }
            struct_float_fields(&f.toks, &mut float_fields);
        }
        CrateModel { fn_index, float_fns, float_fields, test_referenced, uses }
    }

    /// Float-typed names scoped to one fn: float-ascribed params plus
    /// `let` bindings in the body that are float by ascription or by a
    /// float-shaped initializer. Scoping per fn (not per file) is what
    /// keeps an integer-only closure clean in a file that also handles
    /// floats — the §16 NoC accounting path depends on this.
    pub fn fn_float_names(&self, file: &FileCtx, func: &FnInfo) -> BTreeSet<String> {
        let mut out = BTreeSet::new();
        let Some((body_s, body_e)) = func.body else { return out };
        let toks = &file.toks;
        // params: `name: …f32/f64…` up to the matching `,`/`)`
        for j in func.kw_idx..body_s {
            if !punct_at(toks, j + 1, ':') || punct_at(toks, j + 2, ':') {
                continue;
            }
            let Some(name) = ident_at(toks, j) else { continue };
            let mut depth = 0isize;
            let mut m = j + 2;
            while m < body_s {
                match toks[m].kind {
                    TokKind::Punct(c) if c == '<' || c == '(' => depth += 1,
                    TokKind::Punct(c) if c == '>' || c == ')' => {
                        depth -= 1;
                        if depth < 0 {
                            break;
                        }
                    }
                    TokKind::Punct(',') if depth <= 0 => break,
                    _ => {}
                }
                if matches!(ident_at(toks, m), Some("f32") | Some("f64")) {
                    out.insert(name.to_string());
                    break;
                }
                m += 1;
            }
        }
        span_float_lets(toks, body_s, body_e, &self.float_fns, &mut out);
        out
    }
}

/// Add to `out` every `let`-bound name in `[lo, hi]` that is float by
/// type ascription or whose initializer expression mentions `f32`/`f64`,
/// a float literal, or a call-position float-returning fn name.
pub fn span_float_lets(
    toks: &[Token],
    lo: usize,
    hi: usize,
    float_fns: &BTreeSet<String>,
    out: &mut BTreeSet<String>,
) {
    let n = toks.len().min(hi + 1);
    for i in lo..n {
        if ident_at(toks, i) != Some("let") {
            continue;
        }
        let mut k = i + 1;
        if ident_at(toks, k) == Some("mut") {
            k += 1;
        }
        let Some(name) = ident_at(toks, k) else { continue };
        let mut j = k + 1;
        let mut floaty = false;
        if punct_at(toks, j, ':') && !punct_at(toks, j + 1, ':') {
            let mut m = j + 1;
            while m < n && !punct_at(toks, m, '=') && !punct_at(toks, m, ';') {
                if matches!(ident_at(toks, m), Some("f32") | Some("f64")) {
                    floaty = true;
                }
                m += 1;
            }
            j = m;
        }
        while j < n && !punct_at(toks, j, '=') && !punct_at(toks, j, ';') {
            j += 1;
        }
        if punct_at(toks, j, '=') && !punct_at(toks, j + 1, '=') {
            // initializer: scan to the statement's `;` at depth 0
            let mut depth = 0isize;
            let mut m = j + 1;
            while m < n {
                match toks[m].kind {
                    TokKind::Punct(c) if c == '(' || c == '[' || c == '{' => depth += 1,
                    TokKind::Punct(c) if c == ')' || c == ']' || c == '}' => {
                        depth -= 1;
                        if depth < 0 {
                            break;
                        }
                    }
                    TokKind::Punct(';') if depth == 0 => break,
                    _ => {}
                }
                if let Some(id) = ident_at(toks, m) {
                    if id == "f32" || id == "f64" || float_fns.contains(id) {
                        floaty = true;
                    }
                }
                if float_lit_at(toks, m) {
                    floaty = true;
                }
                m += 1;
            }
        }
        if floaty {
            out.insert(name.to_string());
        }
    }
}

/// Add to `out` the names of struct fields whose declared type mentions
/// `f32`/`f64` (depth-1 fields of `struct S { … }` declarations).
fn struct_float_fields(toks: &[Token], out: &mut BTreeSet<String>) {
    let n = toks.len();
    for i in 0..n {
        if ident_at(toks, i) != Some("struct") || ident_at(toks, i + 1).is_none() {
            continue;
        }
        let mut k = i + 2;
        while k < n && !punct_at(toks, k, '{') && !punct_at(toks, k, ';') && !punct_at(toks, k, '(')
        {
            k += 1;
        }
        if !punct_at(toks, k, '{') {
            continue;
        }
        let end = match_delim(toks, k, '{', '}');
        let mut depth = 0isize;
        for j in k..end {
            if punct_at(toks, j, '{') {
                depth += 1;
            } else if punct_at(toks, j, '}') {
                depth -= 1;
            } else if depth == 1 && punct_at(toks, j + 1, ':') && !punct_at(toks, j + 2, ':') {
                let Some(name) = ident_at(toks, j) else { continue };
                let mut fdepth = 0isize;
                let mut m = j + 2;
                while m < end {
                    match toks[m].kind {
                        TokKind::Punct(c) if c == '<' || c == '(' || c == '[' => fdepth += 1,
                        TokKind::Punct(c) if c == '>' || c == ')' || c == ']' => fdepth -= 1,
                        TokKind::Punct(',') if fdepth <= 0 => break,
                        _ => {}
                    }
                    if matches!(ident_at(toks, m), Some("f32") | Some("f64")) {
                        out.insert(name.to_string());
                        break;
                    }
                    m += 1;
                }
            }
        }
    }
}
