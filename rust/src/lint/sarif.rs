//! Machine-readable lint output: SARIF 2.1.0 (the interchange format
//! GitHub code scanning and most IDE SARIF viewers consume) plus a
//! compact plain-JSON shape for scripting. Both are built on
//! [`crate::util::json::Json`], whose BTreeMap-backed objects give
//! byte-deterministic output — the SARIF snapshot test depends on that.
//!
//! Contract (pinned by `tests/lint_sarif.rs`):
//! * `version` is exactly `"2.1.0"` and `$schema` points at the
//!   canonical 2.1.0 schema URI;
//! * the driver's rule array lists the nine catalogue rules in
//!   reporting order, followed by `bad-waiver` and `unused-waiver`;
//! * unwaived findings are `level: error`; waived findings are
//!   `level: note` carrying an `inSource` suppression whose
//!   justification is the waiver's reason verbatim;
//! * unused waivers are `unused-waiver` errors (the gate fails on them).

use super::{LintReport, BAD_WAIVER, RULES};
use crate::util::json::Json;

/// The SARIF spec version emitted — pinned, never inferred.
pub const SARIF_VERSION: &str = "2.1.0";
/// Canonical schema URI for SARIF 2.1.0.
pub const SARIF_SCHEMA: &str =
    "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json";
/// Pseudo-rule id for waivers that suppress nothing (enforced).
pub const UNUSED_WAIVER: &str = "unused-waiver";

/// All reportable rule ids in catalogue order: R1–R9, then the two
/// pseudo-rules. `ruleIndex` in results indexes into this order.
pub fn rule_ids() -> Vec<&'static str> {
    RULES.iter().map(|r| r.id).chain([BAD_WAIVER, UNUSED_WAIVER]).collect()
}

fn rule_summary(id: &str) -> &'static str {
    if id == BAD_WAIVER {
        return "malformed `snn-lint:` waiver comment";
    }
    if id == UNUSED_WAIVER {
        return "waiver that suppresses no finding — stale, must be deleted";
    }
    RULES.iter().find(|r| r.id == id).map(|r| r.summary).unwrap_or("")
}

fn location(path: &str, line: u32) -> Json {
    Json::Arr(vec![Json::obj(vec![(
        "physicalLocation",
        Json::obj(vec![
            (
                "artifactLocation",
                Json::obj(vec![("uri", Json::Str(path.to_string()))]),
            ),
            ("region", Json::obj(vec![("startLine", Json::Num(f64::from(line)))])),
        ]),
    )])])
}

/// Render a report as a SARIF 2.1.0 log with one run.
pub fn to_sarif(report: &LintReport) -> Json {
    let ids = rule_ids();
    let rule_index = |id: &str| ids.iter().position(|r| *r == id);

    let rules: Vec<Json> = ids
        .iter()
        .map(|id| {
            Json::obj(vec![
                ("id", Json::Str((*id).to_string())),
                (
                    "shortDescription",
                    Json::obj(vec![("text", Json::Str(rule_summary(id).to_string()))]),
                ),
            ])
        })
        .collect();

    let mut results: Vec<Json> = Vec::new();
    for f in &report.findings {
        let level = if f.waived.is_some() { "note" } else { "error" };
        let mut pairs: Vec<(&str, Json)> = vec![
            ("ruleId", Json::Str(f.rule.clone())),
            ("level", Json::Str(level.to_string())),
            ("message", Json::obj(vec![("text", Json::Str(f.msg.clone()))])),
            ("locations", location(&f.path, f.line)),
        ];
        if let Some(idx) = rule_index(&f.rule) {
            pairs.push(("ruleIndex", Json::Num(idx as f64)));
        }
        if let Some(reason) = &f.waived {
            pairs.push((
                "suppressions",
                Json::Arr(vec![Json::obj(vec![
                    ("kind", Json::Str("inSource".to_string())),
                    ("justification", Json::Str(reason.clone())),
                ])]),
            ));
        }
        results.push(Json::obj(pairs));
    }
    for (path, line) in &report.unused_waivers {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("ruleId", Json::Str(UNUSED_WAIVER.to_string())),
            ("level", Json::Str("error".to_string())),
            (
                "message",
                Json::obj(vec![(
                    "text",
                    Json::Str(format!(
                        "unused waiver at {path}:{line} — delete it or re-aim it at a real \
                         finding"
                    )),
                )]),
            ),
            ("locations", location(path, *line)),
        ];
        if let Some(idx) = rule_index(UNUSED_WAIVER) {
            pairs.push(("ruleIndex", Json::Num(idx as f64)));
        }
        results.push(Json::obj(pairs));
    }

    let driver = Json::obj(vec![
        ("name", Json::Str("snn-lint".to_string())),
        ("version", Json::Str(env!("CARGO_PKG_VERSION").to_string())),
        ("rules", Json::Arr(rules)),
    ]);
    let run = Json::obj(vec![
        ("tool", Json::obj(vec![("driver", driver)])),
        ("results", Json::Arr(results)),
    ]);
    Json::obj(vec![
        ("$schema", Json::Str(SARIF_SCHEMA.to_string())),
        ("version", Json::Str(SARIF_VERSION.to_string())),
        ("runs", Json::Arr(vec![run])),
    ])
}

/// Render a report as compact machine-readable JSON (not SARIF): the
/// full finding list, unused waivers, counts and the gate verdict.
pub fn to_json(report: &LintReport) -> Json {
    let findings: Vec<Json> = report
        .findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("rule", Json::Str(f.rule.clone())),
                ("path", Json::Str(f.path.clone())),
                ("line", Json::Num(f64::from(f.line))),
                ("message", Json::Str(f.msg.clone())),
                (
                    "waived",
                    match &f.waived {
                        Some(r) => Json::Str(r.clone()),
                        None => Json::Null,
                    },
                ),
            ])
        })
        .collect();
    let unused: Vec<Json> = report
        .unused_waivers
        .iter()
        .map(|(path, line)| {
            Json::obj(vec![
                ("path", Json::Str(path.clone())),
                ("line", Json::Num(f64::from(*line))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("filesScanned", Json::Num(report.files_scanned as f64)),
        ("unwaived", Json::Num(report.unwaived().count() as f64)),
        ("waived", Json::Num(report.waived().count() as f64)),
        ("findings", Json::Arr(findings)),
        ("unusedWaivers", Json::Arr(unused)),
        ("gateOk", Json::Bool(report.gate_ok())),
    ])
}
