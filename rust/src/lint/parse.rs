//! Item-level parsing on top of the lexer: parallel-combinator call
//! regions, closure heads, in-scope bindings, compound assignments,
//! statement spans, lexically-resolvable calls and `use` imports. This
//! is the structural vocabulary the flow-aware rules (R8/R9) and the
//! crate model are written in — [`super::model::FileModel`] stays the
//! per-file item index (fns, impls, test regions, waivers), while this
//! module answers expression-shaped questions inside those items.
//!
//! Everything here is lexical: spans are inclusive token-index ranges,
//! possibly empty (`start > end`), and every walk degrades to
//! over-scanning on malformed input rather than panicking.

use std::collections::BTreeSet;

use super::lexer::{ident_at, match_delim, punct_at, Token, TokKind};

/// The crate's parallel entry points (`util::par` plus `scope.spawn`):
/// a call to any of these opens a *parallel region* whose closure body
/// runs concurrently and is subject to the propose/commit discipline.
pub const PAR_COMBINATORS: [&str; 4] = ["par_map", "chunked_fold", "par_chunks_mut", "spawn"];

/// Rust keywords and path roots that can never be a captured binding.
const KEYWORDS: [&str; 34] = [
    "if", "else", "while", "for", "in", "loop", "match", "return", "break", "continue", "let",
    "mut", "fn", "move", "ref", "pub", "use", "mod", "impl", "struct", "enum", "trait", "type",
    "const", "static", "where", "unsafe", "as", "dyn", "crate", "super", "self", "Self", "true",
];

/// Methods that mutate (or unlock mutation of) their receiver — calling
/// one on captured state inside a parallel closure is a shared write.
const MUT_METHODS: [&str; 36] = [
    "push", "push_str", "insert", "remove", "extend", "clear", "pop", "drain", "append", "retain",
    "truncate", "resize", "resize_with", "fill", "set", "sort", "sort_by", "sort_by_key",
    "sort_unstable", "sort_unstable_by", "sort_unstable_by_key", "store", "fetch_add",
    "fetch_sub", "fetch_and", "fetch_or", "fetch_xor", "compare_exchange", "swap", "replace",
    "take", "lock", "borrow_mut", "get_mut", "write", "next",
];

pub fn is_keyword(name: &str) -> bool {
    KEYWORDS.contains(&name) || name == "false"
}

pub fn is_mut_method(name: &str) -> bool {
    MUT_METHODS.contains(&name)
}

/// One parallel-combinator call site: the combinator name, the line of
/// the call, the token index of the combinator ident, and the inclusive
/// token span of the call's argument list (excluding the parens —
/// possibly empty, in which case `args.0 > args.1`).
#[derive(Debug, Clone)]
pub struct ParRegion {
    pub combinator: String,
    pub line: u32,
    pub call_idx: usize,
    pub args: (usize, usize),
}

/// Every parallel-combinator *call* in the token stream. Definitions
/// (`fn par_map(…)` in `util/par.rs` itself) are skipped via the
/// preceding-`fn` check.
pub fn parallel_regions(toks: &[Token]) -> Vec<ParRegion> {
    let mut out = Vec::new();
    for i in 0..toks.len() {
        let Some(name) = ident_at(toks, i) else { continue };
        if !PAR_COMBINATORS.contains(&name) || !punct_at(toks, i + 1, '(') {
            continue;
        }
        if i > 0 && ident_at(toks, i - 1) == Some("fn") {
            continue;
        }
        let close = match_delim(toks, i + 1, '(', ')');
        out.push(ParRegion {
            combinator: name.to_string(),
            line: toks[i].line,
            call_idx: i,
            args: (i + 2, close.saturating_sub(1)),
        });
    }
    out
}

/// True iff the `|` at `k` opens a closure head (rather than being a
/// binary/bitwise or-pattern `|`): it must follow a call/list/statement
/// boundary, an `=`, or the `move` keyword.
fn closure_bar_at(toks: &[Token], k: usize, span_start: usize, allow_return: bool) -> bool {
    if !punct_at(toks, k, '|') {
        return false;
    }
    if k == span_start || k == 0 {
        return true;
    }
    punct_at(toks, k - 1, '(')
        || punct_at(toks, k - 1, ',')
        || punct_at(toks, k - 1, '{')
        || punct_at(toks, k - 1, ';')
        || punct_at(toks, k - 1, '=')
        || ident_at(toks, k - 1) == Some("move")
        || (allow_return && ident_at(toks, k - 1) == Some("return"))
}

/// Token index of the first closure-opening `|` in `[s, e]`, if any.
/// Rules that only govern the concurrent body (R8/R9) scan from here so
/// arguments *before* the closure (`&mut data`, chunk sizes) stay out
/// of scope.
pub fn closure_start(toks: &[Token], s: usize, e: usize) -> Option<usize> {
    let mut k = s;
    while k <= e && k < toks.len() {
        if closure_bar_at(toks, k, s, false) {
            return Some(k);
        }
        k += 1;
    }
    None
}

/// Names bound *inside* `[s, e]`: closure parameters, `let` bindings,
/// `for` loop variables and `match`-arm pattern idents. Writes to these
/// are closure-local and therefore never shared mutation.
pub fn region_bindings(toks: &[Token], s: usize, e: usize) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    let hi = e.min(toks.len().saturating_sub(1));
    let mut k = s;
    while k <= hi {
        // closure head: everything between the bars is a binding
        if closure_bar_at(toks, k, s, true) {
            let mut j = k + 1;
            while j <= hi && !punct_at(toks, j, '|') {
                if let Some(id) = ident_at(toks, j) {
                    names.insert(id.to_string());
                }
                j += 1;
            }
            k = j + 1;
            continue;
        }
        if ident_at(toks, k) == Some("let") {
            let mut j = k + 1;
            while j <= hi && !punct_at(toks, j, '=') && !punct_at(toks, j, ';') {
                if punct_at(toks, j, ':') {
                    // type ascription: skip to `=`/`;` so type names
                    // are not mistaken for bindings
                    while j <= hi && !punct_at(toks, j, '=') && !punct_at(toks, j, ';') {
                        j += 1;
                    }
                    break;
                }
                if let Some(id) = ident_at(toks, j) {
                    if id != "mut" {
                        names.insert(id.to_string());
                    }
                }
                j += 1;
            }
            k = j;
            continue;
        }
        if ident_at(toks, k) == Some("for") {
            let mut j = k + 1;
            while j <= hi && ident_at(toks, j) != Some("in") {
                if let Some(id) = ident_at(toks, j) {
                    names.insert(id.to_string());
                }
                j += 1;
            }
            k = j;
            continue;
        }
        // match arm: idents in the pattern before `=>` (walk back to
        // the previous arm/brace boundary, bounded)
        if punct_at(toks, k, '=') && punct_at(toks, k + 1, '>') {
            let mut j = k;
            let mut steps = 0;
            while j > s && steps < 24 {
                j -= 1;
                steps += 1;
                if matches!(toks[j].kind, TokKind::Punct(c) if c == ',' || c == '{' || c == '}') {
                    break;
                }
                if let Some(id) = ident_at(toks, j) {
                    if !is_keyword(id) {
                        names.insert(id.to_string());
                    }
                }
            }
        }
        k += 1;
    }
    names
}

/// One compound-assignment site inside a span: the token index of the
/// operator, the (best-effort) target name — the ident immediately left
/// of the op, or left of a bracketed index/paren chain — and its line.
#[derive(Debug, Clone)]
pub struct CompoundOp {
    pub op_idx: usize,
    pub target: Option<String>,
    pub line: u32,
}

/// All `+=`/`-=`/`*=`/`/=` sites in `[s, e)`.
pub fn compound_ops(toks: &[Token], s: usize, e: usize) -> Vec<CompoundOp> {
    let mut out = Vec::new();
    let hi = e.min(toks.len().saturating_sub(1));
    let mut k = s;
    while k < hi {
        let is_arith = matches!(toks[k].kind, TokKind::Punct(c) if "+-*/".contains(c));
        if !is_arith || !punct_at(toks, k + 1, '=') {
            k += 1;
            continue;
        }
        let mut target = None;
        if k > 0 {
            if let Some(id) = ident_at(toks, k - 1) {
                target = Some(id.to_string());
            } else if matches!(toks[k - 1].kind, TokKind::Punct(c) if c == ']' || c == ')') {
                // `name[…] +=` / `name(…).x +=`: walk back over the
                // balanced bracket chain to the head ident
                let mut depth = 0isize;
                let mut j = k - 1;
                loop {
                    match toks[j].kind {
                        TokKind::Punct(c) if c == ']' || c == ')' => depth += 1,
                        TokKind::Punct(c) if c == '[' || c == '(' => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    if j == 0 {
                        break;
                    }
                    j -= 1;
                }
                if j > 0 {
                    if let Some(id) = ident_at(toks, j - 1) {
                        target = Some(id.to_string());
                    }
                }
            }
        }
        out.push(CompoundOp { op_idx: k, target, line: toks[k].line });
        k += 1;
    }
    out
}

/// The statement containing `op_idx`, clamped to `[s, e]`: expands in
/// both directions until a `;`, `{` or `}` boundary.
pub fn stmt_span(toks: &[Token], op_idx: usize, s: usize, e: usize) -> (usize, usize) {
    let boundary =
        |i: usize| matches!(toks[i].kind, TokKind::Punct(c) if c == ';' || c == '{' || c == '}');
    let mut a = op_idx;
    while a > s && !boundary(a - 1) {
        a -= 1;
    }
    let mut b = op_idx;
    let hi = e.min(toks.len().saturating_sub(1));
    while b < hi && !boundary(b + 1) {
        b += 1;
    }
    (a, b)
}

/// Lexically-resolvable calls in `[s, e]`: `name(…)` where `name` is
/// not preceded by `.` (method) or `:` (path segment) — exactly the
/// calls the crate model can resolve by bare fn name.
pub fn direct_calls(toks: &[Token], s: usize, e: usize) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let hi = e.min(toks.len().saturating_sub(1));
    for k in s..=hi {
        let Some(name) = ident_at(toks, k) else { continue };
        if is_keyword(name) || !punct_at(toks, k + 1, '(') {
            continue;
        }
        if k > 0 && (punct_at(toks, k - 1, '.') || punct_at(toks, k - 1, ':')) {
            continue;
        }
        out.push((name.to_string(), k));
    }
    out
}

/// One name a `use` declaration brings into file scope: the binding
/// name (the alias after `as`, else the last path segment) and the line
/// of the declaration.
#[derive(Debug, Clone)]
pub struct UseImport {
    pub name: String,
    pub line: u32,
}

/// All names imported by `use` declarations, including grouped imports
/// (`use a::{b, c as d};`). Glob imports (`use a::*;`) contribute
/// nothing — they bind no resolvable name.
pub fn scan_use_paths(toks: &[Token]) -> Vec<UseImport> {
    let mut out = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if ident_at(toks, i) != Some("use") {
            i += 1;
            continue;
        }
        let line = toks[i].line;
        // find the end of the declaration
        let mut end = i + 1;
        let mut depth = 0isize;
        while end < n {
            match toks[end].kind {
                TokKind::Punct('{') => depth += 1,
                TokKind::Punct('}') => depth -= 1,
                TokKind::Punct(';') if depth <= 0 => break,
                _ => {}
            }
            end += 1;
        }
        // within [i+1, end): a name is bound wherever a segment is
        // followed by `,`, `}` or the terminating `;` — unless the
        // previous meaningful token path continues. `as` aliases win.
        let mut k = i + 1;
        while k < end {
            if let Some(id) = ident_at(toks, k) {
                if id == "as" {
                    k += 1;
                    continue;
                }
                let aliased = ident_at(toks, k + 1) == Some("as");
                let terminal = !aliased
                    && !punct_at(toks, k + 1, ':')
                    && (k + 1 >= end
                        || punct_at(toks, k + 1, ',')
                        || punct_at(toks, k + 1, '}'));
                let alias_binding = k > 0 && ident_at(toks, k - 1) == Some("as");
                if alias_binding || terminal {
                    out.push(UseImport { name: id.to_string(), line });
                }
            }
            k += 1;
        }
        i = end + 1;
    }
    out
}
