//! Hardware fault model: dead cores, dead directed NoC links, and
//! per-core capacity derating over an [`NmhConfig`] lattice.
//!
//! Real neuromorphic chips ship with and accumulate faulty cores and
//! links; a mapping that ignores them either fails outright or routes
//! traffic through dead regions. A [`FaultMask`] records which cores and
//! directed links are unusable and which cores run with reduced
//! `c_npc/c_apc/c_spc` capacity. Masks are constructed explicitly (test
//! scenarios, field reports) or sampled from a seeded fault-rate model
//! ([`FaultMask::sample`] — fixed draw order over cores then links, so an
//! identical seed yields a bit-identical mask on any machine), and a
//! [`FaultSpec`] is the JSON-round-trippable description that rides
//! [`crate::coordinator::spec::PipelineSpec`] like every other knob.
//!
//! Directed links are identified by `core_index * 4 + dir` with
//! `dir` ∈ {E=0, W=1, N=2, S=3} — the same scheme as the NoC
//! simulator's per-link load accounting, so a mask's dead-link set and
//! the simulator's link loads index the same id space.

use super::NmhConfig;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Link direction east (+x).
pub const DIR_E: usize = 0;
/// Link direction west (-x).
pub const DIR_W: usize = 1;
/// Link direction north (+y).
pub const DIR_N: usize = 2;
/// Link direction south (-y).
pub const DIR_S: usize = 3;

/// `(dx, dy)` step for each direction id, in id order E, W, N, S.
pub const DIR_STEPS: [(i32, i32); 4] = [(1, 0), (-1, 0), (0, 1), (0, -1)];

/// A fault mask over a `width × height` core lattice: dead cores, dead
/// directed links and per-core capacity derate factors in `[0, 1]`
/// (1.0 = full capacity). All-healthy masks are behaviorally invisible:
/// every consumer is required to produce bit-identical results with an
/// all-healthy mask and with no mask at all (tested).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultMask {
    /// Lattice width the mask was built for.
    pub width: usize,
    /// Lattice height the mask was built for.
    pub height: usize,
    dead_cores: Vec<bool>,
    dead_links: Vec<bool>,
    derate: Vec<f64>,
}

/// Per-element fault probabilities for [`FaultMask::sample`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultRates {
    /// P(a core is dead).
    pub core_rate: f64,
    /// P(a directed link is dead).
    pub link_rate: f64,
    /// P(an alive core is capacity-derated).
    pub derate_rate: f64,
    /// Sampled derate factors are uniform in `[derate_floor, 1)`.
    pub derate_floor: f64,
}

impl Default for FaultRates {
    fn default() -> Self {
        FaultRates { core_rate: 0.05, link_rate: 0.05, derate_rate: 0.0, derate_floor: 0.5 }
    }
}

impl FaultRates {
    /// Uniform dead-core/dead-link rate `r`, no derating — the CLI's
    /// `--fault-rate` shorthand.
    pub fn uniform(r: f64) -> Self {
        FaultRates { core_rate: r, link_rate: r, derate_rate: 0.0, derate_floor: 0.5 }
    }

    fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("core_rate", self.core_rate),
            ("link_rate", self.link_rate),
            ("derate_rate", self.derate_rate),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("fault {name} must be in [0, 1], got {v}"));
            }
        }
        if !(0.0..=1.0).contains(&self.derate_floor) {
            return Err(format!("fault derate_floor must be in [0, 1], got {}", self.derate_floor));
        }
        Ok(())
    }
}

impl FaultMask {
    /// All-healthy mask over `hw`'s lattice.
    pub fn healthy(hw: &NmhConfig) -> Self {
        let n = hw.num_cores();
        FaultMask {
            width: hw.width,
            height: hw.height,
            dead_cores: vec![false; n],
            dead_links: vec![false; n * 4],
            derate: vec![1.0; n],
        }
    }

    /// Sample a mask from per-element fault rates with a dedicated
    /// seeded RNG stream. The draw order is fixed — cores in linear
    /// index order, then directed links in link-id order, then derates
    /// in core order — so the mask is a pure function of
    /// `(hw dims, rates, seed)` regardless of threads or platform.
    pub fn sample(hw: &NmhConfig, rates: &FaultRates, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0xFA17);
        let mut m = FaultMask::healthy(hw);
        for c in m.dead_cores.iter_mut() {
            *c = rng.bernoulli(rates.core_rate);
        }
        for l in m.dead_links.iter_mut() {
            *l = rng.bernoulli(rates.link_rate);
        }
        for i in 0..m.derate.len() {
            // draw unconditionally per core so the stream position never
            // depends on earlier outcomes' interpretation
            let hit = rng.bernoulli(rates.derate_rate);
            if hit && !m.dead_cores[i] {
                m.derate[i] = rates.derate_floor + rng.next_f64() * (1.0 - rates.derate_floor);
            } else if hit {
                rng.next_f64(); // keep the stream aligned for dead cores
            }
        }
        m
    }

    /// Linear core index (row-major, mask dimensions).
    #[inline]
    fn idx(&self, x: u16, y: u16) -> usize {
        debug_assert!((x as usize) < self.width && (y as usize) < self.height);
        y as usize * self.width + x as usize
    }

    /// Directed-link id for the link leaving `(x, y)` towards `dir`.
    #[inline]
    pub fn link_id(&self, x: u16, y: u16, dir: usize) -> usize {
        self.idx(x, y) * 4 + dir
    }

    /// Is the core at `(x, y)` dead?
    #[inline]
    pub fn is_core_dead(&self, x: u16, y: u16) -> bool {
        self.dead_cores[self.idx(x, y)]
    }

    /// Is the core at linear index `i` dead?
    #[inline]
    pub fn core_dead_idx(&self, i: usize) -> bool {
        self.dead_cores[i]
    }

    /// Is the directed link leaving `(x, y)` towards `dir` dead?
    #[inline]
    pub fn is_link_dead(&self, x: u16, y: u16, dir: usize) -> bool {
        self.dead_links[self.link_id(x, y, dir)]
    }

    /// Capacity derate factor of the core at linear index `i`.
    #[inline]
    pub fn derate_idx(&self, i: usize) -> f64 {
        self.derate[i]
    }

    /// Mark the core at `(x, y)` dead (idempotent).
    pub fn kill_core(&mut self, x: u16, y: u16) {
        let i = self.idx(x, y);
        self.dead_cores[i] = true;
    }

    /// Mark the directed link leaving `(x, y)` towards `dir` dead.
    pub fn kill_link(&mut self, x: u16, y: u16, dir: usize) {
        debug_assert!(dir < 4);
        let l = self.link_id(x, y, dir);
        self.dead_links[l] = true;
    }

    /// Set the capacity derate factor of the core at `(x, y)`.
    pub fn set_derate(&mut self, x: u16, y: u16, f: f64) {
        debug_assert!((0.0..=1.0).contains(&f));
        let i = self.idx(x, y);
        self.derate[i] = f;
    }

    /// Number of alive (non-dead) cores.
    pub fn alive_count(&self) -> usize {
        self.dead_cores.iter().filter(|&&d| !d).count()
    }

    /// Number of dead cores.
    pub fn dead_core_count(&self) -> usize {
        self.dead_cores.len() - self.alive_count()
    }

    /// Number of dead directed links.
    pub fn dead_link_count(&self) -> usize {
        self.dead_links.iter().filter(|&&d| d).count()
    }

    /// True when the mask expresses no fault at all — no dead cores, no
    /// dead links and every derate factor exactly 1.0.
    pub fn is_all_healthy(&self) -> bool {
        self.dead_cores.iter().all(|&d| !d)
            && self.dead_links.iter().all(|&d| !d)
            && self.derate.iter().all(|&f| f == 1.0)
    }

    /// Check the mask's dimensions against a hardware config.
    pub fn check_matches(&self, hw: &NmhConfig) -> Result<(), String> {
        if self.width != hw.width || self.height != hw.height {
            return Err(format!(
                "fault mask is {}x{} but hw lattice is {}x{}",
                self.width, self.height, hw.width, hw.height
            ));
        }
        Ok(())
    }

    /// Hardware config with per-core capacities scaled by the minimum
    /// derate factor among alive cores — the uniform-capacity
    /// conservative view the capacity-only partitioners run against
    /// (they know core *counts*, not core *positions*, so the weakest
    /// surviving core bounds every core). A mask with all derates at
    /// 1.0 returns `hw` unchanged, bit for bit.
    pub fn effective_hw(&self, hw: &NmhConfig) -> NmhConfig {
        let mut f = 1.0f64;
        for i in 0..self.dead_cores.len() {
            if !self.dead_cores[i] && self.derate[i] < f {
                f = self.derate[i];
            }
        }
        if f >= 1.0 {
            return *hw;
        }
        let mut out = *hw;
        // floor, no max(1) clamp: a derate small enough to zero a
        // capacity surfaces as NodeUnmappable downstream, never a panic
        out.c_npc = (hw.c_npc as f64 * f) as usize;
        out.c_apc = (hw.c_apc as f64 * f) as usize;
        out.c_spc = (hw.c_spc as f64 * f) as usize;
        out
    }

    /// Sparse JSON form: dead cores and links as id lists, derates as
    /// `[index, factor]` pairs (only factors ≠ 1.0).
    pub fn to_json(&self) -> Json {
        let dead_cores: Vec<Json> = (0..self.dead_cores.len())
            .filter(|&i| self.dead_cores[i])
            .map(|i| Json::Num(i as f64))
            .collect();
        let dead_links: Vec<Json> = (0..self.dead_links.len())
            .filter(|&l| self.dead_links[l])
            .map(|l| Json::Num(l as f64))
            .collect();
        let derate: Vec<Json> = (0..self.derate.len())
            .filter(|&i| self.derate[i] != 1.0)
            .map(|i| Json::Arr(vec![Json::Num(i as f64), Json::Num(self.derate[i])]))
            .collect();
        Json::obj(vec![
            ("width", Json::Num(self.width as f64)),
            ("height", Json::Num(self.height as f64)),
            ("dead_cores", Json::Arr(dead_cores)),
            ("dead_links", Json::Arr(dead_links)),
            ("derate", Json::Arr(derate)),
        ])
    }

    /// Parse the [`Self::to_json`] form. Strict: unknown keys, missing
    /// dimensions, out-of-range ids and out-of-range factors are errors.
    pub fn from_json(doc: &Json) -> Result<Self, String> {
        let obj = doc.as_obj().ok_or("fault mask must be a JSON object")?;
        const KNOWN: [&str; 5] = ["width", "height", "dead_cores", "dead_links", "derate"];
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!(
                    "unknown fault mask field '{key}' (accepted: {})",
                    KNOWN.join(", ")
                ));
            }
        }
        let width = doc
            .get("width")
            .as_usize()
            .ok_or("fault mask needs an integer 'width'")?;
        let height = doc
            .get("height")
            .as_usize()
            .ok_or("fault mask needs an integer 'height'")?;
        if width == 0 || height == 0 {
            return Err("fault mask dimensions must be positive".to_string());
        }
        let n = width * height;
        let mut m = FaultMask {
            width,
            height,
            dead_cores: vec![false; n],
            dead_links: vec![false; n * 4],
            derate: vec![1.0; n],
        };
        if let Some(arr) = doc.get("dead_cores").as_arr() {
            for v in arr {
                let i = v.as_usize().ok_or("dead_cores entries must be integers")?;
                if i >= n {
                    return Err(format!("dead core index {i} out of range (lattice has {n})"));
                }
                m.dead_cores[i] = true;
            }
        } else if !matches!(doc.get("dead_cores"), Json::Null) {
            return Err("dead_cores must be an array".to_string());
        }
        if let Some(arr) = doc.get("dead_links").as_arr() {
            for v in arr {
                let l = v.as_usize().ok_or("dead_links entries must be integers")?;
                if l >= n * 4 {
                    return Err(format!("dead link id {l} out of range ({} links)", n * 4));
                }
                m.dead_links[l] = true;
            }
        } else if !matches!(doc.get("dead_links"), Json::Null) {
            return Err("dead_links must be an array".to_string());
        }
        if let Some(arr) = doc.get("derate").as_arr() {
            for v in arr {
                let pair = v.as_arr().ok_or("derate entries must be [index, factor] pairs")?;
                if pair.len() != 2 {
                    return Err("derate entries must be [index, factor] pairs".to_string());
                }
                let i = pair[0].as_usize().ok_or("derate index must be an integer")?;
                let f = pair[1].as_f64().ok_or("derate factor must be a number")?;
                if i >= n {
                    return Err(format!("derate index {i} out of range (lattice has {n})"));
                }
                if !(0.0..=1.0).contains(&f) {
                    return Err(format!("derate factor must be in [0, 1], got {f}"));
                }
                m.derate[i] = f;
            }
        } else if !matches!(doc.get("derate"), Json::Null) {
            return Err("derate must be an array".to_string());
        }
        Ok(m)
    }
}

/// The plain-data fault description that rides a pipeline spec: either
/// an explicit mask, or the parameters of the seeded sampling model
/// (resolved against the spec's hardware at pipeline construction, so
/// the spec stays small and the realization deterministic).
#[derive(Clone, Debug, PartialEq)]
pub enum FaultSpec {
    /// A fully explicit mask.
    Explicit(FaultMask),
    /// Sample from per-element rates with the given seed.
    Sampled {
        /// Per-element fault probabilities.
        rates: FaultRates,
        /// Seed of the mask's dedicated RNG stream.
        seed: u64,
    },
}

impl FaultSpec {
    /// Resolve the spec into a concrete mask for `hw`. Explicit masks
    /// must match the lattice dimensions; sampling is a pure function
    /// of `(hw dims, rates, seed)`.
    pub fn realize(&self, hw: &NmhConfig) -> Result<FaultMask, String> {
        match self {
            FaultSpec::Explicit(m) => {
                m.check_matches(hw)?;
                Ok(m.clone())
            }
            FaultSpec::Sampled { rates, seed } => {
                rates.validate()?;
                Ok(FaultMask::sample(hw, rates, *seed))
            }
        }
    }

    /// Serialize (mode-tagged object).
    pub fn to_json(&self) -> Json {
        match self {
            FaultSpec::Explicit(m) => Json::obj(vec![
                ("mode", Json::Str("explicit".to_string())),
                ("mask", m.to_json()),
            ]),
            FaultSpec::Sampled { rates, seed } => Json::obj(vec![
                ("mode", Json::Str("sampled".to_string())),
                ("core_rate", Json::Num(rates.core_rate)),
                ("link_rate", Json::Num(rates.link_rate)),
                ("derate_rate", Json::Num(rates.derate_rate)),
                ("derate_floor", Json::Num(rates.derate_floor)),
                ("seed", Json::Num(*seed as f64)),
            ]),
        }
    }

    /// Parse the [`Self::to_json`] form (strict per mode).
    pub fn from_json(doc: &Json) -> Result<Self, String> {
        let obj = doc.as_obj().ok_or("fault spec must be a JSON object")?;
        let mode = doc.get("mode").as_str().ok_or("fault spec needs a string 'mode'")?;
        match mode {
            "explicit" => {
                const KNOWN: [&str; 2] = ["mode", "mask"];
                for key in obj.keys() {
                    if !KNOWN.contains(&key.as_str()) {
                        return Err(format!(
                            "unknown fault spec field '{key}' (accepted: {})",
                            KNOWN.join(", ")
                        ));
                    }
                }
                Ok(FaultSpec::Explicit(FaultMask::from_json(doc.get("mask"))?))
            }
            "sampled" => {
                const KNOWN: [&str; 6] =
                    ["mode", "core_rate", "link_rate", "derate_rate", "derate_floor", "seed"];
                for key in obj.keys() {
                    if !KNOWN.contains(&key.as_str()) {
                        return Err(format!(
                            "unknown fault spec field '{key}' (accepted: {})",
                            KNOWN.join(", ")
                        ));
                    }
                }
                let mut rates = FaultRates::default();
                if let Some(v) = doc.get("core_rate").as_f64() {
                    rates.core_rate = v;
                }
                if let Some(v) = doc.get("link_rate").as_f64() {
                    rates.link_rate = v;
                }
                if let Some(v) = doc.get("derate_rate").as_f64() {
                    rates.derate_rate = v;
                }
                if let Some(v) = doc.get("derate_floor").as_f64() {
                    rates.derate_floor = v;
                }
                rates.validate()?;
                let seed = doc
                    .get("seed")
                    .as_f64()
                    .ok_or("sampled fault spec needs a numeric 'seed'")?;
                if seed < 0.0 || seed.fract() != 0.0 || seed > 9e15 {
                    return Err(format!("fault seed must be a non-negative integer, got {seed}"));
                }
                Ok(FaultSpec::Sampled { rates, seed: seed as u64 })
            }
            other => Err(format!("unknown fault spec mode '{other}' (accepted: explicit, sampled)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw8() -> NmhConfig {
        let mut hw = NmhConfig::small();
        hw.width = 8;
        hw.height = 8;
        hw
    }

    #[test]
    fn healthy_mask_is_invisible() {
        let hw = hw8();
        let m = FaultMask::healthy(&hw);
        assert!(m.is_all_healthy());
        assert_eq!(m.alive_count(), 64);
        assert_eq!(m.dead_core_count(), 0);
        assert_eq!(m.dead_link_count(), 0);
        assert_eq!(m.effective_hw(&hw), hw);
    }

    #[test]
    fn kill_and_query() {
        let hw = hw8();
        let mut m = FaultMask::healthy(&hw);
        m.kill_core(3, 4);
        m.kill_link(0, 0, DIR_E);
        m.set_derate(1, 1, 0.5);
        assert!(m.is_core_dead(3, 4));
        assert!(!m.is_core_dead(4, 3));
        assert!(m.is_link_dead(0, 0, DIR_E));
        assert!(!m.is_link_dead(0, 0, DIR_N));
        assert_eq!(m.alive_count(), 63);
        assert!(!m.is_all_healthy());
        let eff = m.effective_hw(&hw);
        assert_eq!(eff.c_npc, hw.c_npc / 2);
        assert_eq!(eff.c_apc, hw.c_apc / 2);
        assert_eq!(eff.c_spc, hw.c_spc / 2);
        // geometry fields are untouched by derating
        assert_eq!((eff.width, eff.height), (hw.width, hw.height));
    }

    #[test]
    fn derate_on_dead_core_does_not_bound_capacity() {
        let hw = hw8();
        let mut m = FaultMask::healthy(&hw);
        m.kill_core(0, 0);
        m.set_derate(0, 0, 0.01); // dead core's derate is irrelevant
        assert_eq!(m.effective_hw(&hw), hw);
    }

    #[test]
    fn sampling_is_seed_deterministic_and_rate_sensitive() {
        let hw = NmhConfig::small();
        let rates = FaultRates::uniform(0.05);
        let a = FaultMask::sample(&hw, &rates, 7);
        let b = FaultMask::sample(&hw, &rates, 7);
        assert_eq!(a, b);
        let c = FaultMask::sample(&hw, &rates, 8);
        assert_ne!(a, c, "different seeds should differ at 5% over 4096 cores");
        // ~5% of 4096 cores — loose envelope, but zero would mean broken
        let dead = a.dead_core_count();
        assert!(dead > 100 && dead < 320, "dead cores = {dead}");
        let zero = FaultMask::sample(&hw, &FaultRates::uniform(0.0), 7);
        assert!(zero.is_all_healthy());
    }

    #[test]
    fn sampled_derates_stay_in_range() {
        let hw = hw8();
        let rates =
            FaultRates { core_rate: 0.1, link_rate: 0.0, derate_rate: 0.5, derate_floor: 0.25 };
        let m = FaultMask::sample(&hw, &rates, 3);
        let mut seen_derated = false;
        for i in 0..64 {
            let f = m.derate_idx(i);
            assert!((0.25..=1.0).contains(&f), "derate {f}");
            if m.core_dead_idx(i) {
                assert_eq!(f, 1.0, "dead cores keep derate 1.0");
            } else if f < 1.0 {
                seen_derated = true;
            }
        }
        assert!(seen_derated);
    }

    #[test]
    fn mask_json_roundtrip_exact() {
        let hw = hw8();
        let mut m = FaultMask::healthy(&hw);
        m.kill_core(2, 5);
        m.kill_core(7, 7);
        m.kill_link(1, 1, DIR_S);
        m.set_derate(4, 0, 0.75);
        let text = m.to_json().to_string();
        let back = FaultMask::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(m, back);
        // sampled masks roundtrip too
        let s = FaultMask::sample(&hw, &FaultRates::uniform(0.2), 11);
        let back = FaultMask::from_json(&Json::parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn mask_json_rejects_bad_docs() {
        for bad in [
            r#"{"width": 8}"#,                                        // missing height
            r#"{"width": 8, "height": 8, "dead_cards": []}"#,         // typo'd key
            r#"{"width": 8, "height": 8, "dead_cores": [64]}"#,       // core id out of range
            r#"{"width": 8, "height": 8, "dead_links": [256]}"#,      // link id out of range
            r#"{"width": 8, "height": 8, "derate": [[0, 1.5]]}"#,     // factor out of range
            r#"{"width": 8, "height": 8, "derate": [[0]]}"#,          // malformed pair
            r#"{"width": 0, "height": 8}"#,                           // degenerate lattice
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(FaultMask::from_json(&doc).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn spec_json_roundtrip_both_modes() {
        let hw = hw8();
        let mut m = FaultMask::healthy(&hw);
        m.kill_core(0, 3);
        for spec in [
            FaultSpec::Explicit(m),
            FaultSpec::Sampled { rates: FaultRates::uniform(0.07), seed: 99 },
        ] {
            let text = spec.to_json().to_string();
            let back = FaultSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(spec, back);
        }
    }

    #[test]
    fn spec_realize_checks_dimensions_and_rates() {
        let hw = hw8();
        let other = NmhConfig::small(); // 64x64
        let m = FaultMask::healthy(&hw);
        assert!(FaultSpec::Explicit(m.clone()).realize(&hw).is_ok());
        assert!(FaultSpec::Explicit(m).realize(&other).is_err());
        let bad = FaultSpec::Sampled { rates: FaultRates::uniform(1.5), seed: 0 };
        assert!(bad.realize(&hw).is_err());
        let ok = FaultSpec::Sampled { rates: FaultRates::uniform(0.5), seed: 0 };
        let realized = ok.realize(&hw).unwrap();
        assert_eq!(realized, FaultMask::sample(&hw, &FaultRates::uniform(0.5), 0));
    }

    #[test]
    fn link_ids_cover_the_scheme() {
        let hw = hw8();
        let m = FaultMask::healthy(&hw);
        assert_eq!(m.link_id(0, 0, DIR_E), 0);
        assert_eq!(m.link_id(0, 0, DIR_S), 3);
        assert_eq!(m.link_id(1, 0, DIR_E), 4);
        assert_eq!(m.link_id(0, 1, DIR_E), 32);
    }
}
