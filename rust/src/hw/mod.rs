//! Neuromorphic hardware model (paper §II-B, Eq. 2 and Table II).
//!
//! A chip is a 2D lattice of cores; each core accepts at most `c_npc`
//! neurons, `c_apc` distinct inbound axons (h-edges), and `c_spc` total
//! inbound synapses (connections). Spike movement costs come from Intel
//! Loihi measurements ("small") and from [7] ("large").
//!
//! [`faults`] extends the pristine lattice with a fault mask — dead
//! cores, dead directed NoC links and per-core capacity derating — so
//! mapping and simulation can model degraded chips.

pub mod faults;

/// Per-hop router/wire energy and latency (Table II left).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NocCosts {
    /// Energy for a spike's routing, pJ.
    pub e_r: f64,
    /// Latency for a spike's routing, ns.
    pub l_r: f64,
    /// Energy for a spike's transmission between two cores, pJ.
    pub e_t: f64,
    /// Latency for a spike's transmission between two cores, ns.
    pub l_t: f64,
}

impl NocCosts {
    /// Loihi-derived reference costs (paper Table II).
    pub const fn reference() -> Self {
        NocCosts {
            e_r: 1.7,
            l_r: 2.1,
            e_t: 3.5,
            l_t: 5.3,
        }
    }
}

/// Hardware configuration: lattice dimensions + per-core constraints.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NmhConfig {
    /// Lattice width (cores).
    pub width: usize,
    /// Lattice height (cores).
    pub height: usize,
    /// Max neurons per core.
    pub c_npc: usize,
    /// Max distinct inbound axons (h-edges) per core.
    pub c_apc: usize,
    /// Max inbound synapses (connections) per core.
    pub c_spc: usize,
    /// Spike-movement cost model.
    pub costs: NocCosts,
}

impl NmhConfig {
    /// "small" preset — Loihi-like (Table II).
    pub const fn small() -> Self {
        NmhConfig {
            width: 64,
            height: 64,
            c_npc: 1024,
            c_apc: 4096,
            c_spc: 16384,
            costs: NocCosts::reference(),
        }
    }

    /// "large" preset — [7]-like (Table II).
    pub const fn large() -> Self {
        NmhConfig {
            width: 64,
            height: 64,
            c_npc: 4096,
            c_apc: 65536,
            c_spc: 262144,
            costs: NocCosts::reference(),
        }
    }

    /// Parse a preset by name.
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "small" => Some(Self::small()),
            "large" => Some(Self::large()),
            _ => None,
        }
    }

    /// The paper's rule of thumb: "small" up to 2^26 connections, then
    /// "large" (bigger models exceed 4096 inbound axons per neuron group).
    pub fn for_connections(connections: usize) -> Self {
        if connections <= 1 << 26 {
            Self::small()
        } else {
            Self::large()
        }
    }

    /// Total number of cores |H|.
    #[inline]
    pub fn num_cores(&self) -> usize {
        self.width * self.height
    }

    /// Core coordinate from linear index (row-major).
    #[inline]
    pub fn coord(&self, idx: usize) -> (u16, u16) {
        debug_assert!(idx < self.num_cores());
        ((idx % self.width) as u16, (idx / self.width) as u16)
    }

    /// Linear index from coordinate.
    #[inline]
    pub fn index(&self, x: u16, y: u16) -> usize {
        debug_assert!((x as usize) < self.width && (y as usize) < self.height);
        y as usize * self.width + x as usize
    }

    /// Is `(x, y)` inside the lattice?
    #[inline]
    pub fn contains(&self, x: i32, y: i32) -> bool {
        x >= 0 && y >= 0 && (x as usize) < self.width && (y as usize) < self.height
    }

    /// Manhattan distance between two cores.
    #[inline]
    pub fn manhattan(a: (u16, u16), b: (u16, u16)) -> u32 {
        (a.0 as i32 - b.0 as i32).unsigned_abs() + (a.1 as i32 - b.1 as i32).unsigned_abs()
    }

    /// Scale per-core constraints by `f` (for scaled-down experiments that
    /// keep partition counts representative; see DESIGN.md §5).
    pub fn scaled(&self, f: f64) -> Self {
        let mut c = *self;
        c.c_npc = ((self.c_npc as f64 * f) as usize).max(1);
        c.c_apc = ((self.c_apc as f64 * f) as usize).max(1);
        c.c_spc = ((self.c_spc as f64 * f) as usize).max(1);
        c
    }

    /// Serialize the full configuration (every field explicit, so a
    /// round trip is exact regardless of preset drift).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("width", Json::Num(self.width as f64)),
            ("height", Json::Num(self.height as f64)),
            ("c_npc", Json::Num(self.c_npc as f64)),
            ("c_apc", Json::Num(self.c_apc as f64)),
            ("c_spc", Json::Num(self.c_spc as f64)),
            (
                "costs",
                Json::obj(vec![
                    ("e_r", Json::Num(self.costs.e_r)),
                    ("l_r", Json::Num(self.costs.l_r)),
                    ("e_t", Json::Num(self.costs.e_t)),
                    ("l_t", Json::Num(self.costs.l_t)),
                ]),
            ),
        ])
    }

    /// Parse a configuration from JSON. The document starts from the
    /// named `preset` (default "small"), applies the optional constraint
    /// `scale` factor, then overrides any explicitly given field — so
    /// both the compact experiment-config form
    /// `{"preset": "small", "scale": 0.1}` and the exact
    /// [`Self::to_json`] output parse back faithfully. Unknown keys are
    /// rejected so a typo'd constraint fails instead of silently keeping
    /// the preset value.
    pub fn from_json(doc: &crate::util::json::Json) -> Result<Self, String> {
        if let Some(obj) = doc.as_obj() {
            const KNOWN: [&str; 8] =
                ["preset", "scale", "width", "height", "c_npc", "c_apc", "c_spc", "costs"];
            for key in obj.keys() {
                if !KNOWN.contains(&key.as_str()) {
                    return Err(format!(
                        "unknown hw field '{key}' (accepted: {})",
                        KNOWN.join(", ")
                    ));
                }
            }
        } else {
            return Err("hw must be a JSON object".to_string());
        }
        let mut hw = match doc.get("preset").as_str() {
            Some(name) => {
                Self::preset(name).ok_or_else(|| format!("unknown hw preset '{name}'"))?
            }
            None => Self::small(),
        };
        if let Some(f) = doc.get("scale").as_f64() {
            hw = hw.scaled(f);
        }
        if let Some(v) = doc.get("width").as_usize() {
            hw.width = v;
        }
        if let Some(v) = doc.get("height").as_usize() {
            hw.height = v;
        }
        if let Some(v) = doc.get("c_npc").as_usize() {
            hw.c_npc = v;
        }
        if let Some(v) = doc.get("c_apc").as_usize() {
            hw.c_apc = v;
        }
        if let Some(v) = doc.get("c_spc").as_usize() {
            hw.c_spc = v;
        }
        let costs = doc.get("costs");
        if let Some(cobj) = costs.as_obj() {
            const KNOWN_COSTS: [&str; 4] = ["e_r", "l_r", "e_t", "l_t"];
            for key in cobj.keys() {
                if !KNOWN_COSTS.contains(&key.as_str()) {
                    return Err(format!(
                        "unknown hw.costs field '{key}' (accepted: {})",
                        KNOWN_COSTS.join(", ")
                    ));
                }
            }
            if let Some(v) = costs.get("e_r").as_f64() {
                hw.costs.e_r = v;
            }
            if let Some(v) = costs.get("l_r").as_f64() {
                hw.costs.l_r = v;
            }
            if let Some(v) = costs.get("e_t").as_f64() {
                hw.costs.e_t = v;
            }
            if let Some(v) = costs.get("l_t").as_f64() {
                hw.costs.l_t = v;
            }
        }
        Ok(hw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table2() {
        let s = NmhConfig::small();
        assert_eq!((s.c_npc, s.c_apc, s.c_spc), (1024, 4096, 16384));
        assert_eq!((s.width, s.height), (64, 64));
        let l = NmhConfig::large();
        assert_eq!((l.c_npc, l.c_apc, l.c_spc), (4096, 65536, 262144));
        let c = NocCosts::reference();
        assert_eq!((c.e_r, c.l_r, c.e_t, c.l_t), (1.7, 2.1, 3.5, 5.3));
    }

    #[test]
    fn preset_lookup_and_threshold() {
        assert_eq!(NmhConfig::preset("small"), Some(NmhConfig::small()));
        assert_eq!(NmhConfig::preset("nope"), None);
        assert_eq!(NmhConfig::for_connections(1 << 20), NmhConfig::small());
        assert_eq!(NmhConfig::for_connections((1 << 26) + 1), NmhConfig::large());
    }

    #[test]
    fn coord_index_roundtrip() {
        let c = NmhConfig::small();
        for idx in [0, 1, 63, 64, 4095] {
            let (x, y) = c.coord(idx);
            assert_eq!(c.index(x, y), idx);
        }
        assert!(c.contains(0, 0) && c.contains(63, 63));
        assert!(!c.contains(-1, 0) && !c.contains(64, 0));
    }

    #[test]
    fn manhattan_distance() {
        assert_eq!(NmhConfig::manhattan((0, 0), (3, 4)), 7);
        assert_eq!(NmhConfig::manhattan((5, 5), (5, 5)), 0);
        assert_eq!(NmhConfig::manhattan((10, 2), (2, 10)), 16);
    }

    #[test]
    fn scaling_clamps_to_one() {
        let c = NmhConfig::small().scaled(1e-9);
        assert_eq!((c.c_npc, c.c_apc, c.c_spc), (1, 1, 1));
        let c = NmhConfig::small().scaled(0.5);
        assert_eq!(c.c_npc, 512);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let mut hw = NmhConfig::small().scaled(0.07);
        hw.width = 17;
        hw.costs.e_t = 4.25;
        let doc = crate::util::json::Json::parse(&hw.to_json().to_string()).unwrap();
        assert_eq!(NmhConfig::from_json(&doc).unwrap(), hw);
    }

    #[test]
    fn json_preset_and_scale_form() {
        let doc = crate::util::json::Json::parse(
            r#"{"preset": "small", "scale": 0.05, "width": 8}"#,
        )
        .unwrap();
        let hw = NmhConfig::from_json(&doc).unwrap();
        assert_eq!(hw.c_npc, 51);
        assert_eq!(hw.width, 8);
        let bad = crate::util::json::Json::parse(r#"{"preset": "huge"}"#).unwrap();
        assert!(NmhConfig::from_json(&bad).is_err());
        // typo'd fields fail loudly instead of keeping preset values
        let typo = crate::util::json::Json::parse(r#"{"c_ncp": 100}"#).unwrap();
        assert!(NmhConfig::from_json(&typo).is_err());
        let typo = crate::util::json::Json::parse(r#"{"costs": {"e_x": 1.0}}"#).unwrap();
        assert!(NmhConfig::from_json(&typo).is_err());
    }
}
