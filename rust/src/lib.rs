//! # snnmap — hypergraph-based SNN→neuromorphic-hardware mapping
//!
//! A production-style implementation of *"A Case for Hypergraphs to Model
//! and Map SNNs on Neuromorphic Hardware"* (Ronzani & Silvano): SNNs are
//! modeled as directed single-source hypergraphs; mapping = constrained
//! hypergraph **partitioning** (neurons → virtual cores) followed by
//! **placement** (virtual cores → the 2D NoC lattice), driven by
//! second-order affinity (synaptic reuse) and first-order affinity
//! (connections locality).
//!
//! Architecture (three layers, see DESIGN.md):
//! * this crate (L3) owns the whole mapping path: h-graph model,
//!   partitioners, placers, metric engine, NoC simulator, experiments;
//! * every algorithm is a [`stage`] trait object resolved by name
//!   through [`coordinator::StageRegistry`], and a full run is described
//!   by the serializable [`coordinator::PipelineSpec`] (DESIGN.md §9);
//! * numerical hot spots (the spectral-placement eigensolver and batched
//!   force-field evaluation) are AOT-compiled JAX/Pallas artifacts
//!   executed through PJRT by [`runtime`], with native fallbacks;
//! * CPU-parallel hot paths (metric engine, multilevel partitioning,
//!   spectral matvec, experiment grid) ride the deterministic
//!   scoped-thread engine in [`util::par`] — thread counts are
//!   performance knobs, never semantics knobs (DESIGN.md §6-§7, §10);
//! * long hierarchical runs are crash-safe: [`runtime::checkpoint`]
//!   snapshots the coarsening hierarchy between rounds (atomic writes,
//!   per-section CRCs, corruption falls back to the newest valid file)
//!   and resumes bit-for-bit — even across thread counts (DESIGN.md
//!   §13). CLI: `--checkpoint-dir DIR` to save, `--resume` to continue;
//!   in code, [`CheckpointPolicy`](runtime::CheckpointPolicy) via
//!   `MapperPipeline::with_checkpoint`;
//! * mapping is fault-aware (DESIGN.md §15): a seeded or explicit
//!   [`hw::faults::FaultMask`] derates capacities, steers every placer
//!   off dead cores, reroutes simulator traffic around dead links
//!   (XY → YX → BFS detour, deterministically), and
//!   [`mapping::repair`] re-maps after a core/link death with minimal
//!   neuron churn. `None`/all-healthy masks are bit-identical to the
//!   fault-free pipeline. CLI: `--fault-rate F` / `--fault-spec FILE`
//!   and the `repair` subcommand;
//! * the NoC simulator follows the same two-phase discipline (DESIGN.md
//!   §16): [`sim::simulate_with_threads`] is bit-identical across
//!   worker counts (integer-only chunk accumulators, serial merge), and
//!   [`sim::simulate_batch`] replays many (seed, rate-scale,
//!   fault-mask) configs through shared streams/routes/scratch — the
//!   experiment grid's `--sim-steps`/`--sim-seeds`/`--sim-rate-scales`
//!   axes ride it.
//!
//! Quick tour — the enum-builder shims and the spec form drive the same
//! registry-backed pipeline:
//! ```no_run
//! use snnmap::prelude::*;
//! let net = snnmap::snn::by_name("lenet", 0.25, 42).unwrap();
//! let hw = NmhConfig::small();
//! let mapping = MapperPipeline::new(hw)
//!     .partitioner(PartitionerKind::HyperedgeOverlap)
//!     .placer(PlacerKind::Spectral)
//!     .refiner(RefinerKind::ForceDirected)
//!     .seed(42)
//!     .run(&net.graph, net.layer_ranges.as_deref())
//!     .expect("mapping failed");
//! println!("{}", mapping.report());
//!
//! // the identical run as a JSON-round-trippable spec:
//! let spec = PipelineSpec::from_json_str(
//!     r#"{"partitioner": "overlap", "placer": "spectral",
//!         "refiner": "force", "hw": {"preset": "small"}, "seed": 42}"#,
//! )
//! .unwrap();
//! let same = MapperPipeline::from_spec(&spec)
//!     .unwrap()
//!     .run(&net.graph, net.layer_ranges.as_deref())
//!     .expect("mapping failed");
//! assert_eq!(mapping.rho.assign, same.rho.assign);
//! ```

pub mod coordinator;
pub mod hw;
pub mod hypergraph;
pub mod lint;
pub mod mapping;
pub mod metrics;
pub mod multichip;
pub mod placement;
pub mod runtime;
pub mod sim;
pub mod snn;
pub mod stage;
pub mod util;

/// Common imports for downstream users and the examples.
pub mod prelude {
    pub use crate::coordinator::pipeline::{
        MapperPipeline, MappingResult, PartitionerKind, PlacerKind, RefinerKind,
    };
    pub use crate::coordinator::registry::StageRegistry;
    pub use crate::coordinator::spec::{PipelineSpec, StageSpec};
    pub use crate::hw::faults::{FaultMask, FaultRates, FaultSpec};
    pub use crate::hw::{NmhConfig, NocCosts};
    pub use crate::hypergraph::quotient::{push_forward, Partitioning};
    pub use crate::hypergraph::{Hypergraph, HypergraphBuilder};
    pub use crate::mapping::repair::{repair, FaultEvent, RepairOutcome};
    pub use crate::metrics::MappingMetrics;
    pub use crate::placement::Placement;
    pub use crate::runtime::CheckpointPolicy;
    pub use crate::stage::{Partitioner, Placer, Refiner, StageCtx, StageParams};
}
