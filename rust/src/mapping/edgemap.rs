//! EdgeMap-style partitioning (paper §V-A control experiment, after [15]).
//!
//! A node-centric, *graph*-based greedy scheme: nodes are visited
//! sequentially and each is placed into the open partition that currently
//! minimizes its cut connections — equivalently, maximizes the total
//! spike-frequency weight of its direct (first-order) connections to nodes
//! already inside. This deliberately ignores hyperedge co-membership, so
//! it serves as the paper's control for how much second-order affinity
//! actually buys.

use super::MapError;
use crate::hw::NmhConfig;
use crate::hypergraph::quotient::Partitioning;
use crate::hypergraph::Hypergraph;
use std::collections::HashMap;

/// Maximum open partitions scanned per node (EdgeMap keeps all partitions
/// candidates; we bound the scan to the ones the node actually connects
/// to, plus the latest-opened partition as fallback).
pub fn partition(g: &Hypergraph, hw: &NmhConfig) -> Result<Partitioning, MapError> {
    let n = g.num_nodes();
    super::check_nodes_feasible(g, hw)?;
    let mut assign = vec![u32::MAX; n];
    // One tracker per open partition is too heavy; track per-partition
    // counters + axon stamps in one structure per partition id.
    let mut parts: Vec<PartState> = Vec::new();

    let mut conn_weight: HashMap<u32, f64> = HashMap::new();
    for u in 0..n as u32 {
        // direct-connection weight to each partition (graph view:
        // source->destination edges only)
        conn_weight.clear();
        for &e in g.inbound(u) {
            let s = g.source(e);
            if assign[s as usize] != u32::MAX {
                *conn_weight.entry(assign[s as usize]).or_insert(0.0) += g.weight(e) as f64;
            }
        }
        for &e in g.outbound(u) {
            let w = g.weight(e) as f64;
            for &d in g.dsts(e) {
                if assign[d as usize] != u32::MAX {
                    *conn_weight.entry(assign[d as usize]).or_insert(0.0) += w;
                }
            }
        }
        let mut cands: Vec<(u32, f64)> = conn_weight.iter().map(|(&p, &w)| (p, w)).collect();
        cands.sort_by(|a, b| crate::util::cmp_non_nan(&b.1, &a.1).then(a.0.cmp(&b.0)));
        // fallback: the most recently opened partition
        if let Some(last) = parts.len().checked_sub(1) {
            if !cands.iter().any(|&(p, _)| p as usize == last) {
                cands.push((last as u32, 0.0));
            }
        }

        let mut placed = false;
        for (p, _) in cands {
            if parts[p as usize].fits(g, hw, u) {
                parts[p as usize].add(g, u);
                assign[u as usize] = p;
                placed = true;
                break;
            }
        }
        if !placed {
            // open a new partition
            let mut st = PartState::new(g.num_edges());
            if !st.fits(g, hw, u) {
                // the prelude proved u fits an empty core, so a rejection
                // here is an internal inconsistency, not an unmappable node
                return Err(MapError::ConstraintViolated(format!(
                    "node {u} rejected by empty partition"
                )));
            }
            st.add(g, u);
            parts.push(st);
            assign[u as usize] = (parts.len() - 1) as u32;
            if parts.len() > hw.num_cores() {
                return Err(MapError::TooManyPartitions {
                    got: parts.len(),
                    limit: hw.num_cores(),
                });
            }
        }
    }
    Ok(Partitioning::new(assign, parts.len()))
}

/// Constraint state of one open partition.
struct PartState {
    npc: usize,
    spc: usize,
    apc: usize,
    /// membership bitmap over edges (which axons this partition receives)
    axon: Vec<bool>,
}

impl PartState {
    fn new(num_edges: usize) -> Self {
        PartState {
            npc: 0,
            spc: 0,
            apc: 0,
            axon: vec![false; num_edges],
        }
    }

    fn fits(&self, g: &Hypergraph, hw: &NmhConfig, u: u32) -> bool {
        let inb = g.inbound(u);
        if self.npc + 1 > hw.c_npc || self.spc + inb.len() > hw.c_spc {
            return false;
        }
        let new_axons = inb.iter().filter(|&&e| !self.axon[e as usize]).count();
        self.apc + new_axons <= hw.c_apc
    }

    fn add(&mut self, g: &Hypergraph, u: u32) {
        self.npc += 1;
        self.spc += g.inbound(u).len();
        for &e in g.inbound(u) {
            if !self.axon[e as usize] {
                self.axon[e as usize] = true;
                self.apc += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::validate;
    use crate::hypergraph::HypergraphBuilder;
    use crate::util::rng::Pcg64;

    #[test]
    fn chain_stays_contiguous() {
        let mut b = HypergraphBuilder::new(12);
        for i in 0..11u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 4;
        let rho = partition(&g, &hw).unwrap();
        validate(&g, &rho, &hw).unwrap();
        assert_eq!(rho.num_parts, 3);
        // consecutive nodes mostly share partitions (first-order affinity)
        let same = (0..11).filter(|&i| rho.assign[i] == rho.assign[i + 1]).count();
        assert!(same >= 9, "same={same}");
    }

    #[test]
    fn random_graph_valid() {
        let mut rng = Pcg64::seeded(8);
        let n = 300;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let dsts: Vec<u32> = (0..rng.range(2, 10))
                .map(|_| rng.below(n) as u32)
                .filter(|&d| d != s)
                .collect();
            if !dsts.is_empty() {
                b.add_edge(s, dsts, rng.next_f32() + 0.01);
            }
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 24;
        hw.c_apc = 200;
        let rho = partition(&g, &hw).unwrap();
        validate(&g, &rho, &hw).unwrap();
        assert!(rho.assign.iter().all(|&p| p != u32::MAX));
    }

    #[test]
    fn prefers_connected_partition() {
        // 0,1 tightly connected; 2 far; node 3 connects to 0 strongly
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1, 3], 5.0);
        b.add_edge(2, vec![3], 0.1);
        let g = b.build();
        let hw = NmhConfig::small();
        let rho = partition(&g, &hw).unwrap();
        // everything fits one partition under default constraints
        assert_eq!(rho.num_parts, 1);
    }
}

/// [`crate::stage::Partitioner`] over the graph-based EdgeMap control
/// (registry name "edgemap"). Deterministic and parameter-free.
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeMapPartitioner;

impl EdgeMapPartitioner {
    pub fn from_params(p: &crate::stage::StageParams) -> Result<Self, String> {
        p.check_known(&[])?;
        Ok(EdgeMapPartitioner)
    }
}

// snn-lint: allow(threads-wiring) — greedy edge-by-edge assignment is inherently
// sequential: every admission depends on all prior ones, so a worker budget has no
// sound decomposition (DESIGN.md §10's two-phase recipe does not apply to this stage)
impl crate::stage::Partitioner for EdgeMapPartitioner {
    fn name(&self) -> &str {
        "edgemap"
    }

    fn partition(
        &self,
        g: &Hypergraph,
        hw: &NmhConfig,
        _ctx: &crate::stage::StageCtx,
    ) -> Result<Partitioning, MapError> {
        partition(g, hw)
    }
}
