//! Streaming hypergraph partitioning (after the streaming partitioners of
//! [17] / Fernandez-Musoles [20]).
//!
//! Nodes arrive in a single pass (any order); a bounded lookahead buffer
//! re-ranks the next assignment by second-order affinity to the *open*
//! partition, and each node is placed greedily into the open partition
//! or — when it would not fit — parked until the partition rolls over.
//! This is the O(n) regime of sequential
//! partitioning with a small constant-factor quality recovery, trading
//! the global ordering pass (Alg. 2) for a window: the natural choice
//! when the SNN streams from disk and can't be indexed up front.

use super::{ConstraintTracker, MapError};
use crate::hw::NmhConfig;
use crate::hypergraph::quotient::Partitioning;
use crate::hypergraph::Hypergraph;

/// Streaming parameters.
#[derive(Clone, Copy, Debug)]
pub struct StreamParams {
    /// Lookahead buffer capacity (nodes held for re-ranking).
    pub window: usize,
}

impl Default for StreamParams {
    fn default() -> Self {
        StreamParams { window: 256 }
    }
}

/// Partition `g` with a single streaming pass + lookahead window.
///
/// A window node that would not fit the open partition is **parked** —
/// removed from the ranking until the partition rolls over — and the
/// next-best fitting window candidate is tried instead; the partition
/// rolls over only when *no* window node fits, at which point the parked
/// nodes rejoin the window and compete for the fresh partition.
pub fn partition(
    g: &Hypergraph,
    hw: &NmhConfig,
    params: StreamParams,
) -> Result<Partitioning, MapError> {
    let n = g.num_nodes();
    super::check_nodes_feasible(g, hw)?;
    let mut assign = vec![u32::MAX; n];
    let mut tracker = ConstraintTracker::new(g, hw);
    let mut part = 0u32;

    // the stream + window + parked set (unfitting nodes awaiting rollover)
    let mut next_id = 0u32;
    let mut window: Vec<u32> = Vec::with_capacity(params.window);
    let mut parked: Vec<u32> = Vec::new();

    let fill_window = |window: &mut Vec<u32>, next_id: &mut u32| {
        while window.len() < params.window && (*next_id as usize) < n {
            window.push(*next_id);
            *next_id += 1;
        }
    };
    fill_window(&mut window, &mut next_id);

    while !window.is_empty() || !parked.is_empty() {
        if window.is_empty() {
            // no window node fits the open partition: roll over and let
            // the parked nodes compete for the fresh one
            tracker.reset();
            part += 1;
            if part as usize >= hw.num_cores() {
                return Err(MapError::TooManyPartitions {
                    got: part as usize + 1,
                    limit: hw.num_cores(),
                });
            }
            window.append(&mut parked);
            continue;
        }
        // rank the window by affinity to the current partition: count of
        // inbound axons already present (synaptic reuse now), tie-break by
        // fewest new axons.
        let mut best_idx = 0usize;
        let mut best_key = (usize::MAX, usize::MAX, u32::MAX);
        for (i, &v) in window.iter().enumerate() {
            let new_ax = tracker.new_axons(v);
            let shared = g.inbound(v).len() - new_ax;
            // prefer max shared, then min new axons, then id (stable)
            let key = (usize::MAX - shared, new_ax, v);
            if key < best_key {
                best_key = key;
                best_idx = i;
            }
        }
        let v = window.swap_remove(best_idx);

        if !tracker.fits(v) {
            if tracker.npc == 0 {
                // the prelude proved v fits alone => internal inconsistency
                return Err(MapError::ConstraintViolated(format!(
                    "node {v} rejected by empty partition"
                )));
            }
            // park v until the partition rolls over; the next-best
            // window candidate keeps filling the open partition
            parked.push(v);
            continue;
        }
        tracker.add(v);
        assign[v as usize] = part;
        fill_window(&mut window, &mut next_id);
    }

    Ok(Partitioning::new(assign, part as usize + 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::mapping::{connectivity, sequential, validate};
    use crate::util::rng::Pcg64;

    fn shuffled_clusters(k: usize, size: usize, seed: u64) -> Hypergraph {
        // clustered topology with node ids shuffled: streaming must use
        // affinity, not id order, to group co-members
        let n = k * size;
        let mut rng = Pcg64::seeded(seed);
        let mut perm: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut perm);
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n {
            let c = s / size;
            let dsts: Vec<u32> = (0..6)
                .map(|_| perm[c * size + rng.below(size)])
                .filter(|&d| d != perm[s])
                .collect();
            if !dsts.is_empty() {
                b.add_edge(perm[s], dsts, rng.next_f32() + 0.01);
            }
        }
        b.build()
    }

    fn hw(npc: usize) -> NmhConfig {
        let mut hw = NmhConfig::small();
        hw.c_npc = npc;
        hw
    }

    #[test]
    fn valid_total_assignment() {
        let g = shuffled_clusters(4, 50, 1);
        let hw = hw(50);
        let rho = partition(&g, &hw, StreamParams::default()).unwrap();
        validate(&g, &rho, &hw).unwrap();
        assert!(rho.assign.iter().all(|&p| p != u32::MAX));
    }

    #[test]
    fn window_beats_windowless_on_shuffled_input() {
        let g = shuffled_clusters(6, 40, 3);
        let hw = hw(40);
        let streamed = partition(&g, &hw, StreamParams { window: 256 }).unwrap();
        let no_window = partition(&g, &hw, StreamParams { window: 1 }).unwrap();
        let cs = connectivity(&g, &streamed);
        let cn = connectivity(&g, &no_window);
        assert!(cs <= cn, "window {cs} vs windowless {cn}");
    }

    #[test]
    fn window_one_equals_unordered_sequential() {
        // degenerate window = pure arrival order = sequential unordered
        let g = shuffled_clusters(3, 30, 5);
        let hw = hw(30);
        let streamed = partition(&g, &hw, StreamParams { window: 1 }).unwrap();
        let seq = sequential::partition(&g, &hw, sequential::SeqOrder::Natural).unwrap();
        assert_eq!(streamed.assign, seq.assign);
    }

    #[test]
    fn window_one_equals_sequential_under_rollover_pressure() {
        // window = 1 must track sequential Natural even when partitions
        // roll over constantly (the park-then-rollover path degenerates
        // to sequential's reset-and-retry)
        let g = shuffled_clusters(4, 25, 11);
        let mut hwc = hw(7);
        hwc.c_spc = 40;
        let streamed = partition(&g, &hwc, StreamParams { window: 1 }).unwrap();
        let seq = sequential::partition(&g, &hwc, sequential::SeqOrder::Natural).unwrap();
        assert_eq!(streamed.assign, seq.assign);
    }

    #[test]
    fn parks_oversized_node_while_comembers_keep_filling() {
        // Hub B (node 6) shares two axons with the open partition, so it
        // outranks the remaining smalls the moment small 0 lands — but
        // its 12 inbound synapses exceed the remaining C_spc budget. The
        // doc'd behavior: park B, keep filling with smalls 1-5, roll
        // over once for B alone. The pre-fix code instead rolled over on
        // the spot, scattering the smalls over 6 partitions.
        let mut b = HypergraphBuilder::new(19);
        b.add_edge(7, vec![1, 2, 3, 4, 5, 6], 1.0); // e0: smalls 1-5 + B
        b.add_edge(8, vec![0, 6], 1.0); // e1: small 0 + B
        for i in 0..10u32 {
            b.add_edge(9 + i, vec![6], 1.0); // B's private fan-in
        }
        let g = b.build();
        let mut hwc = hw(30);
        hwc.c_apc = 20;
        hwc.c_spc = 12; // B alone needs 12; small 0 + B needs 13
        let rho = partition(&g, &hwc, StreamParams::default()).unwrap();
        validate(&g, &rho, &hwc).unwrap();
        assert_eq!(rho.num_parts, 2, "assign={:?}", rho.assign);
        // every small co-habits with small 0; B got the rollover alone
        let p0 = rho.assign[0];
        for small in 1..=5usize {
            assert_eq!(rho.assign[small], p0, "small {small} was evicted");
        }
        assert_ne!(rho.assign[6], p0, "the parked hub must wait for the rollover");
    }

    #[test]
    fn respects_constraints_under_pressure() {
        let g = shuffled_clusters(4, 40, 7);
        let mut hwc = hw(16);
        hwc.c_apc = 64;
        hwc.c_spc = 200;
        let rho = partition(&g, &hwc, StreamParams::default()).unwrap();
        validate(&g, &rho, &hwc).unwrap();
    }

    #[test]
    fn unmappable_node_detected() {
        let mut b = HypergraphBuilder::new(6);
        for s in 0..5u32 {
            b.add_edge(s, vec![5], 1.0);
        }
        let g = b.build();
        let mut hwc = hw(8);
        hwc.c_apc = 2; // node 5 has 5 inbound axons
        assert!(matches!(
            partition(&g, &hwc, StreamParams::default()),
            Err(MapError::NodeUnmappable { node: 5, .. })
        ));
    }
}

/// [`crate::stage::Partitioner`] over the one-pass streaming algorithm
/// (registry name "streaming"). The lookahead window is a spec
/// parameter instead of a hard-wired `Default::default()`; the pass
/// itself is deterministic and consumes no randomness.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamingPartitioner {
    pub params: StreamParams,
}

impl StreamingPartitioner {
    pub fn new() -> Self {
        StreamingPartitioner { params: StreamParams::default() }
    }

    /// Construct from spec parameters: `window` (lookahead size ≥ 1).
    pub fn from_params(p: &crate::stage::StageParams) -> Result<Self, String> {
        p.check_known(&["window"])?;
        let mut s = StreamingPartitioner::new();
        if let Some(w) = p.get_usize("window")? {
            if w == 0 {
                return Err("parameter 'window' must be >= 1".to_string());
            }
            s.params.window = w;
        }
        Ok(s)
    }
}

// snn-lint: allow(threads-wiring) — one-pass streaming admission is order-dependent and
// serial by design (the paper's §V baseline); parallelizing it would change semantics
impl crate::stage::Partitioner for StreamingPartitioner {
    fn name(&self) -> &str {
        "streaming"
    }

    fn partition(
        &self,
        g: &Hypergraph,
        hw: &NmhConfig,
        _ctx: &crate::stage::StageCtx,
    ) -> Result<Partitioning, MapError> {
        partition(g, hw, self.params)
    }
}
