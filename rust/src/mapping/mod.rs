//! Constrained hypergraph partitioning (paper §IV-A).
//!
//! All partitioners produce a [`Partitioning`] (ρ: N → P) that must
//! satisfy the NMH per-core constraints (Eqs. 4-6) and the partition-count
//! limit |P| ≤ |H|. The quality objective is the weighted connectivity
//! (λ-style) metric of Eq. 7, computed on the quotient h-graph.

pub mod edgemap;
pub mod hierarchical;
pub mod ordering;
pub mod overlap;
pub mod pruning;
pub mod repair;
pub mod sequential;
pub mod streaming;

use crate::hw::NmhConfig;
use crate::hypergraph::quotient::Partitioning;
use crate::hypergraph::{EdgeId, Hypergraph};
use std::collections::HashSet;

/// Partitioning failure modes.
#[derive(Debug, Clone, PartialEq)]
pub enum MapError {
    /// A single neuron exceeds per-core constraints on its own.
    NodeUnmappable { node: u32, reason: String },
    /// More partitions than hardware cores.
    TooManyPartitions { got: usize, limit: usize },
    /// Constraint violated by a produced partitioning (validation).
    ConstraintViolated(String),
    /// A pipeline spec names an unknown stage or carries bad parameters
    /// (registry/spec layer, see `coordinator::registry`).
    BadSpec(String),
    /// A stage name has no registry entry. Split out of [`Self::BadSpec`]
    /// so callers (the experiment grid, CLI exit paths) can distinguish
    /// "no such algorithm" from "bad parameters for a known algorithm".
    UnknownStage {
        /// Stage kind: "partitioner", "placer" or "refiner".
        kind: &'static str,
        /// The name the spec asked for.
        name: String,
        /// The registered names (canonical, sorted).
        known: Vec<String>,
    },
    /// Checkpoint subsystem failure or a deliberate round-limit stop (the
    /// latter carries the [`crate::runtime::checkpoint::ROUND_LIMIT_PREFIX`]
    /// message prefix and maps to CLI exit code 3).
    Checkpoint(String),
}

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MapError::NodeUnmappable { node, reason } => {
                write!(f, "node {node} cannot fit any core: {reason}")
            }
            MapError::TooManyPartitions { got, limit } => {
                write!(f, "{got} partitions exceed the {limit}-core lattice")
            }
            MapError::ConstraintViolated(m) => write!(f, "constraint violated: {m}"),
            MapError::BadSpec(m) => write!(f, "bad pipeline spec: {m}"),
            MapError::UnknownStage { kind, name, known } => {
                write!(f, "unknown {kind} '{name}' (known: {})", known.join(", "))
            }
            MapError::Checkpoint(m) => write!(f, "checkpoint: {m}"),
        }
    }
}

impl std::error::Error for MapError {}

/// Weighted connectivity of a partitioned h-graph (Eq. 7):
/// `Conn(G_P) = Σ_e w_P(e) · |D_e|` — each h-edge pays its weight once per
/// connected destination partition. Computed directly on `G_S` + ρ without
/// materializing the quotient.
pub fn connectivity(g: &Hypergraph, rho: &Partitioning) -> f64 {
    let mut seen: Vec<u32> = Vec::new();
    let mut stamp = vec![u32::MAX; rho.num_parts];
    let mut total = 0.0f64;
    for e in g.edge_ids() {
        seen.clear();
        for &d in g.dsts(e) {
            let p = rho.assign[d as usize];
            if stamp[p as usize] != e {
                stamp[p as usize] = e;
                seen.push(p);
            }
        }
        total += g.weight(e) as f64 * seen.len() as f64;
    }
    total
}

/// External connectivity variant: destination partitions *other than* the
/// source's (spikes that actually leave the core). Reported alongside
/// Eq. 7 in diagnostics.
pub fn external_connectivity(g: &Hypergraph, rho: &Partitioning) -> f64 {
    let mut stamp = vec![u32::MAX; rho.num_parts];
    let mut total = 0.0f64;
    for e in g.edge_ids() {
        let ps = rho.assign[g.source(e) as usize];
        let mut count = 0usize;
        for &d in g.dsts(e) {
            let p = rho.assign[d as usize];
            if p != ps && stamp[p as usize] != e {
                stamp[p as usize] = e;
                count += 1;
            }
        }
        total += g.weight(e) as f64 * count as f64;
    }
    total
}

/// Validate a partitioning against the hardware constraints
/// (Eqs. 4, 5, 6 and the |P| ≤ |H| bound).
pub fn validate(g: &Hypergraph, rho: &Partitioning, hw: &NmhConfig) -> Result<(), MapError> {
    if rho.assign.len() != g.num_nodes() {
        return Err(MapError::ConstraintViolated(format!(
            "assignment covers {} of {} nodes",
            rho.assign.len(),
            g.num_nodes()
        )));
    }
    if rho.num_parts > hw.num_cores() {
        return Err(MapError::TooManyPartitions {
            got: rho.num_parts,
            limit: hw.num_cores(),
        });
    }
    // Eq. 4: nodes per partition.
    let sizes = rho.sizes();
    if let Some((p, &sz)) = sizes.iter().enumerate().find(|(_, &s)| s > hw.c_npc) {
        return Err(MapError::ConstraintViolated(format!(
            "partition {p} holds {sz} > C_npc={} nodes",
            hw.c_npc
        )));
    }
    // Eq. 6: inbound synapses (connections) per partition.
    let mut syn = vec![0usize; rho.num_parts];
    for e in g.edge_ids() {
        for &d in g.dsts(e) {
            syn[rho.assign[d as usize] as usize] += 1;
        }
    }
    if let Some((p, &s)) = syn.iter().enumerate().find(|(_, &s)| s > hw.c_spc) {
        return Err(MapError::ConstraintViolated(format!(
            "partition {p} receives {s} > C_spc={} synapses",
            hw.c_spc
        )));
    }
    // Eq. 5: distinct inbound h-edges (axons) per partition.
    let mut axons: Vec<HashSet<EdgeId>> = vec![HashSet::new(); rho.num_parts];
    for e in g.edge_ids() {
        let mut last = u32::MAX;
        for &d in g.dsts(e) {
            let p = rho.assign[d as usize];
            if p != last {
                axons[p as usize].insert(e);
                last = p;
            }
        }
    }
    if let Some((p, a)) = axons.iter().enumerate().find(|(_, a)| a.len() > hw.c_apc) {
        return Err(MapError::ConstraintViolated(format!(
            "partition {p} sees {} > C_apc={} distinct axons",
            a.len(),
            hw.c_apc
        )));
    }
    Ok(())
}

/// A single node must fit an empty core, else the graph is unmappable —
/// the O(1) per-node check behind [`check_nodes_feasible`] and
/// [`ConstraintTracker::node_feasible`].
pub fn node_feasible(g: &Hypergraph, hw: &NmhConfig, n: u32) -> Result<(), MapError> {
    if hw.c_npc == 0 {
        // a zero-capacity core admits no node at all: without this check
        // every greedy partitioner would fail mid-run with the internal
        // "rejected by empty partition" inconsistency instead
        return Err(MapError::NodeUnmappable {
            node: n,
            reason: "C_npc=0 admits no node on any core".to_string(),
        });
    }
    let inb = g.inbound(n).len();
    if inb > hw.c_spc {
        return Err(MapError::NodeUnmappable {
            node: n,
            reason: format!("{inb} inbound synapses > C_spc={}", hw.c_spc),
        });
    }
    if inb > hw.c_apc {
        return Err(MapError::NodeUnmappable {
            node: n,
            reason: format!("{inb} inbound axons > C_apc={}", hw.c_apc),
        });
    }
    Ok(())
}

/// Shared partitioner prelude: every node must fit an empty core on its
/// own (Eqs. 5-6 lower bound), else no partitioning exists and the
/// algorithm should fail fast instead of mid-run. O(n) — each check is
/// two index-length comparisons.
pub fn check_nodes_feasible(g: &Hypergraph, hw: &NmhConfig) -> Result<(), MapError> {
    for n in 0..g.num_nodes() as u32 {
        node_feasible(g, hw, n)?;
    }
    Ok(())
}

/// Incremental per-partition constraint bookkeeping shared by the greedy
/// partitioners: tracks node count, synapse count and the distinct
/// inbound-axon set of the partition under construction.
///
/// The read-only queries ([`Self::new_axons`], [`Self::fits`],
/// [`Self::has_axon`]) take `&self` and touch no interior mutability, so
/// a `&ConstraintTracker` can be shared across scoring workers — the
/// overlap partitioner's parallel frontier scoring relies on this
/// (DESIGN.md §11); only [`Self::add`]/[`Self::reset`] mutate state.
pub struct ConstraintTracker<'a> {
    g: &'a Hypergraph,
    hw: &'a NmhConfig,
    /// nodes in current partition
    pub npc: usize,
    /// synapses (inbound connections) in current partition
    pub spc: usize,
    /// stamp[e] == epoch  <=>  h-edge e is in the current partition's axon set
    stamp: Vec<u32>,
    epoch: u32,
    /// |axon set|
    pub apc: usize,
}

impl<'a> ConstraintTracker<'a> {
    pub fn new(g: &'a Hypergraph, hw: &'a NmhConfig) -> Self {
        ConstraintTracker {
            g,
            hw,
            npc: 0,
            spc: 0,
            stamp: vec![0; g.num_edges()],
            epoch: 1,
            apc: 0,
        }
    }

    /// Distinct inbound axons node `n` would add to the current partition.
    #[inline]
    pub fn new_axons(&self, n: u32) -> usize {
        self.g
            .inbound(n)
            .iter()
            .filter(|&&e| self.stamp[e as usize] != self.epoch)
            .count()
    }

    /// Is h-edge `e` already in the current partition's axon set?
    #[inline]
    pub fn has_axon(&self, e: EdgeId) -> bool {
        self.stamp[e as usize] == self.epoch
    }

    /// Would adding node `n` keep the current partition feasible?
    pub fn fits(&self, n: u32) -> bool {
        let inb = self.g.inbound(n).len();
        self.npc + 1 <= self.hw.c_npc
            && self.spc + inb <= self.hw.c_spc
            && self.apc + self.new_axons(n) <= self.hw.c_apc
    }

    /// A single node must fit an empty core, else the graph is unmappable.
    pub fn node_feasible(&self, n: u32) -> Result<(), MapError> {
        node_feasible(self.g, self.hw, n)
    }

    /// Add node `n` to the current partition, updating all counters.
    pub fn add(&mut self, n: u32) {
        self.npc += 1;
        self.spc += self.g.inbound(n).len();
        for &e in self.g.inbound(n) {
            if self.stamp[e as usize] != self.epoch {
                self.stamp[e as usize] = self.epoch;
                self.apc += 1;
            }
        }
    }

    /// Heap footprint of the tracker's scratch (stats reporting).
    pub fn memory_bytes(&self) -> usize {
        self.stamp.len() * std::mem::size_of::<u32>()
    }

    /// Close the current partition and start a fresh one.
    pub fn reset(&mut self) {
        self.npc = 0;
        self.spc = 0;
        self.apc = 0;
        self.epoch += 1;
        if self.epoch == u32::MAX {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;

    fn star() -> Hypergraph {
        // node 0 feeds 1..=4 (one h-edge); node 1 feeds {2, 3}
        let mut b = HypergraphBuilder::new(5);
        b.add_edge(0, vec![1, 2, 3, 4], 2.0);
        b.add_edge(1, vec![2, 3], 1.0);
        b.build()
    }

    #[test]
    fn connectivity_eq7_counts_distinct_partitions() {
        let g = star();
        // everything together: each edge touches exactly 1 partition
        let one = Partitioning::new(vec![0; 5], 1);
        assert!((connectivity(&g, &one) - (2.0 + 1.0)).abs() < 1e-9);
        // split {0,1} | {2,3} | {4}: edge0 dsts {1,2,3,4} -> parts {0,1,2} = 3
        // edge1 dsts {2,3} -> parts {1} = 1
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2], 3);
        assert!((connectivity(&g, &rho) - (2.0 * 3.0 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn external_connectivity_excludes_source_partition() {
        let g = star();
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2], 3);
        // edge0 src part 0, external dsts {1,2} -> 2; edge1 src part 0, dst {1} -> 1
        assert!((external_connectivity(&g, &rho) - (2.0 * 2.0 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn validate_catches_each_constraint() {
        let g = star();
        let mut hw = NmhConfig::small();
        let rho = Partitioning::new(vec![0; 5], 1);
        assert!(validate(&g, &rho, &hw).is_ok());

        hw.c_npc = 4;
        assert!(matches!(
            validate(&g, &rho, &hw),
            Err(MapError::ConstraintViolated(_))
        ));
    }

    #[test]
    fn validate_synapse_and_axon_limits() {
        let g = star();
        let rho = Partitioning::new(vec![0; 5], 1);
        let mut hw = NmhConfig::small();
        hw.c_spc = 5; // 6 synapses total inbound
        let err = validate(&g, &rho, &hw).unwrap_err();
        assert!(matches!(err, MapError::ConstraintViolated(ref m) if m.contains("C_spc")));
        let mut hw = NmhConfig::small();
        hw.c_apc = 1; // partition 0 sees 2 distinct axons
        let err = validate(&g, &rho, &hw).unwrap_err();
        assert!(matches!(err, MapError::ConstraintViolated(ref m) if m.contains("C_apc")));
    }

    #[test]
    fn validate_partition_count() {
        let g = star();
        let mut hw = NmhConfig::small();
        hw.width = 1;
        hw.height = 2;
        let rho = Partitioning::new(vec![0, 1, 2, 0, 1], 3);
        assert!(matches!(
            validate(&g, &rho, &hw),
            Err(MapError::TooManyPartitions { got: 3, limit: 2 })
        ));
    }

    #[test]
    fn tracker_matches_validate() {
        let g = star();
        let hw = NmhConfig::small();
        let mut t = ConstraintTracker::new(&g, &hw);
        assert!(t.fits(2));
        t.add(2); // inbound = {e0, e1}
        assert_eq!((t.npc, t.spc, t.apc), (1, 2, 2));
        t.add(3); // same inbound set -> apc unchanged (synaptic reuse!)
        assert_eq!((t.npc, t.spc, t.apc), (2, 4, 2));
        assert_eq!(t.new_axons(4), 0); // e0 already present
        t.reset();
        assert_eq!((t.npc, t.spc, t.apc), (0, 0, 0));
        assert_eq!(t.new_axons(2), 2);
    }

    #[test]
    fn tracker_node_feasibility() {
        let g = star();
        let mut hw = NmhConfig::small();
        hw.c_spc = 1;
        let t = ConstraintTracker::new(&g, &hw);
        assert!(t.node_feasible(4).is_ok()); // 1 inbound
        assert!(t.node_feasible(2).is_err()); // 2 inbound > 1
    }

    #[test]
    fn zero_npc_classified_as_unmappable_not_internal_inconsistency() {
        // C_npc = 0 means no node fits any core: the prelude must report
        // NodeUnmappable instead of letting the greedy partitioners die
        // mid-run with the "rejected by empty partition" internal error
        let g = star();
        let mut hw = NmhConfig::small();
        hw.c_npc = 0;
        let err = node_feasible(&g, &hw, 0).unwrap_err();
        assert!(matches!(err, MapError::NodeUnmappable { node: 0, .. }), "{err}");
        let seq = crate::mapping::sequential::partition(
            &g,
            &hw,
            crate::mapping::sequential::SeqOrder::Natural,
        );
        let stream = crate::mapping::streaming::partition(&g, &hw, Default::default());
        let edge = crate::mapping::edgemap::partition(&g, &hw);
        for (name, res) in [("sequential", seq), ("streaming", stream), ("edgemap", edge)] {
            assert!(
                matches!(res, Err(MapError::NodeUnmappable { node: 0, .. })),
                "{name}: {res:?}"
            );
        }
    }

    #[test]
    fn check_nodes_feasible_prelude() {
        let g = star();
        assert!(check_nodes_feasible(&g, &NmhConfig::small()).is_ok());
        let mut hw = NmhConfig::small();
        hw.c_spc = 1;
        let err = check_nodes_feasible(&g, &hw).unwrap_err();
        assert!(matches!(err, MapError::NodeUnmappable { node: 2, .. }), "{err}");
        let mut hw = NmhConfig::small();
        hw.c_apc = 1;
        let err = check_nodes_feasible(&g, &hw).unwrap_err();
        assert!(matches!(err, MapError::NodeUnmappable { .. }), "{err}");
    }
}
