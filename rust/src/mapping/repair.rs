//! Minimal-churn remap repair after a runtime hardware fault
//! (DESIGN.md §15).
//!
//! A from-scratch remap after a single core or link death reshuffles
//! nearly every neuron — on real deployments that means rewriting every
//! core's synapse tables. [`repair`] instead perturbs the existing
//! mapping as little as possible:
//!
//! - **Link death** keeps ρ and γ untouched — the NoC simulator reroutes
//!   around dead links ([`crate::sim::noc::simulate_faulty`]), so no
//!   neuron state moves at all.
//! - **Core death** first tries to relocate the victim partition *whole*
//!   to a free alive core, chosen to minimize the weighted Manhattan
//!   distance to its placed quotient neighbors (ties resolve to the
//!   smaller `(y, x)` — deterministic). Only when the lattice has no
//!   free alive core are the victim's neurons redistributed one by one
//!   (ascending node id) to the surviving partition of highest hyperedge
//!   co-membership affinity that still satisfies the derated capacity
//!   constraints.
//!
//! The outcome reports the moved-neuron count next to what a
//! from-scratch remap (sequential partition + masked min-dist placement)
//! would have moved, plus the energy delta against that baseline — the
//! churn/quality trade-off in two numbers.

use crate::hw::faults::FaultMask;
use crate::hw::NmhConfig;
use crate::hypergraph::quotient::{push_forward, Partitioning};
use crate::hypergraph::{EdgeId, Hypergraph};
use crate::mapping::MapError;
use crate::placement::{mindist, Placement};
use std::collections::HashSet;

/// A single runtime fault event to repair around.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultEvent {
    /// The core at `(x, y)` died.
    CoreDeath { x: u16, y: u16 },
    /// The directed link leaving `(x, y)` towards `dir` (E=0, W=1, N=2,
    /// S=3) died.
    LinkDeath { x: u16, y: u16, dir: usize },
}

/// Result of one [`repair`] call.
#[derive(Clone, Debug)]
pub struct RepairOutcome {
    /// Repaired partitioning (unchanged unless neurons redistributed).
    pub rho: Partitioning,
    /// Repaired placement over the surviving cores.
    pub placement: Placement,
    /// Input mask plus the repaired event.
    pub mask: FaultMask,
    /// Neurons whose core coordinate changed.
    pub moved_neurons: usize,
    /// Neurons a from-scratch remap (sequential partition + masked
    /// min-dist placement) would have moved; `None` when that baseline
    /// itself fails on the degraded lattice.
    pub scratch_moved: Option<usize>,
    /// Repaired energy minus from-scratch energy (positive = the cheap
    /// repair pays this much mapping quality for its low churn).
    pub cost_delta: Option<f64>,
}

/// Repair a valid `(ρ, γ)` mapping of `g` after `event`, moving as few
/// neurons as possible. `mask` holds the faults already known *before*
/// the event (pass an all-healthy mask for the first failure).
pub fn repair(
    g: &Hypergraph,
    rho: &Partitioning,
    placement: &Placement,
    hw: &NmhConfig,
    mask: &FaultMask,
    event: FaultEvent,
) -> Result<RepairOutcome, MapError> {
    mask.check_matches(hw).map_err(MapError::BadSpec)?;
    if rho.num_parts != placement.len() {
        return Err(MapError::ConstraintViolated(format!(
            "placement covers {} of {} partitions",
            placement.len(),
            rho.num_parts
        )));
    }
    let mut mask2 = mask.clone();
    let (x, y) = match event {
        FaultEvent::LinkDeath { x, y, dir } => {
            if (x as usize) >= hw.width || (y as usize) >= hw.height || dir >= 4 {
                return Err(MapError::BadSpec(format!(
                    "link ({x}, {y}, dir {dir}) outside the {}x{} lattice",
                    hw.width, hw.height
                )));
            }
            mask2.kill_link(x, y, dir);
            // the simulator reroutes; neuron state stays where it is
            return Ok(RepairOutcome {
                rho: rho.clone(),
                placement: placement.clone(),
                mask: mask2,
                moved_neurons: 0,
                scratch_moved: None,
                cost_delta: None,
            });
        }
        FaultEvent::CoreDeath { x, y } => {
            if (x as usize) >= hw.width || (y as usize) >= hw.height {
                return Err(MapError::BadSpec(format!(
                    "core ({x}, {y}) outside the {}x{} lattice",
                    hw.width, hw.height
                )));
            }
            (x, y)
        }
    };
    mask2.kill_core(x, y);

    let victim = match placement.coords.iter().position(|&c| c == (x, y)) {
        Some(p) => p,
        None => {
            // the dead core carried no partition: nothing to move
            return Ok(RepairOutcome {
                rho: rho.clone(),
                placement: placement.clone(),
                mask: mask2,
                moved_neurons: 0,
                scratch_moved: None,
                cost_delta: None,
            });
        }
    };
    let eff_hw = mask2.effective_hw(hw);

    let (rho2, pl2, moved) = match free_alive_core(hw, &mask2, placement, victim) {
        Some(_) => relocate_partition(g, rho, placement, hw, &mask2, victim),
        None => redistribute_neurons(g, rho, placement, &eff_hw, victim)?,
    };

    // churn + quality vs a from-scratch remap on the degraded lattice
    let (scratch_moved, cost_delta) = match scratch_baseline(g, rho, placement, hw, &eff_hw, &mask2)
    {
        Some((s_moved, s_energy)) => {
            let qg = push_forward(g, &rho2).graph;
            let energy = crate::metrics::evaluate_serial(&qg, &pl2, hw).energy;
            (Some(s_moved), Some(energy - s_energy))
        }
        None => (None, None),
    };

    Ok(RepairOutcome {
        rho: rho2,
        placement: pl2,
        mask: mask2,
        moved_neurons: moved,
        scratch_moved,
        cost_delta,
    })
}

/// First free alive core in row-major `(y, x)` order, skipping cells any
/// partition other than `victim` occupies.
fn free_alive_core(
    hw: &NmhConfig,
    mask: &FaultMask,
    placement: &Placement,
    victim: usize,
) -> Option<(u16, u16)> {
    let mut occupied = vec![false; hw.num_cores()];
    for (p, &(cx, cy)) in placement.coords.iter().enumerate() {
        if p != victim {
            occupied[hw.index(cx, cy)] = true;
        }
    }
    for i in 0..hw.num_cores() {
        if !occupied[i] && !mask.core_dead_idx(i) {
            return Some(hw.coord(i));
        }
    }
    None
}

/// Move the whole victim partition to the free alive core minimizing the
/// weighted Manhattan distance to its placed quotient neighbors. Only the
/// victim's neurons move; ρ is untouched.
fn relocate_partition(
    g: &Hypergraph,
    rho: &Partitioning,
    placement: &Placement,
    hw: &NmhConfig,
    mask: &FaultMask,
    victim: usize,
) -> (Partitioning, Placement, usize) {
    // traffic-weighted quotient neighbors of the victim: source→dst
    // terms of every quotient h-edge touching it
    let qg = push_forward(g, rho).graph;
    let mut nbw = vec![0.0f64; rho.num_parts];
    for e in qg.edge_ids() {
        let s = qg.source(e) as usize;
        let w = qg.weight(e) as f64;
        if s == victim {
            for &d in qg.dsts(e) {
                if d as usize != victim {
                    nbw[d as usize] += w;
                }
            }
        } else if qg.dsts(e).contains(&(victim as u32)) {
            nbw[s] += w;
        }
    }

    let mut occupied = vec![false; hw.num_cores()];
    for (p, &(cx, cy)) in placement.coords.iter().enumerate() {
        if p != victim {
            occupied[hw.index(cx, cy)] = true;
        }
    }
    // row-major scan with strict improvement keeps the first (smallest
    // (y, x)) of any tied score — deterministic on every platform
    let mut best: Option<((u16, u16), f64)> = None;
    for i in 0..hw.num_cores() {
        if occupied[i] || mask.core_dead_idx(i) {
            continue;
        }
        let c = hw.coord(i);
        let mut score = 0.0f64;
        for (q, &w) in nbw.iter().enumerate() {
            if w > 0.0 {
                score += w * NmhConfig::manhattan(c, placement.coords[q]) as f64;
            }
        }
        if !matches!(best, Some((_, b)) if b <= score) {
            best = Some((c, score));
        }
    }
    // free_alive_core() returned Some, so at least one candidate scored
    let target = match best {
        Some((c, _)) => c,
        None => placement.coords[victim],
    };
    let mut coords = placement.coords.clone();
    coords[victim] = target;
    let moved = rho.sizes()[victim];
    (rho.clone(), Placement { coords }, moved)
}

/// Hyperedge co-membership affinity of neuron `n` to partition `q`:
/// Σ over h-edges incident to `n` of `w(e) · |members(e) ∩ q|`, under
/// the current (partially updated) assignment.
fn affinity(g: &Hypergraph, assign: &[u32], n: u32, q: u32) -> f64 {
    let mut a = 0.0f64;
    for &e in g.inbound(n).iter().chain(g.outbound(n).iter()) {
        let w = g.weight(e) as f64;
        let mut members = 0usize;
        let s = g.source(e);
        if s != n && assign[s as usize] == q {
            members += 1;
        }
        for &d in g.dsts(e) {
            if d != n && assign[d as usize] == q {
                members += 1;
            }
        }
        a += w * members as f64;
    }
    a
}

/// No free core left: dissolve the victim partition, sending each neuron
/// (ascending id) to the surviving partition of highest affinity that
/// still fits the derated capacities. The victim's partition id is then
/// compacted away so the placement stays one-coordinate-per-partition.
fn redistribute_neurons(
    g: &Hypergraph,
    rho: &Partitioning,
    placement: &Placement,
    eff_hw: &NmhConfig,
    victim: usize,
) -> Result<(Partitioning, Placement, usize), MapError> {
    // per-partition usage mirroring mapping::validate's three counters
    let mut npc = rho.sizes();
    let mut spc = vec![0usize; rho.num_parts];
    let mut axons: Vec<HashSet<EdgeId>> = vec![HashSet::new(); rho.num_parts];
    for e in g.edge_ids() {
        for &d in g.dsts(e) {
            let p = rho.assign[d as usize] as usize;
            spc[p] += 1;
            axons[p].insert(e);
        }
    }

    let mut assign = rho.assign.clone();
    let members: Vec<u32> = (0..g.num_nodes() as u32)
        .filter(|&n| rho.assign[n as usize] == victim as u32)
        .collect();
    for &n in &members {
        let inb = g.inbound(n);
        let mut best: Option<(u32, f64)> = None;
        for q in 0..rho.num_parts as u32 {
            if q as usize == victim {
                continue;
            }
            let qi = q as usize;
            let new_axons = inb.iter().filter(|e| !axons[qi].contains(e)).count();
            if npc[qi] + 1 > eff_hw.c_npc
                || spc[qi] + inb.len() > eff_hw.c_spc
                || axons[qi].len() + new_axons > eff_hw.c_apc
            {
                continue;
            }
            let a = affinity(g, &assign, n, q);
            // strict improvement: the smallest q of any tied affinity wins
            if !matches!(best, Some((_, b)) if b >= a) {
                best = Some((q, a));
            }
        }
        let q = match best {
            Some((q, _)) => q,
            None => {
                return Err(MapError::NodeUnmappable {
                    node: n,
                    reason: "no surviving partition can absorb it within derated capacity"
                        .to_string(),
                })
            }
        };
        let qi = q as usize;
        assign[n as usize] = q;
        npc[qi] += 1;
        spc[qi] += inb.len();
        axons[qi].extend(inb.iter().copied());
    }

    // drop the now-empty victim id; partitions above it shift down by one,
    // and the placement row for the victim disappears with it
    for a in assign.iter_mut() {
        if *a > victim as u32 {
            *a -= 1;
        }
    }
    let mut coords = placement.coords.clone();
    coords.remove(victim);
    Ok((
        Partitioning::new(assign, rho.num_parts - 1),
        Placement { coords },
        members.len(),
    ))
}

/// From-scratch baseline on the degraded lattice: sequential partition
/// under the derated capacities, masked min-dist placement over the alive
/// cores. Returns (neurons moved vs the old mapping, energy), or `None`
/// when the baseline itself cannot map the degraded hardware.
fn scratch_baseline(
    g: &Hypergraph,
    old_rho: &Partitioning,
    old_placement: &Placement,
    hw: &NmhConfig,
    eff_hw: &NmhConfig,
    mask: &FaultMask,
) -> Option<(usize, f64)> {
    let rho = crate::mapping::sequential::partition(
        g,
        eff_hw,
        crate::mapping::sequential::SeqOrder::Natural,
    )
    .ok()?;
    let qg = push_forward(g, &rho).graph;
    let pl = mindist::place_masked(&qg, hw, 1, Some(mask)).ok()?;
    let moved = (0..g.num_nodes())
        .filter(|&n| {
            old_placement.coords[old_rho.assign[n] as usize] != pl.coords[rho.assign[n] as usize]
        })
        .count();
    let energy = crate::metrics::evaluate_serial(&qg, &pl, hw).energy;
    Some((moved, energy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;

    /// 6-node chain partitioned pairwise onto the bottom row of a 3×3
    /// lattice with room to spare.
    fn chain_mapping() -> (Hypergraph, Partitioning, Placement, NmhConfig) {
        let mut b = HypergraphBuilder::new(6);
        for i in 0..5u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        let g = b.build();
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2, 2], 3);
        let pl = Placement { coords: vec![(0, 0), (1, 0), (2, 0)] };
        let mut hw = NmhConfig::small();
        hw.width = 3;
        hw.height = 3;
        (g, rho, pl, hw)
    }

    #[test]
    fn link_death_moves_nothing() {
        let (g, rho, pl, hw) = chain_mapping();
        let mask = FaultMask::healthy(&hw);
        let out =
            repair(&g, &rho, &pl, &hw, &mask, FaultEvent::LinkDeath { x: 1, y: 0, dir: 0 })
                .unwrap();
        assert_eq!(out.moved_neurons, 0);
        assert_eq!(out.rho.assign, rho.assign);
        assert_eq!(out.placement.coords, pl.coords);
        assert!(out.mask.is_link_dead(1, 0, 0));
        assert_eq!(out.mask.dead_core_count(), 0);
    }

    #[test]
    fn core_death_relocates_whole_partition() {
        let (g, rho, pl, hw) = chain_mapping();
        let mask = FaultMask::healthy(&hw);
        let out =
            repair(&g, &rho, &pl, &hw, &mask, FaultEvent::CoreDeath { x: 1, y: 0 }).unwrap();
        // only partition 1's two neurons move, ρ is untouched
        assert_eq!(out.moved_neurons, 2);
        assert_eq!(out.rho.assign, rho.assign);
        assert_eq!(out.placement.coords[0], (0, 0));
        assert_eq!(out.placement.coords[2], (2, 0));
        let new = out.placement.coords[1];
        assert_ne!(new, (1, 0));
        assert!(!out.mask.is_core_dead(new.0, new.1));
        // neighbors sit at (0,0) and (2,0): row 1 ties at total distance
        // 4, and the row-major scan keeps the smallest (y, x) — (0,1)
        assert_eq!(new, (0, 1));
        // churn beats (or ties) the from-scratch baseline on this lattice
        let scratch = out.scratch_moved.expect("baseline maps the degraded lattice");
        assert!(out.moved_neurons <= scratch, "repair {} vs scratch {scratch}", out.moved_neurons);
        assert!(out.cost_delta.is_some());
        // repeatability: same inputs, same outcome
        let again =
            repair(&g, &rho, &pl, &hw, &mask, FaultEvent::CoreDeath { x: 1, y: 0 }).unwrap();
        assert_eq!(again.placement.coords, out.placement.coords);
    }

    #[test]
    fn core_death_redistributes_when_lattice_is_full() {
        // 2×2 lattice fully occupied by 4 partitions: no free core, so
        // the victim's neurons spread over the survivors by affinity
        let mut b = HypergraphBuilder::new(8);
        for i in 0..7u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        let g = b.build();
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2, 2, 3, 3], 4);
        let pl = Placement { coords: vec![(0, 0), (1, 0), (0, 1), (1, 1)] };
        let mut hw = NmhConfig::small();
        hw.width = 2;
        hw.height = 2;
        let mask = FaultMask::healthy(&hw);
        let out =
            repair(&g, &rho, &pl, &hw, &mask, FaultEvent::CoreDeath { x: 1, y: 0 }).unwrap();
        assert_eq!(out.moved_neurons, 2); // partition 1 = {2, 3}
        assert_eq!(out.rho.num_parts, 3);
        assert_eq!(out.placement.coords, vec![(0, 0), (0, 1), (1, 1)]);
        crate::mapping::validate(&g, &out.rho, &hw).unwrap();
        for &(cx, cy) in &out.placement.coords {
            assert!(!out.mask.is_core_dead(cx, cy));
        }
        // chain affinity pulls 2 and 3 towards partitions holding 1 or 4
        let p2 = out.rho.assign[2];
        let p3 = out.rho.assign[3];
        assert!(p2 == out.rho.assign[1] || p3 == out.rho.assign[4]);
    }

    #[test]
    fn redistribute_respects_capacity() {
        // survivors are all full (c_npc = 2): the victim's neurons have
        // nowhere to go and repair reports the node, never panics
        let mut b = HypergraphBuilder::new(8);
        for i in 0..7u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        let g = b.build();
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2, 2, 3, 3], 4);
        let pl = Placement { coords: vec![(0, 0), (1, 0), (0, 1), (1, 1)] };
        let mut hw = NmhConfig::small();
        hw.width = 2;
        hw.height = 2;
        hw.c_npc = 2;
        let mask = FaultMask::healthy(&hw);
        let err = repair(&g, &rho, &pl, &hw, &mask, FaultEvent::CoreDeath { x: 1, y: 0 })
            .unwrap_err();
        assert!(matches!(err, MapError::NodeUnmappable { node: 2, .. }), "{err}");
    }

    #[test]
    fn unoccupied_core_death_is_a_no_op() {
        let (g, rho, pl, hw) = chain_mapping();
        let mask = FaultMask::healthy(&hw);
        let out =
            repair(&g, &rho, &pl, &hw, &mask, FaultEvent::CoreDeath { x: 2, y: 2 }).unwrap();
        assert_eq!(out.moved_neurons, 0);
        assert_eq!(out.placement.coords, pl.coords);
        assert!(out.mask.is_core_dead(2, 2));
    }

    #[test]
    fn out_of_lattice_events_are_bad_spec() {
        let (g, rho, pl, hw) = chain_mapping();
        let mask = FaultMask::healthy(&hw);
        for ev in [
            FaultEvent::CoreDeath { x: 3, y: 0 },
            FaultEvent::LinkDeath { x: 0, y: 3, dir: 0 },
            FaultEvent::LinkDeath { x: 0, y: 0, dir: 4 },
        ] {
            assert!(matches!(
                repair(&g, &rho, &pl, &hw, &mask, ev),
                Err(MapError::BadSpec(_))
            ));
        }
    }
}
