//! Hierarchical (multilevel) hypergraph partitioning (paper §IV-A1),
//! hMETIS/KaHyPar-style, reworked to *minimize* the number of partitions
//! under NMH constraints instead of producing a fixed balanced k.
//!
//! Pipeline:
//! 1. **Coarsening rounds** — nodes visited in random order; each is
//!    paired with the unmatched co-member of maximum second-order affinity
//!    (total weight of shared h-edges) whose merge stays feasible. Pairs
//!    contract; h-edges are remapped, destination sets dedup'd, and
//!    identical (source, D) edges merged with weight summed while a
//!    multiplicity counter preserves the *original axon count* each coarse
//!    edge represents (C_apc accounting). Stops when no pair forms or the
//!    graph reaches ⌈n/C_npc⌉ nodes. With `threads > 1` each round runs
//!    **two-phase**: a parallel *propose* phase scores every node's top-K
//!    candidate partners (the scoring loop that dominates the round) and a
//!    cheap serial *commit* phase resolves conflicts in the seeded visit
//!    order — bit-for-bit identical to [`coarsen_round_serial`] (tested).
//! 2. **Initial partitioning** — each coarsest node is a partition.
//! 3. **Uncoarsening + boundary-driven refinement** — the assignment is
//!    projected level by level; at each level a work-list of *boundary*
//!    nodes (destinations of h-edges spanning ≥ 2 partitions — the only
//!    nodes with any Eq. 7 gain candidates) is refined: gains are
//!    precomputed in parallel chunks against the pass-start assignment,
//!    then moves are verified and applied serially, each applied move
//!    re-enqueueing its co-members. Thread count never changes results.
//!
//! Memory model (DESIGN.md §10): level 0 *borrows* the input graph
//! (`Cow::Borrowed` — the old engine cloned it), coarser levels share one
//! [`QuotientScratch`] arena across push-forward rounds, axon
//! multiplicities are accumulated inside the push-forward sweep (no
//! `merged_from` lists), and uncoarsening drops each level's graph as
//! soon as its assignment has been projected to the finer level. The
//! per-round push-forward itself runs the §12 two-phase parallel sweep
//! when `threads > 1` (`push_forward_pooled`'s worker knob — bit-for-bit
//! thread-invariant like every other stage here).

use super::MapError;
use crate::hw::NmhConfig;
use crate::hypergraph::quotient::{push_forward_pooled, Partitioning, QuotientScratch};
use crate::hypergraph::Hypergraph;
use crate::runtime::checkpoint::{self, CheckpointPolicy};
use crate::util::rng::Pcg64;
use std::borrow::Cow;
use std::path::Path;

/// Below this node count a coarsening round / refinement pass runs on the
/// serial path even when `threads > 1` — scoped-thread spawn overhead
/// would dominate. Invisible in results: the paths agree bit-for-bit.
/// `pub(crate)` so thread-invariance tests can assert they actually
/// cross it (a sub-threshold "parallel" run would be vacuously serial).
pub(crate) const PAR_MIN_NODES: usize = 512;

/// Candidate partners stored per node by the parallel propose phase. The
/// serial commit needs at most 8 *unmatched* candidates; storing 24 makes
/// the exact-recompute fallback (> 16 of a node's best partners already
/// taken when it is visited) rare.
const CAND_K: usize = 24;

/// Tunables (defaults follow the paper's description).
#[derive(Clone, Debug)]
pub struct HierParams {
    pub seed: u64,
    /// Max refinement passes per uncoarsening level. Passes after the
    /// first only revisit nodes re-enqueued by applied moves, so extra
    /// passes are cheap.
    pub refine_passes: usize,
    /// Stop coarsening when a round pairs fewer than this fraction.
    pub min_pair_fraction: f64,
    /// Worker budget for the two-phase coarsening/refinement rounds
    /// (1 = serial). A performance knob only: the output is bit-for-bit
    /// identical for every value (enforced by tests).
    pub threads: usize,
    /// Crash-safe checkpoint/resume between coarsening rounds
    /// (DESIGN.md §13). `None` (the default) runs without checkpointing.
    /// Like `threads`, this is an environment knob only: resumed runs are
    /// bit-for-bit identical to uninterrupted ones (enforced by tests).
    pub checkpoint: Option<CheckpointPolicy>,
}

impl Default for HierParams {
    fn default() -> Self {
        HierParams {
            seed: 0xC0FFEE,
            refine_passes: 3,
            min_pair_fraction: 0.02,
            threads: 1,
            checkpoint: None,
        }
    }
}

/// Diagnostics from one multilevel run (hotpath bench + `SNNMAP_TIMING`).
#[derive(Clone, Copy, Debug, Default)]
pub struct HierStats {
    /// Levels in the hierarchy, including the borrowed level 0.
    pub levels: usize,
    /// Wall-clock spent coarsening (matching + push-forward rounds).
    pub coarsen_secs: f64,
    /// Wall-clock spent uncoarsening (refinement + projection).
    pub refine_secs: f64,
    /// Peak bytes held in *owned* hierarchy payloads (coarse graphs,
    /// multiplicities, aggregates, projection maps). Level 0 borrows the
    /// input graph and contributes nothing.
    pub peak_hierarchy_bytes: usize,
}

/// Per-coarse-node aggregates that NMH constraints are defined on.
#[derive(Clone, Debug)]
struct Aggregates {
    /// original nodes folded into each coarse node
    node_count: Vec<u32>,
    /// original inbound synapses folded into each coarse node
    syn_count: Vec<u64>,
}

/// One level of the hierarchy. Level 0 borrows the caller's graph; every
/// coarser level owns its quotient.
struct Level<'a> {
    graph: Cow<'a, Hypergraph>,
    /// original-axon multiplicity of each h-edge at this level
    axon_mult: Vec<u32>,
    agg: Aggregates,
    /// fine-node -> coarse-node map to the NEXT level (absent at the top)
    to_coarse: Option<Vec<u32>>,
}

fn hierarchy_bytes(levels: &[Level]) -> usize {
    levels
        .iter()
        .map(|l| {
            let g = match &l.graph {
                Cow::Owned(g) => g.memory_bytes(),
                Cow::Borrowed(_) => 0,
            };
            g + l.axon_mult.len() * 4
                + l.agg.node_count.len() * 4
                + l.agg.syn_count.len() * 8
                + l.to_coarse.as_ref().map_or(0, |v| v.len() * 4)
        })
        .sum()
}

/// Hierarchical partitioning entry point.
pub fn partition(
    g: &Hypergraph,
    hw: &NmhConfig,
    params: HierParams,
) -> Result<Partitioning, MapError> {
    partition_with_stats(g, hw, params).map(|(rho, _)| rho)
}

/// [`partition`] plus per-run diagnostics (level count, stage wall-clock,
/// peak hierarchy bytes) for the hotpath bench.
pub fn partition_with_stats(
    g: &Hypergraph,
    hw: &NmhConfig,
    params: HierParams,
) -> Result<(Partitioning, HierStats), MapError> {
    let n = g.num_nodes();
    let mut stats = HierStats::default();
    if n == 0 {
        return Ok((Partitioning::new(vec![], 0), stats));
    }
    super::check_nodes_feasible(g, hw)?;
    let target = crate::util::div_ceil(n, hw.c_npc).max(1);
    let threads = params.threads.max(1);
    let mut rng = Pcg64::new(params.seed, 23);

    // ---- build hierarchy (level 0 borrows the input graph) ----
    let mut levels: Vec<Level> = vec![Level {
        graph: Cow::Borrowed(g),
        axon_mult: vec![1; g.num_edges()],
        agg: Aggregates {
            node_count: vec![1; n],
            syn_count: (0..n as u32).map(|v| g.inbound(v).len() as u64).collect(),
        },
        to_coarse: None,
    }];

    // ---- checkpoint/resume (DESIGN.md §13) ----
    // The fingerprint pins everything the run is a function of *except*
    // the thread count (a performance knob with bit-identical results),
    // so a checkpoint resumes on any worker budget.
    let policy = params.checkpoint.as_ref();
    let spec_hash = policy.map(|_| run_fingerprint(g, hw, &params));
    // Coarsening rounds completed so far; also names the checkpoint files.
    let mut round: u64 = 0;
    // Coarsening wall-clock carried over from the interrupted run.
    let mut coarsen_base = 0.0f64;
    if let Some(pol) = policy {
        if pol.resume {
            // snn-lint: allow(unwrap-ban) — spec_hash is computed whenever a checkpoint
            // policy is present, and this branch requires one
            let want = spec_hash.unwrap();
            let rec = checkpoint::load_latest(&pol.dir, want).map_err(|e| {
                MapError::Checkpoint(format!("scanning {}: {e}", pol.dir.display()))
            })?;
            for (path, why) in &rec.skipped {
                eprintln!("[ckpt] skipped {}: {why}", path.display());
            }
            if let Some(state) = rec.state {
                let consistent = state
                    .levels
                    .first()
                    .is_some_and(|l0| {
                        l0.graph.is_none()
                            && l0.node_count.len() == n
                            && l0.axon_mult.len() == g.num_edges()
                    })
                    && state.levels.iter().skip(1).all(|l| l.graph.is_some());
                if !consistent {
                    return Err(MapError::Checkpoint(
                        "checkpoint inconsistent with the input graph".into(),
                    ));
                }
                round = state.round;
                rng = Pcg64::from_state(state.rng);
                coarsen_base = state.coarsen_secs;
                stats.peak_hierarchy_bytes = state.peak_hierarchy_bytes as usize;
                levels = state
                    .levels
                    .into_iter()
                    .map(|ls| Level {
                        graph: match ls.graph {
                            Some(qg) => Cow::Owned(qg),
                            None => Cow::Borrowed(g),
                        },
                        axon_mult: ls.axon_mult,
                        agg: Aggregates {
                            node_count: ls.node_count,
                            syn_count: ls.syn_count,
                        },
                        to_coarse: ls.to_coarse,
                    })
                    .collect();
                eprintln!(
                    "[ckpt] resumed round {round} ({} levels) from {}",
                    levels.len(),
                    rec.loaded_from.as_deref().unwrap_or(Path::new("?")).display()
                );
            } else if !rec.skipped.is_empty() {
                eprintln!("[ckpt] no valid checkpoint in {}; starting fresh", pol.dir.display());
            }
        }
    }

    let debug_timing = crate::util::timing_enabled();
    let mut qscratch = QuotientScratch::new();
    let mut props: Vec<NodeProposal> = Vec::new();
    let t_coarsen = std::time::Instant::now();
    loop {
        // snn-lint: allow(unwrap-ban) — levels is seeded with the input graph before the
        // loop and only ever grows
        let top = levels.last().unwrap();
        let graph: &Hypergraph = &top.graph;
        let cur_n = graph.num_nodes();
        if cur_n <= target {
            break;
        }
        let t0 = std::time::Instant::now();
        let matching = if threads > 1 && cur_n >= PAR_MIN_NODES {
            coarsen_round_parallel(
                graph,
                &top.axon_mult,
                &top.agg,
                hw,
                &mut rng,
                threads,
                &mut props,
            )
        } else {
            coarsen_round_serial(graph, &top.axon_mult, &top.agg, hw, &mut rng)
        };
        if debug_timing {
            eprintln!("[hier] coarsen n={cur_n} pairs={} in {:?}", matching.pairs, t0.elapsed());
        }
        let paired = matching.pairs;
        if (paired as f64) < params.min_pair_fraction * cur_n as f64 {
            break;
        }
        let rho = Partitioning::new(matching.assign, matching.num_coarse);
        let t0 = std::time::Instant::now();
        let (qg, axon_mult) =
            push_forward_pooled(graph, &rho, &top.axon_mult, &mut qscratch, threads);
        if debug_timing {
            eprintln!(
                "[hier] push_forward -> n={} e={} in {:?}",
                qg.num_nodes(),
                qg.num_edges(),
                t0.elapsed()
            );
        }
        // node/syn aggregates fold into the coarser level in one sweep
        // (the axon multiplicities were fused into push_forward itself)
        let mut node_count = vec![0u32; rho.num_parts];
        let mut syn_count = vec![0u64; rho.num_parts];
        for fine in 0..cur_n {
            let c = rho.assign[fine] as usize;
            node_count[c] += top.agg.node_count[fine];
            syn_count[c] += top.agg.syn_count[fine];
        }
        // snn-lint: allow(unwrap-ban) — levels is seeded before the loop and only grows
        levels.last_mut().unwrap().to_coarse = Some(rho.assign);
        levels.push(Level {
            graph: Cow::Owned(qg),
            axon_mult,
            agg: Aggregates { node_count, syn_count },
            to_coarse: None,
        });
        stats.peak_hierarchy_bytes = stats.peak_hierarchy_bytes.max(hierarchy_bytes(&levels));
        round += 1;
        if let Some(pol) = policy {
            let stop = pol.stop_after_rounds.is_some_and(|limit| round >= limit);
            if stop || round % pol.interval_rounds.max(1) as u64 == 0 {
                // The RNG state is captured *after* this round, so replay
                // continues exactly where the interrupted run would have.
                let view = checkpoint::RunStateView {
                    // snn-lint: allow(unwrap-ban) — spec_hash is computed whenever a
                    // checkpoint policy is present, and this branch requires one
                    spec_hash: spec_hash.unwrap(),
                    seed: params.seed,
                    round,
                    rng: rng.state(),
                    coarsen_secs: coarsen_base + t_coarsen.elapsed().as_secs_f64(),
                    peak_hierarchy_bytes: stats.peak_hierarchy_bytes as u64,
                    levels: level_views(&levels),
                };
                let path = checkpoint::save(pol, &view).map_err(|e| {
                    MapError::Checkpoint(format!("writing to {}: {e}", pol.dir.display()))
                })?;
                if debug_timing {
                    eprintln!("[ckpt] wrote {} after round {round}", path.display());
                }
                if stop {
                    return Err(MapError::Checkpoint(format!(
                        "{}: stopped after {round} coarsening rounds; state saved to {} \
                         (rerun with --resume to continue)",
                        checkpoint::ROUND_LIMIT_PREFIX,
                        path.display()
                    )));
                }
            }
        }
    }
    stats.coarsen_secs = coarsen_base + t_coarsen.elapsed().as_secs_f64();
    stats.levels = levels.len();
    stats.peak_hierarchy_bytes = stats.peak_hierarchy_bytes.max(hierarchy_bytes(&levels));

    // ---- initial partitioning: coarsest node == partition ----
    // snn-lint: allow(unwrap-ban) — levels is seeded before the coarsening loop, never drained
    let coarsest_n = levels.last().unwrap().graph.num_nodes();
    if coarsest_n > hw.num_cores() {
        return Err(MapError::TooManyPartitions {
            got: coarsest_n,
            limit: hw.num_cores(),
        });
    }
    let mut assign: Vec<u32> = (0..coarsest_n as u32).collect();
    let mut num_parts = coarsest_n;

    // ---- uncoarsen + refine; each level drops once projected ----
    let t_refine = std::time::Instant::now();
    while let Some(level) = levels.pop() {
        let li = levels.len();
        let t0 = std::time::Instant::now();
        let graph: &Hypergraph = &level.graph;
        let mut refiner = Refiner::new(graph, &level.axon_mult, &level.agg, hw, num_parts, &assign);
        for _ in 0..params.refine_passes {
            if refiner.pass(&mut rng, threads) == 0 {
                break;
            }
        }
        if debug_timing {
            eprintln!("[hier] refine level {li} (n={}) in {:?}", graph.num_nodes(), t0.elapsed());
        }
        assign = refiner.assign;
        // project to the finer level, whose to_coarse points here
        if let Some(finer) = levels.last() {
            // snn-lint: allow(unwrap-ban) — every level below the coarsest had to_coarse
            // set when its coarser neighbor was pushed; uncoarsening only visits those
            let map = finer.to_coarse.as_ref().expect("hierarchy link missing");
            let mut fine_assign = vec![0u32; finer.graph.num_nodes()];
            for (f, &c) in map.iter().enumerate() {
                fine_assign[f] = assign[c as usize];
            }
            assign = fine_assign;
        }
        num_parts = num_parts.max(assign.iter().map(|&p| p as usize + 1).max().unwrap_or(0));
        // `level` (its owned graph + aggregates) drops here
    }
    stats.refine_secs = t_refine.elapsed().as_secs_f64();

    Ok((Partitioning::new(assign, num_parts).compacted(), stats))
}

/// Fingerprint of everything a run's output is a function of: input graph
/// structure, hardware constraints, seed and algorithm knobs. The thread
/// count is deliberately excluded (results are thread-invariant, so a
/// checkpoint resumes on any worker budget), as is the checkpoint policy
/// itself (where state is saved cannot change what is computed).
fn run_fingerprint(g: &Hypergraph, hw: &NmhConfig, params: &HierParams) -> u64 {
    let mut h = checkpoint::Fnv64::new();
    h.write_u64(checkpoint::graph_fingerprint(g));
    for v in [hw.width, hw.height, hw.c_npc, hw.c_apc, hw.c_spc] {
        h.write_u64(v as u64);
    }
    h.write_u64(params.seed);
    h.write_u64(params.refine_passes as u64);
    h.write_u64(params.min_pair_fraction.to_bits());
    h.finish()
}

/// Borrowed checkpoint views of the hierarchy. Level 0 is `Cow::Borrowed`
/// (the caller's graph, pinned by the run fingerprint) and serializes no
/// graph; owned quotient levels embed theirs as `SNNHG1` streams.
fn level_views<'a>(levels: &'a [Level]) -> Vec<checkpoint::LevelView<'a>> {
    levels
        .iter()
        .map(|l| checkpoint::LevelView {
            graph: match &l.graph {
                Cow::Owned(qg) => Some(qg),
                Cow::Borrowed(_) => None,
            },
            axon_mult: &l.axon_mult,
            node_count: &l.agg.node_count,
            syn_count: &l.agg.syn_count,
            to_coarse: l.to_coarse.as_deref(),
        })
        .collect()
}

/// Result of one coarsening round.
struct Matching {
    assign: Vec<u32>,
    num_coarse: usize,
    pairs: usize,
}

/// Epoch-stamped dense scoring scratch for serial matching (a HashMap
/// here dominated the whole partitioner's runtime — §Perf: 2.5x on the
/// Allen-V1 row).
struct MatchScratch {
    score: Vec<f64>,
    stamp: Vec<u32>,
    touched: Vec<u32>,
    epoch: u32,
}

impl MatchScratch {
    fn new(n: usize) -> Self {
        MatchScratch {
            score: vec![0.0; n],
            stamp: vec![0; n],
            touched: Vec::new(),
            epoch: 0,
        }
    }
}

/// The co-member affinity sweep shared by every matching path: bump the
/// epoch-stamped scoreboard for each co-member of `u` (skipping `u` and
/// anything `skip` rejects) through u's inbound h-edges (siblings +
/// source) and its outbound h-edges (its own listeners). Keeping this as
/// the single copy is what guarantees the serial round and the parallel
/// propose phase accumulate bit-identical f64 scores.
fn score_comembers<F: Fn(u32) -> bool>(
    g: &Hypergraph,
    u: u32,
    score: &mut [f64],
    stamp: &mut [u32],
    touched: &mut Vec<u32>,
    epoch: u32,
    skip: F,
) {
    let mut bump = |v: u32, w: f64| {
        if v == u || skip(v) {
            return;
        }
        let vi = v as usize;
        if stamp[vi] != epoch {
            stamp[vi] = epoch;
            score[vi] = 0.0;
            touched.push(v);
        }
        score[vi] += w;
    };
    for &e in g.inbound(u) {
        let w = g.weight(e) as f64;
        bump(g.source(e), w);
        for &d in g.dsts(e) {
            bump(d, w);
        }
    }
    for &e in g.outbound(u) {
        let w = g.weight(e) as f64;
        for &d in g.dsts(e) {
            bump(d, w);
        }
    }
}

/// Partial selection of the top `k` candidates by (score desc, id asc),
/// left sorted — hub nodes can touch thousands of nodes, so a full sort
/// is avoided. Shared by the serial matcher (k = 8) and the parallel
/// propose phase (k = CAND_K); the comparator being a total order is
/// what makes "filter a sorted superset" == "sort the filtered subset".
fn select_top_by_score(touched: &mut Vec<u32>, score: &[f64], k: usize) {
    let cmp = |a: &u32, b: &u32| {
        crate::util::cmp_non_nan(&score[*b as usize], &score[*a as usize]).then(a.cmp(b))
    };
    if touched.len() > k {
        touched.select_nth_unstable_by(k - 1, cmp);
        touched.truncate(k);
    }
    touched.sort_by(cmp);
}

/// Serial matching step for one visit node: score the *unmatched*
/// co-members, select the top 8 by (score desc, id asc), pair with the
/// first feasible one. Shared verbatim by [`coarsen_round_serial`] and
/// the parallel commit's exact-recompute fallback, which is what keeps
/// the two round implementations bit-for-bit interchangeable.
#[allow(clippy::too_many_arguments)]
fn match_one_serial(
    g: &Hypergraph,
    axon_mult: &[u32],
    agg: &Aggregates,
    hw: &NmhConfig,
    u: u32,
    mate: &mut [u32],
    scr: &mut MatchScratch,
    edge_stamp: &mut [u32],
    edge_epoch: &mut u32,
) {
    let MatchScratch { score, stamp, touched, epoch } = scr;
    *epoch += 1;
    touched.clear();
    {
        let mate = &*mate;
        score_comembers(g, u, score, stamp, touched, *epoch, |v| {
            mate[v as usize] != u32::MAX
        });
    }
    if touched.is_empty() {
        return;
    }
    select_top_by_score(touched, score, 8);
    for &v in touched.iter().take(8) {
        if merge_feasible(g, axon_mult, agg, hw, u, v, edge_stamp, edge_epoch) {
            mate[u as usize] = v;
            mate[v as usize] = u;
            break;
        }
    }
}

/// Number matched pairs/singletons into consecutive coarse ids.
fn enumerate_matching(mate: &[u32]) -> Matching {
    let n = mate.len();
    let mut assign = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut pairs = 0usize;
    for u in 0..n as u32 {
        if assign[u as usize] != u32::MAX {
            continue;
        }
        assign[u as usize] = next;
        let v = mate[u as usize];
        if v != u32::MAX && assign[v as usize] == u32::MAX {
            assign[v as usize] = next;
            pairs += 1;
        }
        next += 1;
    }
    Matching {
        assign,
        num_coarse: next as usize,
        pairs,
    }
}

/// One pair-coarsening round, fully serial: random visit order, exact
/// pairwise second-order-affinity scoring over co-members,
/// feasibility-checked. The reference implementation the parallel round
/// must reproduce bit-for-bit.
fn coarsen_round_serial(
    g: &Hypergraph,
    axon_mult: &[u32],
    agg: &Aggregates,
    hw: &NmhConfig,
    rng: &mut Pcg64,
) -> Matching {
    let n = g.num_nodes();
    let mut visit: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut visit);
    let mut mate = vec![u32::MAX; n];
    let mut scr = MatchScratch::new(n);
    // edge-membership scratch for merge_feasible's axon-union count
    let mut edge_stamp = vec![0u32; g.num_edges()];
    let mut edge_epoch = 0u32;
    for &u in &visit {
        if mate[u as usize] != u32::MAX {
            continue;
        }
        match_one_serial(
            g,
            axon_mult,
            agg,
            hw,
            u,
            &mut mate,
            &mut scr,
            &mut edge_stamp,
            &mut edge_epoch,
        );
    }
    enumerate_matching(&mate)
}

/// Per-node output of the parallel propose phase: the top-`CAND_K`
/// candidate partners in (score desc, id asc) order, plus whether the
/// stored prefix is the node's *complete* candidate list.
#[derive(Clone, Copy)]
struct NodeProposal {
    len: u8,
    complete: bool,
    cands: [u32; CAND_K],
}

impl Default for NodeProposal {
    fn default() -> Self {
        NodeProposal { len: 0, complete: true, cands: [0; CAND_K] }
    }
}

/// Two-phase deterministic parallel coarsening round.
///
/// *Propose* (parallel): every node's co-member affinity scores — the
/// loop that dominates a round — are computed over fixed node chunks with
/// per-worker epoch-stamped scratch; nothing is matched at round start,
/// so scores are independent of scheduling and each node's sorted top-K
/// candidate list is exactly the serial scoreboard minus the
/// matched-filter.
///
/// *Commit* (serial): walk the seeded visit order; for each unmatched
/// node try its stored candidates, skipping ones matched meanwhile, under
/// the serial 8-attempt budget. A node's stored prefix can only diverge
/// from the serial behavior when it runs dry early (most of its best
/// partners taken) *and* was truncated — then the commit falls back to
/// [`match_one_serial`], the exact serial code path. Result: bit-for-bit
/// identical to [`coarsen_round_serial`] for the same rng state (tested
/// by `coarsen_round_parallel_matches_serial`).
fn coarsen_round_parallel(
    g: &Hypergraph,
    axon_mult: &[u32],
    agg: &Aggregates,
    hw: &NmhConfig,
    rng: &mut Pcg64,
    threads: usize,
    props: &mut Vec<NodeProposal>,
) -> Matching {
    let n = g.num_nodes();
    let mut visit: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut visit);

    // ---- propose (parallel over fixed node chunks) ----
    props.clear();
    props.resize(n, NodeProposal::default());
    let chunk = crate::util::par::fixed_chunk(n, threads);
    // snn-lint: allow(float-merge-order) — propose phase: score_comembers accumulates
    // f64 affinities in this closure's own scoreboard from pass-start state only, each
    // node's proposal lands in its disjoint `props` slot, and the commit loop below is
    // serial in seeded visit order (§12) — no cross-thread float merge exists
    crate::util::par::par_chunks_mut(props, chunk, threads, |ci, slice| {
        let base = ci * chunk;
        let mut score = vec![0.0f64; n];
        let mut stamp = vec![0u32; n];
        let mut touched: Vec<u32> = Vec::new();
        let mut epoch = 0u32;
        for (k, slot) in slice.iter_mut().enumerate() {
            let u = (base + k) as u32;
            epoch += 1;
            touched.clear();
            // same sweep as the serial matcher, minus the matched-filter
            // (nothing is matched at round start)
            score_comembers(g, u, &mut score, &mut stamp, &mut touched, epoch, |_| false);
            let total = touched.len();
            select_top_by_score(&mut touched, &score, CAND_K);
            slot.len = touched.len() as u8;
            slot.complete = total <= CAND_K;
            slot.cands[..touched.len()].copy_from_slice(&touched);
        }
    });

    // ---- commit (serial, seeded visit order) ----
    let mut mate = vec![u32::MAX; n];
    let mut edge_stamp = vec![0u32; g.num_edges()];
    let mut edge_epoch = 0u32;
    let mut fallback: Option<MatchScratch> = None;
    for &u in &visit {
        if mate[u as usize] != u32::MAX {
            continue;
        }
        let p = &props[u as usize];
        let mut tried = 0usize;
        let mut matched = false;
        for &v in &p.cands[..p.len as usize] {
            if mate[v as usize] != u32::MAX {
                continue;
            }
            tried += 1;
            if merge_feasible(g, axon_mult, agg, hw, u, v, &mut edge_stamp, &mut edge_epoch) {
                mate[u as usize] = v;
                mate[v as usize] = u;
                matched = true;
                break;
            }
            if tried == 8 {
                break;
            }
        }
        if !matched && tried < 8 && !p.complete {
            // stored prefix ran dry before the serial attempt budget:
            // recompute this node exactly as the serial round would
            let scr = fallback.get_or_insert_with(|| MatchScratch::new(n));
            match_one_serial(
                g,
                axon_mult,
                agg,
                hw,
                u,
                &mut mate,
                scr,
                &mut edge_stamp,
                &mut edge_epoch,
            );
        }
    }
    enumerate_matching(&mate)
}

/// Would merging coarse nodes u and v stay within per-core limits?
/// `edge_stamp`/`edge_epoch` is reusable O(1)-reset scratch for the exact
/// axon-union count (a per-candidate HashSet dominated coarsening time).
#[allow(clippy::too_many_arguments)]
fn merge_feasible(
    g: &Hypergraph,
    axon_mult: &[u32],
    agg: &Aggregates,
    hw: &NmhConfig,
    u: u32,
    v: u32,
    edge_stamp: &mut [u32],
    edge_epoch: &mut u32,
) -> bool {
    if agg.node_count[u as usize] + agg.node_count[v as usize] > hw.c_npc as u32 {
        return false;
    }
    if agg.syn_count[u as usize] + agg.syn_count[v as usize] > hw.c_spc as u64 {
        return false;
    }
    // distinct original axons of the union: Σ mult over union of inbound
    // coarse-edge sets (exact, computed only for the candidate actually
    // tried — the "original, exact edge-coarsening" the paper keeps).
    *edge_epoch += 1;
    let ep = *edge_epoch;
    let mut axons: u64 = 0;
    for &e in g.inbound(u) {
        edge_stamp[e as usize] = ep;
        axons += axon_mult[e as usize] as u64;
    }
    for &e in g.inbound(v) {
        if edge_stamp[e as usize] != ep {
            axons += axon_mult[e as usize] as u64;
        }
    }
    axons <= hw.c_apc as u64
}

/// Per-worker scratch for the refinement propose phase: epoch-stamped
/// dense per-partition accumulators for the cover decomposition
///
///   gain(u: p→q) = base − (W_u − cover_w(q)),
///   base        = Σ_{e∋u} w(e)·[u is e's only destination in p],
///   W_u         = Σ_{e∋u} w(e),
///   cover_w(q)  = Σ_{e∋u} w(e)·[e already reaches q],
///
/// — no (edge, partition) hash map (which previously dominated
/// hierarchical partitioning; §Perf: 47 s → ~8 s on the Allen-V1 row).
struct ProposeScratch {
    cover_w: Vec<f64>,
    cover_mult: Vec<u64>,
    cand_stamp: Vec<u32>,
    epoch: u32,
    // per-edge partition dedup stamp (one bump per scanned edge)
    pstamp: Vec<u32>,
    pepoch: u32,
    cands: Vec<u32>,
}

impl ProposeScratch {
    fn new(num_parts: usize) -> Self {
        ProposeScratch {
            cover_w: vec![0.0; num_parts],
            cover_mult: vec![0; num_parts],
            cand_stamp: vec![0; num_parts],
            epoch: 0,
            pstamp: vec![0; num_parts],
            pepoch: 0,
            cands: Vec::new(),
        }
    }
}

/// Boundary-driven greedy move refiner at one hierarchy level.
///
/// Instead of re-sweeping all n nodes every pass (the old engine), a
/// work-list holds only *boundary* nodes — destinations of h-edges whose
/// destination set spans ≥ 2 partitions; every other node provably has no
/// Eq. 7 gain candidate. Each pass is two-phase: gains are precomputed in
/// parallel chunks against the pass-start assignment (read-only, so any
/// worker count gives identical proposals), then moves are re-verified
/// against the *current* assignment and applied serially in the seeded
/// visit order; each applied move re-enqueues the co-members whose gains
/// it invalidated. Serial and parallel execution are bit-for-bit
/// identical by construction (and tested).
struct Refiner<'a> {
    g: &'a Hypergraph,
    axon_mult: &'a [u32],
    agg: &'a Aggregates,
    hw: &'a NmhConfig,
    assign: Vec<u32>,
    part_nodes: Vec<u64>,
    part_syn: Vec<u64>,
    part_axons: Vec<u64>,
    /// nodes to (re)visit next pass; `in_list` dedups membership
    worklist: Vec<u32>,
    in_list: Vec<bool>,
}

impl<'a> Refiner<'a> {
    fn new(
        g: &'a Hypergraph,
        axon_mult: &'a [u32],
        agg: &'a Aggregates,
        hw: &'a NmhConfig,
        num_parts: usize,
        assign: &[u32],
    ) -> Self {
        let mut r = Refiner {
            g,
            axon_mult,
            agg,
            hw,
            assign: assign.to_vec(),
            part_nodes: vec![0; num_parts],
            part_syn: vec![0; num_parts],
            part_axons: vec![0; num_parts],
            worklist: Vec::new(),
            in_list: vec![false; g.num_nodes()],
        };
        for v in 0..g.num_nodes() {
            let p = r.assign[v] as usize;
            r.part_nodes[p] += agg.node_count[v] as u64;
            r.part_syn[p] += agg.syn_count[v];
        }
        // One sweep: part_axons (Σ mult over distinct (edge, partition)
        // incidences) fused with boundary detection for the work-list.
        let mut stamp = vec![u32::MAX; num_parts];
        for e in g.edge_ids() {
            let dsts = g.dsts(e);
            let first = dsts.first().map(|&d| r.assign[d as usize]);
            let mut spanning = false;
            for &d in dsts {
                let p = r.assign[d as usize];
                if stamp[p as usize] != e {
                    stamp[p as usize] = e;
                    r.part_axons[p as usize] += axon_mult[e as usize] as u64;
                }
                if Some(p) != first {
                    spanning = true;
                }
            }
            if spanning {
                for &d in dsts {
                    if !r.in_list[d as usize] {
                        r.in_list[d as usize] = true;
                        r.worklist.push(d);
                    }
                }
            }
        }
        r
    }

    /// Target partition of the best positive-gain feasible move for `u`
    /// against the pass-start state; `u32::MAX` when none. Read-only on
    /// `self` — the commit phase recomputes the gain anyway, so only the
    /// chosen target survives the phase boundary.
    fn propose(&self, u: u32, scr: &mut ProposeScratch) -> u32 {
        let from = self.assign[u as usize];
        scr.epoch += 1;
        scr.cands.clear();

        // single sweep: base gain + per-candidate cover accumulation
        let mut base = 0.0f64;
        let mut w_total = 0.0f64;
        let mut mult_total = 0u64;
        for &e in self.g.inbound(u) {
            let w = self.g.weight(e) as f64;
            let mult = self.axon_mult[e as usize] as u64;
            w_total += w;
            mult_total += mult;
            scr.pepoch += 1;
            let mut from_others = false;
            for &d in self.g.dsts(e) {
                if d == u {
                    continue;
                }
                let p = self.assign[d as usize];
                if p == from {
                    from_others = true;
                    continue;
                }
                let pi = p as usize;
                if scr.pstamp[pi] == scr.pepoch {
                    continue; // this edge already covers p
                }
                scr.pstamp[pi] = scr.pepoch;
                if scr.cand_stamp[pi] != scr.epoch {
                    scr.cand_stamp[pi] = scr.epoch;
                    scr.cover_w[pi] = 0.0;
                    scr.cover_mult[pi] = 0;
                    scr.cands.push(p);
                }
                scr.cover_w[pi] += w;
                scr.cover_mult[pi] += mult;
            }
            if !from_others {
                base += w; // u is `from`'s only listener of e
            }
        }

        // pick the best feasible positive-gain candidate
        let mut best: Option<(f64, u32)> = None;
        for &q in &scr.cands {
            let qi = q as usize;
            let gain = base - (w_total - scr.cover_w[qi]);
            if gain <= 1e-12 {
                continue;
            }
            if best.map(|(g, _)| gain <= g).unwrap_or(false) {
                continue;
            }
            // feasibility: nodes, synapses, axons
            if self.part_nodes[qi] + self.agg.node_count[u as usize] as u64
                > self.hw.c_npc as u64
                || self.part_syn[qi] + self.agg.syn_count[u as usize] > self.hw.c_spc as u64
                || self.part_axons[qi] + (mult_total - scr.cover_mult[qi])
                    > self.hw.c_apc as u64
            {
                continue;
            }
            best = Some((gain, q));
        }
        best.map_or(u32::MAX, |(_, q)| q)
    }

    /// Re-verify a proposed move against the *current* assignment (gains
    /// and axon deltas shift as earlier commits land) and apply it if it
    /// still has positive gain and stays feasible.
    fn commit_move(&mut self, u: u32, q: u32) -> bool {
        let from = self.assign[u as usize];
        if q == from {
            return false;
        }
        let mut base = 0.0f64;
        let mut w_total = 0.0f64;
        let mut mult_total = 0u64;
        let mut cover_w_q = 0.0f64;
        let mut cover_mult_q = 0u64;
        for &e in self.g.inbound(u) {
            let w = self.g.weight(e) as f64;
            let mult = self.axon_mult[e as usize] as u64;
            w_total += w;
            mult_total += mult;
            let mut from_others = false;
            let mut covers_q = false;
            for &d in self.g.dsts(e) {
                if d == u {
                    continue;
                }
                let p = self.assign[d as usize];
                if p == from {
                    from_others = true;
                } else if p == q {
                    covers_q = true;
                }
            }
            if !from_others {
                base += w;
            }
            if covers_q {
                cover_w_q += w;
                cover_mult_q += mult;
            }
        }
        let gain = base - (w_total - cover_w_q);
        if gain <= 1e-12 {
            return false;
        }
        let qi = q as usize;
        if self.part_nodes[qi] + self.agg.node_count[u as usize] as u64 > self.hw.c_npc as u64
            || self.part_syn[qi] + self.agg.syn_count[u as usize] > self.hw.c_spc as u64
            || self.part_axons[qi] + (mult_total - cover_mult_q) > self.hw.c_apc as u64
        {
            return false;
        }
        self.apply_move(u, from, q);
        true
    }

    /// One two-phase refinement pass over the current work-list; returns
    /// the number of applied moves (0 = work-list empty or no gains).
    fn pass(&mut self, rng: &mut Pcg64, threads: usize) -> usize {
        if self.worklist.is_empty() {
            return 0;
        }
        let mut order = std::mem::take(&mut self.worklist);
        for &u in &order {
            self.in_list[u as usize] = false;
        }
        rng.shuffle(&mut order);

        // ---- propose (parallel chunks, read-only, pass-start state) ----
        let threads = if order.len() >= PAR_MIN_NODES { threads.max(1) } else { 1 };
        let chunk = crate::util::par::fixed_chunk(order.len(), threads);
        let mut proposals: Vec<u32> = vec![u32::MAX; order.len()];
        {
            let this = &*self;
            let order = &order;
            crate::util::par::par_chunks_mut(&mut proposals, chunk, threads, |ci, slice| {
                let base = ci * chunk;
                let mut scr = ProposeScratch::new(this.part_nodes.len());
                for (k, slot) in slice.iter_mut().enumerate() {
                    *slot = this.propose(order[base + k], &mut scr);
                }
            });
        }

        // ---- commit (serial, in visit order) ----
        let mut moves = 0usize;
        for (i, &u) in order.iter().enumerate() {
            let q = proposals[i];
            if q == u32::MAX {
                continue;
            }
            if self.commit_move(u, q) {
                moves += 1;
            }
        }
        moves
    }

    fn apply_move(&mut self, u: u32, from: u32, to: u32) {
        self.assign[u as usize] = to;
        self.part_nodes[from as usize] -= self.agg.node_count[u as usize] as u64;
        self.part_nodes[to as usize] += self.agg.node_count[u as usize] as u64;
        self.part_syn[from as usize] -= self.agg.syn_count[u as usize];
        self.part_syn[to as usize] += self.agg.syn_count[u as usize];
        // exact axon-set maintenance: re-scan each inbound edge's dsts,
        // re-enqueueing the co-members whose gains this move invalidated
        for &e in self.g.inbound(u) {
            let mult = self.axon_mult[e as usize] as u64;
            let mut from_covered = false;
            let mut to_covered = false;
            for &d in self.g.dsts(e) {
                if d == u {
                    continue;
                }
                let p = self.assign[d as usize];
                from_covered |= p == from;
                to_covered |= p == to;
                if !self.in_list[d as usize] {
                    self.in_list[d as usize] = true;
                    self.worklist.push(d);
                }
            }
            if !from_covered {
                self.part_axons[from as usize] -= mult;
            }
            if !to_covered {
                self.part_axons[to as usize] += mult;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::mapping::{connectivity, validate};

    fn clusters(k: usize, size: usize, rng: &mut Pcg64) -> Hypergraph {
        // k dense clusters with sparse inter-cluster links
        let n = k * size;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let c = s as usize / size;
            let mut dsts: Vec<u32> = (0..4)
                .map(|_| (c * size + rng.below(size)) as u32)
                .filter(|&d| d != s)
                .collect();
            if rng.bernoulli(0.1) {
                dsts.push(rng.below(n) as u32);
            }
            dsts.retain(|&d| d != s);
            if !dsts.is_empty() {
                b.add_edge(s, dsts, rng.next_f32() + 0.01);
            }
        }
        b.build()
    }

    #[test]
    fn recovers_cluster_structure() {
        let mut rng = Pcg64::seeded(3);
        let g = clusters(4, 32, &mut rng);
        let mut hw = NmhConfig::small();
        hw.c_npc = 32;
        let rho = partition(&g, &hw, HierParams::default()).unwrap();
        validate(&g, &rho, &hw).unwrap();
        // close to the 4-cluster optimum (some slack for the heuristic)
        assert!(rho.num_parts >= 4 && rho.num_parts <= 8, "parts={}", rho.num_parts);
        // clusters should be mostly pure: connectivity near the intra-only
        // bound (each edge pays >= its weight once)
        let base: f64 = g.edge_ids().map(|e| g.weight(e) as f64).sum();
        let conn = connectivity(&g, &rho);
        assert!(conn < base * 1.6, "conn={conn} base={base}");
    }

    #[test]
    fn beats_or_matches_unordered_sequential() {
        let mut rng = Pcg64::seeded(9);
        let g = clusters(6, 25, &mut rng);
        let mut hw = NmhConfig::small();
        hw.c_npc = 30;
        let hier = partition(&g, &hw, HierParams::default()).unwrap();
        let seq = crate::mapping::sequential::partition(
            &g,
            &hw,
            crate::mapping::sequential::SeqOrder::Natural,
        )
        .unwrap();
        assert!(connectivity(&g, &hier) <= connectivity(&g, &seq) * 1.02);
        validate(&g, &hier, &hw).unwrap();
    }

    #[test]
    fn respects_apc_through_multiplicity() {
        // many distinct axons converging on one listener group: the
        // multiplicity bookkeeping must stop merges at C_apc
        let mut b = HypergraphBuilder::new(40);
        for s in 0..20u32 {
            b.add_edge(s, vec![20 + (s % 20)], 1.0);
        }
        // the 20 listeners also listen to a common hub
        b.add_edge(20, (21..40).collect(), 1.0);
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_apc = 4;
        let rho = partition(&g, &hw, HierParams::default()).unwrap();
        validate(&g, &rho, &hw).unwrap();
    }

    #[test]
    fn coarsest_partition_count_near_minimum() {
        let mut rng = Pcg64::seeded(17);
        let g = clusters(2, 64, &mut rng);
        let mut hw = NmhConfig::small();
        hw.c_npc = 64;
        let rho = partition(&g, &hw, HierParams::default()).unwrap();
        // ⌈128/64⌉ = 2 partitions is the floor
        assert!(rho.num_parts >= 2 && rho.num_parts <= 4, "parts={}", rho.num_parts);
    }

    #[test]
    fn empty_graph() {
        let g = HypergraphBuilder::new(0).build();
        let hw = NmhConfig::small();
        let rho = partition(&g, &hw, HierParams::default()).unwrap();
        assert_eq!(rho.num_parts, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Pcg64::seeded(21);
        let g = clusters(3, 20, &mut rng);
        let mut hw = NmhConfig::small();
        hw.c_npc = 25;
        let a = partition(&g, &hw, HierParams::default()).unwrap();
        let b = partition(&g, &hw, HierParams::default()).unwrap();
        assert_eq!(a.assign, b.assign);
    }

    #[test]
    fn coarsen_round_parallel_matches_serial() {
        // a graph large enough that the parallel dispatch threshold is
        // genuinely exercised (PAR_MIN_NODES), at several worker counts
        let mut rng = Pcg64::seeded(33);
        let g = clusters(8, 80, &mut rng);
        let n = g.num_nodes();
        assert!(n >= PAR_MIN_NODES);
        let agg = Aggregates {
            node_count: vec![1; n],
            syn_count: (0..n as u32).map(|v| g.inbound(v).len() as u64).collect(),
        };
        let axon_mult = vec![1u32; g.num_edges()];
        let mut hw = NmhConfig::small();
        hw.c_npc = 90;
        let mut rng_s = Pcg64::new(7, 23);
        let serial = coarsen_round_serial(&g, &axon_mult, &agg, &hw, &mut rng_s);
        for threads in [2, 3, 8] {
            let mut rng_p = Pcg64::new(7, 23);
            let mut props = Vec::new();
            let par =
                coarsen_round_parallel(&g, &axon_mult, &agg, &hw, &mut rng_p, threads, &mut props);
            assert_eq!(par.assign, serial.assign, "threads={threads}");
            assert_eq!(par.num_coarse, serial.num_coarse);
            assert_eq!(par.pairs, serial.pairs);
            // the rng must advance identically (round-to-round coupling)
            assert_eq!(rng_p.next_u64(), rng_s.clone().next_u64());
        }
    }

    #[test]
    fn parallel_partition_equals_serial_exactly() {
        // the end-to-end acceptance contract: threads(n) bit-for-bit
        // identical to the serial path, over multiple seeds
        let mut rng = Pcg64::seeded(5);
        let g = clusters(8, 80, &mut rng);
        let mut hw = NmhConfig::small();
        hw.c_npc = 96;
        for seed in [0xC0FFEE, 7, 99] {
            let mut hp = HierParams { seed, ..HierParams::default() };
            hp.threads = 1;
            let serial = partition(&g, &hw, hp.clone()).unwrap();
            for threads in [2, 4, 7] {
                hp.threads = threads;
                let par = partition(&g, &hw, hp.clone()).unwrap();
                assert_eq!(serial.assign, par.assign, "seed={seed} threads={threads}");
                assert_eq!(serial.num_parts, par.num_parts);
            }
        }
    }

    #[test]
    fn stats_report_levels_and_peak_memory() {
        let mut rng = Pcg64::seeded(11);
        let g = clusters(4, 40, &mut rng);
        let mut hw = NmhConfig::small();
        hw.c_npc = 40;
        let (rho, stats) = partition_with_stats(&g, &hw, HierParams::default()).unwrap();
        validate(&g, &rho, &hw).unwrap();
        assert!(stats.levels >= 2, "levels={}", stats.levels);
        assert!(stats.coarsen_secs >= 0.0 && stats.refine_secs >= 0.0);
        // level 0 borrows the input, so the owned high-water mark is the
        // coarse levels only — strictly less than "hierarchy + a clone of
        // the input", the old engine's floor (levels shrink geometrically
        // in n, sub-geometrically in edges, so allow generous slack)
        assert!(stats.peak_hierarchy_bytes > 0);
        assert!(
            stats.peak_hierarchy_bytes < g.memory_bytes() * (stats.levels - 1).max(1),
            "peak {} vs input {} over {} owned levels",
            stats.peak_hierarchy_bytes,
            g.memory_bytes(),
            stats.levels - 1
        );
    }
}

/// [`crate::stage::Partitioner`] over the multilevel algorithm (registry
/// name "hierarchical"). The coarsening/refinement seed follows the
/// pipeline seed from [`crate::stage::StageCtx`] unless pinned by the
/// `seed` parameter; the worker budget follows `StageCtx::threads`
/// (performance-only — results are thread-count invariant).
#[derive(Clone, Debug, Default)]
pub struct HierarchicalPartitioner {
    pub params: HierParams,
    /// When set, overrides `StageCtx::seed` (reproduce one stage while
    /// varying the rest of the pipeline).
    pub seed_override: Option<u64>,
}

impl HierarchicalPartitioner {
    pub fn new() -> Self {
        HierarchicalPartitioner { params: HierParams::default(), seed_override: None }
    }

    /// Construct from spec parameters: `seed`, `refine_passes`,
    /// `min_pair_fraction`.
    pub fn from_params(p: &crate::stage::StageParams) -> Result<Self, String> {
        p.check_known(&["seed", "refine_passes", "min_pair_fraction"])?;
        let mut s = HierarchicalPartitioner::new();
        s.seed_override = p.get_u64("seed")?;
        if let Some(v) = p.get_usize("refine_passes")? {
            s.params.refine_passes = v;
        }
        if let Some(v) = p.get_f64("min_pair_fraction")? {
            s.params.min_pair_fraction = v;
        }
        Ok(s)
    }
}

impl crate::stage::Partitioner for HierarchicalPartitioner {
    fn name(&self) -> &str {
        "hierarchical"
    }

    fn partition(
        &self,
        g: &Hypergraph,
        hw: &NmhConfig,
        ctx: &crate::stage::StageCtx,
    ) -> Result<Partitioning, MapError> {
        let mut hp = self.params.clone();
        hp.seed = self.seed_override.unwrap_or(ctx.seed);
        hp.threads = ctx.threads.max(1);
        // Checkpointing is run-environment, so it rides on StageCtx (not
        // the spec); the pipeline's policy wins over any params-level one.
        if ctx.checkpoint.is_some() {
            hp.checkpoint = ctx.checkpoint.clone();
        }
        partition(g, hw, hp)
    }
}
