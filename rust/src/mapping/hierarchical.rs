//! Hierarchical (multilevel) hypergraph partitioning (paper §IV-A1),
//! hMETIS/KaHyPar-style, reworked to *minimize* the number of partitions
//! under NMH constraints instead of producing a fixed balanced k.
//!
//! Pipeline:
//! 1. **Coarsening rounds** — nodes visited in random order; each is
//!    paired with the unmatched co-member of maximum second-order affinity
//!    (total weight of shared h-edges) whose merge stays feasible. Pairs
//!    contract; h-edges are remapped, destination sets dedup'd, and
//!    identical (source, D) edges merged with weight summed while a
//!    multiplicity counter preserves the *original axon count* each coarse
//!    edge represents (C_apc accounting). Stops when no pair forms or the
//!    graph reaches ⌈n/C_npc⌉ nodes.
//! 2. **Initial partitioning** — each coarsest node is a partition.
//! 3. **Uncoarsening + FM-style refinement** — the assignment is projected
//!    level by level; at each level nodes are greedily moved to
//!    neighboring partitions when the Eq. 7 connectivity gain is positive
//!    and constraints stay satisfied.

use super::MapError;
use crate::hw::NmhConfig;
use crate::hypergraph::quotient::{push_forward, Partitioning};
use crate::hypergraph::Hypergraph;
use crate::util::rng::Pcg64;

/// Tunables (defaults follow the paper's description).
#[derive(Clone, Copy, Debug)]
pub struct HierParams {
    pub seed: u64,
    /// Max refinement passes per uncoarsening level.
    pub refine_passes: usize,
    /// Stop coarsening when a round pairs fewer than this fraction.
    pub min_pair_fraction: f64,
}

impl Default for HierParams {
    fn default() -> Self {
        HierParams {
            seed: 0xC0FFEE,
            refine_passes: 2,
            min_pair_fraction: 0.02,
        }
    }
}

/// Per-coarse-node aggregates that NMH constraints are defined on.
#[derive(Clone, Debug)]
struct Aggregates {
    /// original nodes folded into each coarse node
    node_count: Vec<u32>,
    /// original inbound synapses folded into each coarse node
    syn_count: Vec<u64>,
}

/// One level of the hierarchy.
struct Level {
    graph: Hypergraph,
    /// original-axon multiplicity of each h-edge at this level
    axon_mult: Vec<u32>,
    agg: Aggregates,
    /// fine-node -> coarse-node map to the NEXT level (absent at the top)
    to_coarse: Option<Vec<u32>>,
}

/// Hierarchical partitioning entry point.
pub fn partition(g: &Hypergraph, hw: &NmhConfig, params: HierParams) -> Result<Partitioning, MapError> {
    let n = g.num_nodes();
    if n == 0 {
        return Ok(Partitioning::new(vec![], 0));
    }
    // Per-node feasibility (a neuron that can't fit an empty core).
    {
        let t = super::ConstraintTracker::new(g, hw);
        for node in 0..n as u32 {
            t.node_feasible(node)?;
        }
    }
    let target = crate::util::div_ceil(n, hw.c_npc).max(1);
    let mut rng = Pcg64::new(params.seed, 23);

    // ---- build hierarchy ----
    let mut levels: Vec<Level> = vec![Level {
        graph: g.clone(),
        axon_mult: vec![1; g.num_edges()],
        agg: Aggregates {
            node_count: vec![1; n],
            syn_count: (0..n as u32).map(|v| g.inbound(v).len() as u64).collect(),
        },
        to_coarse: None,
    }];

    let debug_timing = std::env::var("SNNMAP_TIMING").is_ok();
    loop {
        let top = levels.last().unwrap();
        let cur_n = top.graph.num_nodes();
        if cur_n <= target {
            break;
        }
        let t0 = std::time::Instant::now();
        let matching = coarsen_round(&top.graph, &top.axon_mult, &top.agg, hw, &mut rng);
        if debug_timing {
            eprintln!("[hier] coarsen n={cur_n} pairs={} in {:?}", matching.pairs, t0.elapsed());
        }
        let paired = matching.pairs;
        if (paired as f64) < params.min_pair_fraction * cur_n as f64 {
            break;
        }
        let rho = Partitioning::new(matching.assign, matching.num_coarse);
        let t0 = std::time::Instant::now();
        let q = push_forward(&top.graph, &rho);
        if debug_timing {
            eprintln!("[hier] push_forward -> n={} e={} in {:?}", q.graph.num_nodes(), q.graph.num_edges(), t0.elapsed());
        }
        // aggregate multiplicities + node stats into the coarser level
        let mut axon_mult = vec![0u32; q.graph.num_edges()];
        for (ce, orig) in q.merged_from.iter().enumerate() {
            axon_mult[ce] = orig.iter().map(|&e| top.axon_mult[e as usize]).sum();
        }
        let mut node_count = vec![0u32; rho.num_parts];
        let mut syn_count = vec![0u64; rho.num_parts];
        for fine in 0..cur_n {
            let c = rho.assign[fine] as usize;
            node_count[c] += top.agg.node_count[fine];
            syn_count[c] += top.agg.syn_count[fine];
        }
        let to_coarse = Some(rho.assign);
        levels.last_mut().unwrap().to_coarse = to_coarse;
        levels.push(Level {
            graph: q.graph,
            axon_mult,
            agg: Aggregates { node_count, syn_count },
            to_coarse: None,
        });
    }

    // ---- initial partitioning: coarsest node == partition ----
    let coarsest_n = levels.last().unwrap().graph.num_nodes();
    if coarsest_n > hw.num_cores() {
        return Err(MapError::TooManyPartitions {
            got: coarsest_n,
            limit: hw.num_cores(),
        });
    }
    let mut assign: Vec<u32> = (0..coarsest_n as u32).collect();
    let mut num_parts = coarsest_n;

    // ---- uncoarsen + refine ----
    for li in (0..levels.len()).rev() {
        let level = &levels[li];
        // refine at this level
        let t0 = std::time::Instant::now();
        let mut refiner = Refiner::new(&level.graph, &level.axon_mult, &level.agg, hw, num_parts, &assign);
        for _ in 0..params.refine_passes {
            if refiner.pass(&mut rng) == 0 {
                break;
            }
        }
        if debug_timing {
            eprintln!("[hier] refine level {li} (n={}) in {:?}", level.graph.num_nodes(), t0.elapsed());
        }
        assign = refiner.assign;
        // project to the finer level (li-1), whose to_coarse points here
        if li > 0 {
            let finer = &levels[li - 1];
            let map = finer.to_coarse.as_ref().expect("hierarchy link missing");
            let mut fine_assign = vec![0u32; finer.graph.num_nodes()];
            for (f, &c) in map.iter().enumerate() {
                fine_assign[f] = assign[c as usize];
            }
            assign = fine_assign;
        }
        num_parts = num_parts.max(assign.iter().map(|&p| p as usize + 1).max().unwrap_or(0));
    }

    Ok(Partitioning::new(assign, num_parts).compacted())
}

/// Result of one coarsening round.
struct Matching {
    assign: Vec<u32>,
    num_coarse: usize,
    pairs: usize,
}

/// One pair-coarsening round: random visit order, exact pairwise
/// second-order-affinity scoring over co-members, feasibility-checked.
fn coarsen_round(
    g: &Hypergraph,
    axon_mult: &[u32],
    agg: &Aggregates,
    hw: &NmhConfig,
    rng: &mut Pcg64,
) -> Matching {
    let n = g.num_nodes();
    let mut visit: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut visit);
    let mut mate = vec![u32::MAX; n];

    // Scratch: epoch-stamped dense accumulators (a HashMap here dominated
    // the whole partitioner's runtime — §Perf: 2.5x on the Allen-V1 row).
    let mut score = vec![0.0f64; n];
    let mut stamp = vec![0u32; n];
    let mut touched: Vec<u32> = Vec::new();
    let mut epoch = 0u32;
    // edge-membership scratch for merge_feasible's axon-union count
    let mut edge_stamp = vec![0u32; g.num_edges()];
    let mut edge_epoch = 0u32;

    for &u in &visit {
        if mate[u as usize] != u32::MAX {
            continue;
        }
        epoch += 1;
        touched.clear();
        {
            let mut bump = |v: u32, w: f64| {
                if v == u || mate[v as usize] != u32::MAX {
                    return;
                }
                let vi = v as usize;
                if stamp[vi] != epoch {
                    stamp[vi] = epoch;
                    score[vi] = 0.0;
                    touched.push(v);
                }
                score[vi] += w;
            };
            // co-members through u's inbound h-edges (siblings + source)…
            for &e in g.inbound(u) {
                let w = g.weight(e) as f64;
                bump(g.source(e), w);
                for &d in g.dsts(e) {
                    bump(d, w);
                }
            }
            // …and through its outbound h-edges (its own listeners)
            for &e in g.outbound(u) {
                let w = g.weight(e) as f64;
                for &d in g.dsts(e) {
                    bump(d, w);
                }
            }
        }
        if touched.is_empty() {
            continue;
        }
        // best-scoring feasible partner: try the top candidates only
        // (partial selection — hub nodes can touch thousands of nodes)
        let cmp = |a: &u32, b: &u32| {
            score[*b as usize]
                .partial_cmp(&score[*a as usize])
                .unwrap()
                .then(a.cmp(b))
        };
        if touched.len() > 8 {
            touched.select_nth_unstable_by(7, cmp);
            touched.truncate(8);
        }
        touched.sort_by(cmp);
        for &v in touched.iter().take(8) {
            if merge_feasible(g, axon_mult, agg, hw, u, v, &mut edge_stamp, &mut edge_epoch) {
                mate[u as usize] = v;
                mate[v as usize] = u;
                break;
            }
        }
    }

    // enumerate coarse ids
    let mut assign = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut pairs = 0usize;
    for u in 0..n as u32 {
        if assign[u as usize] != u32::MAX {
            continue;
        }
        assign[u as usize] = next;
        let v = mate[u as usize];
        if v != u32::MAX && assign[v as usize] == u32::MAX {
            assign[v as usize] = next;
            pairs += 1;
        }
        next += 1;
    }
    Matching {
        assign,
        num_coarse: next as usize,
        pairs,
    }
}

/// Would merging coarse nodes u and v stay within per-core limits?
/// `edge_stamp`/`edge_epoch` is reusable O(1)-reset scratch for the exact
/// axon-union count (a per-candidate HashSet dominated coarsening time).
#[allow(clippy::too_many_arguments)]
fn merge_feasible(
    g: &Hypergraph,
    axon_mult: &[u32],
    agg: &Aggregates,
    hw: &NmhConfig,
    u: u32,
    v: u32,
    edge_stamp: &mut [u32],
    edge_epoch: &mut u32,
) -> bool {
    if agg.node_count[u as usize] + agg.node_count[v as usize] > hw.c_npc as u32 {
        return false;
    }
    if agg.syn_count[u as usize] + agg.syn_count[v as usize] > hw.c_spc as u64 {
        return false;
    }
    // distinct original axons of the union: Σ mult over union of inbound
    // coarse-edge sets (exact, computed only for the candidate actually
    // tried — the "original, exact edge-coarsening" the paper keeps).
    *edge_epoch += 1;
    let ep = *edge_epoch;
    let mut axons: u64 = 0;
    for &e in g.inbound(u) {
        edge_stamp[e as usize] = ep;
        axons += axon_mult[e as usize] as u64;
    }
    for &e in g.inbound(v) {
        if edge_stamp[e as usize] != ep {
            axons += axon_mult[e as usize] as u64;
        }
    }
    axons <= hw.c_apc as u64
}

/// FM-style greedy move refiner at one hierarchy level.
///
/// Gains for *all* candidate partitions of a node are computed in one
/// sweep of its inbound h-edges using the cover decomposition
///
///   gain(u: p→q) = base − (W_u − cover_w(q)),
///   base        = Σ_{e∋u} w(e)·[u is e's only destination in p],
///   W_u         = Σ_{e∋u} w(e),
///   cover_w(q)  = Σ_{e∋u} w(e)·[e already reaches q],
///
/// with epoch-stamped dense accumulators — no (edge, partition) hash map
/// (which previously dominated hierarchical partitioning; §Perf: 47 s →
/// ~8 s on the Allen-V1 row).
struct Refiner<'a> {
    g: &'a Hypergraph,
    axon_mult: &'a [u32],
    agg: &'a Aggregates,
    hw: &'a NmhConfig,
    assign: Vec<u32>,
    part_nodes: Vec<u64>,
    part_syn: Vec<u64>,
    part_axons: Vec<u64>,
    // per-pass scratch, stamped by candidate-collection epoch
    cover_w: Vec<f64>,
    cover_mult: Vec<u64>,
    cand_stamp: Vec<u32>,
    epoch: u32,
    // per-edge partition dedup stamp (one bump per scanned edge)
    pstamp: Vec<u32>,
    pepoch: u32,
}

impl<'a> Refiner<'a> {
    fn new(
        g: &'a Hypergraph,
        axon_mult: &'a [u32],
        agg: &'a Aggregates,
        hw: &'a NmhConfig,
        num_parts: usize,
        assign: &[u32],
    ) -> Self {
        let mut r = Refiner {
            g,
            axon_mult,
            agg,
            hw,
            assign: assign.to_vec(),
            part_nodes: vec![0; num_parts],
            part_syn: vec![0; num_parts],
            part_axons: vec![0; num_parts],
            cover_w: vec![0.0; num_parts],
            cover_mult: vec![0; num_parts],
            cand_stamp: vec![0; num_parts],
            epoch: 0,
            pstamp: vec![0; num_parts],
            pepoch: 0,
        };
        for v in 0..g.num_nodes() {
            let p = r.assign[v] as usize;
            r.part_nodes[p] += agg.node_count[v] as u64;
            r.part_syn[p] += agg.syn_count[v];
        }
        // part_axons: Σ mult over distinct (edge, partition) incidences
        let mut stamp = vec![u32::MAX; num_parts];
        for e in g.edge_ids() {
            for &d in g.dsts(e) {
                let p = r.assign[d as usize];
                if stamp[p as usize] != e {
                    stamp[p as usize] = e;
                    r.part_axons[p as usize] += axon_mult[e as usize] as u64;
                }
            }
        }
        r
    }

    /// One refinement pass over all nodes in random order; returns the
    /// number of applied moves.
    fn pass(&mut self, rng: &mut Pcg64) -> usize {
        let n = self.g.num_nodes();
        let mut visit: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut visit);
        let mut moves = 0usize;
        let mut cands: Vec<u32> = Vec::new();
        for &u in &visit {
            let from = self.assign[u as usize];
            self.epoch += 1;
            cands.clear();

            // single sweep: base gain + per-candidate cover accumulation
            let mut base = 0.0f64;
            let mut w_total = 0.0f64;
            let mut mult_total = 0u64;
            for &e in self.g.inbound(u) {
                let w = self.g.weight(e) as f64;
                let mult = self.axon_mult[e as usize] as u64;
                w_total += w;
                mult_total += mult;
                self.pepoch += 1;
                let mut from_others = false;
                for &d in self.g.dsts(e) {
                    if d == u {
                        continue;
                    }
                    let p = self.assign[d as usize];
                    if p == from {
                        from_others = true;
                        continue;
                    }
                    let pi = p as usize;
                    if self.pstamp[pi] == self.pepoch {
                        continue; // this edge already covers p
                    }
                    self.pstamp[pi] = self.pepoch;
                    if self.cand_stamp[pi] != self.epoch {
                        self.cand_stamp[pi] = self.epoch;
                        self.cover_w[pi] = 0.0;
                        self.cover_mult[pi] = 0;
                        cands.push(p);
                    }
                    self.cover_w[pi] += w;
                    self.cover_mult[pi] += mult;
                }
                if !from_others {
                    base += w; // u is `from`'s only listener of e
                }
            }

            // pick the best feasible positive-gain candidate
            let mut best: Option<(f64, u32)> = None;
            for &q in &cands {
                let qi = q as usize;
                let gain = base - (w_total - self.cover_w[qi]);
                if gain <= 1e-12 {
                    continue;
                }
                if best.map(|(g, _)| gain <= g).unwrap_or(false) {
                    continue;
                }
                // feasibility: nodes, synapses, axons
                if self.part_nodes[qi] + self.agg.node_count[u as usize] as u64
                    > self.hw.c_npc as u64
                    || self.part_syn[qi] + self.agg.syn_count[u as usize] > self.hw.c_spc as u64
                    || self.part_axons[qi] + (mult_total - self.cover_mult[qi])
                        > self.hw.c_apc as u64
                {
                    continue;
                }
                best = Some((gain, q));
            }
            if let Some((_, q)) = best {
                self.apply_move(u, from, q);
                moves += 1;
            }
        }
        moves
    }

    fn apply_move(&mut self, u: u32, from: u32, to: u32) {
        self.assign[u as usize] = to;
        self.part_nodes[from as usize] -= self.agg.node_count[u as usize] as u64;
        self.part_nodes[to as usize] += self.agg.node_count[u as usize] as u64;
        self.part_syn[from as usize] -= self.agg.syn_count[u as usize];
        self.part_syn[to as usize] += self.agg.syn_count[u as usize];
        // exact axon-set maintenance: re-scan each inbound edge's dsts
        for &e in self.g.inbound(u) {
            let mult = self.axon_mult[e as usize] as u64;
            let mut from_covered = false;
            let mut to_covered = false;
            for &d in self.g.dsts(e) {
                if d == u {
                    continue;
                }
                let p = self.assign[d as usize];
                from_covered |= p == from;
                to_covered |= p == to;
            }
            if !from_covered {
                self.part_axons[from as usize] -= mult;
            }
            if !to_covered {
                self.part_axons[to as usize] += mult;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::mapping::{connectivity, validate};

    fn clusters(k: usize, size: usize, rng: &mut Pcg64) -> Hypergraph {
        // k dense clusters with sparse inter-cluster links
        let n = k * size;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let c = s as usize / size;
            let mut dsts: Vec<u32> = (0..4)
                .map(|_| (c * size + rng.below(size)) as u32)
                .filter(|&d| d != s)
                .collect();
            if rng.bernoulli(0.1) {
                dsts.push(rng.below(n) as u32);
            }
            dsts.retain(|&d| d != s);
            if !dsts.is_empty() {
                b.add_edge(s, dsts, rng.next_f32() + 0.01);
            }
        }
        b.build()
    }

    #[test]
    fn recovers_cluster_structure() {
        let mut rng = Pcg64::seeded(3);
        let g = clusters(4, 32, &mut rng);
        let mut hw = NmhConfig::small();
        hw.c_npc = 32;
        let rho = partition(&g, &hw, HierParams::default()).unwrap();
        validate(&g, &rho, &hw).unwrap();
        // close to the 4-cluster optimum (some slack for the heuristic)
        assert!(rho.num_parts >= 4 && rho.num_parts <= 8, "parts={}", rho.num_parts);
        // clusters should be mostly pure: connectivity near the intra-only
        // bound (each edge pays >= its weight once)
        let base: f64 = g.edge_ids().map(|e| g.weight(e) as f64).sum();
        let conn = connectivity(&g, &rho);
        assert!(conn < base * 1.6, "conn={conn} base={base}");
    }

    #[test]
    fn beats_or_matches_unordered_sequential() {
        let mut rng = Pcg64::seeded(9);
        let g = clusters(6, 25, &mut rng);
        let mut hw = NmhConfig::small();
        hw.c_npc = 30;
        let hier = partition(&g, &hw, HierParams::default()).unwrap();
        let seq = crate::mapping::sequential::partition(
            &g,
            &hw,
            crate::mapping::sequential::SeqOrder::Natural,
        )
        .unwrap();
        assert!(connectivity(&g, &hier) <= connectivity(&g, &seq) * 1.02);
        validate(&g, &hier, &hw).unwrap();
    }

    #[test]
    fn respects_apc_through_multiplicity() {
        // many distinct axons converging on one listener group: the
        // multiplicity bookkeeping must stop merges at C_apc
        let mut b = HypergraphBuilder::new(40);
        for s in 0..20u32 {
            b.add_edge(s, vec![20 + (s % 20)], 1.0);
        }
        // the 20 listeners also listen to a common hub
        b.add_edge(20, (21..40).collect(), 1.0);
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_apc = 4;
        let rho = partition(&g, &hw, HierParams::default()).unwrap();
        validate(&g, &rho, &hw).unwrap();
    }

    #[test]
    fn coarsest_partition_count_near_minimum() {
        let mut rng = Pcg64::seeded(17);
        let g = clusters(2, 64, &mut rng);
        let mut hw = NmhConfig::small();
        hw.c_npc = 64;
        let rho = partition(&g, &hw, HierParams::default()).unwrap();
        // ⌈128/64⌉ = 2 partitions is the floor
        assert!(rho.num_parts >= 2 && rho.num_parts <= 4, "parts={}", rho.num_parts);
    }

    #[test]
    fn empty_graph() {
        let g = HypergraphBuilder::new(0).build();
        let hw = NmhConfig::small();
        let rho = partition(&g, &hw, HierParams::default()).unwrap();
        assert_eq!(rho.num_parts, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Pcg64::seeded(21);
        let g = clusters(3, 20, &mut rng);
        let mut hw = NmhConfig::small();
        hw.c_npc = 25;
        let a = partition(&g, &hw, HierParams::default()).unwrap();
        let b = partition(&g, &hw, HierParams::default()).unwrap();
        assert_eq!(a.assign, b.assign);
    }
}

/// [`crate::stage::Partitioner`] over the multilevel algorithm (registry
/// name "hierarchical"). The coarsening/refinement seed follows the
/// pipeline seed from [`crate::stage::StageCtx`] unless pinned by the
/// `seed` parameter.
#[derive(Clone, Copy, Debug, Default)]
pub struct HierarchicalPartitioner {
    pub params: HierParams,
    /// When set, overrides `StageCtx::seed` (reproduce one stage while
    /// varying the rest of the pipeline).
    pub seed_override: Option<u64>,
}

impl HierarchicalPartitioner {
    pub fn new() -> Self {
        HierarchicalPartitioner { params: HierParams::default(), seed_override: None }
    }

    /// Construct from spec parameters: `seed`, `refine_passes`,
    /// `min_pair_fraction`.
    pub fn from_params(p: &crate::stage::StageParams) -> Result<Self, String> {
        p.check_known(&["seed", "refine_passes", "min_pair_fraction"])?;
        let mut s = HierarchicalPartitioner::new();
        s.seed_override = p.get_u64("seed")?;
        if let Some(v) = p.get_usize("refine_passes")? {
            s.params.refine_passes = v;
        }
        if let Some(v) = p.get_f64("min_pair_fraction")? {
            s.params.min_pair_fraction = v;
        }
        Ok(s)
    }
}

impl crate::stage::Partitioner for HierarchicalPartitioner {
    fn name(&self) -> &str {
        "hierarchical"
    }

    fn partition(
        &self,
        g: &Hypergraph,
        hw: &NmhConfig,
        ctx: &crate::stage::StageCtx,
    ) -> Result<Partitioning, MapError> {
        let mut hp = self.params;
        hp.seed = self.seed_override.unwrap_or(ctx.seed);
        partition(g, hw, hp)
    }
}
