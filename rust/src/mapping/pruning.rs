//! Synapse pruning preprocessing (after Xiao et al. [16], who combine
//! hierarchical mapping with pruning).
//!
//! Drops the weakest connections before partitioning: either every h-edge
//! whose spike frequency falls below an absolute threshold, or the
//! weakest fraction of total spike mass. Pruning trades model fidelity
//! for mapping cost — fewer synapses per core (C_spc headroom), fewer
//! distinct axons (C_apc headroom), fewer partitions, shorter wires. The
//! ablation bench sweeps the threshold to expose the tradeoff curve.

use crate::hypergraph::{Hypergraph, HypergraphBuilder};

/// Pruning report: what was removed.
#[derive(Debug, Clone, PartialEq)]
pub struct PruneReport {
    pub edges_before: usize,
    pub edges_after: usize,
    pub connections_before: usize,
    pub connections_after: usize,
    /// Fraction of total spike-frequency mass removed.
    pub mass_removed: f64,
}

/// Rebuild `g` keeping exactly the edges `keep` admits, reporting the
/// removed spike mass — the shared tail of both pruning entry points.
fn rebuild_keeping(
    g: &Hypergraph,
    keep: impl Fn(crate::hypergraph::EdgeId) -> bool,
) -> (Hypergraph, PruneReport) {
    let total_mass: f64 = g.edge_ids().map(|e| g.weight(e) as f64).sum();
    let mut b = HypergraphBuilder::new(g.num_nodes());
    let mut kept_mass = 0.0f64;
    for e in g.edge_ids() {
        if keep(e) {
            kept_mass += g.weight(e) as f64;
            b.add_edge_sorted(g.source(e), g.dsts(e), g.weight(e));
        }
    }
    let pruned = b.build();
    let report = PruneReport {
        edges_before: g.num_edges(),
        edges_after: pruned.num_edges(),
        connections_before: g.num_connections(),
        connections_after: pruned.num_connections(),
        mass_removed: if total_mass > 0.0 { 1.0 - kept_mass / total_mass } else { 0.0 },
    };
    (pruned, report)
}

/// Remove h-edges with spike frequency below `threshold`.
/// (An axon's spikes all share its frequency, so pruning is edge-level:
/// per-synapse pruning would break the single-source h-edge invariant.)
pub fn prune_below(g: &Hypergraph, threshold: f32) -> (Hypergraph, PruneReport) {
    rebuild_keeping(g, |e| g.weight(e) >= threshold)
}

/// Remove the weakest h-edges totalling at most `fraction` of the spike
/// mass (0.0 = no-op, approaching 1.0 = drop almost everything).
///
/// Edges are pruned weakest-first with ties resolved by edge id, so
/// tied-weight edges are dropped only up to the remaining budget
/// (deterministically) — a threshold-based cut would prune the whole tie
/// class and overshoot the budget.
pub fn prune_fraction(g: &Hypergraph, fraction: f64) -> (Hypergraph, PruneReport) {
    assert!((0.0..1.0).contains(&fraction), "fraction must be in [0, 1)");
    if g.num_edges() == 0 || fraction == 0.0 {
        let report = PruneReport {
            edges_before: g.num_edges(),
            edges_after: g.num_edges(),
            connections_before: g.num_connections(),
            connections_after: g.num_connections(),
            mass_removed: 0.0,
        };
        return (g.clone(), report);
    }
    let mut order: Vec<u32> = g.edge_ids().collect();
    order.sort_by(|&a, &b| {
        crate::util::cmp_non_nan(&g.weight(a), &g.weight(b)).then(a.cmp(&b))
    });
    let total: f64 = order.iter().map(|&e| g.weight(e) as f64).sum();
    let budget = total * fraction;
    let mut acc = 0.0f64;
    let mut drop = vec![false; g.num_edges()];
    for &e in &order {
        let w = g.weight(e) as f64;
        if acc + w > budget {
            break; // weights ascend: no later edge fits either
        }
        acc += w;
        drop[e as usize] = true;
    }
    rebuild_keeping(g, |e| !drop[e as usize])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;

    fn weighted() -> Hypergraph {
        let mut b = HypergraphBuilder::new(6);
        b.add_edge(0, vec![1, 2], 0.1);
        b.add_edge(1, vec![2, 3], 0.5);
        b.add_edge(2, vec![3, 4], 1.0);
        b.add_edge(3, vec![4, 5], 2.0);
        b.build()
    }

    #[test]
    fn prune_below_threshold() {
        let g = weighted();
        let (p, r) = prune_below(&g, 0.6);
        assert_eq!(p.num_edges(), 2); // 1.0 and 2.0 survive
        assert_eq!(r.edges_before, 4);
        assert_eq!(r.edges_after, 2);
        assert_eq!(r.connections_after, 4);
        assert!((r.mass_removed - 0.6 / 3.6).abs() < 1e-6);
        p.validate().unwrap();
    }

    #[test]
    fn prune_zero_threshold_is_noop() {
        let g = weighted();
        let (p, r) = prune_below(&g, 0.0);
        assert_eq!(p.num_edges(), g.num_edges());
        assert_eq!(r.mass_removed, 0.0);
    }

    #[test]
    fn prune_fraction_respects_budget() {
        let g = weighted();
        // 10% of mass (0.36): only the 0.1 edge fits the budget
        let (p, r) = prune_fraction(&g, 0.1);
        assert_eq!(p.num_edges(), 3);
        assert!(r.mass_removed <= 0.1 + 1e-9, "removed {}", r.mass_removed);
        // 50% of mass (1.8): 0.1 + 0.5 + 1.0 = 1.6 fits
        let (p, r) = prune_fraction(&g, 0.5);
        assert_eq!(p.num_edges(), 1);
        assert!(r.mass_removed <= 0.5 + 1e-9);
    }

    #[test]
    fn prune_fraction_tied_weights_respect_budget() {
        // four equal-weight edges, fraction 0.3: the budget (1.2 of 4.0)
        // admits exactly one tied edge — a threshold cut would prune all
        // four (100% of the mass, the bug this test pins down)
        let mut b = HypergraphBuilder::new(5);
        for s in 0..4u32 {
            b.add_edge(s, vec![s + 1], 1.0);
        }
        let g = b.build();
        let (p, r) = prune_fraction(&g, 0.3);
        assert_eq!(p.num_edges(), 3, "tied weights overshot the budget");
        assert!(r.mass_removed <= 0.3 + 1e-9, "removed {}", r.mass_removed);
        // deterministic: the lowest-id edge of the tie class goes first
        assert!(p.edge_ids().all(|e| p.source(e) != 0), "edge 0 survived");
        p.validate().unwrap();
    }

    #[test]
    fn prune_fraction_zero_is_noop() {
        let g = weighted();
        let (p, r) = prune_fraction(&g, 0.0);
        assert_eq!(p.num_edges(), 4);
        assert_eq!(r.mass_removed, 0.0);
    }

    #[test]
    fn pruning_reduces_mapping_cost() {
        use crate::mapping::{connectivity, overlap};
        let mut rng = crate::util::rng::Pcg64::seeded(9);
        let n = 300;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let dsts: Vec<u32> = (0..8).map(|_| rng.below(n) as u32).filter(|&d| d != s).collect();
            b.add_edge(s, dsts, rng.lognormal_median_cv(0.23, 1.58) as f32);
        }
        let g = b.build();
        let (pruned, _) = prune_fraction(&g, 0.3);
        let mut hw = crate::hw::NmhConfig::small();
        hw.c_npc = 32;
        let full = overlap::partition(&g, &hw).unwrap();
        let less = overlap::partition(&pruned, &hw).unwrap();
        assert!(connectivity(&pruned, &less) < connectivity(&g, &full));
    }
}
