//! Hyperedge-overlap partitioning — the paper's novel greedy heuristic
//! (§IV-A2, Algorithm 1).
//!
//! Builds partitions one at a time by sweeping h-edges: the next h-edge is
//! the one whose nodes exhibit the highest (spike-frequency-weighted)
//! co-membership with the partition under construction — an incremental
//! proxy of second-order affinity. Within an h-edge, nodes are assigned in
//! the order that introduces the fewest new inbound axons to the partition
//! (lexicographic tie-break on largest inbound set), which directly
//! maximizes synaptic reuse while snug-fitting constraints.
//!
//! Complexity O(e·d·log d): each node's connections are visited once; the
//! priority queue is a lazy max-heap flushed per partition via an epoch
//! stamp (O(1) flush). The inner argmin^lex selection runs on a flat
//! [`Scoreboard`] — dense per-node slots plus buckets keyed on the cached
//! `new_axons` value — instead of a `BTreeSet` + `HashMap` pair, so the
//! hot loop does no hashing and no remove/reinsert churn: candidate keys
//! only *decrease* while a partition grows, so a monotone bucket floor
//! plus recompute-on-peek reproduces the exact ordered-set semantics.
//!
//! With `threads > 1` the candidate-scoreboard growth steps run
//! **two-phase** (DESIGN.md §11): scoring the frontier (an h-edge's
//! unassigned nodes, or — on partition close — every surviving
//! candidate) against the open partition is a parallel sweep over fixed
//! chunks into scratch slots, and the serial insertion that follows
//! replays the exact seeded order of the serial reference
//! ([`grow_serial`]). Stale keys remain safe for the same reason they
//! always were: [`Scoreboard::peek_best`] recomputes a candidate's key
//! at commit time. Results are bit-for-bit thread-invariant (tested).

use super::{ConstraintTracker, MapError};
use crate::hw::NmhConfig;
use crate::hypergraph::quotient::Partitioning;
use crate::hypergraph::{EdgeId, Hypergraph};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

/// Below this frontier size a growth step scores serially even when
/// `threads > 1` — scoped-thread spawn overhead would dominate the
/// per-candidate `new_axons` sweeps. Invisible in results: the paths
/// agree bit-for-bit. Public so thread-invariance tests can assert their
/// workloads actually cross it (see [`OverlapStats::par_growth_steps`]).
pub const PAR_MIN_FRONTIER: usize = 192;

/// Diagnostics from one overlap run (hotpath bench + CI trajectory),
/// mirroring `hierarchical::partition_with_stats`'s `HierStats`.
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapStats {
    /// Wall-clock spent scoring frontiers (the propose phase).
    pub score_secs: f64,
    /// Wall-clock of everything else: edge selection, argmin^lex
    /// commits, queue maintenance.
    pub commit_secs: f64,
    /// Growth steps that dispatched the parallel scoring path.
    pub par_growth_steps: u64,
    /// Frontier candidates scored across all growth steps.
    pub scored_candidates: u64,
    /// Heap high-water mark of the partitioner's scratch structures.
    pub peak_scratch_bytes: usize,
}

/// Heap entry for the h-edge priority queue, with lazy invalidation.
struct EdgeEntry {
    prio: f64,
    edge: EdgeId,
    epoch: u32,
}

impl PartialEq for EdgeEntry {
    fn eq(&self, other: &Self) -> bool {
        self.prio == other.prio && self.edge == other.edge
    }
}
impl Eq for EdgeEntry {}
impl PartialOrd for EdgeEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EdgeEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        crate::util::cmp_non_nan(&self.prio, &other.prio)
            .then_with(|| other.edge.cmp(&self.edge))
    }
}

/// In-bucket selection rank: ascending order puts the lex-best candidate
/// (largest inbound set, then smallest node id) last, so `Vec::pop` is the
/// argmin^lex within a `new_axons` bucket.
#[inline]
fn rank_of(g: &Hypergraph, n: u32, sel_min: bool) -> u64 {
    let inv_id = (u32::MAX - n) as u64;
    if sel_min {
        ((g.inbound(n).len() as u64) << 32) | inv_id
    } else {
        inv_id
    }
}

/// Flat candidate scoreboard for the inner argmin^lex selection:
/// (new inbound axons ascending, inbound-set size descending, id).
///
/// Entries live in `buckets[new_axons]`, each bucket sorted ascending by
/// [`rank_of`] (best last). Dense per-node `cached`/`stamp` slots replace
/// the old `HashMap` membership test; `cur_min` is a monotone floor over
/// nonempty buckets that is lowered only when a recomputed key moves an
/// entry down. All mutations are deterministic.
struct Scoreboard {
    /// `buckets[a]` = candidates with cached `new_axons == a`, as
    /// `(rank, node)` sorted ascending by rank.
    buckets: Vec<Vec<(u64, u32)>>,
    /// Bucket ids currently holding entries (cleared in O(touched)).
    dirty: Vec<u32>,
    /// Per-node candidate generation; 0 = not a live candidate.
    stamp: Vec<u32>,
    gen: u32,
    /// Floor: no live entry sits in a bucket below `cur_min`.
    cur_min: usize,
    /// Live candidate count.
    live: usize,
    /// Nodes inserted in the current generation (rebuild scratch).
    members: Vec<u32>,
    /// Apply the argmin-new-axons policy (ablation knob).
    sel_min: bool,
}

impl Scoreboard {
    fn new(n_nodes: usize, sel_min: bool) -> Self {
        Scoreboard {
            buckets: Vec::new(),
            dirty: Vec::new(),
            stamp: vec![0; n_nodes],
            gen: 0,
            cur_min: 0,
            live: 0,
            members: Vec::new(),
            sel_min,
        }
    }

    fn bump_gen(&mut self) {
        if self.gen == u32::MAX {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.gen = 1;
        } else {
            self.gen += 1;
        }
    }

    /// Start collecting candidates for a new h-edge.
    fn begin(&mut self) {
        for b in self.dirty.drain(..) {
            self.buckets[b as usize].clear();
        }
        self.bump_gen();
        self.cur_min = 0;
        self.live = 0;
        self.members.clear();
    }

    fn push_entry(&mut self, n: u32, axons: u32, rank: u64) {
        let b = axons as usize;
        if b >= self.buckets.len() {
            self.buckets.resize_with(b + 1, Vec::new);
        }
        let bucket = &mut self.buckets[b];
        if bucket.is_empty() {
            self.dirty.push(b as u32);
        }
        let pos = bucket.partition_point(|&(r, _)| r < rank);
        bucket.insert(pos, (rank, n));
        if b < self.cur_min {
            self.cur_min = b;
        }
    }

    /// Add candidate `n` (no-op if already a live candidate).
    fn insert(&mut self, n: u32, axons: u32, rank: u64) {
        if self.stamp[n as usize] == self.gen {
            return;
        }
        self.stamp[n as usize] = self.gen;
        self.members.push(n);
        self.push_entry(n, axons, rank);
        self.live += 1;
    }

    /// Current argmin^lex candidate, lazily refreshing stale keys via
    /// `fresh` (keys can only have decreased since insertion). The entry
    /// stays in place: callers either [`Self::remove_best`] it on
    /// assignment or [`Self::rebuild_from`] everything on partition
    /// close. This commit-time recompute is also the staleness backstop
    /// of the parallel scoring path (DESIGN.md §11).
    fn peek_best(&mut self, mut fresh: impl FnMut(u32) -> u32) -> Option<u32> {
        if self.live == 0 {
            return None;
        }
        loop {
            while self.cur_min < self.buckets.len() && self.buckets[self.cur_min].is_empty() {
                self.cur_min += 1;
            }
            // `live > 0` guarantees a non-empty bucket exists; degrade to
            // None (scan exhausted) rather than panicking if it ever
            // doesn't, so the partitioner surfaces an error, not an abort
            let &(rank, n) = match self.buckets.get(self.cur_min).and_then(|b| b.last()) {
                Some(top) => top,
                None => return None,
            };
            if self.sel_min {
                let f = fresh(n);
                if f as usize != self.cur_min {
                    self.buckets[self.cur_min].pop();
                    self.push_entry(n, f, rank);
                    continue;
                }
            }
            return Some(n);
        }
    }

    /// Remove the candidate just returned by [`Self::peek_best`].
    fn remove_best(&mut self, n: u32) {
        let popped = self.buckets[self.cur_min].pop();
        debug_assert_eq!(popped.map(|(_, m)| m), Some(n));
        self.stamp[n as usize] = 0;
        self.live -= 1;
    }

    /// Live candidates in insertion order — the frontier a partition
    /// close must re-score (all `new_axons` counts reset).
    fn live_members(&self) -> Vec<u32> {
        self.members
            .iter()
            .copied()
            .filter(|&n| self.stamp[n as usize] != 0)
            .collect()
    }

    /// Re-key the scoreboard from precomputed `(new_axons, rank)` keys,
    /// one per `survivors` entry (the [`Self::live_members`] order).
    /// Splitting collection from insertion lets the key computation run
    /// on either the serial or the parallel scoring path while this
    /// serial insertion replays the identical order.
    fn rebuild_from(&mut self, survivors: &[u32], keys: &[(u32, u64)]) {
        debug_assert_eq!(survivors.len(), keys.len());
        for b in self.dirty.drain(..) {
            self.buckets[b as usize].clear();
        }
        self.bump_gen();
        self.cur_min = 0;
        self.live = 0;
        self.members.clear();
        for (i, &n) in survivors.iter().enumerate() {
            let (a, r) = keys[i];
            self.stamp[n as usize] = self.gen;
            self.members.push(n);
            self.push_entry(n, a, r);
            self.live += 1;
        }
    }

    /// Heap footprint of the scoreboard's scratch (stats reporting).
    fn memory_bytes(&self) -> usize {
        self.buckets
            .iter()
            .map(|b| b.capacity() * std::mem::size_of::<(u64, u32)>())
            .sum::<usize>()
            + self.buckets.capacity() * std::mem::size_of::<Vec<(u64, u32)>>()
            + self.stamp.len() * std::mem::size_of::<u32>()
            + self.dirty.capacity() * std::mem::size_of::<u32>()
            + self.members.capacity() * std::mem::size_of::<u32>()
    }
}

/// Serial reference growth step (Alg. 1 lines 18-19): score each
/// frontier node's would-be new inbound axons against the open partition
/// and insert it, one at a time, in frontier order. The parallel path
/// ([`grow_parallel`]) must reproduce this bit-for-bit — insertions
/// never touch the tracker, so every frontier node is scored against the
/// same partition state regardless of execution order.
fn grow_serial(
    g: &Hypergraph,
    tracker: &ConstraintTracker,
    sb: &mut Scoreboard,
    frontier: &[u32],
    sel_min: bool,
) {
    for &n in frontier {
        let axons = if sel_min { tracker.new_axons(n) as u32 } else { 0 };
        sb.insert(n, axons, rank_of(g, n, sel_min));
    }
}

/// Two-phase parallel growth step: frontier scoring (the `new_axons`
/// sweeps that dominate large growth steps) runs over fixed chunks into
/// per-slot scratch — the tracker is shared read-only, so every score is
/// a pure function of the open partition's state — then a serial
/// insertion in frontier order replays [`grow_serial`] exactly. Only
/// dispatched with the argmin-new-axons policy on (`sel_min`); the
/// ablation path has nothing to score.
// snn-lint: allow(parallel-serial-pairing) — grow_serial runs via the threads<=1 dispatch
// in the growth step; overlap_parallel_equals_serial_exactly asserts the two paths produce
// bit-identical partitions, it just reaches them through the public partition entry point
fn grow_parallel(
    g: &Hypergraph,
    tracker: &ConstraintTracker,
    sb: &mut Scoreboard,
    frontier: &[u32],
    axons: &mut Vec<u32>,
    threads: usize,
) {
    score_frontier(tracker, frontier, axons, threads);
    for (i, &n) in frontier.iter().enumerate() {
        sb.insert(n, axons[i], rank_of(g, n, true));
    }
}

/// Parallel `new_axons` sweep shared by [`grow_parallel`] and the
/// partition-close re-key: `axons[i]` receives frontier node i's count.
fn score_frontier(
    tracker: &ConstraintTracker,
    frontier: &[u32],
    axons: &mut Vec<u32>,
    threads: usize,
) {
    axons.clear();
    axons.resize(frontier.len(), 0);
    let chunk = crate::util::par::fixed_chunk(frontier.len(), threads);
    crate::util::par::par_chunks_mut(axons, chunk, threads, |ci, slice| {
        let base = ci * chunk;
        for (k, slot) in slice.iter_mut().enumerate() {
            *slot = tracker.new_axons(frontier[base + k]) as u32;
        }
    });
}

/// Queue update (Alg. 1 lines 31-33): every unseen h-edge touching an
/// assigned node gains an occurrence and loses a remaining slot.
#[allow(clippy::too_many_arguments)]
fn touch_edge(
    c: EdgeId,
    epoch: u32,
    seen: &[bool],
    pq: &mut [f64],
    pq_epoch: &mut [u32],
    size: &mut [u32],
    wf: &[f64],
    heap: &mut BinaryHeap<EdgeEntry>,
) {
    if seen[c as usize] {
        return;
    }
    let ci = c as usize;
    if pq_epoch[ci] != epoch {
        pq[ci] = 0.0;
        pq_epoch[ci] = epoch;
    }
    let sz = size[ci] as f64;
    if sz > 1.0 {
        pq[ci] = (pq[ci] * sz + 1.0) / (sz - 1.0);
    } else {
        pq[ci] = 0.0; // fully assigned edge: no pull left
    }
    size[ci] = size[ci].saturating_sub(1);
    if pq[ci] > 0.0 {
        heap.push(EdgeEntry { prio: pq[ci] * wf[ci], edge: c, epoch });
    }
}

/// Ablation knobs (benches/ablations.rs): Algorithm 1 with pieces off.
#[derive(Clone, Copy, Debug)]
pub struct OverlapParams {
    /// Use the co-membership priority queue to pick the next h-edge
    /// (lines 13-14). Off = pure descending-size order — isolates how
    /// much the dynamic second-order-affinity ordering buys.
    pub use_queue: bool,
    /// Use the argmin^lex node selection (line 21). Off = h-edge
    /// destination order — isolates the snug-fit node policy.
    pub select_min_new_axons: bool,
}

impl Default for OverlapParams {
    fn default() -> Self {
        OverlapParams { use_queue: true, select_min_new_axons: true }
    }
}

/// Partition `g` by hyperedge overlap (Algorithm 1).
pub fn partition(g: &Hypergraph, hw: &NmhConfig) -> Result<Partitioning, MapError> {
    partition_with_params(g, hw, OverlapParams::default())
}

/// Algorithm 1 with ablation parameters (serial reference path).
pub fn partition_with_params(
    g: &Hypergraph,
    hw: &NmhConfig,
    params: OverlapParams,
) -> Result<Partitioning, MapError> {
    partition_with_stats(g, hw, params, 1).map(|(rho, _)| rho)
}

/// Algorithm 1 with an explicit worker budget (fed from
/// [`crate::stage::StageCtx::threads`] by [`OverlapPartitioner`]) and
/// per-run diagnostics. `threads` is a performance knob only: growth
/// steps below [`PAR_MIN_FRONTIER`] — and every run with `threads <= 1`
/// — take the serial path, and the two paths agree bit-for-bit.
pub fn partition_with_stats(
    g: &Hypergraph,
    hw: &NmhConfig,
    params: OverlapParams,
    threads: usize,
) -> Result<(Partitioning, OverlapStats), MapError> {
    let threads = threads.max(1);
    let mut stats = OverlapStats::default();
    let t_run = Instant::now();
    let e_total = g.num_edges();
    super::check_nodes_feasible(g, hw)?;
    let mut assign = vec![u32::MAX; g.num_nodes()];
    let mut tracker = ConstraintTracker::new(g, hw);

    // size(e) = remaining (unassigned destinations + source) count; the
    // denominator of the queue's occurrences/size ratio (Alg. 1 line 6).
    let mut size: Vec<u32> = g
        .edge_ids()
        .map(|e| g.cardinality(e) as u32 + 1)
        .collect();
    // pq(e): co-membership ratio of edge e w.r.t. the current partition.
    let mut pq: Vec<f64> = vec![0.0; e_total];
    // queue epoch of an edge's pq value (flush = bump partition epoch)
    let mut pq_epoch: Vec<u32> = vec![0; e_total];
    let mut epoch = 0u32;

    // h-edge weights as f64, computed once for the heap priorities.
    let wf: Vec<f64> = g.edge_ids().map(|e| g.weight(e) as f64).collect();

    let mut seen = vec![false; e_total];
    let mut seen_count = 0usize;

    // Outer fallback: edges sorted by descending connection count (line 8).
    let mut sorted: Vec<EdgeId> = g.edge_ids().collect();
    sorted.sort_by_key(|&e| std::cmp::Reverse(size[e as usize]));
    let mut sorted_cursor = 0usize;

    let mut heap: BinaryHeap<EdgeEntry> = BinaryHeap::new();
    let mut part = 0u32;

    // Flat scoreboard for the inner node-selection (reused across edges).
    let sel_min = params.select_min_new_axons;
    let mut sb = Scoreboard::new(g.num_nodes(), sel_min);

    // Growth-step scratch, reused across edges: the frontier under
    // scoring, its parallel score slots, and re-key pairs.
    let mut frontier: Vec<u32> = Vec::new();
    let mut axon_scratch: Vec<u32> = Vec::new();
    let mut key_scratch: Vec<(u32, u64)> = Vec::new();

    while seen_count < e_total {
        // ---- pick the next h-edge (lines 13-16) ----
        // pop-first (peek would return the same entry pop removes, so
        // checking staleness after the pop is behavior-identical and
        // leaves no unwrap on the re-pop)
        let e = if !params.use_queue { None } else { loop {
            match heap.pop() {
                Some(entry) => {
                    let stale = seen[entry.edge as usize]
                        || entry.epoch != epoch
                        || {
                            let cur = pq[entry.edge as usize] * wf[entry.edge as usize];
                            (cur - entry.prio).abs() > 1e-12
                        };
                    if stale {
                        continue;
                    }
                    break Some(entry.edge);
                }
                None => break None,
            }
        } };
        let e = match e {
            Some(e) => e,
            None => {
                while seen[sorted[sorted_cursor] as usize] {
                    sorted_cursor += 1;
                }
                sorted[sorted_cursor]
            }
        };
        seen[e as usize] = true;
        seen_count += 1;

        // ---- collect + score assignable nodes of e (lines 18-19);
        // the scoring half is the growth step's propose phase ----
        frontier.clear();
        for &d in g.dsts(e) {
            if assign[d as usize] == u32::MAX {
                frontier.push(d);
            }
        }
        let s = g.source(e);
        if g.inbound(s).is_empty() && assign[s as usize] == u32::MAX {
            // input nodes are free of inbound axons: co-locate with dsts
            frontier.push(s);
        }
        sb.begin();
        let t0 = Instant::now();
        if sel_min && threads > 1 && frontier.len() >= PAR_MIN_FRONTIER {
            grow_parallel(g, &tracker, &mut sb, &frontier, &mut axon_scratch, threads);
            stats.par_growth_steps += 1;
        } else {
            grow_serial(g, &tracker, &mut sb, &frontier, sel_min);
        }
        stats.scored_candidates += frontier.len() as u64;
        stats.score_secs += t0.elapsed().as_secs_f64();

        // ---- assign nodes (lines 20-33) ----
        while let Some(n) = sb.peek_best(|m| tracker.new_axons(m) as u32) {
            if !tracker.fits(n) {
                if tracker.npc == 0 {
                    // prelude proved n fits alone => internal inconsistency
                    return Err(MapError::ConstraintViolated(format!(
                        "node {n} rejected by empty partition"
                    )));
                }
                // close partition: flush queue (epoch bump), open next
                epoch += 1;
                heap.clear();
                tracker.reset();
                part += 1;
                if part as usize >= hw.num_cores() {
                    return Err(MapError::TooManyPartitions {
                        got: part as usize + 1,
                        limit: hw.num_cores(),
                    });
                }
                // candidate axon-counts all reset: re-key the scoreboard
                // (every surviving candidate is the frontier here — on
                // large runs this is the growth step worth parallelizing)
                let t0 = Instant::now();
                let survivors = sb.live_members();
                key_scratch.clear();
                if sel_min && threads > 1 && survivors.len() >= PAR_MIN_FRONTIER {
                    score_frontier(&tracker, &survivors, &mut axon_scratch, threads);
                    for (i, &m) in survivors.iter().enumerate() {
                        key_scratch.push((axon_scratch[i], rank_of(g, m, true)));
                    }
                    stats.par_growth_steps += 1;
                } else {
                    for &m in &survivors {
                        key_scratch.push(if sel_min {
                            (tracker.new_axons(m) as u32, rank_of(g, m, true))
                        } else {
                            (0, rank_of(g, m, false))
                        });
                    }
                }
                sb.rebuild_from(&survivors, &key_scratch);
                stats.scored_candidates += survivors.len() as u64;
                stats.score_secs += t0.elapsed().as_secs_f64();
                continue;
            }

            // assign n to the current partition (lines 28-30)
            sb.remove_best(n);
            tracker.add(n);
            assign[n as usize] = part;

            // update the h-edge queue (lines 31-33)
            for &c in g.inbound(n) {
                touch_edge(c, epoch, &seen, &mut pq, &mut pq_epoch, &mut size, &wf, &mut heap);
            }
            for &c in g.outbound(n) {
                touch_edge(c, epoch, &seen, &mut pq, &mut pq_epoch, &mut size, &wf, &mut heap);
            }
        }
    }

    // Nodes untouched by any h-edge (isolated or sink-only components
    // whose h-edges never listed them): sweep them into open partitions.
    for n in 0..g.num_nodes() as u32 {
        if assign[n as usize] == u32::MAX {
            if !tracker.fits(n) {
                // n fits alone (prelude), so rolling over must succeed
                tracker.reset();
                part += 1;
                if part as usize >= hw.num_cores() {
                    return Err(MapError::TooManyPartitions {
                        got: part as usize + 1,
                        limit: hw.num_cores(),
                    });
                }
            }
            tracker.add(n);
            assign[n as usize] = part;
        }
    }

    stats.peak_scratch_bytes = sb.memory_bytes()
        + tracker.memory_bytes()
        + heap.capacity() * std::mem::size_of::<EdgeEntry>()
        + pq.capacity() * std::mem::size_of::<f64>()
        + pq_epoch.capacity() * std::mem::size_of::<u32>()
        + size.capacity() * std::mem::size_of::<u32>()
        + wf.capacity() * std::mem::size_of::<f64>()
        + seen.capacity()
        + sorted.capacity() * std::mem::size_of::<EdgeId>()
        + assign.capacity() * std::mem::size_of::<u32>()
        + frontier.capacity() * std::mem::size_of::<u32>()
        + axon_scratch.capacity() * std::mem::size_of::<u32>()
        + key_scratch.capacity() * std::mem::size_of::<(u32, u64)>();
    stats.commit_secs = (t_run.elapsed().as_secs_f64() - stats.score_secs).max(0.0);
    Ok((Partitioning::new(assign, part as usize + 1).compacted(), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::mapping::{connectivity, validate};
    use crate::util::rng::Pcg64;

    #[test]
    fn groups_overlapping_listeners() {
        // two axons with identical destination sets + one disjoint axon:
        // overlap partitioning must co-locate the shared listeners
        let mut b = HypergraphBuilder::new(12);
        b.add_edge(0, vec![3, 4, 5, 6], 1.0);
        b.add_edge(1, vec![3, 4, 5, 6], 1.0);
        b.add_edge(2, vec![7, 8, 9, 10], 1.0);
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 6;
        let rho = partition(&g, &hw).unwrap();
        validate(&g, &rho, &hw).unwrap();
        // listeners of the twin axons all share one partition
        let p = rho.assign[3];
        assert!(
            [4, 5, 6].iter().all(|&n| rho.assign[n as usize] == p),
            "assign={:?}",
            rho.assign
        );
    }

    #[test]
    fn connectivity_not_worse_than_unordered_sequential() {
        let mut rng = Pcg64::seeded(23);
        let n = 400;
        // random overlapping-clusters topology
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let center = rng.below(n) as i64;
            let dsts: Vec<u32> = (0..rng.range(4, 12))
                .map(|_| {
                    ((center + rng.range(0, 20) as i64 - 10).rem_euclid(n as i64)) as u32
                })
                .filter(|&d| d != s)
                .collect();
            if !dsts.is_empty() {
                b.add_edge(s, dsts, rng.next_f32() + 0.01);
            }
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 32;
        let ov = partition(&g, &hw).unwrap();
        validate(&g, &ov, &hw).unwrap();
        let seq = crate::mapping::sequential::partition(
            &g,
            &hw,
            crate::mapping::sequential::SeqOrder::Natural,
        )
        .unwrap();
        let c_ov = connectivity(&g, &ov);
        let c_seq = connectivity(&g, &seq);
        assert!(
            c_ov <= c_seq * 1.05,
            "overlap {c_ov} should not lose to unordered sequential {c_seq}"
        );
    }

    #[test]
    fn all_nodes_assigned_even_isolated() {
        let mut b = HypergraphBuilder::new(6);
        b.add_edge(0, vec![1], 1.0);
        // nodes 2..=5 isolated
        let g = b.build();
        let hw = NmhConfig::small();
        let rho = partition(&g, &hw).unwrap();
        assert!(rho.assign.iter().all(|&p| p != u32::MAX));
        validate(&g, &rho, &hw).unwrap();
    }

    #[test]
    fn input_nodes_colocated_with_listeners() {
        // node 0 has no inbound: Alg. 1 line 19 pulls it into the
        // partition of its destinations
        let mut b = HypergraphBuilder::new(5);
        b.add_edge(0, vec![1, 2, 3, 4], 1.0);
        b.add_edge(1, vec![2], 1.0);
        let g = b.build();
        let hw = NmhConfig::small();
        let rho = partition(&g, &hw).unwrap();
        assert_eq!(rho.num_parts, 1);
        assert_eq!(rho.assign[0], rho.assign[1]);
    }

    #[test]
    fn honors_tight_constraints() {
        let mut rng = Pcg64::seeded(31);
        let n = 200;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let dsts: Vec<u32> = (0..8).map(|_| rng.below(n) as u32).filter(|&d| d != s).collect();
            b.add_edge(s, dsts, rng.next_f32() + 0.01);
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 10;
        hw.c_apc = 60;
        hw.c_spc = 70;
        let rho = partition(&g, &hw).unwrap();
        validate(&g, &rho, &hw).unwrap();
    }

    #[test]
    fn deterministic() {
        let mut rng = Pcg64::seeded(37);
        let n = 150;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let dsts: Vec<u32> = (0..6).map(|_| rng.below(n) as u32).filter(|&d| d != s).collect();
            b.add_edge(s, dsts, rng.next_f32() + 0.01);
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 16;
        let a = partition(&g, &hw).unwrap();
        let b2 = partition(&g, &hw).unwrap();
        assert_eq!(a.assign, b2.assign);
    }

    #[test]
    fn overlap_parallel_equals_serial_exactly() {
        // one hub h-edge fans out past PAR_MIN_FRONTIER so the parallel
        // growth path provably dispatches (non-vacuity asserted via
        // par_growth_steps), on top of a random overlapping topology
        let mut rng = Pcg64::seeded(91);
        let n = 600;
        let hub_fan = PAR_MIN_FRONTIER as u32 + 40;
        let mut b = HypergraphBuilder::new(n);
        b.add_edge(0, (1..=hub_fan).collect(), 2.0);
        for s in 0..n as u32 {
            let dsts: Vec<u32> =
                (0..6).map(|_| rng.below(n) as u32).filter(|&d| d != s).collect();
            if !dsts.is_empty() {
                b.add_edge(s, dsts, rng.next_f32() + 0.01);
            }
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 24;
        let (reference, st_ser) =
            partition_with_stats(&g, &hw, OverlapParams::default(), 1).unwrap();
        validate(&g, &reference, &hw).unwrap();
        assert_eq!(st_ser.par_growth_steps, 0, "serial run must never dispatch");
        for threads in [2, 4, 8] {
            let (rho, st) =
                partition_with_stats(&g, &hw, OverlapParams::default(), threads).unwrap();
            assert_eq!(rho.assign, reference.assign, "threads={threads}");
            assert_eq!(rho.num_parts, reference.num_parts, "threads={threads}");
            assert!(
                st.par_growth_steps > 0,
                "parallel path never dispatched (threads={threads})"
            );
            assert_eq!(st.scored_candidates, st_ser.scored_candidates);
        }
    }

    #[test]
    fn ablations_still_valid_partitionings() {
        // both knobs off must still produce constraint-satisfying output
        let mut rng = Pcg64::seeded(41);
        let n = 120;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let dsts: Vec<u32> = (0..5).map(|_| rng.below(n) as u32).filter(|&d| d != s).collect();
            if !dsts.is_empty() {
                b.add_edge(s, dsts, rng.next_f32() + 0.01);
            }
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 12;
        for (uq, sm) in [(false, true), (true, false), (false, false)] {
            let rho = partition_with_params(
                &g,
                &hw,
                OverlapParams { use_queue: uq, select_min_new_axons: sm },
            )
            .unwrap();
            validate(&g, &rho, &hw).unwrap();
        }
    }
}

/// [`crate::stage::Partitioner`] over Algorithm 1 (registry name
/// "overlap"). Deterministic — the pipeline seed is not consumed, and
/// the worker budget follows [`crate::stage::StageCtx::threads`]
/// (performance-only — results are thread-count invariant, §11).
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapPartitioner {
    pub params: OverlapParams,
}

impl OverlapPartitioner {
    pub fn new() -> Self {
        OverlapPartitioner { params: OverlapParams::default() }
    }

    /// Construct from spec parameters: `use_queue`,
    /// `select_min_new_axons` (the ablation knobs).
    pub fn from_params(p: &crate::stage::StageParams) -> Result<Self, String> {
        p.check_known(&["use_queue", "select_min_new_axons"])?;
        let mut s = OverlapPartitioner::new();
        if let Some(v) = p.get_bool("use_queue")? {
            s.params.use_queue = v;
        }
        if let Some(v) = p.get_bool("select_min_new_axons")? {
            s.params.select_min_new_axons = v;
        }
        Ok(s)
    }
}

impl crate::stage::Partitioner for OverlapPartitioner {
    fn name(&self) -> &str {
        "overlap"
    }

    fn partition(
        &self,
        g: &Hypergraph,
        hw: &NmhConfig,
        ctx: &crate::stage::StageCtx,
    ) -> Result<Partitioning, MapError> {
        partition_with_stats(g, hw, self.params, ctx.threads.max(1)).map(|(rho, _)| rho)
    }
}
