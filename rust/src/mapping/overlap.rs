//! Hyperedge-overlap partitioning — the paper's novel greedy heuristic
//! (§IV-A2, Algorithm 1).
//!
//! Builds partitions one at a time by sweeping h-edges: the next h-edge is
//! the one whose nodes exhibit the highest (spike-frequency-weighted)
//! co-membership with the partition under construction — an incremental
//! proxy of second-order affinity. Within an h-edge, nodes are assigned in
//! the order that introduces the fewest new inbound axons to the partition
//! (lexicographic tie-break on largest inbound set), which directly
//! maximizes synaptic reuse while snug-fitting constraints.
//!
//! Complexity O(e·d·log d): each node's connections are visited once; the
//! priority queue is a lazy max-heap flushed per partition via an epoch
//! stamp (O(1) flush).

use super::{ConstraintTracker, MapError};
use crate::hw::NmhConfig;
use crate::hypergraph::quotient::Partitioning;
use crate::hypergraph::{EdgeId, Hypergraph};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry for the h-edge priority queue, with lazy invalidation.
struct EdgeEntry {
    prio: f64,
    edge: EdgeId,
    epoch: u32,
}

impl PartialEq for EdgeEntry {
    fn eq(&self, other: &Self) -> bool {
        self.prio == other.prio && self.edge == other.edge
    }
}
impl Eq for EdgeEntry {}
impl PartialOrd for EdgeEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EdgeEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.prio
            .partial_cmp(&other.prio)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.edge.cmp(&self.edge))
    }
}

/// Candidate-node scoreboard for the inner argmin^lex selection:
/// (new inbound axons ascending, inbound-set size descending, id).
#[derive(PartialEq, Eq, PartialOrd, Ord, Clone, Copy)]
struct NodeKey {
    new_axons: u32,
    neg_inbound: i64,
    node: u32,
}

/// Ablation knobs (benches/ablations.rs): Algorithm 1 with pieces off.
#[derive(Clone, Copy, Debug)]
pub struct OverlapParams {
    /// Use the co-membership priority queue to pick the next h-edge
    /// (lines 13-14). Off = pure descending-size order — isolates how
    /// much the dynamic second-order-affinity ordering buys.
    pub use_queue: bool,
    /// Use the argmin^lex node selection (line 21). Off = h-edge
    /// destination order — isolates the snug-fit node policy.
    pub select_min_new_axons: bool,
}

impl Default for OverlapParams {
    fn default() -> Self {
        OverlapParams { use_queue: true, select_min_new_axons: true }
    }
}

/// Partition `g` by hyperedge overlap (Algorithm 1).
pub fn partition(g: &Hypergraph, hw: &NmhConfig) -> Result<Partitioning, MapError> {
    partition_with_params(g, hw, OverlapParams::default())
}

/// Algorithm 1 with ablation parameters.
pub fn partition_with_params(
    g: &Hypergraph,
    hw: &NmhConfig,
    params: OverlapParams,
) -> Result<Partitioning, MapError> {
    let e_total = g.num_edges();
    let mut assign = vec![u32::MAX; g.num_nodes()];
    let mut tracker = ConstraintTracker::new(g, hw);

    // size(e) = remaining (unassigned destinations + source) count; the
    // denominator of the queue's occurrences/size ratio (Alg. 1 line 6).
    let mut size: Vec<u32> = g
        .edge_ids()
        .map(|e| g.cardinality(e) as u32 + 1)
        .collect();
    // pq(e): co-membership ratio of edge e w.r.t. the current partition.
    let mut pq: Vec<f64> = vec![0.0; e_total];
    // queue epoch of an edge's pq value (flush = bump partition epoch)
    let mut pq_epoch: Vec<u32> = vec![0; e_total];
    let mut epoch = 0u32;

    let mut seen = vec![false; e_total];
    let mut seen_count = 0usize;

    // Outer fallback: edges sorted by descending connection count (line 8).
    let mut sorted: Vec<EdgeId> = g.edge_ids().collect();
    sorted.sort_by_key(|&e| std::cmp::Reverse(size[e as usize]));
    let mut sorted_cursor = 0usize;

    let mut heap: BinaryHeap<EdgeEntry> = BinaryHeap::new();
    let mut part = 0u32;

    // Scratch for the inner node-selection scoreboard.
    let mut cand: std::collections::BTreeSet<NodeKey> = std::collections::BTreeSet::new();
    let mut cand_key: std::collections::HashMap<u32, NodeKey> = std::collections::HashMap::new();

    while seen_count < e_total {
        // ---- pick the next h-edge (lines 13-16) ----
        let e = if !params.use_queue { None } else { loop {
            match heap.peek() {
                Some(entry) => {
                    let stale = seen[entry.edge as usize]
                        || entry.epoch != epoch
                        || {
                            let cur = pq[entry.edge as usize] * g.weight(entry.edge) as f64;
                            (cur - entry.prio).abs() > 1e-12
                        };
                    if stale {
                        heap.pop();
                        continue;
                    }
                    break Some(heap.pop().unwrap().edge);
                }
                None => break None,
            }
        } };
        let e = match e {
            Some(e) => e,
            None => {
                while seen[sorted[sorted_cursor] as usize] {
                    sorted_cursor += 1;
                }
                sorted[sorted_cursor]
            }
        };
        seen[e as usize] = true;
        seen_count += 1;

        // ---- collect assignable nodes of e (lines 18-19) ----
        cand.clear();
        cand_key.clear();
        let s = g.source(e);
        let sel_min = params.select_min_new_axons;
        let push_cand = |n: u32,
                             cand: &mut std::collections::BTreeSet<NodeKey>,
                             cand_key: &mut std::collections::HashMap<u32, NodeKey>,
                             tracker: &ConstraintTracker| {
            if assign[n as usize] == u32::MAX && !cand_key.contains_key(&n) {
                let key = if sel_min {
                    NodeKey {
                        new_axons: tracker.new_axons(n) as u32,
                        neg_inbound: -(g.inbound(n).len() as i64),
                        node: n,
                    }
                } else {
                    NodeKey { new_axons: 0, neg_inbound: 0, node: n }
                };
                cand.insert(key);
                cand_key.insert(n, key);
            }
        };
        for &d in g.dsts(e) {
            push_cand(d, &mut cand, &mut cand_key, &tracker);
        }
        if g.inbound(s).is_empty() {
            // input nodes are free of inbound axons: co-locate with dsts
            push_cand(s, &mut cand, &mut cand_key, &tracker);
        }

        // ---- assign nodes (lines 20-33) ----
        while let Some(&key) = cand.iter().next() {
            let n = key.node;
            // key.new_axons may be stale only w.r.t. *reductions* (axons
            // added to the partition since insertion); recompute cheaply
            // and reinsert if it improved.
            let fresh = if params.select_min_new_axons { tracker.new_axons(n) as u32 } else { 0 };
            if fresh != key.new_axons {
                cand.remove(&key);
                let nk = NodeKey { new_axons: fresh, ..key };
                cand.insert(nk);
                cand_key.insert(n, nk);
                continue;
            }

            if !tracker.fits(n) {
                if tracker.npc == 0 {
                    tracker.node_feasible(n)?;
                    return Err(MapError::ConstraintViolated(format!(
                        "node {n} rejected by empty partition"
                    )));
                }
                // close partition: flush queue (epoch bump), open next
                epoch += 1;
                heap.clear();
                tracker.reset();
                part += 1;
                if part as usize >= hw.num_cores() {
                    return Err(MapError::TooManyPartitions {
                        got: part as usize + 1,
                        limit: hw.num_cores(),
                    });
                }
                // candidate axon-counts all reset: rebuild the scoreboard
                let nodes: Vec<u32> = cand_key.keys().copied().collect();
                cand.clear();
                cand_key.clear();
                for m in nodes {
                    let k = if params.select_min_new_axons {
                        NodeKey {
                            new_axons: tracker.new_axons(m) as u32,
                            neg_inbound: -(g.inbound(m).len() as i64),
                            node: m,
                        }
                    } else {
                        NodeKey { new_axons: 0, neg_inbound: 0, node: m }
                    };
                    cand.insert(k);
                    cand_key.insert(m, k);
                }
                continue;
            }

            // assign n to the current partition (lines 28-30)
            cand.remove(&key);
            cand_key.remove(&n);
            tracker.add(n);
            assign[n as usize] = part;

            // update the h-edge queue (lines 31-33): every unseen h-edge
            // touching n gains an occurrence and loses a remaining slot
            let mut touch = |c: EdgeId, heap: &mut BinaryHeap<EdgeEntry>| {
                if seen[c as usize] {
                    return;
                }
                let ci = c as usize;
                if pq_epoch[ci] != epoch {
                    pq[ci] = 0.0;
                    pq_epoch[ci] = epoch;
                }
                let sz = size[ci] as f64;
                if sz > 1.0 {
                    pq[ci] = (pq[ci] * sz + 1.0) / (sz - 1.0);
                } else {
                    pq[ci] = 0.0; // fully assigned edge: no pull left
                }
                size[ci] = size[ci].saturating_sub(1);
                if pq[ci] > 0.0 {
                    heap.push(EdgeEntry {
                        prio: pq[ci] * g.weight(c) as f64,
                        edge: c,
                        epoch,
                    });
                }
            };
            for &c in g.inbound(n) {
                touch(c, &mut heap);
            }
            for &c in g.outbound(n) {
                touch(c, &mut heap);
            }
        }
    }

    // Nodes untouched by any h-edge (isolated or sink-only components
    // whose h-edges never listed them): sweep them into open partitions.
    for n in 0..g.num_nodes() as u32 {
        if assign[n as usize] == u32::MAX {
            if !tracker.fits(n) {
                tracker.node_feasible(n)?;
                tracker.reset();
                part += 1;
                if part as usize >= hw.num_cores() {
                    return Err(MapError::TooManyPartitions {
                        got: part as usize + 1,
                        limit: hw.num_cores(),
                    });
                }
            }
            tracker.add(n);
            assign[n as usize] = part;
        }
    }

    Ok(Partitioning::new(assign, part as usize + 1).compacted())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::mapping::{connectivity, validate};
    use crate::util::rng::Pcg64;

    #[test]
    fn groups_overlapping_listeners() {
        // two axons with identical destination sets + one disjoint axon:
        // overlap partitioning must co-locate the shared listeners
        let mut b = HypergraphBuilder::new(12);
        b.add_edge(0, vec![3, 4, 5, 6], 1.0);
        b.add_edge(1, vec![3, 4, 5, 6], 1.0);
        b.add_edge(2, vec![7, 8, 9, 10], 1.0);
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 6;
        let rho = partition(&g, &hw, ).unwrap();
        validate(&g, &rho, &hw).unwrap();
        // listeners of the twin axons all share one partition
        let p = rho.assign[3];
        assert!(
            [4, 5, 6].iter().all(|&n| rho.assign[n as usize] == p),
            "assign={:?}",
            rho.assign
        );
    }

    #[test]
    fn connectivity_not_worse_than_unordered_sequential() {
        let mut rng = Pcg64::seeded(23);
        let n = 400;
        // random overlapping-clusters topology
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let center = rng.below(n) as i64;
            let dsts: Vec<u32> = (0..rng.range(4, 12))
                .map(|_| {
                    ((center + rng.range(0, 20) as i64 - 10).rem_euclid(n as i64)) as u32
                })
                .filter(|&d| d != s)
                .collect();
            if !dsts.is_empty() {
                b.add_edge(s, dsts, rng.next_f32() + 0.01);
            }
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 32;
        let ov = partition(&g, &hw).unwrap();
        validate(&g, &ov, &hw).unwrap();
        let seq =
            crate::mapping::sequential::partition(&g, &hw, crate::mapping::sequential::SeqOrder::Natural)
                .unwrap();
        let c_ov = connectivity(&g, &ov);
        let c_seq = connectivity(&g, &seq);
        assert!(
            c_ov <= c_seq * 1.05,
            "overlap {c_ov} should not lose to unordered sequential {c_seq}"
        );
    }

    #[test]
    fn all_nodes_assigned_even_isolated() {
        let mut b = HypergraphBuilder::new(6);
        b.add_edge(0, vec![1], 1.0);
        // nodes 2..=5 isolated
        let g = b.build();
        let hw = NmhConfig::small();
        let rho = partition(&g, &hw).unwrap();
        assert!(rho.assign.iter().all(|&p| p != u32::MAX));
        validate(&g, &rho, &hw).unwrap();
    }

    #[test]
    fn input_nodes_colocated_with_listeners() {
        // node 0 has no inbound: Alg. 1 line 19 pulls it into the
        // partition of its destinations
        let mut b = HypergraphBuilder::new(5);
        b.add_edge(0, vec![1, 2, 3, 4], 1.0);
        b.add_edge(1, vec![2], 1.0);
        let g = b.build();
        let hw = NmhConfig::small();
        let rho = partition(&g, &hw).unwrap();
        assert_eq!(rho.num_parts, 1);
        assert_eq!(rho.assign[0], rho.assign[1]);
    }

    #[test]
    fn honors_tight_constraints() {
        let mut rng = Pcg64::seeded(31);
        let n = 200;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let dsts: Vec<u32> = (0..8).map(|_| rng.below(n) as u32).filter(|&d| d != s).collect();
            b.add_edge(s, dsts, rng.next_f32() + 0.01);
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 10;
        hw.c_apc = 60;
        hw.c_spc = 70;
        let rho = partition(&g, &hw).unwrap();
        validate(&g, &rho, &hw).unwrap();
    }

    #[test]
    fn deterministic() {
        let mut rng = Pcg64::seeded(37);
        let n = 150;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let dsts: Vec<u32> = (0..6).map(|_| rng.below(n) as u32).filter(|&d| d != s).collect();
            b.add_edge(s, dsts, rng.next_f32() + 0.01);
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_npc = 16;
        let a = partition(&g, &hw).unwrap();
        let b2 = partition(&g, &hw).unwrap();
        assert_eq!(a.assign, b2.assign);
    }
}
