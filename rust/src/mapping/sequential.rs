//! Sequential partitioning (paper §IV-A3, from [7]).
//!
//! Walks nodes in a given order, filling the current partition until any
//! NMH constraint would be violated, then opens the next. O(n) once the
//! order exists. Quality is entirely inherited from the order: natural
//! (layer-major) for ANN-derived SNNs, Alg. 2's greedy order otherwise,
//! or raw node-id order for the "unordered" baseline variant.

use super::{ConstraintTracker, MapError};
use crate::hw::NmhConfig;
use crate::hypergraph::quotient::Partitioning;
use crate::hypergraph::Hypergraph;

/// Ordering strategy for [`partition`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqOrder {
    /// Natural node-id order (the paper's "unordered" baseline; for
    /// layered generators node ids already follow the layer structure).
    Natural,
    /// Greedy frequency-accumulation order (Alg. 2).
    Greedy,
    /// Kahn topological order when acyclic, else greedy.
    Auto,
}

/// Sequentially partition `g` under `hw` constraints using `order`.
pub fn partition(
    g: &Hypergraph,
    hw: &NmhConfig,
    order: SeqOrder,
) -> Result<Partitioning, MapError> {
    partition_threads(g, hw, order, 1)
}

/// [`partition`] with a worker budget for the ordering pass (fed from
/// [`crate::stage::StageCtx::threads`] by [`SequentialPartitioner`]).
/// Performance knob only: `greedy_order_threads` is bit-for-bit
/// thread-invariant, so the partitioning is too.
// snn-lint: allow(parallel-serial-pairing) — worker-budget wrapper: the only parallelism
// is inside greedy_order_threads, which owns the serial twin and its equality tests
pub fn partition_threads(
    g: &Hypergraph,
    hw: &NmhConfig,
    order: SeqOrder,
    threads: usize,
) -> Result<Partitioning, MapError> {
    let order_vec: Vec<u32> = match order {
        SeqOrder::Natural => (0..g.num_nodes() as u32).collect(),
        SeqOrder::Greedy => super::ordering::greedy_order_threads(g, threads),
        SeqOrder::Auto => super::ordering::auto_order_threads(g, threads),
    };
    partition_with_order(g, hw, &order_vec)
}

/// Sequential partitioning over an explicit node order.
pub fn partition_with_order(
    g: &Hypergraph,
    hw: &NmhConfig,
    order: &[u32],
) -> Result<Partitioning, MapError> {
    assert_eq!(order.len(), g.num_nodes());
    super::check_nodes_feasible(g, hw)?;
    let mut assign = vec![u32::MAX; g.num_nodes()];
    let mut tracker = ConstraintTracker::new(g, hw);
    let mut part = 0u32;
    for &n in order {
        if !tracker.fits(n) {
            if tracker.npc == 0 {
                // the prelude proved n fits alone => internal inconsistency
                return Err(MapError::ConstraintViolated(format!(
                    "node {n} rejected by empty partition"
                )));
            }
            tracker.reset();
            part += 1;
            if part as usize >= hw.num_cores() {
                return Err(MapError::TooManyPartitions {
                    got: part as usize + 1,
                    limit: hw.num_cores(),
                });
            }
            if !tracker.fits(n) {
                return Err(MapError::ConstraintViolated(format!(
                    "node {n} rejected by empty partition"
                )));
            }
        }
        tracker.add(n);
        assign[n as usize] = part;
    }
    Ok(Partitioning::new(assign, part as usize + 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::mapping::{connectivity, validate};

    fn chain(n: usize) -> Hypergraph {
        let mut b = HypergraphBuilder::new(n);
        for i in 0..(n - 1) as u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        b.build()
    }

    fn tiny_hw(npc: usize) -> NmhConfig {
        let mut hw = NmhConfig::small();
        hw.c_npc = npc;
        hw
    }

    #[test]
    fn fills_partitions_in_order() {
        let g = chain(10);
        let hw = tiny_hw(4);
        let rho = partition(&g, &hw, SeqOrder::Natural).unwrap();
        assert_eq!(rho.num_parts, 3); // 4 + 4 + 2
        assert_eq!(rho.assign[0..4], [0, 0, 0, 0]);
        assert_eq!(rho.assign[4..8], [1, 1, 1, 1]);
        assert_eq!(rho.assign[8..10], [2, 2]);
        validate(&g, &rho, &hw).unwrap();
    }

    #[test]
    fn respects_synapse_limit() {
        // every node receives 3 synapses from a hub trio
        let mut b = HypergraphBuilder::new(13);
        for h in 0..3u32 {
            b.add_edge(h, (3..13).collect(), 1.0);
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_spc = 9; // 3 synapses per non-hub node -> 3 nodes max per core
        let rho = partition(&g, &hw, SeqOrder::Natural).unwrap();
        validate(&g, &rho, &hw).unwrap();
        for &sz in rho
            .sizes()
            .iter()
            .filter(|&&s| s > 0)
            .collect::<Vec<_>>()
            .iter()
        {
            assert!(*sz <= 6);
        }
    }

    #[test]
    fn respects_axon_limit_via_reuse() {
        // nodes 2.. all listen to the same two axons: with C_apc = 2 they
        // can still share one core thanks to synaptic reuse
        let mut b = HypergraphBuilder::new(8);
        b.add_edge(0, (2..8).collect(), 1.0);
        b.add_edge(1, (2..8).collect(), 1.0);
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_apc = 2;
        let rho = partition(&g, &hw, SeqOrder::Natural).unwrap();
        validate(&g, &rho, &hw).unwrap();
        // all 6 listeners fit one partition: distinct axons = 2
        let sizes = rho.sizes();
        assert!(sizes.iter().any(|&s| s >= 6), "sizes={sizes:?}");
    }

    #[test]
    fn greedy_order_beats_bad_natural_order_on_shuffled_chain() {
        // Build a chain over randomly-permuted ids: natural order is then
        // meaningless, Alg. 2 should recover locality and fewer cuts.
        let n = 64;
        let mut rng = crate::util::rng::Pcg64::seeded(5);
        let mut perm: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut perm);
        let mut b = HypergraphBuilder::new(n);
        for i in 0..n - 1 {
            b.add_edge(perm[i], vec![perm[i + 1]], 1.0);
        }
        let g = b.build();
        let hw = tiny_hw(8);
        let natural = partition(&g, &hw, SeqOrder::Natural).unwrap();
        let greedy = partition(&g, &hw, SeqOrder::Greedy).unwrap();
        assert!(
            connectivity(&g, &greedy) <= connectivity(&g, &natural),
            "greedy {} vs natural {}",
            connectivity(&g, &greedy),
            connectivity(&g, &natural)
        );
        validate(&g, &greedy, &hw).unwrap();
    }

    #[test]
    fn single_unmappable_node_reported() {
        let mut b = HypergraphBuilder::new(5);
        for s in 0..4u32 {
            b.add_edge(s, vec![4], 1.0);
        }
        let g = b.build();
        let mut hw = NmhConfig::small();
        hw.c_apc = 3; // node 4 alone has 4 inbound axons
        let err = partition(&g, &hw, SeqOrder::Natural).unwrap_err();
        assert!(matches!(err, MapError::NodeUnmappable { node: 4, .. }));
    }

    #[test]
    fn too_many_partitions_detected() {
        let g = chain(10);
        let mut hw = tiny_hw(1);
        hw.width = 2;
        hw.height = 2;
        assert!(matches!(
            partition(&g, &hw, SeqOrder::Natural),
            Err(MapError::TooManyPartitions { .. })
        ));
    }
}

/// [`crate::stage::Partitioner`] over sequential partitioning (registry
/// names "sequential" and "seq-unordered").
///
/// With `order = None` the stage is layer-aware like the historical
/// pipeline default: natural (layer-major) order when the context
/// carries layer ranges, Alg. 2's greedy order otherwise.
#[derive(Clone, Copy, Debug)]
pub struct SequentialPartitioner {
    /// Pinned ordering strategy; `None` = layer-aware auto.
    pub order: Option<SeqOrder>,
    display: &'static str,
}

impl SequentialPartitioner {
    /// Layer-aware variant ("sequential").
    pub fn auto() -> Self {
        SequentialPartitioner { order: None, display: "sequential" }
    }

    /// Natural-order baseline of [7] ("seq-unordered").
    pub fn unordered() -> Self {
        SequentialPartitioner { order: Some(SeqOrder::Natural), display: "seq-unordered" }
    }

    /// Construct the "sequential" stage from spec parameters: `order` in
    /// {"auto", "natural", "greedy", "kahn"} (default layer-aware auto).
    pub fn from_params(p: &crate::stage::StageParams) -> Result<Self, String> {
        p.check_known(&["order"])?;
        let mut s = SequentialPartitioner::auto();
        match p.get_str("order")? {
            None | Some("auto") => {}
            Some("natural") => s.order = Some(SeqOrder::Natural),
            Some("greedy") => s.order = Some(SeqOrder::Greedy),
            Some("kahn") => s.order = Some(SeqOrder::Auto),
            Some(other) => {
                return Err(format!(
                    "unknown order '{other}' (accepted: auto, natural, greedy, kahn)"
                ))
            }
        }
        Ok(s)
    }

    /// Construct the "seq-unordered" stage (accepts no parameters).
    pub fn from_params_unordered(p: &crate::stage::StageParams) -> Result<Self, String> {
        p.check_known(&[])?;
        Ok(SequentialPartitioner::unordered())
    }
}

impl crate::stage::Partitioner for SequentialPartitioner {
    fn name(&self) -> &str {
        self.display
    }

    fn partition(
        &self,
        g: &Hypergraph,
        hw: &NmhConfig,
        ctx: &crate::stage::StageCtx,
    ) -> Result<Partitioning, MapError> {
        let order = match self.order {
            Some(o) => o,
            // layered nets: natural ids are already layer-major
            None if ctx.layer_ranges.is_some() => SeqOrder::Natural,
            None => SeqOrder::Greedy,
        };
        partition_threads(g, hw, order, ctx.threads.max(1))
    }
}
