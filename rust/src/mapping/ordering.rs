//! Node orderings (paper Alg. 2 + the queue-based Kahn variant).
//!
//! Sequential partitioning, the Hilbert placement and minimum-distance
//! placement all consume a linear order of nodes. For layered SNNs the
//! natural (layer-major) order already has locality; for arbitrary
//! h-graphs the paper introduces a greedy frequency-accumulation order
//! (Alg. 2) and, for acyclic quotient graphs, weighted Kahn topological
//! ordering.
//!
//! Alg. 2's engine is an **addressable** (position-indexed) max-heap:
//! a priority bump re-sifts the node's single live entry in place, so
//! the structure never holds stale duplicates — the lazy-invalidation
//! `BinaryHeap` churn of the reference implementation
//! ([`greedy_order_serial`], kept as the bit-exact oracle) is gone. With
//! `threads > 1` the per-placement frequency propagation (the `dsts`
//! fan-out of the placed node's outbound h-edges) runs **two-phase**
//! (DESIGN.md §12): a parallel propose over fixed fan-out chunks marks
//! which destinations take a bump against the step-start state, and a
//! serial commit applies the bumps in destination order — bit-for-bit
//! identical to the serial walk for every worker count (tested).

use crate::hypergraph::Hypergraph;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::VecDeque;
use std::time::Instant;

/// Below this per-step fan-out (Σ |D_e| over the placed node's outbound
/// h-edges) the frequency propagation runs serially even when
/// `threads > 1`. The propose phase does only two array reads per
/// element, so — unlike the force scan's per-candidate `swap_gain` —
/// there is nothing to amortize the scoped-thread spawn against until
/// the fan-out's random `placed`/`prio` reads (the cache-miss-bound cost
/// on large graphs) reach the thousands; a small floor would make the
/// parallel path a net pessimization on exactly the steps it targets.
/// Fine SNN graphs (|D| ≈ mean cardinality) and small-scale quotient
/// graphs stay serial by design; billion-edge hub fan-outs dispatch.
/// Public so thread-invariance tests can assert their workloads actually
/// dispatch (see [`OrderStats::par_steps`]).
pub const PAR_MIN_FANOUT: usize = 1024;

/// Diagnostics from one greedy-ordering run (hotpath bench + CI
/// trajectory), mirroring `QuotientStats`/`OverlapStats` (DESIGN.md §12).
#[derive(Clone, Copy, Debug, Default)]
pub struct OrderStats {
    /// Wall-clock of parallel propose phases (zero when never dispatched).
    pub propose_secs: f64,
    /// Wall-clock of everything else: selection, bumps, heap maintenance.
    pub commit_secs: f64,
    /// Placement steps that dispatched the parallel propose path — the
    /// counter that makes broken `threads` wiring observable despite
    /// bit-identical outputs.
    pub par_steps: u64,
    /// Heap high-water mark of the ordering's scratch structures.
    pub peak_scratch_bytes: usize,
}

/// Max-heap entry with lazy invalidation (reference implementation only).
#[derive(PartialEq)]
struct Entry {
    prio: f64,
    node: u32,
}

impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // max-heap by priority; tie-break by node id for determinism
        crate::util::cmp_non_nan(&self.prio, &other.prio)
            .then_with(|| other.node.cmp(&self.node))
    }
}

/// Addressable binary max-heap over node ids, keyed by an external
/// priority slice. `pos[n]` tracks n's heap slot, so a priority increase
/// re-sifts the existing entry in place — at most one live entry per
/// node, never a stale one. Ordering is (priority desc, node id asc),
/// the same total order as the reference [`Entry`], so selections and
/// tie-breaks are identical by construction.
struct AddressableHeap {
    heap: Vec<u32>,
    /// node -> heap slot, `u32::MAX` when absent.
    pos: Vec<u32>,
}

impl AddressableHeap {
    fn new(n: usize) -> Self {
        AddressableHeap { heap: Vec::with_capacity(n), pos: vec![u32::MAX; n] }
    }

    /// The heap's total order: higher priority first, smaller id on ties.
    #[inline]
    fn better(prio: &[f64], a: u32, b: u32) -> bool {
        let (pa, pb) = (prio[a as usize], prio[b as usize]);
        pa > pb || (pa == pb && a < b)
    }

    /// Insert `n`, or restore the heap property after n's priority rose
    /// (priorities only ever increase in Alg. 2, so sift-up suffices).
    fn bump(&mut self, prio: &[f64], n: u32) {
        let i = self.pos[n as usize];
        if i == u32::MAX {
            self.pos[n as usize] = self.heap.len() as u32;
            self.heap.push(n);
            self.sift_up(prio, self.heap.len() - 1);
        } else {
            self.sift_up(prio, i as usize);
        }
    }

    fn pop(&mut self, prio: &[f64]) -> Option<u32> {
        if self.heap.is_empty() {
            return None;
        }
        let top = self.heap[0];
        self.pos[top as usize] = u32::MAX;
        // snn-lint: allow(unwrap-ban) — guarded by the is_empty() early return above
        let last = self.heap.pop().unwrap();
        if !self.heap.is_empty() {
            self.heap[0] = last;
            self.pos[last as usize] = 0;
            self.sift_down(prio, 0);
        }
        Some(top)
    }

    fn sift_up(&mut self, prio: &[f64], mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::better(prio, self.heap[i], self.heap[parent]) {
                self.swap_slots(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, prio: &[f64], mut i: usize) {
        loop {
            let l = 2 * i + 1;
            if l >= self.heap.len() {
                break;
            }
            let r = l + 1;
            let mut best = l;
            if r < self.heap.len() && Self::better(prio, self.heap[r], self.heap[l]) {
                best = r;
            }
            if Self::better(prio, self.heap[best], self.heap[i]) {
                self.swap_slots(i, best);
                i = best;
            } else {
                break;
            }
        }
    }

    fn swap_slots(&mut self, a: usize, b: usize) {
        self.heap.swap(a, b);
        self.pos[self.heap[a] as usize] = a as u32;
        self.pos[self.heap[b] as usize] = b as u32;
    }

    fn memory_bytes(&self) -> usize {
        self.heap.capacity() * 4 + self.pos.capacity() * 4
    }
}

/// Greedy nodes ordering (Alg. 2), serial single-thread entry point.
pub fn greedy_order(g: &Hypergraph) -> Vec<u32> {
    greedy_order_threads(g, 1)
}

/// [`greedy_order`] with an explicit worker budget (fed from
/// [`crate::stage::StageCtx::threads`] by the sequential partitioner and
/// the Hilbert/minimum-distance placers). A performance knob only:
/// the output is bit-for-bit identical for every value (enforced by
/// tests against [`greedy_order_serial`]).
pub fn greedy_order_threads(g: &Hypergraph, threads: usize) -> Vec<u32> {
    greedy_order_with_stats(g, threads).0
}

/// [`greedy_order_threads`] plus per-run diagnostics for the hotpath
/// bench and the CI trajectory.
///
/// The addressable priority structure accumulates, per node, the total
/// spike frequency of connections from already-ordered nodes; the next
/// node is the highest-priority unordered one, falling back to
/// minimum-inbound nodes when no unordered node has positive priority
/// (Alg. 2 lines 6-7, 12). Invariant: the heap holds exactly the
/// unplaced nodes whose priority is positive (or the +inf seeds), at
/// their *current* priority — which is precisely the set the reference
/// heap's skip-stale pop converges to.
pub fn greedy_order_with_stats(g: &Hypergraph, threads: usize) -> (Vec<u32>, OrderStats) {
    let threads = threads.max(1);
    let mut stats = OrderStats::default();
    let t_run = Instant::now();
    let n = g.num_nodes();
    let mut order = Vec::with_capacity(n);
    let mut prio = vec![0.0f64; n];
    let mut placed = vec![false; n];
    let mut heap = AddressableHeap::new(n);

    // Nodes sorted by inbound-set size: the fallback source (line 12) and
    // the +inf seeding of minimum-inbound nodes (lines 6-7).
    let mut by_inbound: Vec<u32> = (0..n as u32).collect();
    by_inbound.sort_by_key(|&m| (g.inbound(m).len(), m));
    let min_inbound = by_inbound
        .first()
        .map(|&m| g.inbound(m).len())
        .unwrap_or(0);
    for &m in by_inbound.iter().take_while(|&&m| g.inbound(m).len() == min_inbound) {
        prio[m as usize] = f64::INFINITY;
        heap.bump(&prio, m);
    }
    let mut fallback_cursor = 0usize;

    // fan-out propose scratch, reused across placement steps
    let mut pairs: Vec<(u32, f64)> = Vec::new();
    let mut keep: Vec<bool> = Vec::new();

    while order.len() < n {
        // highest-priority unordered node, else next min-inbound unplaced
        let node = heap.pop(&prio).unwrap_or_else(|| {
            while placed[by_inbound[fallback_cursor] as usize] {
                fallback_cursor += 1;
            }
            by_inbound[fallback_cursor]
        });
        placed[node as usize] = true;
        order.push(node);

        // propagate frequency to destinations (lines 14-15); the fan-out
        // size is only worth computing when a parallel pool exists
        let par_fanout = threads > 1
            && g.outbound(node).iter().map(|&e| g.cardinality(e)).sum::<usize>()
                >= PAR_MIN_FANOUT;
        if par_fanout {
            // flatten the fan-out in (outbound edge, destination) order
            pairs.clear();
            for &e in g.outbound(node) {
                let w = g.weight(e) as f64;
                for &m in g.dsts(e) {
                    pairs.push((m, w));
                }
            }
            // propose (parallel): mark destinations that take a bump.
            // Exact against the step-start state: neither `placed` nor a
            // priority's finiteness changes inside the step, so every
            // mark is a pure function of (graph, step-start state).
            stats.par_steps += 1;
            let t0 = Instant::now();
            keep.clear();
            keep.resize(pairs.len(), false);
            let chunk = crate::util::par::fixed_chunk(pairs.len(), threads);
            {
                let (pairs_ref, placed_ref, prio_ref) = (&pairs, &placed, &prio);
                crate::util::par::par_chunks_mut(&mut keep, chunk, threads, |ci, slice| {
                    let base = ci * chunk;
                    for (k, slot) in slice.iter_mut().enumerate() {
                        let (m, _) = pairs_ref[base + k];
                        *slot = !placed_ref[m as usize] && prio_ref[m as usize].is_finite();
                    }
                });
            }
            stats.propose_secs += t0.elapsed().as_secs_f64();
            // commit (serial, destination order == the serial walk's, so
            // the f64 accumulation order is identical)
            for (i, &(m, w)) in pairs.iter().enumerate() {
                if keep[i] {
                    prio[m as usize] += w;
                    if prio[m as usize] > 0.0 {
                        heap.bump(&prio, m);
                    }
                }
            }
        } else {
            // serial walk, same (edge, destination) order
            for &e in g.outbound(node) {
                let w = g.weight(e) as f64;
                for &m in g.dsts(e) {
                    if !placed[m as usize] && prio[m as usize].is_finite() {
                        prio[m as usize] += w;
                        if prio[m as usize] > 0.0 {
                            heap.bump(&prio, m);
                        }
                    }
                }
            }
        }
    }
    stats.peak_scratch_bytes = heap.memory_bytes()
        + prio.capacity() * 8
        + placed.capacity()
        + by_inbound.capacity() * 4
        + pairs.capacity() * std::mem::size_of::<(u32, f64)>()
        + keep.capacity();
    stats.commit_secs = (t_run.elapsed().as_secs_f64() - stats.propose_secs).max(0.0);
    (order, stats)
}

/// The pre-addressable-heap reference implementation of Alg. 2: a lazy
/// `BinaryHeap` that pushes a fresh entry on every bump and skips
/// stale/placed/non-positive entries at pop. Kept verbatim as the
/// bit-exact oracle the production engine is tested against — a popped
/// entry is live iff it records the node's current priority, so the
/// selection rule is "argmax (priority, smaller id) over unplaced nodes
/// with positive priority", exactly the addressable heap's invariant.
pub fn greedy_order_serial(g: &Hypergraph) -> Vec<u32> {
    let n = g.num_nodes();
    let mut order = Vec::with_capacity(n);
    let mut prio = vec![0.0f64; n];
    let mut placed = vec![false; n];
    let mut heap: BinaryHeap<Entry> = BinaryHeap::new();

    let mut by_inbound: Vec<u32> = (0..n as u32).collect();
    by_inbound.sort_by_key(|&m| (g.inbound(m).len(), m));
    let min_inbound = by_inbound
        .first()
        .map(|&m| g.inbound(m).len())
        .unwrap_or(0);
    for &m in by_inbound.iter().take_while(|&&m| g.inbound(m).len() == min_inbound) {
        prio[m as usize] = f64::INFINITY;
        heap.push(Entry { prio: f64::INFINITY, node: m });
    }
    let mut fallback_cursor = 0usize;

    while order.len() < n {
        // pop from queue (skipping stale/placed entries)…
        let next = loop {
            match heap.pop() {
                Some(Entry { prio: p, node }) => {
                    if placed[node as usize] || prio[node as usize] != p || p <= 0.0 {
                        continue;
                    }
                    break Some(node);
                }
                None => break None,
            }
        };
        // …or fall back to the next min-inbound unplaced node.
        let node = next.unwrap_or_else(|| {
            while placed[by_inbound[fallback_cursor] as usize] {
                fallback_cursor += 1;
            }
            by_inbound[fallback_cursor]
        });

        placed[node as usize] = true;
        order.push(node);
        // propagate frequency to destinations (lines 14-15)
        for &e in g.outbound(node) {
            let w = g.weight(e) as f64;
            for &m in g.dsts(e) {
                if !placed[m as usize] {
                    let p = &mut prio[m as usize];
                    if p.is_finite() {
                        *p += w;
                        heap.push(Entry { prio: *p, node: m });
                    }
                }
            }
        }
    }
    order
}

/// Weighted queue-based Kahn topological order (§IV-B1): roots first; each
/// node's outgoing h-edges are processed in decreasing weight order before
/// newly freed nodes enter the FIFO. Returns None on cyclic graphs.
pub fn kahn_order(g: &Hypergraph) -> Option<Vec<u32>> {
    let n = g.num_nodes();
    let mut indeg = vec![0usize; n];
    for e in g.edge_ids() {
        for &d in g.dsts(e) {
            // self-loops in quotient graphs don't constrain the order
            if d != g.source(e) {
                indeg[d as usize] += 1;
            }
        }
    }
    let mut queue: VecDeque<u32> =
        (0..n as u32).filter(|&v| indeg[v as usize] == 0).collect();
    let mut order = Vec::with_capacity(n);
    let mut out_edges: Vec<u32> = Vec::new();
    while let Some(u) = queue.pop_front() {
        order.push(u);
        out_edges.clear();
        out_edges.extend_from_slice(g.outbound(u));
        out_edges.sort_by(|&a, &b| {
            crate::util::cmp_non_nan(&g.weight(b), &g.weight(a)).then(a.cmp(&b))
        });
        for &e in &out_edges {
            for &d in g.dsts(e) {
                if d != u {
                    indeg[d as usize] -= 1;
                    if indeg[d as usize] == 0 {
                        queue.push_back(d);
                    }
                }
            }
        }
    }
    (order.len() == n).then_some(order)
}

/// Order for an arbitrary h-graph: Kahn when acyclic, else greedy (the
/// dispatch rule used throughout §IV).
pub fn auto_order(g: &Hypergraph) -> Vec<u32> {
    auto_order_threads(g, 1)
}

/// [`auto_order`] with a worker budget for the greedy branch (Kahn is
/// O(e·d) and stays serial). Performance knob only — thread-invariant.
// snn-lint: allow(parallel-serial-pairing) — dispatcher, not an algorithm: it picks
// kahn_order (serial by design) or greedy_order_threads, whose serial twin carries the
// equality tests (prop_greedy_order_edge_cases_serial_equals_parallel)
pub fn auto_order_threads(g: &Hypergraph, threads: usize) -> Vec<u32> {
    kahn_order(g).unwrap_or_else(|| greedy_order_threads(g, threads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::util::rng::Pcg64;

    fn is_permutation(order: &[u32], n: usize) -> bool {
        let mut seen = vec![false; n];
        for &x in order {
            if seen[x as usize] {
                return false;
            }
            seen[x as usize] = true;
        }
        order.len() == n
    }

    #[test]
    fn greedy_order_chain_follows_edges() {
        let mut b = HypergraphBuilder::new(5);
        for i in 0..4u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        let g = b.build();
        let order = greedy_order(&g);
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn greedy_order_prefers_heavier_connection() {
        // 0 feeds 1 (w=1) and 2 (w=10) with separate h-edges? single axon:
        // use two sources: 0 -> {1} w=1 ; 3 -> {2} w=10; both roots.
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(3, vec![2], 10.0);
        let g = b.build();
        let order = greedy_order(&g);
        assert!(is_permutation(&order, 4));
        // after roots 0 and 3 are placed, node 2 (prio 10) precedes node 1
        let pos = |x: u32| order.iter().position(|&v| v == x).unwrap();
        assert!(pos(2) < pos(1));
    }

    #[test]
    fn greedy_order_handles_cycles() {
        let mut b = HypergraphBuilder::new(3);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(1, vec![2], 1.0);
        b.add_edge(2, vec![0], 1.0);
        let g = b.build();
        let order = greedy_order(&g);
        assert!(is_permutation(&order, 3));
    }

    #[test]
    fn greedy_order_random_graphs_complete() {
        let mut rng = Pcg64::seeded(17);
        for trial in 0..5 {
            let n = 300;
            let mut b = HypergraphBuilder::new(n);
            for s in 0..n as u32 {
                if rng.bernoulli(0.8) {
                    let k = rng.range(1, 12);
                    let dsts: Vec<u32> = (0..k).map(|_| rng.below(n) as u32).collect();
                    b.add_edge(s, dsts, rng.next_f32() + 1e-3);
                }
            }
            let g = b.build();
            let order = greedy_order(&g);
            assert!(is_permutation(&order, n), "trial {trial}");
        }
    }

    #[test]
    fn addressable_heap_matches_lazy_reference_on_random_graphs() {
        // zero-weight h-edges included: the reference skips their
        // non-positive entries at pop, the addressable heap never
        // inserts them — both must land on the same order
        let mut rng = Pcg64::seeded(0xA11);
        for trial in 0..12 {
            let n = rng.range(30, 400);
            let mut b = HypergraphBuilder::new(n);
            for s in 0..n as u32 {
                if rng.bernoulli(0.85) {
                    let k = rng.range(1, 10);
                    let dsts: Vec<u32> =
                        (0..k).map(|_| rng.below(n) as u32).filter(|&d| d != s).collect();
                    if dsts.is_empty() {
                        continue;
                    }
                    let w = if rng.bernoulli(0.15) { 0.0 } else { rng.next_f32() + 1e-3 };
                    b.add_edge(s, dsts, w);
                }
            }
            let g = b.build();
            let reference = greedy_order_serial(&g);
            assert_eq!(greedy_order(&g), reference, "trial {trial}");
        }
    }

    /// A quotient-style hub graph whose first placements fan out past
    /// [`PAR_MIN_FANOUT`], so multi-thread runs genuinely dispatch.
    fn hub_graph(n: usize, seed: u64) -> Hypergraph {
        let mut rng = Pcg64::seeded(seed);
        let mut b = HypergraphBuilder::new(n);
        // node 0: the only zero-inbound node; its axon reaches everyone
        b.add_edge(0, (1..n as u32).collect(), 1.5);
        for s in 1..n as u32 {
            let k = rng.range(1, 8);
            let dsts: Vec<u32> = (0..k)
                .map(|_| 1 + rng.below(n - 1) as u32)
                .filter(|&d| d != s)
                .collect();
            if !dsts.is_empty() {
                b.add_edge(s, dsts, rng.next_f32() + 1e-3);
            }
        }
        b.build()
    }

    #[test]
    fn parallel_propagation_matches_serial_with_dispatch() {
        let n = PAR_MIN_FANOUT * 3;
        let g = hub_graph(n, 0x0DD);
        let reference = greedy_order_serial(&g);
        let (one, st1) = greedy_order_with_stats(&g, 1);
        assert_eq!(one, reference);
        assert_eq!(st1.par_steps, 0);
        for threads in [2, 4, 8] {
            let (order, stats) = greedy_order_with_stats(&g, threads);
            assert_eq!(order, reference, "threads={threads}");
            assert!(stats.par_steps > 0, "threads={threads} never dispatched");
            assert!(stats.peak_scratch_bytes > 0);
        }
    }

    #[test]
    fn all_min_inbound_cycle_orders_by_id() {
        // a ring: every node has exactly one inbound axon, so all are
        // +inf-seeded and pop purely by the id tie-break — the fallback
        // cursor is never consulted and the bump guard never fires
        let n = 64;
        let mut b = HypergraphBuilder::new(n);
        for i in 0..n as u32 {
            b.add_edge(i, vec![(i + 1) % n as u32], 1.0);
        }
        let g = b.build();
        let want: Vec<u32> = (0..n as u32).collect();
        assert_eq!(greedy_order_serial(&g), want);
        for threads in [1, 4] {
            assert_eq!(greedy_order_threads(&g, threads), want, "threads={threads}");
        }
    }

    #[test]
    fn zero_weight_edges_never_promote() {
        // a zero-weight axon must not pull its listeners ahead of the
        // fallback order (their priority stays non-positive)
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![2, 3], 0.0);
        b.add_edge(1, vec![3], 2.0);
        let g = b.build();
        let reference = greedy_order_serial(&g);
        for threads in [1, 2] {
            assert_eq!(greedy_order_threads(&g, threads), reference);
        }
        // node 3 (promoted by the weighted axon) precedes node 2 (not)
        let pos = |x: u32| reference.iter().position(|&v| v == x).unwrap();
        assert!(pos(3) < pos(2), "order={reference:?}");
    }

    #[test]
    fn kahn_respects_topology() {
        let mut b = HypergraphBuilder::new(6);
        b.add_edge(0, vec![2, 3], 1.0);
        b.add_edge(1, vec![3], 5.0);
        b.add_edge(2, vec![4], 1.0);
        b.add_edge(3, vec![4, 5], 1.0);
        let g = b.build();
        let order = kahn_order(&g).unwrap();
        assert!(is_permutation(&order, 6));
        let pos = |x: u32| order.iter().position(|&v| v == x).unwrap();
        assert!(pos(0) < pos(2) && pos(0) < pos(3));
        assert!(pos(3) < pos(4) && pos(3) < pos(5));
    }

    #[test]
    fn kahn_rejects_cycles_and_tolerates_self_loops() {
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(1, vec![0], 1.0);
        assert!(kahn_order(&b.build()).is_none());

        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![0, 1], 1.0); // quotient-style self-loop
        let g = b.build();
        assert_eq!(kahn_order(&g), Some(vec![0, 1]));
    }

    #[test]
    fn auto_order_dispatch() {
        // acyclic -> kahn result
        let mut b = HypergraphBuilder::new(3);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(1, vec![2], 1.0);
        let g = b.build();
        assert_eq!(auto_order(&g), kahn_order(&g).unwrap());
        // cyclic -> still a permutation
        let mut b = HypergraphBuilder::new(3);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(1, vec![0, 2], 1.0);
        b.add_edge(2, vec![0], 1.0);
        let g = b.build();
        assert!(is_permutation(&auto_order(&g), 3));
        // the threaded variant takes the same branches
        assert_eq!(auto_order_threads(&g, 4), auto_order(&g));
    }
}
