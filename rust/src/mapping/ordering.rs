//! Node orderings (paper Alg. 2 + the queue-based Kahn variant).
//!
//! Sequential partitioning, the Hilbert placement and minimum-distance
//! placement all consume a linear order of nodes. For layered SNNs the
//! natural (layer-major) order already has locality; for arbitrary
//! h-graphs the paper introduces a greedy frequency-accumulation order
//! (Alg. 2) and, for acyclic quotient graphs, weighted Kahn topological
//! ordering.

use crate::hypergraph::Hypergraph;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

/// Max-heap entry with lazy invalidation.
#[derive(PartialEq)]
struct Entry {
    prio: f64,
    node: u32,
}

impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // max-heap by priority; tie-break by node id for determinism
        self.prio
            .partial_cmp(&other.prio)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
    }
}

/// Greedy nodes ordering (Alg. 2).
///
/// An addressable priority queue accumulates, per node, the total spike
/// frequency of connections from already-ordered nodes; the next node is
/// the highest-priority unordered one, falling back to minimum-inbound
/// nodes when the queue is exhausted. Produces an order with high local
/// synaptic reuse in O(e·d·log n).
pub fn greedy_order(g: &Hypergraph) -> Vec<u32> {
    let n = g.num_nodes();
    let mut order = Vec::with_capacity(n);
    let mut prio = vec![0.0f64; n];
    let mut placed = vec![false; n];
    let mut heap: BinaryHeap<Entry> = BinaryHeap::new();

    // Nodes sorted by inbound-set size: the fallback source (line 12) and
    // the +inf seeding of minimum-inbound nodes (lines 6-7).
    let mut by_inbound: Vec<u32> = (0..n as u32).collect();
    by_inbound.sort_by_key(|&m| (g.inbound(m).len(), m));
    let min_inbound = by_inbound
        .first()
        .map(|&m| g.inbound(m).len())
        .unwrap_or(0);
    for &m in by_inbound.iter().take_while(|&&m| g.inbound(m).len() == min_inbound) {
        prio[m as usize] = f64::INFINITY;
        heap.push(Entry { prio: f64::INFINITY, node: m });
    }
    let mut fallback_cursor = 0usize;

    while order.len() < n {
        // pop from queue (skipping stale/placed entries)…
        let next = loop {
            match heap.pop() {
                Some(Entry { prio: p, node }) => {
                    if placed[node as usize] || prio[node as usize] != p || p <= 0.0 {
                        continue;
                    }
                    break Some(node);
                }
                None => break None,
            }
        };
        // …or fall back to the next min-inbound unplaced node.
        let node = next.unwrap_or_else(|| {
            while placed[by_inbound[fallback_cursor] as usize] {
                fallback_cursor += 1;
            }
            by_inbound[fallback_cursor]
        });

        placed[node as usize] = true;
        order.push(node);
        // propagate frequency to destinations (lines 14-15)
        for &e in g.outbound(node) {
            let w = g.weight(e) as f64;
            for &m in g.dsts(e) {
                if !placed[m as usize] {
                    let p = &mut prio[m as usize];
                    if p.is_finite() {
                        *p += w;
                        heap.push(Entry { prio: *p, node: m });
                    }
                }
            }
        }
    }
    order
}

/// Weighted queue-based Kahn topological order (§IV-B1): roots first; each
/// node's outgoing h-edges are processed in decreasing weight order before
/// newly freed nodes enter the FIFO. Returns None on cyclic graphs.
pub fn kahn_order(g: &Hypergraph) -> Option<Vec<u32>> {
    let n = g.num_nodes();
    let mut indeg = vec![0usize; n];
    for e in g.edge_ids() {
        for &d in g.dsts(e) {
            // self-loops in quotient graphs don't constrain the order
            if d != g.source(e) {
                indeg[d as usize] += 1;
            }
        }
    }
    let mut queue: VecDeque<u32> =
        (0..n as u32).filter(|&v| indeg[v as usize] == 0).collect();
    let mut order = Vec::with_capacity(n);
    let mut out_edges: Vec<u32> = Vec::new();
    while let Some(u) = queue.pop_front() {
        order.push(u);
        out_edges.clear();
        out_edges.extend_from_slice(g.outbound(u));
        out_edges.sort_by(|&a, &b| {
            g.weight(b)
                .partial_cmp(&g.weight(a))
                .unwrap_or(Ordering::Equal)
                .then(a.cmp(&b))
        });
        for &e in &out_edges {
            for &d in g.dsts(e) {
                if d != u {
                    indeg[d as usize] -= 1;
                    if indeg[d as usize] == 0 {
                        queue.push_back(d);
                    }
                }
            }
        }
    }
    (order.len() == n).then_some(order)
}

/// Order for an arbitrary h-graph: Kahn when acyclic, else greedy (the
/// dispatch rule used throughout §IV).
pub fn auto_order(g: &Hypergraph) -> Vec<u32> {
    kahn_order(g).unwrap_or_else(|| greedy_order(g))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::util::rng::Pcg64;

    fn is_permutation(order: &[u32], n: usize) -> bool {
        let mut seen = vec![false; n];
        for &x in order {
            if seen[x as usize] {
                return false;
            }
            seen[x as usize] = true;
        }
        order.len() == n
    }

    #[test]
    fn greedy_order_chain_follows_edges() {
        let mut b = HypergraphBuilder::new(5);
        for i in 0..4u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        let g = b.build();
        let order = greedy_order(&g);
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn greedy_order_prefers_heavier_connection() {
        // 0 feeds 1 (w=1) and 2 (w=10) with separate h-edges? single axon:
        // use two sources: 0 -> {1} w=1 ; 3 -> {2} w=10; both roots.
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(3, vec![2], 10.0);
        let g = b.build();
        let order = greedy_order(&g);
        assert!(is_permutation(&order, 4));
        // after roots 0 and 3 are placed, node 2 (prio 10) precedes node 1
        let pos = |x: u32| order.iter().position(|&v| v == x).unwrap();
        assert!(pos(2) < pos(1));
    }

    #[test]
    fn greedy_order_handles_cycles() {
        let mut b = HypergraphBuilder::new(3);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(1, vec![2], 1.0);
        b.add_edge(2, vec![0], 1.0);
        let g = b.build();
        let order = greedy_order(&g);
        assert!(is_permutation(&order, 3));
    }

    #[test]
    fn greedy_order_random_graphs_complete() {
        let mut rng = Pcg64::seeded(17);
        for trial in 0..5 {
            let n = 300;
            let mut b = HypergraphBuilder::new(n);
            for s in 0..n as u32 {
                if rng.bernoulli(0.8) {
                    let k = rng.range(1, 12);
                    let dsts: Vec<u32> = (0..k).map(|_| rng.below(n) as u32).collect();
                    b.add_edge(s, dsts, rng.next_f32() + 1e-3);
                }
            }
            let g = b.build();
            let order = greedy_order(&g);
            assert!(is_permutation(&order, n), "trial {trial}");
        }
    }

    #[test]
    fn kahn_respects_topology() {
        let mut b = HypergraphBuilder::new(6);
        b.add_edge(0, vec![2, 3], 1.0);
        b.add_edge(1, vec![3], 5.0);
        b.add_edge(2, vec![4], 1.0);
        b.add_edge(3, vec![4, 5], 1.0);
        let g = b.build();
        let order = kahn_order(&g).unwrap();
        assert!(is_permutation(&order, 6));
        let pos = |x: u32| order.iter().position(|&v| v == x).unwrap();
        assert!(pos(0) < pos(2) && pos(0) < pos(3));
        assert!(pos(3) < pos(4) && pos(3) < pos(5));
    }

    #[test]
    fn kahn_rejects_cycles_and_tolerates_self_loops() {
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(1, vec![0], 1.0);
        assert!(kahn_order(&b.build()).is_none());

        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![0, 1], 1.0); // quotient-style self-loop
        let g = b.build();
        assert_eq!(kahn_order(&g), Some(vec![0, 1]));
    }

    #[test]
    fn auto_order_dispatch() {
        // acyclic -> kahn result
        let mut b = HypergraphBuilder::new(3);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(1, vec![2], 1.0);
        let g = b.build();
        assert_eq!(auto_order(&g), kahn_order(&g).unwrap());
        // cyclic -> still a permutation
        let mut b = HypergraphBuilder::new(3);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(1, vec![0, 2], 1.0);
        b.add_edge(2, vec![0], 1.0);
        let g = b.build();
        assert!(is_permutation(&auto_order(&g), 3));
    }
}
