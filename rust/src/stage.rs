//! The pluggable stage API: object-safe traits for the three pipeline
//! stages (partition → place → refine), the shared per-run context, and
//! the untyped parameter maps stages are constructed from.
//!
//! Every algorithm in the mapper is a value implementing one of these
//! traits; [`crate::coordinator::registry::StageRegistry`] maps string
//! names to constructors (all nine built-ins pre-registered, downstream
//! algorithms welcome), and
//! [`crate::coordinator::spec::PipelineSpec`] is the plain-data,
//! JSON-round-trippable description of a full run. The old
//! `PartitionerKind`/`PlacerKind`/`RefinerKind` enums survive as thin
//! shims over the registry.
//!
//! Contract (DESIGN.md §9):
//! * stages are deterministic functions of their inputs plus
//!   [`StageCtx::seed`] — thread counts and the optional PJRT runtime
//!   must never change results beyond documented engine tolerances;
//! * a [`Partitioner`] must return an assignment that passes
//!   [`crate::mapping::validate`]; a [`Placer`] must return an injective
//!   in-bounds placement of the quotient graph's nodes;
//! * stages hold their own typed knobs (parsed once at construction from
//!   [`StageParams`]) and borrow everything run-scoped from [`StageCtx`].

use crate::hw::NmhConfig;
use crate::hypergraph::quotient::Partitioning;
use crate::hypergraph::Hypergraph;
use crate::mapping::MapError;
use crate::placement::force::RefineStats;
use crate::placement::Placement;
use crate::runtime::PjrtRuntime;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Run-scoped context shared by every stage invocation: the pipeline
/// seed, the worker-pool budget, the network's layer structure (when
/// known) and the optional PJRT runtime for AOT-compiled numeric kernels.
pub struct StageCtx<'a> {
    /// The pipeline-level seed; every randomized stage must derive its
    /// randomness from this (uniform `--seed` behavior).
    pub seed: u64,
    /// Worker-pool width available to the stage (1 = serial). Must be a
    /// performance knob only, never a semantics knob (DESIGN.md §6) —
    /// the hierarchical partitioner's two-phase rounds and the spectral
    /// placer's parallel matvec (§10), the overlap partitioner's
    /// frontier scoring and the force refiner's candidate scan (§11),
    /// the quotient push-forward's parallel scan and the greedy
    /// ordering's fan-out propagation behind the sequential partitioner
    /// and the Hilbert/minimum-distance placers (§12), and the NoC
    /// simulator's two-phase step accumulation behind
    /// [`crate::sim::simulate_with_threads`] and the batched replay
    /// (§16) all honor this bit-for-bit.
    pub threads: usize,
    /// Layer ranges of layered (ANN-derived) networks, `None` for cyclic
    /// nets; order-sensitive partitioners may exploit this.
    pub layer_ranges: Option<&'a [(u32, u32)]>,
    /// PJRT runtime for the AOT JAX/Pallas artifacts; stages fall back to
    /// native engines when absent.
    pub runtime: Option<&'a PjrtRuntime>,
    /// Crash-safe checkpoint/resume policy (DESIGN.md §13). Like
    /// `threads`, run-environment only: stages that honor it (the
    /// hierarchical partitioner) must produce bit-identical results with
    /// or without it, resumed or not.
    pub checkpoint: Option<crate::runtime::checkpoint::CheckpointPolicy>,
    /// Hardware fault mask (DESIGN.md §15): dead cores / links and
    /// capacity derating the run must respect. Placers skip dead cores
    /// (the shared [`crate::placement::gridfind::GridFinder`] masked
    /// constructor and occupancy pre-marking); partitioners see the
    /// capacity effect through the derated hardware config the pipeline
    /// hands them instead. `None` — and an all-healthy mask — must be
    /// bit-identical to the pre-fault behavior.
    pub faults: Option<&'a crate::hw::faults::FaultMask>,
}

impl<'a> StageCtx<'a> {
    /// A minimal context: the given seed, full thread budget, no layer
    /// information, no checkpointing and the native numeric engines.
    pub fn new(seed: u64) -> StageCtx<'a> {
        StageCtx {
            seed,
            threads: crate::util::par::max_threads(),
            layer_ranges: None,
            runtime: None,
            checkpoint: None,
            faults: None,
        }
    }
}

/// A partitioning algorithm: ρ — neurons → virtual cores (paper §IV-A).
pub trait Partitioner: Send + Sync {
    /// Stable display/registry name.
    fn name(&self) -> &str;
    /// Produce a constraint-feasible partitioning of `g` under `hw`.
    fn partition(
        &self,
        g: &Hypergraph,
        hw: &NmhConfig,
        ctx: &StageCtx,
    ) -> Result<Partitioning, MapError>;
}

/// An initial/direct placement algorithm: γ — virtual cores → lattice
/// cores (paper §IV-B/C2). `gp` is the quotient h-graph.
pub trait Placer: Send + Sync {
    /// Stable display/registry name.
    fn name(&self) -> &str;
    /// Place every node of `gp` on a distinct core of `hw`.
    fn place(
        &self,
        gp: &Hypergraph,
        hw: &NmhConfig,
        ctx: &StageCtx,
    ) -> Result<Placement, MapError>;
    /// Direct placers (e.g. minimum-distance) already optimize the final
    /// objective and are skipped by the refinement stage, matching the
    /// paper's Table IV pipeline combinations.
    fn is_direct(&self) -> bool {
        false
    }
}

/// A placement refinement algorithm (paper §IV-C1).
pub trait Refiner: Send + Sync {
    /// Stable display/registry name.
    fn name(&self) -> &str;
    /// Refine `placement` in place; returns per-run statistics when the
    /// refiner does any work (`None` = identity).
    fn refine(
        &self,
        gp: &Hypergraph,
        hw: &NmhConfig,
        placement: &mut Placement,
        ctx: &StageCtx,
    ) -> Result<Option<RefineStats>, MapError>;
}

/// The identity refiner (registry name "none").
#[derive(Clone, Copy, Debug, Default)]
pub struct NoRefiner;

// snn-lint: allow(threads-wiring) — the identity refiner does no work; there is nothing
// for a worker budget to parallelize
impl Refiner for NoRefiner {
    fn name(&self) -> &str {
        "none"
    }

    fn refine(
        &self,
        _gp: &Hypergraph,
        _hw: &NmhConfig,
        _placement: &mut Placement,
        _ctx: &StageCtx,
    ) -> Result<Option<RefineStats>, MapError> {
        Ok(None)
    }
}

/// Untyped per-stage parameters: a string → JSON map parsed from a
/// [`crate::coordinator::spec::PipelineSpec`] document and consumed by a
/// stage constructor, which converts it into the stage's typed knobs
/// (`HierParams`, `ForceParams`, the streaming lookahead, ...).
///
/// Getters are strict: a present-but-mistyped value is an error, a
/// missing key is `Ok(None)` so constructors can apply defaults.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StageParams(BTreeMap<String, Json>);

impl StageParams {
    /// No parameters (every built-in accepts this and uses defaults).
    pub fn empty() -> StageParams {
        StageParams(BTreeMap::new())
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Builder-style insertion.
    pub fn set(mut self, key: &str, value: Json) -> StageParams {
        self.0.insert(key.to_string(), value);
        self
    }

    /// Parse from a JSON value: an object, or null/absent for empty.
    pub fn from_json(doc: &Json) -> Result<StageParams, String> {
        match doc {
            Json::Null => Ok(StageParams::empty()),
            Json::Obj(m) => Ok(StageParams(m.clone())),
            other => Err(format!("stage params must be an object, got {other:?}")),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.0.clone())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.0.get(key)
    }

    /// Reject any key outside `allowed` — typos in a spec fail loudly
    /// instead of silently running with defaults.
    pub fn check_known(&self, allowed: &[&str]) -> Result<(), String> {
        for key in self.0.keys() {
            if !allowed.contains(&key.as_str()) {
                return Err(format!(
                    "unknown parameter '{key}' (accepted: {})",
                    if allowed.is_empty() { "none".to_string() } else { allowed.join(", ") }
                ));
            }
        }
        Ok(())
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        match self.0.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_f64()
                .map(Some)
                .ok_or_else(|| format!("parameter '{key}' must be a number, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>, String> {
        match self.get_f64(key)? {
            None => Ok(None),
            Some(x) if x >= 0.0 && x.fract() == 0.0 => Ok(Some(x as u64)),
            Some(x) => Err(format!("parameter '{key}' must be a non-negative integer, got {x}")),
        }
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        Ok(self.get_u64(key)?.map(|x| x as usize))
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>, String> {
        match self.0.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_bool()
                .map(Some)
                .ok_or_else(|| format!("parameter '{key}' must be a boolean, got {v:?}")),
        }
    }

    pub fn get_str(&self, key: &str) -> Result<Option<&str>, String> {
        match self.0.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_str()
                .map(Some)
                .ok_or_else(|| format!("parameter '{key}' must be a string, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_typed_getters() {
        let p = StageParams::empty()
            .set("window", Json::Num(64.0))
            .set("fast", Json::Bool(true))
            .set("order", Json::Str("greedy".into()));
        assert_eq!(p.get_usize("window").unwrap(), Some(64));
        assert_eq!(p.get_bool("fast").unwrap(), Some(true));
        assert_eq!(p.get_str("order").unwrap(), Some("greedy"));
        assert_eq!(p.get_f64("missing").unwrap(), None);
        assert!(p.get_bool("window").is_err());
        assert!(p.get_u64("order").is_err());
    }

    #[test]
    fn params_reject_fractional_and_negative_ints() {
        let p = StageParams::empty().set("n", Json::Num(1.5));
        assert!(p.get_u64("n").is_err());
        let p = StageParams::empty().set("n", Json::Num(-3.0));
        assert!(p.get_u64("n").is_err());
    }

    #[test]
    fn params_check_known() {
        let p = StageParams::empty().set("window", Json::Num(8.0));
        assert!(p.check_known(&["window", "seed"]).is_ok());
        assert!(p.check_known(&["seed"]).is_err());
        assert!(StageParams::empty().check_known(&[]).is_ok());
    }

    #[test]
    fn params_json_roundtrip() {
        let p = StageParams::empty()
            .set("a", Json::Num(2.0))
            .set("b", Json::Str("x".into()));
        let back = StageParams::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        assert_eq!(StageParams::from_json(&Json::Null).unwrap(), StageParams::empty());
        assert!(StageParams::from_json(&Json::Num(1.0)).is_err());
    }
}
