//! Structural statistics of h-graphs (paper Fig. 8): average path length
//! and mean h-edge overlap — the small-world evidence motivating synaptic
//! reuse — plus degree/cardinality summaries used by Table III.

use super::{EdgeId, Hypergraph, NodeId};
use crate::util::rng::Pcg64;
use std::collections::VecDeque;

/// Summary row matching Table III.
#[derive(Debug, Clone)]
pub struct GraphSummary {
    pub nodes: usize,
    pub edges: usize,
    pub connections: usize,
    pub mean_cardinality: f64,
    pub max_cardinality: usize,
    pub max_inbound: usize,
}

pub fn summarize(g: &Hypergraph) -> GraphSummary {
    GraphSummary {
        nodes: g.num_nodes(),
        edges: g.num_edges(),
        connections: g.num_connections(),
        mean_cardinality: g.mean_cardinality(),
        max_cardinality: g.edge_ids().map(|e| g.cardinality(e)).max().unwrap_or(0),
        max_inbound: g.node_ids().map(|n| g.inbound(n).len()).max().unwrap_or(0),
    }
}

/// Average shortest-path length estimated by BFS from `samples` random
/// source nodes over the *undirected star expansion* (spikes travel
/// source→destination, but path length in Fig. 8 measures topological
/// proximity, so we symmetrize). Unreachable pairs are skipped.
pub fn avg_path_length(g: &Hypergraph, samples: usize, seed: u64) -> f64 {
    if g.num_nodes() == 0 {
        return 0.0;
    }
    let mut rng = Pcg64::new(seed, 101);
    let mut total = 0u64;
    let mut count = 0u64;
    let mut dist = vec![u32::MAX; g.num_nodes()];
    let mut queue = VecDeque::new();

    for _ in 0..samples {
        let start = rng.below(g.num_nodes()) as NodeId;
        dist.iter_mut().for_each(|d| *d = u32::MAX);
        dist[start as usize] = 0;
        queue.clear();
        queue.push_back(start);
        while let Some(u) = queue.pop_front() {
            let du = dist[u as usize];
            // forward: u's axon(s) reach their destinations
            for &e in g.outbound(u) {
                for &v in g.dsts(e) {
                    if dist[v as usize] == u32::MAX {
                        dist[v as usize] = du + 1;
                        queue.push_back(v);
                    }
                }
            }
            // backward: sources of u's inbound h-edges
            for &e in g.inbound(u) {
                let v = g.source(e);
                if dist[v as usize] == u32::MAX {
                    dist[v as usize] = du + 1;
                    queue.push_back(v);
                }
            }
        }
        for (v, &d) in dist.iter().enumerate() {
            if d != u32::MAX && v != start as usize {
                total += d as u64;
                count += 1;
            }
        }
    }
    if count == 0 {
        0.0
    } else {
        total as f64 / count as f64
    }
}

/// Mean h-edge overlap (Fig. 8 companion measure): for sampled h-edges,
/// the mean Jaccard similarity of destination sets with a co-incident
/// h-edge (one sharing at least one destination node). This captures how
/// often "any pair of h-edges tends to overlap", i.e. the raw material for
/// synaptic reuse.
pub fn mean_hedge_overlap(g: &Hypergraph, samples: usize, seed: u64) -> f64 {
    if g.num_edges() < 2 {
        return 0.0;
    }
    let mut rng = Pcg64::new(seed, 103);
    let mut total = 0.0;
    let mut count = 0usize;
    for _ in 0..samples {
        let e1 = rng.below(g.num_edges()) as EdgeId;
        let d1 = g.dsts(e1);
        if d1.is_empty() {
            continue;
        }
        // pick a co-incident edge through a random shared destination
        let pivot = d1[rng.below(d1.len())];
        let inb = g.inbound(pivot);
        if inb.len() < 2 {
            continue;
        }
        let e2 = loop {
            let c = inb[rng.below(inb.len())];
            if c != e1 {
                break c;
            }
        };
        total += jaccard_sorted(d1, g.dsts(e2));
        count += 1;
    }
    if count == 0 {
        0.0
    } else {
        total / count as f64
    }
}

/// Jaccard similarity of two sorted unique slices.
pub fn jaccard_sorted(a: &[NodeId], b: &[NodeId]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 0.0;
    }
    let mut i = 0;
    let mut j = 0;
    let mut inter = 0usize;
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    inter as f64 / (a.len() + b.len() - inter) as f64
}

/// Size of the intersection of two sorted unique slices.
pub fn intersection_size(a: &[NodeId], b: &[NodeId]) -> usize {
    let mut i = 0;
    let mut j = 0;
    let mut inter = 0usize;
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    inter
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;

    #[test]
    fn jaccard_basics() {
        assert!((jaccard_sorted(&[1, 2, 3], &[2, 3, 4]) - 0.5).abs() < 1e-12);
        assert_eq!(jaccard_sorted(&[1], &[2]), 0.0);
        assert_eq!(jaccard_sorted(&[], &[]), 0.0);
        assert!((jaccard_sorted(&[5, 9], &[5, 9]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn intersection_counts() {
        assert_eq!(intersection_size(&[1, 3, 5], &[2, 3, 5, 7]), 2);
        assert_eq!(intersection_size(&[], &[1]), 0);
    }

    #[test]
    fn path_length_on_chain() {
        // chain of 5: exact mean shortest path over all ordered pairs = 2.0
        let mut b = HypergraphBuilder::new(5);
        for i in 0..4u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        let g = b.build();
        // sample every node many times -> converges to exact value
        let apl = avg_path_length(&g, 200, 7);
        assert!((apl - 2.0).abs() < 0.15, "apl={apl}");
    }

    #[test]
    fn path_length_on_clique_is_one() {
        let mut b = HypergraphBuilder::new(6);
        for i in 0..6u32 {
            let dsts: Vec<u32> = (0..6).filter(|&j| j != i).collect();
            b.add_edge(i, dsts, 1.0);
        }
        let g = b.build();
        let apl = avg_path_length(&g, 50, 1);
        assert!((apl - 1.0).abs() < 1e-9, "apl={apl}");
    }

    #[test]
    fn overlap_full_on_identical_axons() {
        // all sources hit the same destination set -> overlap 1
        let mut b = HypergraphBuilder::new(8);
        for i in 0..4u32 {
            b.add_edge(i, vec![4, 5, 6, 7], 1.0);
        }
        let g = b.build();
        let ov = mean_hedge_overlap(&g, 200, 3);
        assert!((ov - 1.0).abs() < 1e-9, "ov={ov}");
    }

    #[test]
    fn overlap_zero_when_disjoint() {
        let mut b = HypergraphBuilder::new(9);
        b.add_edge(0, vec![3, 4], 1.0);
        b.add_edge(1, vec![5, 6], 1.0);
        b.add_edge(2, vec![7, 8], 1.0);
        let g = b.build();
        // no two h-edges share a destination -> sampler never finds a pair
        assert_eq!(mean_hedge_overlap(&g, 100, 5), 0.0);
    }

    #[test]
    fn summary_row() {
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1, 2, 3], 1.0);
        b.add_edge(1, vec![2], 1.0);
        let g = b.build();
        let s = summarize(&g);
        assert_eq!(s.nodes, 4);
        assert_eq!(s.edges, 2);
        assert_eq!(s.connections, 4);
        assert_eq!(s.max_cardinality, 3);
        assert_eq!(s.max_inbound, 2); // node 2
    }
}
