//! Partition push-forward: `G_P` from `G_S` and ρ (paper §III, Eq. 3).
//!
//! Every h-edge `(s, D)` maps to `(ρ(s), {ρ(d) | d ∈ D})`; h-edges with
//! identical source and destination set are then merged by summing their
//! weights ("we may subsequently merge h-edges with identical source and
//! destinations by adding together their weights").

use super::{EdgeId, Hypergraph, HypergraphBuilder, NodeId};
use std::collections::HashMap;

/// A partitioning ρ: N → P plus its cardinality.
#[derive(Clone, Debug)]
pub struct Partitioning {
    /// `assign[n]` = partition of node n.
    pub assign: Vec<u32>,
    /// Number of partitions |P|.
    pub num_parts: usize,
}

impl Partitioning {
    pub fn new(assign: Vec<u32>, num_parts: usize) -> Self {
        debug_assert!(assign.iter().all(|&p| (p as usize) < num_parts));
        Partitioning { assign, num_parts }
    }

    /// Identity partitioning (each node its own partition) — useful for
    /// treating an unpartitioned graph uniformly in the metric engine.
    pub fn identity(n: usize) -> Self {
        Partitioning {
            assign: (0..n as u32).collect(),
            num_parts: n,
        }
    }

    /// Partition sizes |ρ^{-1}(p)|.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.num_parts];
        for &p in &self.assign {
            s[p as usize] += 1;
        }
        s
    }

    /// Renumber partitions to drop empty ids (keeps relative order).
    pub fn compacted(mut self) -> Self {
        let sizes = self.sizes();
        let mut remap = vec![u32::MAX; self.num_parts];
        let mut next = 0u32;
        for (p, &sz) in sizes.iter().enumerate() {
            if sz > 0 {
                remap[p] = next;
                next += 1;
            }
        }
        for p in self.assign.iter_mut() {
            *p = remap[*p as usize];
        }
        self.num_parts = next as usize;
        self
    }
}

/// Result of the push-forward: the quotient h-graph and, for bookkeeping,
/// the mapping from quotient h-edge to the original h-edges it merged.
pub struct Quotient {
    pub graph: Hypergraph,
    /// For each quotient h-edge, the original edge ids folded into it.
    pub merged_from: Vec<Vec<EdgeId>>,
}

/// FNV-1a step over one little-endian u32.
#[inline]
fn fnv1a_u32(mut h: u64, x: u32) -> u64 {
    for b in x.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Reusable arenas + scratch for repeated push-forward sweeps.
///
/// The multilevel partitioner runs one push-forward per coarsening round;
/// with a fresh set of vectors per round the allocator dominated peak
/// memory. A single `QuotientScratch` threaded through the rounds keeps
/// every intermediate buffer (dedup stamps, the unique-edge arena, the
/// hash chain) at its high-water capacity and recycles it.
#[derive(Default)]
pub struct QuotientScratch {
    // Unique quotient edges: source, arena-backed dst span, weight.
    srcs: Vec<u32>,
    arena: Vec<NodeId>,
    span_off: Vec<usize>,
    weights: Vec<f32>,
    /// Per-unique-edge accumulated fine multiplicity (see
    /// [`push_forward_pooled`]); empty when no `fine_mult` was supplied.
    mult: Vec<u32>,
    // hash -> chain head; `chain[i]` links unique edges sharing a hash.
    index: HashMap<u64, u32>,
    chain: Vec<u32>,
    // stamp[p] == e marks partition p seen for edge e (reset per sweep:
    // edge ids restart at 0 every round, so stale stamps would alias).
    stamp: Vec<u32>,
    dset: Vec<NodeId>,
}

impl QuotientScratch {
    pub fn new() -> Self {
        QuotientScratch::default()
    }

    fn reset(&mut self, num_parts: usize, ne: usize) {
        self.srcs.clear();
        self.arena.clear();
        self.span_off.clear();
        self.span_off.push(0);
        self.weights.clear();
        self.mult.clear();
        self.index.clear();
        self.index.reserve(ne); // no-op once the retained capacity suffices
        self.chain.clear();
        self.stamp.clear();
        self.stamp.resize(num_parts, u32::MAX);
        self.dset.clear();
    }
}

/// The shared sweep behind both push-forward entry points. Deduplicates
/// per-edge destination partitions through `scratch.stamp`, merges
/// identical `(source, D)` quotient edges via the flat arena + hash
/// chain, and — fused into the same pass — accumulates `fine_mult` (the
/// original-axon multiplicity each fine edge represents) into
/// `scratch.mult` and/or appends to per-unique-edge `merged` lists.
fn sweep(
    g: &Hypergraph,
    rho: &Partitioning,
    fine_mult: Option<&[u32]>,
    scratch: &mut QuotientScratch,
    mut merged: Option<&mut Vec<Vec<EdgeId>>>,
) {
    assert_eq!(g.num_nodes(), rho.assign.len());
    scratch.reset(rho.num_parts, g.num_edges());

    for e in g.edge_ids() {
        let ps = rho.assign[g.source(e) as usize];
        scratch.dset.clear();
        for &d in g.dsts(e) {
            let p = rho.assign[d as usize];
            if scratch.stamp[p as usize] != e {
                scratch.stamp[p as usize] = e;
                scratch.dset.push(p);
            }
        }
        scratch.dset.sort_unstable();

        let mut h = fnv1a_u32(0xcbf2_9ce4_8422_2325, ps);
        for &p in &scratch.dset {
            h = fnv1a_u32(h, p);
        }

        // walk the collision chain for an identical (ps, dset)
        let mut found = None;
        if let Some(&head) = scratch.index.get(&h) {
            let mut cur = head;
            while cur != u32::MAX {
                let ci = cur as usize;
                if scratch.srcs[ci] == ps
                    && scratch.arena[scratch.span_off[ci]..scratch.span_off[ci + 1]]
                        == scratch.dset[..]
                {
                    found = Some(ci);
                    break;
                }
                cur = scratch.chain[ci];
            }
        }
        let ci = match found {
            Some(ci) => {
                scratch.weights[ci] += g.weight(e);
                ci
            }
            None => {
                let id = scratch.srcs.len() as u32;
                scratch.srcs.push(ps);
                scratch.arena.extend_from_slice(&scratch.dset);
                scratch.span_off.push(scratch.arena.len());
                scratch.weights.push(g.weight(e));
                if fine_mult.is_some() {
                    scratch.mult.push(0);
                }
                if let Some(m) = merged.as_deref_mut() {
                    m.push(Vec::new());
                }
                let prev_head = scratch.index.insert(h, id);
                scratch.chain.push(prev_head.unwrap_or(u32::MAX));
                id as usize
            }
        };
        if let Some(fm) = fine_mult {
            scratch.mult[ci] += fm[e as usize];
        }
        if let Some(m) = merged.as_deref_mut() {
            m[ci].push(e);
        }
    }
}

fn build_graph(num_parts: usize, scratch: &QuotientScratch) -> Hypergraph {
    let mut builder = HypergraphBuilder::new(num_parts);
    builder.reserve(scratch.srcs.len(), scratch.arena.len());
    for i in 0..scratch.srcs.len() {
        builder.add_edge_sorted(
            scratch.srcs[i],
            &scratch.arena[scratch.span_off[i]..scratch.span_off[i + 1]],
            scratch.weights[i],
        );
    }
    builder.build()
}

/// Push `g` forward through `rho` (Eq. 3), merging duplicate h-edges.
///
/// Self-loops are preserved when a partition sends spikes to itself
/// (intra-partition traffic is later priced at zero distance by the
/// metric engine, matching core-internal replication).
///
/// Hot-path layout: destination sets are deduplicated through a reusable
/// partition-stamp scratch array (no per-edge sort over duplicates) and
/// unique quotient edges live in one flat arena indexed by a
/// hash → chain-link table, so the sweep allocates nothing per input
/// h-edge — the old version cloned every candidate key into a
/// `HashMap<(u32, Vec<NodeId>), _>`. Callers that run many rounds should
/// prefer [`push_forward_pooled`], which recycles the arenas and skips
/// the `merged_from` lists entirely.
pub fn push_forward(g: &Hypergraph, rho: &Partitioning) -> Quotient {
    let mut scratch = QuotientScratch::new();
    let mut merged_from: Vec<Vec<EdgeId>> = Vec::new();
    sweep(g, rho, None, &mut scratch, Some(&mut merged_from));
    Quotient {
        graph: build_graph(rho.num_parts, &scratch),
        merged_from,
    }
}

/// Arena-reusing push-forward for the multilevel engine: no
/// `merged_from` bookkeeping (one `Vec` per quotient edge in the plain
/// entry point); instead, `fine_mult[e]` — the original-axon multiplicity
/// each fine h-edge represents — is accumulated into the returned
/// per-quotient-edge multiplicity vector *during* the sweep, which is
/// exactly the aggregate the coarsening bookkeeping needs (C_apc
/// accounting). `scratch` is recycled across calls; only the returned
/// graph and multiplicity vector are fresh allocations.
pub fn push_forward_pooled(
    g: &Hypergraph,
    rho: &Partitioning,
    fine_mult: &[u32],
    scratch: &mut QuotientScratch,
) -> (Hypergraph, Vec<u32>) {
    assert_eq!(g.num_edges(), fine_mult.len());
    sweep(g, rho, Some(fine_mult), scratch, None);
    let graph = build_graph(rho.num_parts, scratch);
    (graph, std::mem::take(&mut scratch.mult))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Hypergraph {
        // 6 nodes in a chain, unit weights: i -> {i+1}
        let mut b = HypergraphBuilder::new(6);
        for i in 0..5u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        b.build()
    }

    #[test]
    fn identity_partitioning_is_isomorphic() {
        let g = chain();
        let q = push_forward(&g, &Partitioning::identity(6));
        assert_eq!(q.graph.num_nodes(), 6);
        assert_eq!(q.graph.num_edges(), 5);
        assert_eq!(q.graph.num_connections(), 5);
    }

    #[test]
    fn merges_identical_edges_and_sums_weights() {
        // two sources in the same partition hitting the same partition set
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![2, 3], 1.5);
        b.add_edge(1, vec![2, 3], 2.5);
        let g = b.build();
        // rho: {0,1} -> 0, {2,3} -> 1
        let rho = Partitioning::new(vec![0, 0, 1, 1], 2);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 1);
        assert!((q.graph.weight(0) - 4.0).abs() < 1e-6);
        assert_eq!(q.graph.dsts(0), &[1]);
        assert_eq!(q.merged_from[0], vec![0, 1]);
    }

    #[test]
    fn distinct_dst_sets_stay_separate() {
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![2], 1.0);
        b.add_edge(1, vec![3], 1.0);
        let g = b.build();
        let rho = Partitioning::new(vec![0, 0, 1, 2], 3);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 2);
    }

    #[test]
    fn weight_is_conserved() {
        let g = chain();
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2, 2], 3);
        let q = push_forward(&g, &rho);
        let orig: f64 = g.edge_ids().map(|e| g.weight(e) as f64).sum();
        let quot: f64 = q.graph.edge_ids().map(|e| q.graph.weight(e) as f64).sum();
        assert!((orig - quot).abs() < 1e-6);
    }

    #[test]
    fn self_loops_preserved() {
        let g = chain();
        let rho = Partitioning::new(vec![0; 6], 1);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 1); // all edges merge to 0 -> {0}
        assert_eq!(q.graph.dsts(0), &[0]);
        assert!((q.graph.weight(0) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn pooled_matches_plain_and_fuses_multiplicity() {
        let mut b = HypergraphBuilder::new(6);
        b.add_edge(0, vec![2, 3], 1.5);
        b.add_edge(1, vec![2, 3], 2.5);
        b.add_edge(4, vec![5], 0.5);
        b.add_edge(2, vec![0, 1], 1.0);
        let g = b.build();
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2, 2], 3);
        let plain = push_forward(&g, &rho);
        let fine_mult = vec![3u32, 4, 5, 6];
        let mut scratch = QuotientScratch::new();
        // run twice through the same scratch: reuse must not leak state
        for _ in 0..2 {
            let (graph, mult) = push_forward_pooled(&g, &rho, &fine_mult, &mut scratch);
            assert_eq!(graph.num_edges(), plain.graph.num_edges());
            for e in graph.edge_ids() {
                assert_eq!(graph.source(e), plain.graph.source(e));
                assert_eq!(graph.dsts(e), plain.graph.dsts(e));
                assert!((graph.weight(e) - plain.graph.weight(e)).abs() < 1e-6);
                // fused multiplicity == Σ fine_mult over merged_from
                let want: u32 = plain.merged_from[e as usize]
                    .iter()
                    .map(|&f| fine_mult[f as usize])
                    .sum();
                assert_eq!(mult[e as usize], want, "edge {e}");
            }
        }
    }

    #[test]
    fn compacted_drops_empty_partitions() {
        let p = Partitioning::new(vec![0, 2, 2], 4).compacted();
        assert_eq!(p.num_parts, 2);
        assert_eq!(p.assign, vec![0, 1, 1]);
    }
}
