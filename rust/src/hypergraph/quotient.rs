//! Partition push-forward: `G_P` from `G_S` and ρ (paper §III, Eq. 3).
//!
//! Every h-edge `(s, D)` maps to `(ρ(s), {ρ(d) | d ∈ D})`; h-edges with
//! identical source and destination set are then merged by summing their
//! weights ("we may subsequently merge h-edges with identical source and
//! destinations by adding together their weights").

use super::{EdgeId, Hypergraph, HypergraphBuilder, NodeId};
use std::collections::HashMap;

/// A partitioning ρ: N → P plus its cardinality.
#[derive(Clone, Debug)]
pub struct Partitioning {
    /// `assign[n]` = partition of node n.
    pub assign: Vec<u32>,
    /// Number of partitions |P|.
    pub num_parts: usize,
}

impl Partitioning {
    pub fn new(assign: Vec<u32>, num_parts: usize) -> Self {
        debug_assert!(assign.iter().all(|&p| (p as usize) < num_parts));
        Partitioning { assign, num_parts }
    }

    /// Identity partitioning (each node its own partition) — useful for
    /// treating an unpartitioned graph uniformly in the metric engine.
    pub fn identity(n: usize) -> Self {
        Partitioning {
            assign: (0..n as u32).collect(),
            num_parts: n,
        }
    }

    /// Partition sizes |ρ^{-1}(p)|.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.num_parts];
        for &p in &self.assign {
            s[p as usize] += 1;
        }
        s
    }

    /// Renumber partitions to drop empty ids (keeps relative order).
    pub fn compacted(mut self) -> Self {
        let sizes = self.sizes();
        let mut remap = vec![u32::MAX; self.num_parts];
        let mut next = 0u32;
        for (p, &sz) in sizes.iter().enumerate() {
            if sz > 0 {
                remap[p] = next;
                next += 1;
            }
        }
        for p in self.assign.iter_mut() {
            *p = remap[*p as usize];
        }
        self.num_parts = next as usize;
        self
    }
}

/// Result of the push-forward: the quotient h-graph and, for bookkeeping,
/// the mapping from quotient h-edge to the original h-edges it merged.
pub struct Quotient {
    pub graph: Hypergraph,
    /// For each quotient h-edge, the original edge ids folded into it.
    pub merged_from: Vec<Vec<EdgeId>>,
}

/// Push `g` forward through `rho` (Eq. 3), merging duplicate h-edges.
///
/// Self-loops are preserved when a partition sends spikes to itself
/// (intra-partition traffic is later priced at zero distance by the
/// metric engine, matching core-internal replication).
pub fn push_forward(g: &Hypergraph, rho: &Partitioning) -> Quotient {
    assert_eq!(g.num_nodes(), rho.assign.len());
    let mut builder = HypergraphBuilder::new(rho.num_parts);
    builder.reserve(g.num_edges(), g.num_edges() * 2);

    // Key: (source partition, destination partition set) -> quotient edge.
    let mut merge: HashMap<(u32, Vec<NodeId>), usize> = HashMap::new();
    let mut weights: Vec<f32> = Vec::new();
    let mut keys: Vec<(u32, Vec<NodeId>)> = Vec::new();
    let mut merged_from: Vec<Vec<EdgeId>> = Vec::new();

    let mut dset: Vec<NodeId> = Vec::new();
    for e in g.edge_ids() {
        let ps = rho.assign[g.source(e) as usize];
        dset.clear();
        dset.extend(g.dsts(e).iter().map(|&d| rho.assign[d as usize]));
        dset.sort_unstable();
        dset.dedup();
        let key = (ps, dset.clone());
        match merge.get(&key) {
            Some(&idx) => {
                weights[idx] += g.weight(e);
                merged_from[idx].push(e);
            }
            None => {
                let idx = weights.len();
                merge.insert(key.clone(), idx);
                keys.push(key);
                weights.push(g.weight(e));
                merged_from.push(vec![e]);
            }
        }
    }

    for (idx, (ps, dset)) in keys.iter().enumerate() {
        builder.add_edge_sorted(*ps, dset, weights[idx]);
    }
    Quotient {
        graph: builder.build(),
        merged_from,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Hypergraph {
        // 6 nodes in a chain, unit weights: i -> {i+1}
        let mut b = HypergraphBuilder::new(6);
        for i in 0..5u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        b.build()
    }

    #[test]
    fn identity_partitioning_is_isomorphic() {
        let g = chain();
        let q = push_forward(&g, &Partitioning::identity(6));
        assert_eq!(q.graph.num_nodes(), 6);
        assert_eq!(q.graph.num_edges(), 5);
        assert_eq!(q.graph.num_connections(), 5);
    }

    #[test]
    fn merges_identical_edges_and_sums_weights() {
        // two sources in the same partition hitting the same partition set
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![2, 3], 1.5);
        b.add_edge(1, vec![2, 3], 2.5);
        let g = b.build();
        // rho: {0,1} -> 0, {2,3} -> 1
        let rho = Partitioning::new(vec![0, 0, 1, 1], 2);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 1);
        assert!((q.graph.weight(0) - 4.0).abs() < 1e-6);
        assert_eq!(q.graph.dsts(0), &[1]);
        assert_eq!(q.merged_from[0], vec![0, 1]);
    }

    #[test]
    fn distinct_dst_sets_stay_separate() {
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![2], 1.0);
        b.add_edge(1, vec![3], 1.0);
        let g = b.build();
        let rho = Partitioning::new(vec![0, 0, 1, 2], 3);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 2);
    }

    #[test]
    fn weight_is_conserved() {
        let g = chain();
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2, 2], 3);
        let q = push_forward(&g, &rho);
        let orig: f64 = g.edge_ids().map(|e| g.weight(e) as f64).sum();
        let quot: f64 = q.graph.edge_ids().map(|e| q.graph.weight(e) as f64).sum();
        assert!((orig - quot).abs() < 1e-6);
    }

    #[test]
    fn self_loops_preserved() {
        let g = chain();
        let rho = Partitioning::new(vec![0; 6], 1);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 1); // all edges merge to 0 -> {0}
        assert_eq!(q.graph.dsts(0), &[0]);
        assert!((q.graph.weight(0) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn compacted_drops_empty_partitions() {
        let p = Partitioning::new(vec![0, 2, 2], 4).compacted();
        assert_eq!(p.num_parts, 2);
        assert_eq!(p.assign, vec![0, 1, 1]);
    }
}
