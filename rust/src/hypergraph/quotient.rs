//! Partition push-forward: `G_P` from `G_S` and ρ (paper §III, Eq. 3).
//!
//! Every h-edge `(s, D)` maps to `(ρ(s), {ρ(d) | d ∈ D})`; h-edges with
//! identical source and destination set are then merged by summing their
//! weights ("we may subsequently merge h-edges with identical source and
//! destinations by adding together their weights").
//!
//! The pooled entry point runs **two-phase** when given a worker budget
//! (DESIGN.md §12): a parallel *scan* over fixed edge-id chunks computes
//! each edge's deduplicated, sorted destination-partition set, its FNV
//! key and a chunk-local unique-edge list, and a serial *commit* merges
//! the chunk results in edge-id order into the shared [`QuotientScratch`]
//! — replaying [`sweep_serial`]'s insertion and f32 accumulation order
//! exactly, so the worker count is never observable in the output.

use super::{EdgeId, Hypergraph, HypergraphBuilder, NodeId};
use std::collections::HashMap;
use std::time::Instant;

/// A partitioning ρ: N → P plus its cardinality.
#[derive(Clone, Debug)]
pub struct Partitioning {
    /// `assign[n]` = partition of node n.
    pub assign: Vec<u32>,
    /// Number of partitions |P|.
    pub num_parts: usize,
}

impl Partitioning {
    pub fn new(assign: Vec<u32>, num_parts: usize) -> Self {
        debug_assert!(assign.iter().all(|&p| (p as usize) < num_parts));
        Partitioning { assign, num_parts }
    }

    /// Identity partitioning (each node its own partition) — useful for
    /// treating an unpartitioned graph uniformly in the metric engine.
    pub fn identity(n: usize) -> Self {
        Partitioning {
            assign: (0..n as u32).collect(),
            num_parts: n,
        }
    }

    /// Partition sizes |ρ^{-1}(p)|.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.num_parts];
        for &p in &self.assign {
            s[p as usize] += 1;
        }
        s
    }

    /// Renumber partitions to drop empty ids (keeps relative order).
    pub fn compacted(mut self) -> Self {
        let sizes = self.sizes();
        let mut remap = vec![u32::MAX; self.num_parts];
        let mut next = 0u32;
        for (p, &sz) in sizes.iter().enumerate() {
            if sz > 0 {
                remap[p] = next;
                next += 1;
            }
        }
        for p in self.assign.iter_mut() {
            *p = remap[*p as usize];
        }
        self.num_parts = next as usize;
        self
    }
}

/// Result of the push-forward: the quotient h-graph and, for bookkeeping,
/// the mapping from quotient h-edge to the original h-edges it merged.
pub struct Quotient {
    pub graph: Hypergraph,
    /// For each quotient h-edge, the original edge ids folded into it.
    pub merged_from: Vec<Vec<EdgeId>>,
}

/// Below this edge count the pooled push-forward sweeps serially even
/// when `threads > 1` — scoped-thread spawn overhead would dominate the
/// per-edge destination dedup. Invisible in results: the paths agree
/// bit-for-bit. Public so thread-invariance tests can assert their
/// workloads actually cross it (see [`QuotientStats::par_sweeps`]).
pub const PAR_MIN_EDGES: usize = 512;

/// Diagnostics from one pooled push-forward (hotpath bench + CI
/// trajectory), mirroring `HierStats`/`OverlapStats` (DESIGN.md §10-§12).
#[derive(Clone, Copy, Debug, Default)]
pub struct QuotientStats {
    /// Wall-clock of the scan phase (destination dedup + sort + hashing;
    /// parallel when dispatched, the whole serial sweep otherwise).
    pub scan_secs: f64,
    /// Wall-clock of the serial commit merge (zero on the serial path,
    /// where scan and commit are one fused sweep).
    pub commit_secs: f64,
    /// Sweeps that dispatched the parallel scan path (0 or 1 per call) —
    /// the counter that makes broken `threads` wiring observable despite
    /// bit-identical outputs.
    pub par_sweeps: u64,
    /// Heap high-water mark of the sweep's scratch (shared arenas plus,
    /// on the parallel path, the per-chunk scan buffers).
    pub peak_scratch_bytes: usize,
}

/// FNV-1a step over one little-endian u32.
#[inline]
fn fnv1a_u32(mut h: u64, x: u32) -> u64 {
    for b in x.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Reusable arenas + scratch for repeated push-forward sweeps.
///
/// The multilevel partitioner runs one push-forward per coarsening round;
/// with a fresh set of vectors per round the allocator dominated peak
/// memory. A single `QuotientScratch` threaded through the rounds keeps
/// every intermediate buffer (dedup stamps, the unique-edge arena, the
/// hash chain) at its high-water capacity and recycles it.
#[derive(Default)]
pub struct QuotientScratch {
    // Unique quotient edges: source, arena-backed dst span, weight.
    srcs: Vec<u32>,
    arena: Vec<NodeId>,
    span_off: Vec<usize>,
    weights: Vec<f32>,
    /// Per-unique-edge accumulated fine multiplicity (see
    /// [`push_forward_pooled`]); empty when no `fine_mult` was supplied.
    mult: Vec<u32>,
    // hash -> chain head; `chain[i]` links unique edges sharing a hash.
    index: HashMap<u64, u32>,
    chain: Vec<u32>,
    // stamp[p] == e marks partition p seen for edge e (reset per sweep:
    // edge ids restart at 0 every round, so stale stamps would alias).
    stamp: Vec<u32>,
    dset: Vec<NodeId>,
    // Parallel-sweep pools: per-chunk scan slots and the commit's
    // local→global map, recycled across sweeps like every other arena.
    scans: Vec<ChunkScan>,
    gmap: Vec<u32>,
}

impl QuotientScratch {
    pub fn new() -> Self {
        QuotientScratch::default()
    }

    fn reset(&mut self, num_parts: usize, ne: usize) {
        self.srcs.clear();
        self.arena.clear();
        self.span_off.clear();
        self.span_off.push(0);
        self.weights.clear();
        self.mult.clear();
        self.index.clear();
        self.index.reserve(ne); // no-op once the retained capacity suffices
        self.chain.clear();
        self.stamp.clear();
        self.stamp.resize(num_parts, u32::MAX);
        self.dset.clear();
    }

    /// Heap footprint of the retained arenas (stats reporting).
    pub fn memory_bytes(&self) -> usize {
        self.srcs.capacity() * 4
            + self.arena.capacity() * 4
            + self.span_off.capacity() * 8
            + self.weights.capacity() * 4
            + self.mult.capacity() * 4
            + self.index.capacity() * (8 + 4)
            + self.chain.capacity() * 4
            + self.stamp.capacity() * 4
            + self.dset.capacity() * 4
            + self.scans.iter().map(ChunkScan::memory_bytes).sum::<usize>()
            + self.gmap.capacity() * 4
    }
}

/// Find-or-insert one `(src, D)` record with FNV key `h`, accumulating
/// weight `w` — the single intern routine shared by [`sweep_serial`] and
/// [`sweep_parallel`]'s commit, so the two paths cannot drift apart
/// (divergence impossible by construction, §11's `scan_one` pattern).
/// Returns the unique-edge id and whether it was freshly inserted.
fn intern_edge(
    scratch: &mut QuotientScratch,
    ps: u32,
    dset: &[NodeId],
    h: u64,
    w: f32,
    track_mult: bool,
) -> (usize, bool) {
    // walk the collision chain for an identical (ps, dset)
    let mut found = None;
    if let Some(&head) = scratch.index.get(&h) {
        let mut cur = head;
        while cur != u32::MAX {
            let ci = cur as usize;
            if scratch.srcs[ci] == ps
                && scratch.arena[scratch.span_off[ci]..scratch.span_off[ci + 1]] == dset[..]
            {
                found = Some(ci);
                break;
            }
            cur = scratch.chain[ci];
        }
    }
    match found {
        Some(ci) => {
            scratch.weights[ci] += w;
            (ci, false)
        }
        None => {
            let id = scratch.srcs.len() as u32;
            scratch.srcs.push(ps);
            scratch.arena.extend_from_slice(dset);
            scratch.span_off.push(scratch.arena.len());
            scratch.weights.push(w);
            if track_mult {
                scratch.mult.push(0);
            }
            let prev_head = scratch.index.insert(h, id);
            scratch.chain.push(prev_head.unwrap_or(u32::MAX));
            (id as usize, true)
        }
    }
}

/// The serial reference sweep behind both push-forward entry points.
/// Deduplicates per-edge destination partitions through `scratch.stamp`,
/// merges identical `(source, D)` quotient edges via the flat arena +
/// hash chain, and — fused into the same pass — accumulates `fine_mult`
/// (the original-axon multiplicity each fine edge represents) into
/// `scratch.mult` and/or appends to per-unique-edge `merged` lists.
/// [`sweep_parallel`] must reproduce this bit-for-bit (tested).
fn sweep_serial(
    g: &Hypergraph,
    rho: &Partitioning,
    fine_mult: Option<&[u32]>,
    scratch: &mut QuotientScratch,
    mut merged: Option<&mut Vec<Vec<EdgeId>>>,
) {
    assert_eq!(g.num_nodes(), rho.assign.len());
    scratch.reset(rho.num_parts, g.num_edges());

    for e in g.edge_ids() {
        let ps = rho.assign[g.source(e) as usize];
        scratch.dset.clear();
        for &d in g.dsts(e) {
            let p = rho.assign[d as usize];
            if scratch.stamp[p as usize] != e {
                scratch.stamp[p as usize] = e;
                scratch.dset.push(p);
            }
        }
        scratch.dset.sort_unstable();

        let mut h = fnv1a_u32(0xcbf2_9ce4_8422_2325, ps);
        for &p in &scratch.dset {
            h = fnv1a_u32(h, p);
        }

        // intern through the shared routine (dset swaps out of the
        // scratch for the call — a pointer move, not a copy)
        let dset = std::mem::take(&mut scratch.dset);
        let (ci, fresh) = intern_edge(scratch, ps, &dset, h, g.weight(e), fine_mult.is_some());
        scratch.dset = dset;
        if fresh {
            if let Some(m) = merged.as_deref_mut() {
                m.push(Vec::new());
            }
        }
        if let Some(fm) = fine_mult {
            scratch.mult[ci] += fm[e as usize];
        }
        if let Some(m) = merged.as_deref_mut() {
            m[ci].push(e);
        }
    }
}

/// Per-chunk slot of the parallel scan phase: each edge in the chunk
/// maps to a chunk-local unique `(src, D)` record; first occurrences own
/// a span in the chunk arena plus the precomputed FNV key, so the serial
/// commit never re-deduplicates, re-sorts or re-hashes a destination
/// set. Each slot also owns its worker-local dedup state (partition
/// stamp, sorted-set buffer, local hash chain), so the whole structure
/// pools inside [`QuotientScratch`] across sweeps — no per-sweep
/// allocation beyond capacity growth.
#[derive(Default)]
struct ChunkScan {
    /// per-edge (in chunk order): chunk-local unique record id
    lu: Vec<u32>,
    /// per-unique: FNV key of (src, D) — identical to the serial sweep's
    hash: Vec<u64>,
    /// per-unique: source partition
    src: Vec<u32>,
    /// per-unique destination spans in `arena`
    span_off: Vec<usize>,
    arena: Vec<NodeId>,
    // worker-local scan state (reset per sweep, capacity retained)
    stamp: Vec<u32>,
    dset: Vec<NodeId>,
    index: HashMap<u64, u32>,
    lchain: Vec<u32>,
}

impl ChunkScan {
    fn reset(&mut self, num_parts: usize) {
        self.lu.clear();
        self.hash.clear();
        self.src.clear();
        self.span_off.clear();
        self.span_off.push(0);
        self.arena.clear();
        // stamp epochs are chunk-local edge indices restarting at 0, so
        // stale values from the previous sweep would alias: refill
        self.stamp.clear();
        self.stamp.resize(num_parts, u32::MAX);
        self.dset.clear();
        self.index.clear();
        self.lchain.clear();
    }

    fn memory_bytes(&self) -> usize {
        self.lu.capacity() * 4
            + self.hash.capacity() * 8
            + self.src.capacity() * 4
            + self.span_off.capacity() * 8
            + self.arena.capacity() * 4
            + self.stamp.capacity() * 4
            + self.dset.capacity() * 4
            + self.index.capacity() * (8 + 4)
            + self.lchain.capacity() * 4
    }
}

/// Two-phase parallel sweep (DESIGN.md §12), bit-for-bit identical to
/// [`sweep_serial`].
///
/// *Scan* (parallel): fixed contiguous edge-id chunks
/// ([`crate::util::par::par_chunks_mut`] over one [`ChunkScan`] slot per
/// chunk) each dedup their edges' destination partitions through a
/// per-worker epoch-stamped array, sort them, compute the FNV key, and
/// collapse chunk-internal duplicates through a chunk-local arena + hash
/// chain. Every slot is a pure function of its edge range, so scheduling
/// is unobservable.
///
/// *Commit* (serial): chunks merge in ascending chunk order and, inside
/// a chunk, in ascending edge order — i.e. ascending global edge id.
/// First occurrences walk the shared hash chain exactly as the serial
/// sweep does (same keys, same insertion order, hence the same unique
/// ids), repeats resolve through a per-chunk local→global map, and every
/// edge contributes its own f32 weight individually — the accumulation
/// tree is the serial left-to-right order, never per-chunk partial sums,
/// which is what keeps the f32 weights bit-identical for any chunking.
// snn-lint: allow(parallel-serial-pairing) — sweep_serial runs via the public
// push_forward dispatch at threads<=1; parallel_sweep_matches_serial_bitwise_across_threads
// asserts bitwise equality of the two sweeps across worker counts
fn sweep_parallel(
    g: &Hypergraph,
    rho: &Partitioning,
    fine_mult: Option<&[u32]>,
    scratch: &mut QuotientScratch,
    threads: usize,
    stats: &mut QuotientStats,
) {
    assert_eq!(g.num_nodes(), rho.assign.len());
    let ne = g.num_edges();
    scratch.reset(rho.num_parts, ne);

    // ---- scan (parallel propose over fixed edge-id chunks) ----
    // The chunk slots and the commit's local→global map pool inside the
    // scratch; they swap out for the sweep (borrowck) and back in below.
    let t0 = Instant::now();
    let chunk = crate::util::par::fixed_chunk(ne, threads);
    let n_chunks = crate::util::div_ceil(ne, chunk);
    let mut scans = std::mem::take(&mut scratch.scans);
    let mut gmap = std::mem::take(&mut scratch.gmap);
    scans.resize_with(n_chunks, ChunkScan::default);
    let assign = &rho.assign[..];
    let num_parts = rho.num_parts;
    crate::util::par::par_chunks_mut(&mut scans, 1, threads, |ci, slot| {
        let cs = &mut slot[0];
        cs.reset(num_parts);
        let lo = ci * chunk;
        let hi = (lo + chunk).min(ne);
        for (k, e) in (lo..hi).enumerate() {
            let e = e as EdgeId;
            let ps = assign[g.source(e) as usize];
            cs.dset.clear();
            for &d in g.dsts(e) {
                let p = assign[d as usize];
                if cs.stamp[p as usize] != k as u32 {
                    cs.stamp[p as usize] = k as u32;
                    cs.dset.push(p);
                }
            }
            cs.dset.sort_unstable();
            let mut h = fnv1a_u32(0xcbf2_9ce4_8422_2325, ps);
            for &p in &cs.dset {
                h = fnv1a_u32(h, p);
            }
            // chunk-local dedup through the local hash chain
            let mut found = None;
            if let Some(&head) = cs.index.get(&h) {
                let mut cur = head;
                while cur != u32::MAX {
                    let ui = cur as usize;
                    if cs.src[ui] == ps
                        && cs.arena[cs.span_off[ui]..cs.span_off[ui + 1]] == cs.dset[..]
                    {
                        found = Some(cur);
                        break;
                    }
                    cur = cs.lchain[ui];
                }
            }
            let id = match found {
                Some(id) => id,
                None => {
                    let id = cs.src.len() as u32;
                    cs.src.push(ps);
                    cs.hash.push(h);
                    cs.arena.extend_from_slice(&cs.dset);
                    cs.span_off.push(cs.arena.len());
                    let prev = cs.index.insert(h, id);
                    cs.lchain.push(prev.unwrap_or(u32::MAX));
                    id
                }
            };
            cs.lu.push(id);
        }
    });
    stats.scan_secs += t0.elapsed().as_secs_f64();

    // ---- commit (serial merge in ascending edge-id order) ----
    let t1 = Instant::now();
    for (ci, cs) in scans.iter().enumerate() {
        let lo = ci * chunk;
        gmap.clear();
        gmap.resize(cs.src.len(), u32::MAX);
        for (k, &lu) in cs.lu.iter().enumerate() {
            let e = (lo + k) as EdgeId;
            let li = lu as usize;
            let gi = if gmap[li] != u32::MAX {
                // repeat within the chunk: the global record is known and
                // the serial sweep would have found it too — accumulate
                let gi = gmap[li] as usize;
                scratch.weights[gi] += g.weight(e);
                gi
            } else {
                // first chunk occurrence: the identical intern routine the
                // serial sweep runs, on the precomputed (src, dset, key)
                let dset = &cs.arena[cs.span_off[li]..cs.span_off[li + 1]];
                let (gi, _) = intern_edge(
                    scratch,
                    cs.src[li],
                    dset,
                    cs.hash[li],
                    g.weight(e),
                    fine_mult.is_some(),
                );
                gmap[li] = gi as u32;
                gi
            };
            if let Some(fm) = fine_mult {
                scratch.mult[gi] += fm[e as usize];
            }
        }
    }
    stats.commit_secs += t1.elapsed().as_secs_f64();
    // return the pooled buffers; memory_bytes() then sees them too
    scratch.scans = scans;
    scratch.gmap = gmap;
    stats.peak_scratch_bytes = stats.peak_scratch_bytes.max(scratch.memory_bytes());
}

fn build_graph(num_parts: usize, scratch: &QuotientScratch) -> Hypergraph {
    let mut builder = HypergraphBuilder::new(num_parts);
    builder.reserve(scratch.srcs.len(), scratch.arena.len());
    for i in 0..scratch.srcs.len() {
        builder.add_edge_sorted(
            scratch.srcs[i],
            &scratch.arena[scratch.span_off[i]..scratch.span_off[i + 1]],
            scratch.weights[i],
        );
    }
    builder.build()
}

/// Push `g` forward through `rho` (Eq. 3), merging duplicate h-edges.
///
/// Self-loops are preserved when a partition sends spikes to itself
/// (intra-partition traffic is later priced at zero distance by the
/// metric engine, matching core-internal replication).
///
/// Hot-path layout: destination sets are deduplicated through a reusable
/// partition-stamp scratch array (no per-edge sort over duplicates) and
/// unique quotient edges live in one flat arena indexed by a
/// hash → chain-link table, so the sweep allocates nothing per input
/// h-edge — the old version cloned every candidate key into a
/// `HashMap<(u32, Vec<NodeId>), _>`. Callers that run many rounds should
/// prefer [`push_forward_pooled`], which recycles the arenas and skips
/// the `merged_from` lists entirely.
pub fn push_forward(g: &Hypergraph, rho: &Partitioning) -> Quotient {
    let mut scratch = QuotientScratch::new();
    let mut merged_from: Vec<Vec<EdgeId>> = Vec::new();
    sweep_serial(g, rho, None, &mut scratch, Some(&mut merged_from));
    Quotient {
        graph: build_graph(rho.num_parts, &scratch),
        merged_from,
    }
}

/// Arena-reusing push-forward for the multilevel engine: no
/// `merged_from` bookkeeping (one `Vec` per quotient edge in the plain
/// entry point); instead, `fine_mult[e]` — the original-axon multiplicity
/// each fine h-edge represents — is accumulated into the returned
/// per-quotient-edge multiplicity vector *during* the sweep, which is
/// exactly the aggregate the coarsening bookkeeping needs (C_apc
/// accounting). `scratch` is recycled across calls; only the returned
/// graph and multiplicity vector are fresh allocations.
///
/// `threads` is a performance knob only: runs with `threads <= 1` — and
/// every graph below [`PAR_MIN_EDGES`] — take [`sweep_serial`], and the
/// two-phase parallel path agrees with it bit-for-bit (tested).
pub fn push_forward_pooled(
    g: &Hypergraph,
    rho: &Partitioning,
    fine_mult: &[u32],
    scratch: &mut QuotientScratch,
    threads: usize,
) -> (Hypergraph, Vec<u32>) {
    let (graph, mult, _) = push_forward_pooled_with_stats(g, rho, fine_mult, scratch, threads);
    (graph, mult)
}

/// [`push_forward_pooled`] plus per-sweep diagnostics (scan/commit
/// wall-clock, scratch high-water mark, parallel dispatch counter) for
/// the hotpath bench and the CI trajectory.
pub fn push_forward_pooled_with_stats(
    g: &Hypergraph,
    rho: &Partitioning,
    fine_mult: &[u32],
    scratch: &mut QuotientScratch,
    threads: usize,
) -> (Hypergraph, Vec<u32>, QuotientStats) {
    assert_eq!(g.num_edges(), fine_mult.len());
    let mut stats = QuotientStats::default();
    if threads > 1 && g.num_edges() >= PAR_MIN_EDGES {
        stats.par_sweeps = 1;
        sweep_parallel(g, rho, Some(fine_mult), scratch, threads, &mut stats);
    } else {
        let t0 = Instant::now();
        sweep_serial(g, rho, Some(fine_mult), scratch, None);
        stats.scan_secs = t0.elapsed().as_secs_f64();
    }
    stats.peak_scratch_bytes = stats.peak_scratch_bytes.max(scratch.memory_bytes());
    let graph = build_graph(rho.num_parts, scratch);
    (graph, std::mem::take(&mut scratch.mult), stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Hypergraph {
        // 6 nodes in a chain, unit weights: i -> {i+1}
        let mut b = HypergraphBuilder::new(6);
        for i in 0..5u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        b.build()
    }

    #[test]
    fn identity_partitioning_is_isomorphic() {
        let g = chain();
        let q = push_forward(&g, &Partitioning::identity(6));
        assert_eq!(q.graph.num_nodes(), 6);
        assert_eq!(q.graph.num_edges(), 5);
        assert_eq!(q.graph.num_connections(), 5);
    }

    #[test]
    fn merges_identical_edges_and_sums_weights() {
        // two sources in the same partition hitting the same partition set
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![2, 3], 1.5);
        b.add_edge(1, vec![2, 3], 2.5);
        let g = b.build();
        // rho: {0,1} -> 0, {2,3} -> 1
        let rho = Partitioning::new(vec![0, 0, 1, 1], 2);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 1);
        assert!((q.graph.weight(0) - 4.0).abs() < 1e-6);
        assert_eq!(q.graph.dsts(0), &[1]);
        assert_eq!(q.merged_from[0], vec![0, 1]);
    }

    #[test]
    fn distinct_dst_sets_stay_separate() {
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![2], 1.0);
        b.add_edge(1, vec![3], 1.0);
        let g = b.build();
        let rho = Partitioning::new(vec![0, 0, 1, 2], 3);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 2);
    }

    #[test]
    fn weight_is_conserved() {
        let g = chain();
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2, 2], 3);
        let q = push_forward(&g, &rho);
        let orig: f64 = g.edge_ids().map(|e| g.weight(e) as f64).sum();
        let quot: f64 = q.graph.edge_ids().map(|e| q.graph.weight(e) as f64).sum();
        assert!((orig - quot).abs() < 1e-6);
    }

    #[test]
    fn self_loops_preserved() {
        let g = chain();
        let rho = Partitioning::new(vec![0; 6], 1);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 1); // all edges merge to 0 -> {0}
        assert_eq!(q.graph.dsts(0), &[0]);
        assert!((q.graph.weight(0) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn pooled_matches_plain_and_fuses_multiplicity() {
        let mut b = HypergraphBuilder::new(6);
        b.add_edge(0, vec![2, 3], 1.5);
        b.add_edge(1, vec![2, 3], 2.5);
        b.add_edge(4, vec![5], 0.5);
        b.add_edge(2, vec![0, 1], 1.0);
        let g = b.build();
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2, 2], 3);
        let plain = push_forward(&g, &rho);
        let fine_mult = vec![3u32, 4, 5, 6];
        let mut scratch = QuotientScratch::new();
        // run twice through the same scratch: reuse must not leak state
        for _ in 0..2 {
            let (graph, mult) = push_forward_pooled(&g, &rho, &fine_mult, &mut scratch, 1);
            assert_eq!(graph.num_edges(), plain.graph.num_edges());
            for e in graph.edge_ids() {
                assert_eq!(graph.source(e), plain.graph.source(e));
                assert_eq!(graph.dsts(e), plain.graph.dsts(e));
                assert!((graph.weight(e) - plain.graph.weight(e)).abs() < 1e-6);
                // fused multiplicity == Σ fine_mult over merged_from
                let want: u32 = plain.merged_from[e as usize]
                    .iter()
                    .map(|&f| fine_mult[f as usize])
                    .sum();
                assert_eq!(mult[e as usize], want, "edge {e}");
            }
        }
    }

    #[test]
    fn compacted_drops_empty_partitions() {
        let p = Partitioning::new(vec![0, 2, 2], 4).compacted();
        assert_eq!(p.num_parts, 2);
        assert_eq!(p.assign, vec![0, 1, 1]);
    }

    /// Random graph big enough to clear [`PAR_MIN_EDGES`] (one h-edge
    /// per node), with enough duplicate (src, D) quotient keys that the
    /// merge paths are genuinely exercised.
    fn bulk_graph(seed: u64) -> (Hypergraph, Partitioning) {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(seed);
        let n = PAR_MIN_EDGES + 77;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let k = rng.range(1, 9);
            let mut dsts: Vec<u32> = (0..k)
                .map(|_| rng.below(n) as u32)
                .filter(|&d| d != s)
                .collect();
            if dsts.is_empty() {
                dsts.push((s + 1) % n as u32);
            }
            b.add_edge(s, dsts, rng.next_f32() + 1e-4);
        }
        let parts = 23;
        let assign: Vec<u32> = (0..n).map(|_| rng.below(parts) as u32).collect();
        (b.build(), Partitioning::new(assign, parts))
    }

    #[test]
    fn parallel_sweep_matches_serial_bitwise_across_threads() {
        let (g, rho) = bulk_graph(0xBEEF);
        assert!(g.num_edges() >= PAR_MIN_EDGES);
        let fine_mult: Vec<u32> = (0..g.num_edges()).map(|i| (i % 7 + 1) as u32).collect();
        let mut scr_s = QuotientScratch::new();
        let (g1, m1, st1) = push_forward_pooled_with_stats(&g, &rho, &fine_mult, &mut scr_s, 1);
        assert_eq!(st1.par_sweeps, 0);
        // one reused scratch across all thread counts: reuse + parallel
        // sweeps must not interact
        let mut scr_p = QuotientScratch::new();
        for threads in [2, 4, 8] {
            let (g2, m2, st2) =
                push_forward_pooled_with_stats(&g, &rho, &fine_mult, &mut scr_p, threads);
            assert_eq!(st2.par_sweeps, 1, "threads={threads} dispatched serially");
            assert!(st2.peak_scratch_bytes > 0);
            assert_eq!(g1.num_edges(), g2.num_edges(), "threads={threads}");
            for e in g1.edge_ids() {
                assert_eq!(g1.source(e), g2.source(e), "edge {e} threads={threads}");
                assert_eq!(g1.dsts(e), g2.dsts(e), "edge {e} threads={threads}");
                assert_eq!(
                    g1.weight(e).to_bits(),
                    g2.weight(e).to_bits(),
                    "edge {e} threads={threads}"
                );
            }
            assert_eq!(m1, m2, "threads={threads}");
        }
    }

    #[test]
    fn parallel_sweep_matches_plain_reference() {
        // the parallel pooled sweep vs the merged_from bookkeeping of the
        // plain entry point: same quotient, multiplicity == Σ fine_mult
        let (g, rho) = bulk_graph(0x5EED);
        let plain = push_forward(&g, &rho);
        let fine_mult: Vec<u32> = (0..g.num_edges()).map(|i| (i % 5 + 1) as u32).collect();
        let mut scratch = QuotientScratch::new();
        let (qg, mult, stats) =
            push_forward_pooled_with_stats(&g, &rho, &fine_mult, &mut scratch, 4);
        assert_eq!(stats.par_sweeps, 1);
        assert_eq!(qg.num_edges(), plain.graph.num_edges());
        for e in qg.edge_ids() {
            assert_eq!(qg.source(e), plain.graph.source(e));
            assert_eq!(qg.dsts(e), plain.graph.dsts(e));
            assert_eq!(qg.weight(e).to_bits(), plain.graph.weight(e).to_bits());
            let want: u32 = plain.merged_from[e as usize]
                .iter()
                .map(|&f| fine_mult[f as usize])
                .sum();
            assert_eq!(mult[e as usize], want, "edge {e}");
        }
    }
}
