//! Partition push-forward: `G_P` from `G_S` and ρ (paper §III, Eq. 3).
//!
//! Every h-edge `(s, D)` maps to `(ρ(s), {ρ(d) | d ∈ D})`; h-edges with
//! identical source and destination set are then merged by summing their
//! weights ("we may subsequently merge h-edges with identical source and
//! destinations by adding together their weights").

use super::{EdgeId, Hypergraph, HypergraphBuilder, NodeId};
use std::collections::HashMap;

/// A partitioning ρ: N → P plus its cardinality.
#[derive(Clone, Debug)]
pub struct Partitioning {
    /// `assign[n]` = partition of node n.
    pub assign: Vec<u32>,
    /// Number of partitions |P|.
    pub num_parts: usize,
}

impl Partitioning {
    pub fn new(assign: Vec<u32>, num_parts: usize) -> Self {
        debug_assert!(assign.iter().all(|&p| (p as usize) < num_parts));
        Partitioning { assign, num_parts }
    }

    /// Identity partitioning (each node its own partition) — useful for
    /// treating an unpartitioned graph uniformly in the metric engine.
    pub fn identity(n: usize) -> Self {
        Partitioning {
            assign: (0..n as u32).collect(),
            num_parts: n,
        }
    }

    /// Partition sizes |ρ^{-1}(p)|.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.num_parts];
        for &p in &self.assign {
            s[p as usize] += 1;
        }
        s
    }

    /// Renumber partitions to drop empty ids (keeps relative order).
    pub fn compacted(mut self) -> Self {
        let sizes = self.sizes();
        let mut remap = vec![u32::MAX; self.num_parts];
        let mut next = 0u32;
        for (p, &sz) in sizes.iter().enumerate() {
            if sz > 0 {
                remap[p] = next;
                next += 1;
            }
        }
        for p in self.assign.iter_mut() {
            *p = remap[*p as usize];
        }
        self.num_parts = next as usize;
        self
    }
}

/// Result of the push-forward: the quotient h-graph and, for bookkeeping,
/// the mapping from quotient h-edge to the original h-edges it merged.
pub struct Quotient {
    pub graph: Hypergraph,
    /// For each quotient h-edge, the original edge ids folded into it.
    pub merged_from: Vec<Vec<EdgeId>>,
}

/// FNV-1a step over one little-endian u32.
#[inline]
fn fnv1a_u32(mut h: u64, x: u32) -> u64 {
    for b in x.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Push `g` forward through `rho` (Eq. 3), merging duplicate h-edges.
///
/// Self-loops are preserved when a partition sends spikes to itself
/// (intra-partition traffic is later priced at zero distance by the
/// metric engine, matching core-internal replication).
///
/// Hot-path layout: destination sets are deduplicated through a reusable
/// partition-stamp scratch array (no per-edge sort over duplicates) and
/// unique quotient edges live in one flat arena indexed by a
/// hash → chain-link table, so the sweep allocates nothing per input
/// h-edge — the old version cloned every candidate key into a
/// `HashMap<(u32, Vec<NodeId>), _>`.
pub fn push_forward(g: &Hypergraph, rho: &Partitioning) -> Quotient {
    assert_eq!(g.num_nodes(), rho.assign.len());
    let ne = g.num_edges();

    // Unique quotient edges: source, arena-backed dst span, weight.
    let mut srcs: Vec<u32> = Vec::new();
    let mut arena: Vec<NodeId> = Vec::new();
    let mut span_off: Vec<usize> = vec![0];
    let mut weights: Vec<f32> = Vec::new();
    let mut merged_from: Vec<Vec<EdgeId>> = Vec::new();
    // hash -> chain head; `chain[i]` links unique edges sharing a hash.
    let mut index: HashMap<u64, u32> = HashMap::with_capacity(ne);
    let mut chain: Vec<u32> = Vec::new();

    // Reusable scratch: stamp[p] == e marks partition p seen for edge e.
    let mut stamp: Vec<u32> = vec![u32::MAX; rho.num_parts];
    let mut dset: Vec<NodeId> = Vec::new();

    for e in g.edge_ids() {
        let ps = rho.assign[g.source(e) as usize];
        dset.clear();
        for &d in g.dsts(e) {
            let p = rho.assign[d as usize];
            if stamp[p as usize] != e {
                stamp[p as usize] = e;
                dset.push(p);
            }
        }
        dset.sort_unstable();

        let mut h = fnv1a_u32(0xcbf2_9ce4_8422_2325, ps);
        for &p in &dset {
            h = fnv1a_u32(h, p);
        }

        // walk the collision chain for an identical (ps, dset)
        let mut found = None;
        if let Some(&head) = index.get(&h) {
            let mut cur = head;
            while cur != u32::MAX {
                let ci = cur as usize;
                if srcs[ci] == ps && arena[span_off[ci]..span_off[ci + 1]] == dset[..] {
                    found = Some(ci);
                    break;
                }
                cur = chain[ci];
            }
        }
        match found {
            Some(ci) => {
                weights[ci] += g.weight(e);
                merged_from[ci].push(e);
            }
            None => {
                let id = srcs.len() as u32;
                srcs.push(ps);
                arena.extend_from_slice(&dset);
                span_off.push(arena.len());
                weights.push(g.weight(e));
                merged_from.push(vec![e]);
                let prev_head = index.insert(h, id);
                chain.push(prev_head.unwrap_or(u32::MAX));
            }
        }
    }

    let mut builder = HypergraphBuilder::new(rho.num_parts);
    builder.reserve(srcs.len(), arena.len());
    for i in 0..srcs.len() {
        builder.add_edge_sorted(srcs[i], &arena[span_off[i]..span_off[i + 1]], weights[i]);
    }
    Quotient {
        graph: builder.build(),
        merged_from,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Hypergraph {
        // 6 nodes in a chain, unit weights: i -> {i+1}
        let mut b = HypergraphBuilder::new(6);
        for i in 0..5u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        b.build()
    }

    #[test]
    fn identity_partitioning_is_isomorphic() {
        let g = chain();
        let q = push_forward(&g, &Partitioning::identity(6));
        assert_eq!(q.graph.num_nodes(), 6);
        assert_eq!(q.graph.num_edges(), 5);
        assert_eq!(q.graph.num_connections(), 5);
    }

    #[test]
    fn merges_identical_edges_and_sums_weights() {
        // two sources in the same partition hitting the same partition set
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![2, 3], 1.5);
        b.add_edge(1, vec![2, 3], 2.5);
        let g = b.build();
        // rho: {0,1} -> 0, {2,3} -> 1
        let rho = Partitioning::new(vec![0, 0, 1, 1], 2);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 1);
        assert!((q.graph.weight(0) - 4.0).abs() < 1e-6);
        assert_eq!(q.graph.dsts(0), &[1]);
        assert_eq!(q.merged_from[0], vec![0, 1]);
    }

    #[test]
    fn distinct_dst_sets_stay_separate() {
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![2], 1.0);
        b.add_edge(1, vec![3], 1.0);
        let g = b.build();
        let rho = Partitioning::new(vec![0, 0, 1, 2], 3);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 2);
    }

    #[test]
    fn weight_is_conserved() {
        let g = chain();
        let rho = Partitioning::new(vec![0, 0, 1, 1, 2, 2], 3);
        let q = push_forward(&g, &rho);
        let orig: f64 = g.edge_ids().map(|e| g.weight(e) as f64).sum();
        let quot: f64 = q.graph.edge_ids().map(|e| q.graph.weight(e) as f64).sum();
        assert!((orig - quot).abs() < 1e-6);
    }

    #[test]
    fn self_loops_preserved() {
        let g = chain();
        let rho = Partitioning::new(vec![0; 6], 1);
        let q = push_forward(&g, &rho);
        assert_eq!(q.graph.num_edges(), 1); // all edges merge to 0 -> {0}
        assert_eq!(q.graph.dsts(0), &[0]);
        assert!((q.graph.weight(0) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn compacted_drops_empty_partitions() {
        let p = Partitioning::new(vec![0, 2, 2], 4).compacted();
        assert_eq!(p.num_parts, 2);
        assert_eq!(p.assign, vec![0, 1, 1]);
    }
}
