//! Directed single-source hypergraph (paper §II-A, Eq. 1).
//!
//! A SNN is modeled as `G_S = (N, E_S, w_S)` where every h-edge
//! `e = (s, D)` bundles one neuron's axon: source `s`, destination set `D`,
//! and a spike-frequency weight. For SNN graphs there is exactly one
//! outbound h-edge per neuron; the quotient (partitioned) h-graph `G_P`
//! (see [`quotient`]) relaxes this to arbitrarily many.
//!
//! Storage is flat CSR: h-edges own contiguous destination slices, and two
//! auxiliary CSR indices give O(1) access to a node's inbound h-edge set
//! and outbound h-edge list — the exact data layout the paper's §IV
//! algorithms assume ("two auxiliary indices provide constant-time access
//! to the set of h-edges inbound to a node and to its outbound h-edge").

pub mod builder;
pub mod io;
pub mod quotient;
pub mod stats;

pub use builder::HypergraphBuilder;

/// Node identifier (consecutive integers from 0).
pub type NodeId = u32;
/// H-edge identifier (consecutive integers from 0).
pub type EdgeId = u32;

/// Immutable directed single-source hypergraph in CSR form.
#[derive(Clone, Debug, Default)]
pub struct Hypergraph {
    pub(crate) n_nodes: usize,
    /// Source node of each h-edge.
    pub(crate) sources: Vec<NodeId>,
    /// Destination CSR offsets: edge `e` owns `dsts[dst_off[e]..dst_off[e+1]]`.
    pub(crate) dst_off: Vec<usize>,
    pub(crate) dsts: Vec<NodeId>,
    /// Spike-frequency weight of each h-edge.
    pub(crate) weights: Vec<f32>,
    /// Inbound index: node `n` is a destination of `in_edges[in_off[n]..in_off[n+1]]`.
    pub(crate) in_off: Vec<usize>,
    pub(crate) in_edges: Vec<EdgeId>,
    /// Outbound index: node `n` sources `out_edges[out_off[n]..out_off[n+1]]`.
    pub(crate) out_off: Vec<usize>,
    pub(crate) out_edges: Vec<EdgeId>,
}

impl Hypergraph {
    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Number of h-edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.sources.len()
    }

    /// Total connection (synapse) count: Σ_e |D_e|.
    #[inline]
    pub fn num_connections(&self) -> usize {
        self.dsts.len()
    }

    /// Mean h-edge cardinality `d` (paper Table III column).
    pub fn mean_cardinality(&self) -> f64 {
        if self.num_edges() == 0 {
            0.0
        } else {
            self.num_connections() as f64 / self.num_edges() as f64
        }
    }

    /// Source node of h-edge `e`.
    #[inline]
    pub fn source(&self, e: EdgeId) -> NodeId {
        self.sources[e as usize]
    }

    /// Destination slice of h-edge `e`.
    #[inline]
    pub fn dsts(&self, e: EdgeId) -> &[NodeId] {
        &self.dsts[self.dst_off[e as usize]..self.dst_off[e as usize + 1]]
    }

    /// Cardinality |D| of h-edge `e`.
    #[inline]
    pub fn cardinality(&self, e: EdgeId) -> usize {
        self.dst_off[e as usize + 1] - self.dst_off[e as usize]
    }

    /// Spike-frequency weight of h-edge `e`.
    #[inline]
    pub fn weight(&self, e: EdgeId) -> f32 {
        self.weights[e as usize]
    }

    /// H-edges having node `n` among their destinations (the node's
    /// distinct inbound axons).
    #[inline]
    pub fn inbound(&self, n: NodeId) -> &[EdgeId] {
        &self.in_edges[self.in_off[n as usize]..self.in_off[n as usize + 1]]
    }

    /// H-edges sourced at node `n`. For SNN graphs this has length <= 1
    /// (one axon per neuron); quotient graphs may have many.
    #[inline]
    pub fn outbound(&self, n: NodeId) -> &[EdgeId] {
        &self.out_edges[self.out_off[n as usize]..self.out_off[n as usize + 1]]
    }

    /// The single outbound h-edge of an SNN neuron, if any.
    #[inline]
    pub fn axon(&self, n: NodeId) -> Option<EdgeId> {
        let o = self.outbound(n);
        debug_assert!(o.len() <= 1, "axon() called on a multi-outbound h-graph");
        o.first().copied()
    }

    /// Iterator over all h-edge ids.
    pub fn edge_ids(&self) -> impl Iterator<Item = EdgeId> + '_ {
        (0..self.num_edges() as u32).map(|e| e as EdgeId)
    }

    /// Iterator over all node ids.
    pub fn node_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.n_nodes as u32).map(|n| n as NodeId)
    }

    /// True iff every node has at most one outbound h-edge (SNN property).
    pub fn is_single_axon(&self) -> bool {
        (0..self.n_nodes).all(|n| self.out_off[n + 1] - self.out_off[n] <= 1)
    }

    /// Total inbound spike-frequency weight of a node.
    pub fn inbound_weight(&self, n: NodeId) -> f64 {
        self.inbound(n).iter().map(|&e| self.weight(e) as f64).sum()
    }

    /// Bytes of payload held (diagnostic).
    pub fn memory_bytes(&self) -> usize {
        self.sources.len() * 4
            + self.dst_off.len() * 8
            + self.dsts.len() * 4
            + self.weights.len() * 4
            + self.in_off.len() * 8
            + self.in_edges.len() * 4
            + self.out_off.len() * 8
            + self.out_edges.len() * 4
    }

    /// Structural sanity check used by tests and after deserialization.
    pub fn validate(&self) -> Result<(), String> {
        let e = self.num_edges();
        if self.dst_off.len() != e + 1 || self.weights.len() != e {
            return Err("offset/weight array length mismatch".into());
        }
        if *self.dst_off.last().unwrap_or(&0) != self.dsts.len() {
            return Err("dst_off does not cover dsts".into());
        }
        if self.in_off.len() != self.n_nodes + 1 || self.out_off.len() != self.n_nodes + 1 {
            return Err("node index length mismatch".into());
        }
        for w in 0..e {
            if self.dst_off[w] > self.dst_off[w + 1] {
                return Err(format!("dst_off not monotone at {w}"));
            }
            if !self.weights[w].is_finite() || self.weights[w] < 0.0 {
                return Err(format!("bad weight on edge {w}"));
            }
        }
        let nn = self.n_nodes as u32;
        if self.sources.iter().any(|&s| s >= nn) || self.dsts.iter().any(|&d| d >= nn) {
            return Err("node id out of range".into());
        }
        // Inbound index must exactly mirror destination membership.
        let mut in_count = vec![0usize; self.n_nodes];
        for eid in 0..e {
            let mut seen_prev = None;
            for &d in self.dsts(eid as EdgeId) {
                // destinations must be sorted & unique within an h-edge
                if let Some(p) = seen_prev {
                    if d <= p {
                        return Err(format!("edge {eid} destinations unsorted/dup"));
                    }
                }
                seen_prev = Some(d);
                in_count[d as usize] += 1;
            }
        }
        for n in 0..self.n_nodes {
            if in_count[n] != self.in_off[n + 1] - self.in_off[n] {
                return Err(format!("inbound index wrong at node {n}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny() -> Hypergraph {
        // 4 nodes: 0 -> {1,2}, 1 -> {2,3}, 2 -> {3}
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1, 2], 1.0);
        b.add_edge(1, vec![2, 3], 2.0);
        b.add_edge(2, vec![3], 0.5);
        b.build()
    }

    #[test]
    fn accessors() {
        let g = tiny();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.num_connections(), 5);
        assert_eq!(g.source(0), 0);
        assert_eq!(g.dsts(1), &[2, 3]);
        assert_eq!(g.weight(2), 0.5);
        assert_eq!(g.cardinality(0), 2);
        assert!((g.mean_cardinality() - 5.0 / 3.0).abs() < 1e-12);
        g.validate().unwrap();
    }

    #[test]
    fn inbound_outbound_indices() {
        let g = tiny();
        assert_eq!(g.inbound(0), &[] as &[EdgeId]);
        assert_eq!(g.inbound(2), &[0, 1]);
        assert_eq!(g.inbound(3), &[1, 2]);
        assert_eq!(g.axon(0), Some(0));
        assert_eq!(g.axon(3), None);
        assert!(g.is_single_axon());
    }

    #[test]
    fn inbound_weight_sums() {
        let g = tiny();
        assert!((g.inbound_weight(3) - 2.5).abs() < 1e-6);
        assert!((g.inbound_weight(0) - 0.0).abs() < 1e-12);
    }
}
