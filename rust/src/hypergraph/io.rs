//! H-graph (de)serialization.
//!
//! Binary format `SNNHG1` (little-endian): header counts, then the flat
//! CSR arrays. Node indices are rebuilt on load (cheaper to recompute than
//! to store). A human-readable text format (one h-edge per line:
//! `src w d1 d2 ...`) supports tests, fixtures and interchange with the
//! paper's planned open-source benchmark hypergraphs.

use super::{Hypergraph, HypergraphBuilder};
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"SNNHG1";

/// Write `g` to `path` in the binary format.
pub fn save_binary(g: &Hypergraph, path: &Path) -> io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_u64(&mut w, g.num_nodes() as u64)?;
    write_u64(&mut w, g.num_edges() as u64)?;
    write_u64(&mut w, g.num_connections() as u64)?;
    for &s in &g.sources {
        write_u32(&mut w, s)?;
    }
    for &o in &g.dst_off {
        write_u64(&mut w, o as u64)?;
    }
    for &d in &g.dsts {
        write_u32(&mut w, d)?;
    }
    for &x in &g.weights {
        write_u32(&mut w, x.to_bits())?;
    }
    w.flush()
}

/// Load a binary h-graph from `path`.
pub fn load_binary(path: &Path) -> io::Result<Hypergraph> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 6];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let n = read_u64(&mut r)? as usize;
    let e = read_u64(&mut r)? as usize;
    let c = read_u64(&mut r)? as usize;
    let mut sources = Vec::with_capacity(e);
    for _ in 0..e {
        sources.push(read_u32(&mut r)?);
    }
    let mut dst_off = Vec::with_capacity(e + 1);
    for _ in 0..=e {
        dst_off.push(read_u64(&mut r)? as usize);
    }
    let mut dsts = Vec::with_capacity(c);
    for _ in 0..c {
        dsts.push(read_u32(&mut r)?);
    }
    let mut weights = Vec::with_capacity(e);
    for _ in 0..e {
        weights.push(f32::from_bits(read_u32(&mut r)?));
    }
    // Rebuild through the builder to regenerate node indices and validate.
    let mut b = HypergraphBuilder::new(n);
    b.reserve(e, c);
    for i in 0..e {
        let slice = &dsts[dst_off[i]..dst_off[i + 1]];
        b.add_edge_sorted(sources[i], slice, weights[i]);
    }
    let g = b.build();
    g.validate()
        .map_err(|m| io::Error::new(io::ErrorKind::InvalidData, m))?;
    Ok(g)
}

/// Write the text format: first line `n`, then one line per h-edge.
pub fn save_text(g: &Hypergraph, path: &Path) -> io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{}", g.num_nodes())?;
    for e in g.edge_ids() {
        write!(w, "{} {}", g.source(e), g.weight(e))?;
        for &d in g.dsts(e) {
            write!(w, " {}", d)?;
        }
        writeln!(w)?;
    }
    w.flush()
}

/// Load the text format.
pub fn load_text(path: &Path) -> io::Result<Hypergraph> {
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let mut lines = r.lines();
    let n: usize = lines
        .next()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "empty file"))??
        .trim()
        .parse()
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad node count"))?;
    let mut b = HypergraphBuilder::new(n);
    for line in lines {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let bad = || io::Error::new(io::ErrorKind::InvalidData, "bad edge line");
        let src: u32 = it.next().ok_or_else(bad)?.parse().map_err(|_| bad())?;
        let w: f32 = it.next().ok_or_else(bad)?.parse().map_err(|_| bad())?;
        let dsts: Result<Vec<u32>, _> = it.map(|t| t.parse::<u32>()).collect();
        let dsts = dsts.map_err(|_| bad())?;
        b.add_edge(src, dsts, w);
    }
    Ok(b.build())
}

fn write_u32<W: Write>(w: &mut W, x: u32) -> io::Result<()> {
    w.write_all(&x.to_le_bytes())
}
fn write_u64<W: Write>(w: &mut W, x: u64) -> io::Result<()> {
    w.write_all(&x.to_le_bytes())
}
fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_graph(seed: u64) -> Hypergraph {
        let mut rng = Pcg64::seeded(seed);
        let n = 200;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            if rng.bernoulli(0.9) {
                let k = rng.range(1, 10);
                let dsts: Vec<u32> = (0..k).map(|_| rng.below(n) as u32).collect();
                b.add_edge(s, dsts, rng.next_f32() * 2.0 + 0.001);
            }
        }
        b.build()
    }

    fn graphs_equal(a: &Hypergraph, b: &Hypergraph) -> bool {
        a.num_nodes() == b.num_nodes()
            && a.sources == b.sources
            && a.dst_off == b.dst_off
            && a.dsts == b.dsts
            && a.weights == b.weights
    }

    #[test]
    fn binary_roundtrip() {
        let g = random_graph(11);
        let dir = std::env::temp_dir().join("snnmap_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.hg");
        save_binary(&g, &p).unwrap();
        let g2 = load_binary(&p).unwrap();
        assert!(graphs_equal(&g, &g2));
    }

    #[test]
    fn text_roundtrip() {
        let g = random_graph(13);
        let dir = std::env::temp_dir().join("snnmap_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        save_text(&g, &p).unwrap();
        let g2 = load_text(&p).unwrap();
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.num_edges(), g2.num_edges());
        assert_eq!(g.dsts, g2.dsts);
        for e in g.edge_ids() {
            assert!((g.weight(e) - g2.weight(e)).abs() < 1e-6);
        }
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("snnmap_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.hg");
        std::fs::write(&p, b"NOTMAGIC").unwrap();
        assert!(load_binary(&p).is_err());
    }

    #[test]
    fn text_skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("snnmap_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("comments.txt");
        std::fs::write(&p, "3\n# comment\n\n0 1.5 1 2\n").unwrap();
        let g = load_text(&p).unwrap();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.dsts(0), &[1, 2]);
    }
}
