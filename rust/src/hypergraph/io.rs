//! H-graph (de)serialization.
//!
//! Binary format `SNNHG1` (little-endian): header counts, then the flat
//! CSR arrays. Node indices are rebuilt on load (cheaper to recompute than
//! to store). A human-readable text format (one h-edge per line:
//! `src w d1 d2 ...`) supports tests, fixtures and interchange with the
//! paper's planned open-source benchmark hypergraphs.
//!
//! The binary reader treats its input as untrusted (DESIGN.md §13): header
//! counts are validated against the stream length before any allocation,
//! offsets are checked for monotonicity and coverage, and every malformed
//! input maps to `InvalidData` instead of an OOM abort or a slice panic.
//! The streaming [`write_binary`]/[`read_binary`] pair is reused by the
//! `SNNCK1` checkpoint format to embed per-level graphs.

use super::{Hypergraph, HypergraphBuilder};
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"SNNHG1";

/// Header size in bytes: magic + three u64 counts.
const HEADER_BYTES: u64 = 6 + 3 * 8;

/// Preallocation cap (in elements) for streams whose length is unknown:
/// hostile counts then fail at `read_exact` instead of aborting on a
/// multi-terabyte `Vec::with_capacity`.
const PREALLOC_CAP: usize = 1 << 20;

/// Write `g` to `path` in the binary format.
pub fn save_binary(g: &Hypergraph, path: &Path) -> io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    write_binary(g, &mut w)?;
    w.flush()
}

/// Stream `g` to any writer in the binary format.
pub fn write_binary<W: Write>(g: &Hypergraph, w: &mut W) -> io::Result<()> {
    w.write_all(MAGIC)?;
    write_u64(w, g.num_nodes() as u64)?;
    write_u64(w, g.num_edges() as u64)?;
    write_u64(w, g.num_connections() as u64)?;
    for &s in &g.sources {
        write_u32(w, s)?;
    }
    for &o in &g.dst_off {
        write_u64(w, o as u64)?;
    }
    for &d in &g.dsts {
        write_u32(w, d)?;
    }
    for &x in &g.weights {
        write_u32(w, x.to_bits())?;
    }
    Ok(())
}

/// Load a binary h-graph from `path`. The file length bounds the header
/// counts, so corrupt/hostile files are rejected before allocation.
pub fn load_binary(path: &Path) -> io::Result<Hypergraph> {
    let f = std::fs::File::open(path)?;
    let limit = f.metadata().ok().map(|m| m.len());
    let mut r = BufReader::new(f);
    read_binary(&mut r, limit)
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Read a binary h-graph from any reader. `byte_limit`, when known (file
/// length, or an embedding section's length), is an upper bound on the
/// whole stream including the header; header counts implying more bytes
/// than that are rejected up front.
pub fn read_binary<R: Read>(r: &mut R, byte_limit: Option<u64>) -> io::Result<Hypergraph> {
    let mut magic = [0u8; 6];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("bad magic"));
    }
    let n64 = read_u64(r)?;
    let e64 = read_u64(r)?;
    let c64 = read_u64(r)?;
    // Node/edge ids are u32 on the wire — counts beyond that id space
    // cannot describe a well-formed graph, and rejecting them also bounds
    // the builder's O(n) index allocation.
    let id_space = u32::MAX as u64 + 1;
    if n64 > id_space || e64 > id_space {
        return Err(bad(format!("counts exceed u32 id space: n={n64} e={e64}")));
    }
    // Untrusted header counts: bound the implied body size (checked
    // arithmetic — u64::MAX counts must not wrap into plausibility).
    let body = e64
        .checked_mul(4) // sources
        .and_then(|b| (e64 + 1).checked_mul(8).and_then(|x| b.checked_add(x))) // dst_off
        .and_then(|b| c64.checked_mul(4).and_then(|x| b.checked_add(x))) // dsts
        .and_then(|b| e64.checked_mul(4).and_then(|x| b.checked_add(x))) // weights
        .ok_or_else(|| bad("header counts overflow"))?;
    if let Some(limit) = byte_limit {
        if body.checked_add(HEADER_BYTES).is_none_or(|total| total > limit) {
            return Err(bad(format!("header counts imply {body} body bytes, stream has at most {limit}")));
        }
    }
    let n = n64 as usize;
    let e = e64 as usize;
    let c = c64 as usize;
    let mut sources = Vec::with_capacity(e.min(PREALLOC_CAP));
    for _ in 0..e {
        sources.push(read_u32(r)?);
    }
    let mut dst_off = Vec::with_capacity((e + 1).min(PREALLOC_CAP));
    for _ in 0..=e {
        let o = read_u64(r)?;
        if o > c64 {
            return Err(bad(format!("dst offset {o} exceeds connection count {c64}")));
        }
        dst_off.push(o as usize);
    }
    if dst_off[0] != 0 {
        return Err(bad("dst offsets must start at 0"));
    }
    if dst_off.windows(2).any(|w| w[0] > w[1]) {
        return Err(bad("dst offsets must be non-decreasing"));
    }
    // snn-lint: allow(unwrap-ban) — dst_off is non-empty: dst_off[0] was read two checks above
    if *dst_off.last().unwrap() != c {
        return Err(bad("dst offsets do not cover the connection array"));
    }
    let mut dsts = Vec::with_capacity(c.min(PREALLOC_CAP));
    for _ in 0..c {
        dsts.push(read_u32(r)?);
    }
    let mut weights = Vec::with_capacity(e.min(PREALLOC_CAP));
    for _ in 0..e {
        weights.push(f32::from_bits(read_u32(r)?));
    }
    // Rebuild through the builder to regenerate node indices and validate.
    let mut b = HypergraphBuilder::new(n);
    b.reserve(e, c);
    for i in 0..e {
        let slice = &dsts[dst_off[i]..dst_off[i + 1]];
        b.add_edge_sorted(sources[i], slice, weights[i]);
    }
    let g = b.build();
    g.validate()
        .map_err(|m| io::Error::new(io::ErrorKind::InvalidData, m))?;
    Ok(g)
}

/// Write the text format: first line `n`, then one line per h-edge.
pub fn save_text(g: &Hypergraph, path: &Path) -> io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{}", g.num_nodes())?;
    for e in g.edge_ids() {
        write!(w, "{} {}", g.source(e), g.weight(e))?;
        for &d in g.dsts(e) {
            write!(w, " {}", d)?;
        }
        writeln!(w)?;
    }
    w.flush()
}

/// Load the text format.
pub fn load_text(path: &Path) -> io::Result<Hypergraph> {
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let mut lines = r.lines();
    let n: usize = lines
        .next()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "empty file"))??
        .trim()
        .parse()
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad node count"))?;
    let mut b = HypergraphBuilder::new(n);
    for line in lines {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let bad = || io::Error::new(io::ErrorKind::InvalidData, "bad edge line");
        let src: u32 = it.next().ok_or_else(bad)?.parse().map_err(|_| bad())?;
        let w: f32 = it.next().ok_or_else(bad)?.parse().map_err(|_| bad())?;
        let dsts: Result<Vec<u32>, _> = it.map(|t| t.parse::<u32>()).collect();
        let dsts = dsts.map_err(|_| bad())?;
        b.add_edge(src, dsts, w);
    }
    Ok(b.build())
}

fn write_u32<W: Write>(w: &mut W, x: u32) -> io::Result<()> {
    w.write_all(&x.to_le_bytes())
}
fn write_u64<W: Write>(w: &mut W, x: u64) -> io::Result<()> {
    w.write_all(&x.to_le_bytes())
}
fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_graph(seed: u64) -> Hypergraph {
        let mut rng = Pcg64::seeded(seed);
        let n = 200;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            if rng.bernoulli(0.9) {
                let k = rng.range(1, 10);
                let dsts: Vec<u32> = (0..k).map(|_| rng.below(n) as u32).collect();
                b.add_edge(s, dsts, rng.next_f32() * 2.0 + 0.001);
            }
        }
        b.build()
    }

    fn graphs_equal(a: &Hypergraph, b: &Hypergraph) -> bool {
        a.num_nodes() == b.num_nodes()
            && a.sources == b.sources
            && a.dst_off == b.dst_off
            && a.dsts == b.dsts
            && a.weights == b.weights
    }

    #[test]
    fn binary_roundtrip() {
        let g = random_graph(11);
        let dir = std::env::temp_dir().join("snnmap_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.hg");
        save_binary(&g, &p).unwrap();
        let g2 = load_binary(&p).unwrap();
        assert!(graphs_equal(&g, &g2));
    }

    #[test]
    fn text_roundtrip() {
        let g = random_graph(13);
        let dir = std::env::temp_dir().join("snnmap_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        save_text(&g, &p).unwrap();
        let g2 = load_text(&p).unwrap();
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.num_edges(), g2.num_edges());
        assert_eq!(g.dsts, g2.dsts);
        for e in g.edge_ids() {
            assert!((g.weight(e) - g2.weight(e)).abs() < 1e-6);
        }
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("snnmap_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.hg");
        std::fs::write(&p, b"NOTMAGIC").unwrap();
        assert!(load_binary(&p).is_err());
    }

    /// Hand-assemble a raw SNNHG1 stream from header counts + body words.
    fn craft(n: u64, e: u64, c: u64, body: &[(u8, u64)]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        for x in [n, e, c] {
            out.extend_from_slice(&x.to_le_bytes());
        }
        for &(width, word) in body {
            match width {
                4 => out.extend_from_slice(&(word as u32).to_le_bytes()),
                8 => out.extend_from_slice(&word.to_le_bytes()),
                _ => unreachable!(),
            }
        }
        out
    }

    fn load_bytes(name: &str, bytes: &[u8]) -> io::Result<Hypergraph> {
        let dir = std::env::temp_dir().join("snnmap_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, bytes).unwrap();
        load_binary(&p)
    }

    fn assert_invalid(res: io::Result<Hypergraph>) {
        match res {
            Err(e) => assert_eq!(e.kind(), io::ErrorKind::InvalidData, "kind={:?}", e.kind()),
            Ok(_) => panic!("malformed file was accepted"),
        }
    }

    #[test]
    fn binary_rejects_truncated_body() {
        let g = random_graph(17);
        let dir = std::env::temp_dir().join("snnmap_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.hg");
        save_binary(&g, &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        // Chop off the tail: header counts now exceed the file size and
        // must be rejected before any allocation.
        std::fs::write(&p, &full[..full.len() - 16]).unwrap();
        assert_invalid(load_binary(&p));
    }

    #[test]
    fn binary_rejects_huge_counts() {
        // Counts whose implied body size overflows u64 / dwarfs the file:
        // previously a `Vec::with_capacity(u64::MAX as usize)` OOM abort.
        assert_invalid(load_bytes("huge1.hg", &craft(4, u64::MAX, 2, &[])));
        assert_invalid(load_bytes("huge2.hg", &craft(4, 2, u64::MAX, &[])));
        // Counts past the u32 id space are structurally impossible.
        assert_invalid(load_bytes("huge3.hg", &craft(1 << 33, 0, 0, &[(8, 0)])));
    }

    #[test]
    fn binary_rejects_bad_offsets() {
        // n=4, e=2, c=3; sources [0,1]; then a dst_off table of 3 u64s,
        // dsts [2,3,3 as u32], weights [2 f32 words].
        let tail: &[(u8, u64)] = &[(4, 2), (4, 3), (4, 3), (4, 0x3f80_0000), (4, 0x3f80_0000)];
        let mk = |offs: [u64; 3]| {
            let mut body: Vec<(u8, u64)> = vec![(4, 0), (4, 1)];
            body.extend(offs.iter().map(|&o| (8u8, o)));
            body.extend_from_slice(tail);
            craft(4, 2, 3, &body)
        };
        // Decreasing offsets: previously panicked slicing dsts[2..1].
        assert_invalid(load_bytes("offdec.hg", &mk([0, 2, 1])));
        // First offset nonzero.
        assert_invalid(load_bytes("offstart.hg", &mk([1, 2, 3])));
        // Offset beyond the connection array.
        assert_invalid(load_bytes("offover.hg", &mk([0, 2, 9])));
        // Last offset short of the connection array.
        assert_invalid(load_bytes("offshort.hg", &mk([0, 1, 2])));
        // Sanity: the well-formed variant of the same stream loads.
        let g = load_bytes("offok.hg", &mk([0, 2, 3])).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.num_connections(), 3);
    }

    #[test]
    fn streaming_roundtrip_with_limit() {
        let g = random_graph(19);
        let mut buf = Vec::new();
        write_binary(&g, &mut buf).unwrap();
        let mut cursor: &[u8] = &buf;
        let g2 = read_binary(&mut cursor, Some(buf.len() as u64)).unwrap();
        assert!(graphs_equal(&g, &g2));
        // A limit tighter than the header's implied size is rejected.
        let mut cursor: &[u8] = &buf;
        assert_invalid(read_binary(&mut cursor, Some(buf.len() as u64 - 1)));
    }

    #[test]
    fn text_skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("snnmap_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("comments.txt");
        std::fs::write(&p, "3\n# comment\n\n0 1.5 1 2\n").unwrap();
        let g = load_text(&p).unwrap();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.dsts(0), &[1, 2]);
    }
}
