//! Incremental hypergraph construction.
//!
//! The builder accepts h-edges in any order, sorts/dedups destination sets,
//! drops empty h-edges, and assembles the CSR payload plus both auxiliary
//! node indices in two linear passes.

use super::{EdgeId, Hypergraph, NodeId};

/// Builder for [`Hypergraph`].
#[derive(Debug, Default)]
pub struct HypergraphBuilder {
    n_nodes: usize,
    sources: Vec<NodeId>,
    dst_off: Vec<usize>,
    dsts: Vec<NodeId>,
    weights: Vec<f32>,
}

impl HypergraphBuilder {
    /// Start a builder over `n_nodes` nodes (ids `0..n_nodes`).
    pub fn new(n_nodes: usize) -> Self {
        HypergraphBuilder {
            n_nodes,
            sources: Vec::new(),
            dst_off: vec![0],
            dsts: Vec::new(),
            weights: Vec::new(),
        }
    }

    /// Reserve capacity for `edges` h-edges totalling `connections`
    /// destinations (avoids reallocation churn on large generators).
    pub fn reserve(&mut self, edges: usize, connections: usize) {
        self.sources.reserve(edges);
        self.dst_off.reserve(edges);
        self.weights.reserve(edges);
        self.dsts.reserve(connections);
    }

    /// Add the h-edge `(source, dsts)` with spike frequency `weight`.
    /// Destinations are sorted and deduplicated; empty destination sets are
    /// dropped (an axon reaching no neuron transmits nothing).
    pub fn add_edge(&mut self, source: NodeId, mut dsts: Vec<NodeId>, weight: f32) {
        dsts.sort_unstable();
        dsts.dedup();
        self.add_edge_sorted(source, &dsts, weight);
    }

    /// Add an h-edge whose destination slice is already sorted + unique.
    pub fn add_edge_sorted(&mut self, source: NodeId, dsts: &[NodeId], weight: f32) {
        debug_assert!(dsts.windows(2).all(|w| w[0] < w[1]), "dsts must be sorted unique");
        if dsts.is_empty() {
            return;
        }
        debug_assert!((source as usize) < self.n_nodes);
        debug_assert!(weight.is_finite() && weight >= 0.0);
        self.sources.push(source);
        self.dsts.extend_from_slice(dsts);
        self.dst_off.push(self.dsts.len());
        self.weights.push(weight);
    }

    /// Number of h-edges added so far.
    pub fn num_edges(&self) -> usize {
        self.sources.len()
    }

    /// Finalize: build the inbound/outbound CSR indices.
    pub fn build(self) -> Hypergraph {
        let n = self.n_nodes;
        let e = self.sources.len();

        // Outbound: counting sort of edge ids by source.
        let mut out_off = vec![0usize; n + 1];
        for &s in &self.sources {
            out_off[s as usize + 1] += 1;
        }
        for i in 0..n {
            out_off[i + 1] += out_off[i];
        }
        let mut out_edges = vec![0 as EdgeId; e];
        let mut cursor = out_off.clone();
        for (eid, &s) in self.sources.iter().enumerate() {
            out_edges[cursor[s as usize]] = eid as EdgeId;
            cursor[s as usize] += 1;
        }

        // Inbound: counting sort of edge ids by destination membership.
        let mut in_off = vec![0usize; n + 1];
        for &d in &self.dsts {
            in_off[d as usize + 1] += 1;
        }
        for i in 0..n {
            in_off[i + 1] += in_off[i];
        }
        let mut in_edges = vec![0 as EdgeId; self.dsts.len()];
        let mut cursor = in_off.clone();
        for eid in 0..e {
            for &d in &self.dsts[self.dst_off[eid]..self.dst_off[eid + 1]] {
                in_edges[cursor[d as usize]] = eid as EdgeId;
                cursor[d as usize] += 1;
            }
        }

        Hypergraph {
            n_nodes: n,
            sources: self.sources,
            dst_off: self.dst_off,
            dsts: self.dsts,
            weights: self.weights,
            in_off,
            in_edges,
            out_off,
            out_edges,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedups_and_sorts_destinations() {
        let mut b = HypergraphBuilder::new(5);
        b.add_edge(0, vec![3, 1, 3, 2, 1], 1.0);
        let g = b.build();
        assert_eq!(g.dsts(0), &[1, 2, 3]);
        g.validate().unwrap();
    }

    #[test]
    fn drops_empty_edges() {
        let mut b = HypergraphBuilder::new(3);
        b.add_edge(0, vec![], 1.0);
        b.add_edge(1, vec![2], 1.0);
        let g = b.build();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.source(0), 1);
    }

    #[test]
    fn indices_sorted_within_node() {
        // inbound/outbound edge lists come out in ascending edge id order
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![3], 1.0);
        b.add_edge(1, vec![3], 1.0);
        b.add_edge(2, vec![3], 1.0);
        let g = b.build();
        assert_eq!(g.inbound(3), &[0, 1, 2]);
        g.validate().unwrap();
    }

    #[test]
    fn multi_outbound_allowed() {
        // quotient graphs have several h-edges per source
        let mut b = HypergraphBuilder::new(3);
        b.add_edge(0, vec![1], 1.0);
        b.add_edge(0, vec![2], 2.0);
        let g = b.build();
        assert_eq!(g.outbound(0), &[0, 1]);
        assert!(!g.is_single_axon());
        g.validate().unwrap();
    }

    #[test]
    fn large_counting_sort_consistency() {
        let mut b = HypergraphBuilder::new(1000);
        let mut rng = crate::util::rng::Pcg64::seeded(42);
        for s in 0..1000u32 {
            let k = rng.range(1, 8);
            let dsts: Vec<u32> = (0..k).map(|_| rng.below(1000) as u32).collect();
            b.add_edge(s, dsts, rng.next_f32() + 0.01);
        }
        let g = b.build();
        g.validate().unwrap();
        // spot-check inbound symmetry
        for e in g.edge_ids() {
            for &d in g.dsts(e) {
                assert!(g.inbound(d).contains(&e));
            }
        }
    }
}
