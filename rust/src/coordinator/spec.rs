//! The serializable pipeline description.
//!
//! [`PipelineSpec`] is plain data — stage names + per-stage parameter
//! maps + hardware + seed + thread budget — and round-trips through
//! JSON. It is the single source of truth for a mapping run: the
//! builder API, the experiment grid, the ensemble racer and the CLI all
//! construct one of these (explicitly or through shims) and hand it to
//! [`super::pipeline::MapperPipeline::from_spec`].
//!
//! Document shape (stages accept the string shorthand when they carry
//! no parameters):
//!
//! ```json
//! {
//!   "partitioner": {"name": "hierarchical", "params": {"refine_passes": 3}},
//!   "placer": "spectral",
//!   "refiner": "force",
//!   "hw": {"preset": "small", "scale": 0.1},
//!   "seed": 42,
//!   "threads": 4
//! }
//! ```

use crate::hw::faults::FaultSpec;
use crate::hw::NmhConfig;
use crate::stage::StageParams;
use crate::util::json::Json;

/// One stage reference: a registry name plus its parameter map.
#[derive(Clone, Debug, PartialEq)]
pub struct StageSpec {
    pub name: String,
    pub params: StageParams,
}

impl StageSpec {
    /// A named stage with default parameters.
    pub fn new(name: &str) -> StageSpec {
        StageSpec { name: name.to_string(), params: StageParams::empty() }
    }

    /// A named stage with explicit parameters.
    pub fn with_params(name: &str, params: StageParams) -> StageSpec {
        StageSpec { name: name.to_string(), params }
    }

    /// Serialize: the bare name when parameter-free, else
    /// `{"name": ..., "params": {...}}`.
    pub fn to_json(&self) -> Json {
        if self.params.is_empty() {
            Json::Str(self.name.clone())
        } else {
            Json::obj(vec![
                ("name", Json::Str(self.name.clone())),
                ("params", self.params.to_json()),
            ])
        }
    }

    /// Parse either form.
    pub fn from_json(doc: &Json) -> Result<StageSpec, String> {
        match doc {
            Json::Str(name) => Ok(StageSpec::new(name)),
            Json::Obj(_) => {
                let name = doc
                    .get("name")
                    .as_str()
                    .ok_or("stage object needs a string 'name' field")?;
                let params = StageParams::from_json(doc.get("params"))?;
                Ok(StageSpec { name: name.to_string(), params })
            }
            other => Err(format!("stage must be a name or {{name, params}} object, got {other:?}")),
        }
    }
}

/// A complete, serializable description of one mapping run.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineSpec {
    pub hw: NmhConfig,
    pub partitioner: StageSpec,
    pub placer: StageSpec,
    pub refiner: StageSpec,
    /// Pipeline seed. JSON serialization is exact only up to 2^53
    /// (JSON numbers are f64); `from_json` rejects anything beyond.
    pub seed: u64,
    /// Worker-pool width for the parallel stages (performance knob only,
    /// never observable in results — DESIGN.md §6).
    pub threads: usize,
    /// Optional hardware fault description (DESIGN.md §15) — explicit
    /// mask or seeded sampling model, resolved against `hw` at pipeline
    /// construction. `None` (the default, and what pre-fault spec
    /// documents parse to) is the pristine lattice.
    pub faults: Option<FaultSpec>,
}

impl PipelineSpec {
    /// The default pipeline (the paper's headline combination) on `hw`.
    pub fn new(hw: NmhConfig) -> PipelineSpec {
        PipelineSpec {
            hw,
            partitioner: StageSpec::new("overlap"),
            placer: StageSpec::new("spectral"),
            refiner: StageSpec::new("force"),
            seed: 42,
            threads: crate::util::par::max_threads(),
            faults: None,
        }
    }

    /// Builder-style stage override.
    pub fn partitioner(mut self, s: StageSpec) -> PipelineSpec {
        self.partitioner = s;
        self
    }

    /// Builder-style stage override.
    pub fn placer(mut self, s: StageSpec) -> PipelineSpec {
        self.placer = s;
        self
    }

    /// Builder-style stage override.
    pub fn refiner(mut self, s: StageSpec) -> PipelineSpec {
        self.refiner = s;
        self
    }

    /// Builder-style seed override.
    pub fn seed(mut self, s: u64) -> PipelineSpec {
        self.seed = s;
        self
    }

    /// Builder-style fault-model override.
    pub fn faults(mut self, f: FaultSpec) -> PipelineSpec {
        self.faults = Some(f);
        self
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("partitioner", self.partitioner.to_json()),
            ("placer", self.placer.to_json()),
            ("refiner", self.refiner.to_json()),
            ("hw", self.hw.to_json()),
            ("seed", Json::Num(self.seed as f64)),
            ("threads", Json::Num(self.threads as f64)),
        ];
        // omitted when None so pre-fault documents round-trip unchanged
        if let Some(f) = &self.faults {
            fields.push(("faults", f.to_json()));
        }
        Json::obj(fields)
    }

    /// Parse a spec document; missing fields fall back to the
    /// [`Self::new`] defaults (hardware: the "small" preset). Unknown
    /// top-level keys are rejected, matching the strict per-stage
    /// parameter parsing — a typo'd field fails instead of silently
    /// running with a default.
    pub fn from_json(doc: &Json) -> Result<PipelineSpec, String> {
        let Some(obj) = doc.as_obj() else {
            return Err("pipeline spec must be a JSON object".to_string());
        };
        const KNOWN: [&str; 7] =
            ["partitioner", "placer", "refiner", "hw", "seed", "threads", "faults"];
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!("unknown spec field '{key}' (accepted: {})", KNOWN.join(", ")));
            }
        }
        let hw_doc = doc.get("hw");
        let hw = if hw_doc.as_obj().is_some() {
            NmhConfig::from_json(hw_doc)?
        } else {
            NmhConfig::small()
        };
        let mut spec = PipelineSpec::new(hw);
        for (field, slot) in [
            ("partitioner", &mut spec.partitioner),
            ("placer", &mut spec.placer),
            ("refiner", &mut spec.refiner),
        ] {
            let stage_doc = doc.get(field);
            if *stage_doc != Json::Null {
                *slot = StageSpec::from_json(stage_doc).map_err(|e| format!("{field}: {e}"))?;
            }
        }
        if let Some(seed) = doc.get("seed").as_f64() {
            // JSON numbers are f64: seeds are exact only up to 2^53, and
            // negatives are rejected rather than silently saturated.
            if seed < 0.0 || seed.fract() != 0.0 || seed > 9_007_199_254_740_992.0 {
                return Err(format!("seed must be an integer in [0, 2^53], got {seed}"));
            }
            spec.seed = seed as u64;
        }
        if let Some(threads) = doc.get("threads").as_usize() {
            spec.threads = threads.max(1);
        }
        let faults_doc = doc.get("faults");
        if *faults_doc != Json::Null {
            spec.faults = Some(FaultSpec::from_json(faults_doc).map_err(|e| format!("faults: {e}"))?);
        }
        Ok(spec)
    }

    /// Parse from JSON text.
    pub fn from_json_str(text: &str) -> Result<PipelineSpec, String> {
        PipelineSpec::from_json(&Json::parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_spec_both_forms_parse() {
        let bare = StageSpec::from_json(&Json::parse("\"overlap\"").unwrap()).unwrap();
        assert_eq!(bare, StageSpec::new("overlap"));
        let full = StageSpec::from_json(
            &Json::parse(r#"{"name": "streaming", "params": {"window": 32}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(full.name, "streaming");
        assert_eq!(full.params.get_usize("window").unwrap(), Some(32));
        assert!(StageSpec::from_json(&Json::Num(3.0)).is_err());
        assert!(StageSpec::from_json(&Json::parse(r#"{"params": {}}"#).unwrap()).is_err());
    }

    #[test]
    fn spec_json_roundtrip_exact() {
        let mut spec = PipelineSpec::new(NmhConfig::small().scaled(0.06)).seed(9);
        spec.partitioner = StageSpec::with_params(
            "hierarchical",
            StageParams::empty().set("refine_passes", Json::Num(3.0)),
        );
        spec.placer = StageSpec::new("hilbert");
        spec.refiner = StageSpec::new("none");
        spec.threads = 2;
        let text = spec.to_json().to_pretty();
        let back = PipelineSpec::from_json_str(&text).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn spec_defaults_fill_missing_fields() {
        let spec = PipelineSpec::from_json_str(r#"{"partitioner": "edgemap"}"#).unwrap();
        assert_eq!(spec.partitioner, StageSpec::new("edgemap"));
        assert_eq!(spec.placer, StageSpec::new("spectral"));
        assert_eq!(spec.refiner, StageSpec::new("force"));
        assert_eq!(spec.hw, NmhConfig::small());
        assert_eq!(spec.seed, 42);
        assert!(PipelineSpec::from_json_str("[1, 2]").is_err());
    }

    #[test]
    fn spec_rejects_unknown_fields_and_bad_seeds() {
        assert!(PipelineSpec::from_json_str(r#"{"sead": 7}"#).is_err());
        assert!(PipelineSpec::from_json_str(r#"{"seed": -1}"#).is_err());
        assert!(PipelineSpec::from_json_str(r#"{"seed": 1.5}"#).is_err());
        assert!(PipelineSpec::from_json_str(r#"{"hw": {"c_ncp": 9}}"#).is_err());
        assert!(PipelineSpec::from_json_str(r#"{"seed": 7}"#).is_ok());
        assert!(PipelineSpec::from_json_str(r#"{"faults": {"mode": "nope"}}"#).is_err());
    }

    #[test]
    fn spec_faults_roundtrip_and_default_to_none() {
        use crate::hw::faults::{FaultMask, FaultRates, FaultSpec};
        // pre-fault documents parse to None and re-serialize without the key
        let spec = PipelineSpec::from_json_str(r#"{"seed": 7}"#).unwrap();
        assert_eq!(spec.faults, None);
        assert!(!spec.to_json().to_string().contains("faults"));
        // sampled form
        let spec = PipelineSpec::new(NmhConfig::small())
            .faults(FaultSpec::Sampled { rates: FaultRates::uniform(0.05), seed: 7 });
        let back = PipelineSpec::from_json_str(&spec.to_json().to_pretty()).unwrap();
        assert_eq!(back, spec);
        // explicit-mask form
        let mut mask = FaultMask::healthy(&NmhConfig::small());
        mask.kill_core(5, 9);
        let spec = PipelineSpec::new(NmhConfig::small()).faults(FaultSpec::Explicit(mask));
        let back = PipelineSpec::from_json_str(&spec.to_json().to_pretty()).unwrap();
        assert_eq!(back, spec);
    }
}
