//! Experiment grid runner: reproduces the paper's evaluation sweeps
//! (Figs. 9-11) over the network suite × algorithm combinations, with
//! optional thread-parallel execution across networks.

use super::pipeline::{MapperPipeline, PartitionerKind, PlacerKind, RefinerKind};
use crate::hw::NmhConfig;
use crate::snn::{self, Network};
use std::time::Duration;

/// One grid cell result: everything Figs. 9-11 plot.
#[derive(Debug, Clone)]
pub struct ExperimentRow {
    pub network: String,
    pub nodes: usize,
    pub connections: usize,
    pub partitioner: &'static str,
    pub placer: &'static str,
    pub refiner: &'static str,
    pub partitions: usize,
    pub connectivity: f64,
    pub energy: f64,
    pub latency: f64,
    pub congestion: f64,
    pub elp: f64,
    pub sr_arith: f64,
    pub sr_geo: f64,
    pub cl_arith: f64,
    pub cl_geo: f64,
    pub partition_time: Duration,
    pub placement_time: Duration,
    pub error: Option<String>,
}

impl ExperimentRow {
    pub const CSV_HEADER: &'static str = "network,nodes,connections,partitioner,placer,refiner,\
partitions,connectivity,energy,latency,congestion,elp,sr_arith,sr_geo,cl_arith,cl_geo,\
partition_time_s,placement_time_s,error";

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{}",
            self.network,
            self.nodes,
            self.connections,
            self.partitioner,
            self.placer,
            self.refiner,
            self.partitions,
            self.connectivity,
            self.energy,
            self.latency,
            self.congestion,
            self.elp,
            self.sr_arith,
            self.sr_geo,
            self.cl_arith,
            self.cl_geo,
            self.partition_time.as_secs_f64(),
            self.placement_time.as_secs_f64(),
            self.error.as_deref().unwrap_or("")
        )
    }
}

/// Grid specification.
#[derive(Clone)]
pub struct GridSpec {
    pub networks: Vec<String>,
    pub scale: f64,
    pub seed: u64,
    pub partitioners: Vec<PartitionerKind>,
    pub combos: Vec<(PlacerKind, RefinerKind)>,
    /// Threads across networks (1 = sequential; PJRT engine forces 1).
    pub threads: usize,
    /// Per-network hardware override; default = auto by connection count,
    /// constraints scaled alongside the network so partition counts stay
    /// representative (DESIGN.md §5).
    pub hw: Option<NmhConfig>,
}

impl GridSpec {
    /// Fig. 9 grid: all partitioners, placement fixed to Hilbert/none
    /// (partitioning quality is placement-independent).
    pub fn fig9(scale: f64) -> GridSpec {
        GridSpec {
            networks: default_suite(),
            scale,
            seed: 42,
            partitioners: PartitionerKind::ALL.to_vec(),
            combos: vec![(PlacerKind::Hilbert, RefinerKind::None)],
            threads: 1,
            hw: None,
        }
    }

    /// Parse a grid from a JSON config document, e.g.
    ///
    /// ```json
    /// {
    ///   "networks": ["lenet", "16k_rand"],
    ///   "scale": 0.2,
    ///   "seed": 7,
    ///   "partitioners": ["overlap", "hierarchical"],
    ///   "combos": [["hilbert", "force"], ["spectral", "force"]],
    ///   "threads": 2,
    ///   "hw": {"preset": "small", "scale": 0.1}
    /// }
    /// ```
    ///
    /// Missing fields fall back to the fig9 defaults at the given scale.
    pub fn from_json(doc: &crate::util::json::Json) -> Result<GridSpec, String> {
        let scale = doc.get("scale").as_f64().unwrap_or(0.25);
        let mut spec = GridSpec::fig9(scale);
        if let Some(nets) = doc.get("networks").as_arr() {
            spec.networks = nets
                .iter()
                .filter_map(|n| n.as_str().map(String::from))
                .collect();
        }
        if let Some(seed) = doc.get("seed").as_f64() {
            spec.seed = seed as u64;
        }
        if let Some(pks) = doc.get("partitioners").as_arr() {
            spec.partitioners = pks
                .iter()
                .map(|p| {
                    let name = p.as_str().ok_or("partitioner must be a string")?;
                    PartitionerKind::parse(name).ok_or_else(|| format!("unknown partitioner '{name}'"))
                })
                .collect::<Result<_, String>>()?;
        }
        if let Some(combos) = doc.get("combos").as_arr() {
            spec.combos = combos
                .iter()
                .map(|c| {
                    let pair = c.as_arr().ok_or("combo must be [placer, refiner]")?;
                    if pair.len() != 2 {
                        return Err("combo must be [placer, refiner]".to_string());
                    }
                    let pl = pair[0]
                        .as_str()
                        .and_then(PlacerKind::parse)
                        .ok_or_else(|| format!("bad placer {:?}", pair[0]))?;
                    let rf = pair[1]
                        .as_str()
                        .and_then(RefinerKind::parse)
                        .ok_or_else(|| format!("bad refiner {:?}", pair[1]))?;
                    Ok((pl, rf))
                })
                .collect::<Result<_, String>>()?;
        }
        if let Some(t) = doc.get("threads").as_usize() {
            spec.threads = t;
        }
        let hw_doc = doc.get("hw");
        if hw_doc.as_obj().is_some() {
            let preset = hw_doc.get("preset").as_str().unwrap_or("small");
            let mut hw = NmhConfig::preset(preset)
                .ok_or_else(|| format!("unknown hw preset '{preset}'"))?;
            if let Some(f) = hw_doc.get("scale").as_f64() {
                hw = hw.scaled(f);
            }
            spec.hw = Some(hw);
        }
        if spec.networks.is_empty() {
            return Err("config selects no networks".into());
        }
        Ok(spec)
    }

    /// Fig. 10 grid: 3 headline partitioners × all placement combos.
    pub fn fig10(scale: f64) -> GridSpec {
        GridSpec {
            networks: default_suite(),
            scale,
            seed: 42,
            partitioners: vec![
                PartitionerKind::Hierarchical,
                PartitionerKind::HyperedgeOverlap,
                PartitionerKind::Sequential,
            ],
            combos: vec![
                (PlacerKind::Hilbert, RefinerKind::None),
                (PlacerKind::Spectral, RefinerKind::None),
                (PlacerKind::Hilbert, RefinerKind::ForceDirected),
                (PlacerKind::Spectral, RefinerKind::ForceDirected),
                (PlacerKind::MinDistance, RefinerKind::None),
            ],
            threads: 1,
            hw: None,
        }
    }
}

/// The default (feasible-tier) network subset; big nets join via --scale.
pub fn default_suite() -> Vec<String> {
    ["16k_model", "lenet", "allen_v1", "16k_rand"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

/// Hardware for a generated network: preset by connection count, per-core
/// constraints scaled with the experiment scale.
pub fn hw_for(net: &Network, scale: f64) -> NmhConfig {
    NmhConfig::for_connections(net.graph.num_connections()).scaled(scale.min(1.0))
}

/// Run the grid. Returns rows in deterministic (network-major) order —
/// network-level parallelism rides the shared [`crate::util::par`] engine
/// (index-slotted results, so scheduling never reorders the output).
pub fn run_grid(spec: &GridSpec) -> Vec<ExperimentRow> {
    let threads = spec.threads.max(1);
    crate::util::par::par_map(spec.networks.len(), threads, |i| {
        run_network(spec, &spec.networks[i])
    })
    .into_iter()
    .flatten()
    .collect()
}

/// All grid cells of one network.
fn run_network(spec: &GridSpec, name: &str) -> Vec<ExperimentRow> {
    let Some(net) = snn::by_name(name, spec.scale, spec.seed) else {
        return vec![];
    };
    let hw = spec.hw.unwrap_or_else(|| hw_for(&net, spec.scale));
    // Split the pool between grid workers and the metric engine so the
    // two levels of parallelism don't multiply into oversubscription
    // (results are thread-count-invariant either way, DESIGN.md §6).
    let grid_workers = spec.threads.clamp(1, spec.networks.len().max(1));
    let inner_threads = (crate::util::par::max_threads() / grid_workers).max(1);
    let mut rows = Vec::new();
    for &pk in &spec.partitioners {
        for &(pl, rf) in &spec.combos {
            let pipeline = MapperPipeline::new(hw)
                .partitioner(pk)
                .placer(pl)
                .refiner(rf)
                .threads(inner_threads)
                .seed(spec.seed);
            let row = match pipeline.run(&net.graph, net.layer_ranges.as_deref()) {
                Ok(res) => ExperimentRow {
                    network: net.name.clone(),
                    nodes: net.graph.num_nodes(),
                    connections: net.graph.num_connections(),
                    partitioner: pk.name(),
                    placer: pl.name(),
                    refiner: rf.name(),
                    partitions: res.rho.num_parts,
                    connectivity: res.metrics.connectivity,
                    energy: res.metrics.energy,
                    latency: res.metrics.latency,
                    congestion: res.metrics.congestion,
                    elp: res.metrics.elp,
                    sr_arith: res.sr.0,
                    sr_geo: res.sr.1,
                    cl_arith: res.cl.0,
                    cl_geo: res.cl.1,
                    partition_time: res.partition_time,
                    placement_time: res.placement_time,
                    error: None,
                },
                Err(e) => ExperimentRow {
                    network: net.name.clone(),
                    nodes: net.graph.num_nodes(),
                    connections: net.graph.num_connections(),
                    partitioner: pk.name(),
                    placer: pl.name(),
                    refiner: rf.name(),
                    partitions: 0,
                    connectivity: f64::NAN,
                    energy: f64::NAN,
                    latency: f64::NAN,
                    congestion: f64::NAN,
                    elp: f64::NAN,
                    sr_arith: f64::NAN,
                    sr_geo: f64::NAN,
                    cl_arith: f64::NAN,
                    cl_geo: f64::NAN,
                    partition_time: Duration::ZERO,
                    placement_time: Duration::ZERO,
                    error: Some(e.to_string()),
                },
            };
            rows.push(row);
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn json_config_roundtrip() {
        let doc = Json::parse(
            r#"{
              "networks": ["lenet"],
              "scale": 0.1,
              "seed": 9,
              "partitioners": ["overlap", "streaming"],
              "combos": [["hilbert", "none"], ["spectral", "force"]],
              "threads": 2,
              "hw": {"preset": "small", "scale": 0.05}
            }"#,
        )
        .unwrap();
        let spec = GridSpec::from_json(&doc).unwrap();
        assert_eq!(spec.networks, vec!["lenet"]);
        assert_eq!(spec.seed, 9);
        assert_eq!(
            spec.partitioners,
            vec![PartitionerKind::HyperedgeOverlap, PartitionerKind::Streaming]
        );
        assert_eq!(spec.combos.len(), 2);
        assert_eq!(spec.threads, 2);
        assert_eq!(spec.hw.unwrap().c_npc, 51); // 1024 * 0.05
        // and the grid actually runs
        let rows = run_grid(&spec);
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.error.is_none()));
    }

    #[test]
    fn json_config_rejects_bad_fields() {
        for bad in [
            r#"{"networks": [], "scale": 0.1}"#,
            r#"{"partitioners": ["nope"]}"#,
            r#"{"combos": [["hilbert"]]}"#,
            r#"{"hw": {"preset": "huge"}}"#,
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(GridSpec::from_json(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn json_config_defaults() {
        let doc = Json::parse(r#"{"scale": 0.05}"#).unwrap();
        let spec = GridSpec::from_json(&doc).unwrap();
        assert_eq!(spec.networks, default_suite());
        assert!(spec.hw.is_none());
    }

    fn tiny_spec() -> GridSpec {
        GridSpec {
            networks: vec!["lenet".into()],
            scale: 0.1,
            seed: 3,
            partitioners: vec![PartitionerKind::Sequential, PartitionerKind::HyperedgeOverlap],
            combos: vec![(PlacerKind::Hilbert, RefinerKind::None)],
            threads: 1,
            hw: Some(NmhConfig::small().scaled(0.05)),
        }
    }

    #[test]
    fn grid_produces_all_cells() {
        let rows = run_grid(&tiny_spec());
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.partitions > 1);
            assert!(r.elp.is_finite());
        }
    }

    #[test]
    fn csv_rows_parse_back() {
        let rows = run_grid(&tiny_spec());
        let header_cols = ExperimentRow::CSV_HEADER.split(',').count();
        for r in &rows {
            // trailing empty error field: split counts still match
            assert_eq!(r.to_csv().split(',').count(), header_cols, "{}", r.to_csv());
        }
    }

    #[test]
    fn threaded_matches_sequential() {
        let mut spec = tiny_spec();
        spec.networks = vec!["lenet".into(), "16k_rand".into()];
        spec.scale = 0.05;
        let seq = run_grid(&spec);
        spec.threads = 2;
        let par = run_grid(&spec);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.network, b.network);
            assert_eq!(a.partitions, b.partitions);
            assert!((a.connectivity - b.connectivity).abs() < 1e-9);
        }
    }
}
