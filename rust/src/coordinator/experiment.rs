//! Experiment grid runner: reproduces the paper's evaluation sweeps
//! (Figs. 9-11) over the network suite × algorithm combinations, with
//! optional thread-parallel execution across networks.
//!
//! Grid cells are [`StageSpec`] references resolved through the stage
//! registry — the grid is `PipelineSpec`-driven and accepts any
//! registered algorithm, not just the built-in enums.
//!
//! With [`GridSpec::sim_steps`] > 0 every successful cell additionally
//! replays NoC traffic over its mapping: the (sim seed × rate scale)
//! configurations run through one [`crate::sim::simulate_batch`] call
//! per cell, so streams are built once and the cell's fault mask is
//! route-classified once for the whole sweep (DESIGN.md §16).

use super::pipeline::{MapperPipeline, PartitionerKind};
use super::registry::StageRegistry;
use super::report::csv_escape;
use super::spec::{PipelineSpec, StageSpec};
use crate::hw::faults::{FaultRates, FaultSpec};
use crate::hw::NmhConfig;
use crate::snn::{self, Network};
use std::time::Duration;

/// One grid cell result: everything Figs. 9-11 plot.
#[derive(Debug, Clone)]
pub struct ExperimentRow {
    pub network: String,
    pub nodes: usize,
    pub connections: usize,
    pub partitioner: String,
    pub placer: String,
    pub refiner: String,
    /// Uniform dead-core/dead-link rate of the cell's sampled fault mask
    /// (0.0 = fault-free cell).
    pub fault_rate: f64,
    pub partitions: usize,
    pub connectivity: f64,
    pub energy: f64,
    pub latency: f64,
    pub congestion: f64,
    pub elp: f64,
    pub sr_arith: f64,
    pub sr_geo: f64,
    pub cl_arith: f64,
    pub cl_geo: f64,
    /// Mean simulated energy per timestep (pJ) over the cell's replay
    /// batch; `None` when the grid runs without simulation.
    pub sim_energy_per_step: Option<f64>,
    /// Mean of the batch's per-replay mean makespans (ns).
    pub sim_makespan: Option<f64>,
    /// Mean dropped-spike count per replay (0 for fault-free cells).
    pub sim_dropped: Option<f64>,
    pub partition_time: Duration,
    pub placement_time: Duration,
    pub error: Option<String>,
}

impl ExperimentRow {
    /// Column names — the single source of truth for header/row arity
    /// (the field array below is the same fixed size by construction).
    pub const COLUMNS: [&'static str; 23] = [
        "network",
        "nodes",
        "connections",
        "partitioner",
        "placer",
        "refiner",
        "fault_rate",
        "partitions",
        "connectivity",
        "energy",
        "latency",
        "congestion",
        "elp",
        "sr_arith",
        "sr_geo",
        "cl_arith",
        "cl_geo",
        "sim_energy_per_step",
        "sim_makespan",
        "sim_dropped",
        "partition_time_s",
        "placement_time_s",
        "error",
    ];

    /// The CSV header line, derived from [`Self::COLUMNS`].
    pub fn csv_header() -> String {
        Self::COLUMNS.join(",")
    }

    /// Format an optional simulation metric: empty cell when the grid
    /// ran without simulation.
    fn sim_field(v: Option<f64>) -> String {
        v.map(|x| format!("{x:.6e}")).unwrap_or_default()
    }

    /// Row fields in [`Self::COLUMNS`] order, unescaped.
    pub fn csv_fields(&self) -> [String; 23] {
        [
            self.network.clone(),
            self.nodes.to_string(),
            self.connections.to_string(),
            self.partitioner.clone(),
            self.placer.clone(),
            self.refiner.clone(),
            format!("{:.4}", self.fault_rate),
            self.partitions.to_string(),
            format!("{:.6e}", self.connectivity),
            format!("{:.6e}", self.energy),
            format!("{:.6e}", self.latency),
            format!("{:.6e}", self.congestion),
            format!("{:.6e}", self.elp),
            format!("{:.4}", self.sr_arith),
            format!("{:.4}", self.sr_geo),
            format!("{:.4}", self.cl_arith),
            format!("{:.4}", self.cl_geo),
            Self::sim_field(self.sim_energy_per_step),
            Self::sim_field(self.sim_makespan),
            Self::sim_field(self.sim_dropped),
            format!("{:.4}", self.partition_time.as_secs_f64()),
            format!("{:.4}", self.placement_time.as_secs_f64()),
            self.error.clone().unwrap_or_default(),
        ]
    }

    /// Emit the row through the quote-aware writer: commas, quotes and
    /// newlines in free-text fields (network names, error messages) are
    /// RFC-4180-escaped instead of corrupting the column structure.
    pub fn to_csv(&self) -> String {
        let fields = self.csv_fields();
        let mut out = String::new();
        for (i, f) in fields.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&csv_escape(f));
        }
        out
    }
}

/// Grid specification. Stage entries are registry names + params; the
/// compact [`GridSpec::from_json`] form accepts bare strings.
#[derive(Clone)]
pub struct GridSpec {
    pub networks: Vec<String>,
    pub scale: f64,
    pub seed: u64,
    pub partitioners: Vec<StageSpec>,
    pub combos: Vec<(StageSpec, StageSpec)>,
    /// Threads across networks (1 = sequential; PJRT engine forces 1).
    pub threads: usize,
    /// Per-network hardware override; default = auto by connection count,
    /// constraints scaled alongside the network so partition counts stay
    /// representative (DESIGN.md §5).
    pub hw: Option<NmhConfig>,
    /// Fault-rate axis (DESIGN.md §15): each rate r multiplies the grid
    /// with a cell mapped under a seeded uniform-rate fault mask
    /// (`FaultSpec::Sampled` at the grid seed). Empty = fault-free only.
    pub fault_rates: Vec<f64>,
    /// NoC-replay timesteps per simulation config; 0 disables the
    /// post-mapping simulation pass (the sim_* CSV columns stay empty).
    pub sim_steps: usize,
    /// Spike-RNG seeds of the per-cell replay batch; empty = the grid
    /// seed alone.
    pub sim_seeds: Vec<u64>,
    /// Spike-rate multipliers of the per-cell replay batch; empty =
    /// `[1.0]`. The batch is the (seed × rate-scale) cross product, fed
    /// to [`crate::sim::simulate_batch`] in that fixed order.
    pub sim_rate_scales: Vec<f64>,
}

impl GridSpec {
    /// Fig. 9 grid: all partitioners, placement fixed to Hilbert/none
    /// (partitioning quality is placement-independent).
    pub fn fig9(scale: f64) -> GridSpec {
        GridSpec {
            networks: default_suite(),
            scale,
            seed: 42,
            partitioners: PartitionerKind::ALL.iter().map(|k| StageSpec::new(k.name())).collect(),
            combos: vec![(StageSpec::new("hilbert"), StageSpec::new("none"))],
            threads: 1,
            hw: None,
            fault_rates: vec![],
            sim_steps: 0,
            sim_seeds: vec![],
            sim_rate_scales: vec![],
        }
    }

    /// Parse a grid from a JSON config document, e.g.
    ///
    /// ```json
    /// {
    ///   "networks": ["lenet", "16k_rand"],
    ///   "scale": 0.2,
    ///   "seed": 7,
    ///   "partitioners": ["overlap", {"name": "streaming", "params": {"window": 64}}],
    ///   "combos": [["hilbert", "force"], ["spectral", "force"]],
    ///   "threads": 2,
    ///   "hw": {"preset": "small", "scale": 0.1}
    /// }
    /// ```
    ///
    /// Missing fields fall back to the fig9 defaults at the given scale.
    /// Stage names and params are validated against the built-in
    /// registry up front so a bad config fails before any run starts.
    pub fn from_json(doc: &crate::util::json::Json) -> Result<GridSpec, String> {
        let registry = StageRegistry::global();
        if let Some(obj) = doc.as_obj() {
            const KNOWN: [&str; 11] = [
                "networks",
                "scale",
                "seed",
                "partitioners",
                "combos",
                "threads",
                "hw",
                "fault_rates",
                "sim_steps",
                "sim_seeds",
                "sim_rate_scales",
            ];
            for key in obj.keys() {
                if !KNOWN.contains(&key.as_str()) {
                    return Err(format!(
                        "unknown config field '{key}' (accepted: {})",
                        KNOWN.join(", ")
                    ));
                }
            }
        } else {
            return Err("grid config must be a JSON object".to_string());
        }
        let scale = doc.get("scale").as_f64().unwrap_or(0.25);
        let mut spec = GridSpec::fig9(scale);
        if let Some(nets) = doc.get("networks").as_arr() {
            spec.networks = nets
                .iter()
                .filter_map(|n| n.as_str().map(String::from))
                .collect();
        }
        if let Some(seed) = doc.get("seed").as_f64() {
            spec.seed = seed as u64;
        }
        if let Some(pks) = doc.get("partitioners").as_arr() {
            spec.partitioners = pks
                .iter()
                .map(|p| {
                    let s = StageSpec::from_json(p)?;
                    registry.partitioner(&s.name, &s.params).map_err(|e| e.to_string())?;
                    Ok(s)
                })
                .collect::<Result<_, String>>()?;
        }
        if let Some(combos) = doc.get("combos").as_arr() {
            spec.combos = combos
                .iter()
                .map(|c| {
                    let pair = c.as_arr().ok_or("combo must be [placer, refiner]")?;
                    if pair.len() != 2 {
                        return Err("combo must be [placer, refiner]".to_string());
                    }
                    let pl = StageSpec::from_json(&pair[0])?;
                    registry.placer(&pl.name, &pl.params).map_err(|e| e.to_string())?;
                    let rf = StageSpec::from_json(&pair[1])?;
                    registry.refiner(&rf.name, &rf.params).map_err(|e| e.to_string())?;
                    Ok((pl, rf))
                })
                .collect::<Result<_, String>>()?;
        }
        if let Some(t) = doc.get("threads").as_usize() {
            spec.threads = t;
        }
        let hw_doc = doc.get("hw");
        if hw_doc.as_obj().is_some() {
            spec.hw = Some(NmhConfig::from_json(hw_doc)?);
        }
        if let Some(rates) = doc.get("fault_rates").as_arr() {
            spec.fault_rates = rates
                .iter()
                .map(|r| {
                    let v = r.as_f64().ok_or("fault_rates entries must be numbers")?;
                    if !(0.0..=1.0).contains(&v) {
                        return Err(format!("fault rate must be in [0, 1], got {v}"));
                    }
                    Ok(v)
                })
                .collect::<Result<_, String>>()?;
        }
        if let Some(steps) = doc.get("sim_steps").as_usize() {
            spec.sim_steps = steps;
        }
        if let Some(seeds) = doc.get("sim_seeds").as_arr() {
            spec.sim_seeds = seeds
                .iter()
                .map(|s| {
                    s.as_f64()
                        .map(|v| v as u64)
                        .ok_or_else(|| "sim_seeds entries must be numbers".to_string())
                })
                .collect::<Result<_, String>>()?;
        }
        if let Some(scales) = doc.get("sim_rate_scales").as_arr() {
            spec.sim_rate_scales = scales
                .iter()
                .map(|r| {
                    let v = r.as_f64().ok_or("sim_rate_scales entries must be numbers")?;
                    if !(v.is_finite() && v > 0.0) {
                        return Err(format!("sim rate scale must be finite and > 0, got {v}"));
                    }
                    Ok(v)
                })
                .collect::<Result<_, String>>()?;
        }
        if spec.networks.is_empty() {
            return Err("config selects no networks".into());
        }
        Ok(spec)
    }

    /// Fig. 10 grid: 3 headline partitioners × all placement combos.
    pub fn fig10(scale: f64) -> GridSpec {
        GridSpec {
            networks: default_suite(),
            scale,
            seed: 42,
            partitioners: vec![
                StageSpec::new("hierarchical"),
                StageSpec::new("overlap"),
                StageSpec::new("sequential"),
            ],
            combos: vec![
                (StageSpec::new("hilbert"), StageSpec::new("none")),
                (StageSpec::new("spectral"), StageSpec::new("none")),
                (StageSpec::new("hilbert"), StageSpec::new("force")),
                (StageSpec::new("spectral"), StageSpec::new("force")),
                (StageSpec::new("mindist"), StageSpec::new("none")),
            ],
            threads: 1,
            hw: None,
            fault_rates: vec![],
            sim_steps: 0,
            sim_seeds: vec![],
            sim_rate_scales: vec![],
        }
    }
}

/// The default (feasible-tier) network subset; big nets join via --scale.
pub fn default_suite() -> Vec<String> {
    ["16k_model", "lenet", "allen_v1", "16k_rand"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

/// Hardware for a generated network: preset by connection count, per-core
/// constraints scaled with the experiment scale.
pub fn hw_for(net: &Network, scale: f64) -> NmhConfig {
    NmhConfig::for_connections(net.graph.num_connections()).scaled(scale.min(1.0))
}

/// Run the grid. Returns rows in deterministic (network-major) order —
/// network-level parallelism rides the shared [`crate::util::par`] engine
/// (index-slotted results, so scheduling never reorders the output).
pub fn run_grid(spec: &GridSpec) -> Vec<ExperimentRow> {
    let threads = spec.threads.max(1);
    crate::util::par::par_map(spec.networks.len(), threads, |i| {
        run_network(spec, &spec.networks[i])
    })
    .into_iter()
    .flatten()
    .collect()
}

/// All grid cells of one network.
fn run_network(spec: &GridSpec, name: &str) -> Vec<ExperimentRow> {
    let Some(net) = snn::by_name(name, spec.scale, spec.seed) else {
        return vec![];
    };
    let hw = spec.hw.unwrap_or_else(|| hw_for(&net, spec.scale));
    // Split the pool between grid workers and the metric engine so the
    // two levels of parallelism don't multiply into oversubscription
    // (results are thread-count-invariant either way, DESIGN.md §6).
    let grid_workers = spec.threads.clamp(1, spec.networks.len().max(1));
    let inner_threads = (crate::util::par::max_threads() / grid_workers).max(1);
    let registry = StageRegistry::global();
    // fault axis: a fault-free pass by default, one extra pass per rate
    let fault_axis: Vec<Option<f64>> = if spec.fault_rates.is_empty() {
        vec![None]
    } else {
        spec.fault_rates.iter().copied().map(Some).collect()
    };
    let mut rows = Vec::new();
    for pk in &spec.partitioners {
        for (pl, rf) in &spec.combos {
            for &rate in &fault_axis {
                // each cell is one PipelineSpec — the single source of truth
                let cell = PipelineSpec {
                    hw,
                    partitioner: pk.clone(),
                    placer: pl.clone(),
                    refiner: rf.clone(),
                    seed: spec.seed,
                    threads: inner_threads,
                    faults: rate.map(|r| FaultSpec::Sampled {
                        rates: FaultRates::uniform(r),
                        seed: spec.seed,
                    }),
                };
                let outcome = MapperPipeline::from_spec_with(registry, &cell)
                    .and_then(|p| p.run(&net.graph, net.layer_ranges.as_deref()).map(|r| (p, r)));
                let row = match outcome {
                    Ok((pipeline, res)) => {
                        let (sim_energy_per_step, sim_makespan, sim_dropped) =
                            simulate_cell(spec, &pipeline, &res, inner_threads);
                        ExperimentRow {
                            network: net.name.clone(),
                            nodes: net.graph.num_nodes(),
                            connections: net.graph.num_connections(),
                            partitioner: pk.name.clone(),
                            placer: pl.name.clone(),
                            refiner: rf.name.clone(),
                            fault_rate: rate.unwrap_or(0.0),
                            partitions: res.rho.num_parts,
                            connectivity: res.metrics.connectivity,
                            energy: res.metrics.energy,
                            latency: res.metrics.latency,
                            congestion: res.metrics.congestion,
                            elp: res.metrics.elp,
                            sr_arith: res.sr.0,
                            sr_geo: res.sr.1,
                            cl_arith: res.cl.0,
                            cl_geo: res.cl.1,
                            sim_energy_per_step,
                            sim_makespan,
                            sim_dropped,
                            partition_time: res.partition_time,
                            placement_time: res.placement_time,
                            error: None,
                        }
                    }
                    Err(e) => ExperimentRow {
                        network: net.name.clone(),
                        nodes: net.graph.num_nodes(),
                        connections: net.graph.num_connections(),
                        partitioner: pk.name.clone(),
                        placer: pl.name.clone(),
                        refiner: rf.name.clone(),
                        fault_rate: rate.unwrap_or(0.0),
                        partitions: 0,
                        connectivity: f64::NAN,
                        energy: f64::NAN,
                        latency: f64::NAN,
                        congestion: f64::NAN,
                        elp: f64::NAN,
                        sr_arith: f64::NAN,
                        sr_geo: f64::NAN,
                        cl_arith: f64::NAN,
                        cl_geo: f64::NAN,
                        sim_energy_per_step: None,
                        sim_makespan: None,
                        sim_dropped: None,
                        partition_time: Duration::ZERO,
                        placement_time: Duration::ZERO,
                        error: Some(e.to_string()),
                    },
                };
                rows.push(row);
            }
        }
    }
    rows
}

/// Replay the cell's (seed × rate-scale) simulation batch and reduce it
/// to the three sim_* columns. One [`crate::sim::simulate_batch`] call
/// per cell: streams are built once and the cell's fault mask (shared
/// by every config) is route-classified once. Means are accumulated in
/// the fixed config order, so they are thread-count-invariant like the
/// per-replay reports themselves.
fn simulate_cell(
    spec: &GridSpec,
    pipeline: &MapperPipeline,
    res: &crate::coordinator::pipeline::MappingResult,
    threads: usize,
) -> (Option<f64>, Option<f64>, Option<f64>) {
    if spec.sim_steps == 0 {
        return (None, None, None);
    }
    let seeds: Vec<u64> =
        if spec.sim_seeds.is_empty() { vec![spec.seed] } else { spec.sim_seeds.clone() };
    let scales: Vec<f64> =
        if spec.sim_rate_scales.is_empty() { vec![1.0] } else { spec.sim_rate_scales.clone() };
    let mut configs = Vec::with_capacity(seeds.len() * scales.len());
    for &seed in &seeds {
        for &rate_scale in &scales {
            configs.push(crate::sim::SimConfig {
                params: crate::sim::SimParams {
                    timesteps: spec.sim_steps,
                    seed,
                    poisson_spikes: true,
                },
                rate_scale,
                faults: pipeline.faults.as_ref(),
            });
        }
    }
    let reports =
        crate::sim::simulate_batch(&res.gp, &res.placement, &pipeline.hw, &configs, threads);
    let n = reports.len().max(1) as f64;
    let mut energy_per_step = 0.0;
    let mut makespan = 0.0;
    let mut dropped = 0.0;
    for r in &reports {
        energy_per_step += r.energy_per_step();
        makespan += r.mean_makespan;
        dropped += r.dropped_spikes as f64;
    }
    (Some(energy_per_step / n), Some(makespan / n), Some(dropped / n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn json_config_roundtrip() {
        let doc = Json::parse(
            r#"{
              "networks": ["lenet"],
              "scale": 0.1,
              "seed": 9,
              "partitioners": ["overlap", "streaming"],
              "combos": [["hilbert", "none"], ["spectral", "force"]],
              "threads": 2,
              "hw": {"preset": "small", "scale": 0.05}
            }"#,
        )
        .unwrap();
        let spec = GridSpec::from_json(&doc).unwrap();
        assert_eq!(spec.networks, vec!["lenet"]);
        assert_eq!(spec.seed, 9);
        assert_eq!(
            spec.partitioners,
            vec![StageSpec::new("overlap"), StageSpec::new("streaming")]
        );
        assert_eq!(spec.combos.len(), 2);
        assert_eq!(spec.threads, 2);
        assert_eq!(spec.hw.unwrap().c_npc, 51); // 1024 * 0.05
        // and the grid actually runs
        let rows = run_grid(&spec);
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.error.is_none()));
    }

    #[test]
    fn json_config_accepts_stage_params() {
        let doc = Json::parse(
            r#"{
              "networks": ["lenet"],
              "scale": 0.1,
              "partitioners": [{"name": "streaming", "params": {"window": 16}}],
              "hw": {"preset": "small", "scale": 0.05}
            }"#,
        )
        .unwrap();
        let spec = GridSpec::from_json(&doc).unwrap();
        assert_eq!(spec.partitioners.len(), 1);
        assert_eq!(spec.partitioners[0].name, "streaming");
        assert_eq!(spec.partitioners[0].params.get_usize("window").unwrap(), Some(16));
    }

    #[test]
    fn json_config_rejects_bad_fields() {
        for bad in [
            r#"{"networks": [], "scale": 0.1}"#,
            r#"{"partitioners": ["nope"]}"#,
            r#"{"partitioners": [{"name": "streaming", "params": {"window": "big"}}]}"#,
            r#"{"combos": [["hilbert"]]}"#,
            r#"{"combos": [["hilbert", "nope"]]}"#,
            r#"{"hw": {"preset": "huge"}}"#,
            r#"{"partitoners": ["overlap"]}"#,
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(GridSpec::from_json(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn json_config_defaults() {
        let doc = Json::parse(r#"{"scale": 0.05}"#).unwrap();
        let spec = GridSpec::from_json(&doc).unwrap();
        assert_eq!(spec.networks, default_suite());
        assert!(spec.hw.is_none());
    }

    fn tiny_spec() -> GridSpec {
        GridSpec {
            networks: vec!["lenet".into()],
            scale: 0.1,
            seed: 3,
            partitioners: vec![StageSpec::new("sequential"), StageSpec::new("overlap")],
            combos: vec![(StageSpec::new("hilbert"), StageSpec::new("none"))],
            threads: 1,
            hw: Some(NmhConfig::small().scaled(0.05)),
            fault_rates: vec![],
            sim_steps: 0,
            sim_seeds: vec![],
            sim_rate_scales: vec![],
        }
    }

    #[test]
    fn grid_produces_all_cells() {
        let rows = run_grid(&tiny_spec());
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.partitions > 1);
            assert!(r.elp.is_finite());
        }
    }

    #[test]
    fn csv_rows_parse_back() {
        let rows = run_grid(&tiny_spec());
        let header_cols = ExperimentRow::csv_header().split(',').count();
        assert_eq!(header_cols, ExperimentRow::COLUMNS.len());
        for r in &rows {
            // clean fields: no quoting engaged, split counts still match
            assert_eq!(r.to_csv().split(',').count(), header_cols, "{}", r.to_csv());
        }
    }

    #[test]
    fn csv_quotes_hostile_fields() {
        use crate::coordinator::report::csv_split;
        let mut rows = run_grid(&tiny_spec());
        let row = &mut rows[0];
        row.network = "evil,net \"v2\"".to_string();
        row.error = Some("line1\nline2, still the error".to_string());
        let line = row.to_csv();
        let fields = csv_split(&line);
        assert_eq!(fields.len(), ExperimentRow::COLUMNS.len());
        assert_eq!(fields[0], row.network);
        assert_eq!(fields[22], row.error.clone().unwrap());
    }

    #[test]
    fn sim_columns_empty_when_simulation_is_off() {
        let rows = run_grid(&tiny_spec());
        for r in &rows {
            assert!(r.sim_energy_per_step.is_none());
            assert!(r.sim_makespan.is_none());
            assert!(r.sim_dropped.is_none());
            let fields = r.csv_fields();
            assert_eq!(fields[17], "", "sim_energy_per_step cell");
            assert_eq!(fields[18], "", "sim_makespan cell");
            assert_eq!(fields[19], "", "sim_dropped cell");
        }
    }

    #[test]
    fn sim_columns_populate_from_batched_replay() {
        let mut spec = tiny_spec();
        spec.partitioners = vec![StageSpec::new("sequential")];
        spec.sim_steps = 20;
        spec.sim_seeds = vec![1, 2];
        spec.sim_rate_scales = vec![1.0, 2.0];
        let rows = run_grid(&spec);
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert!(r.error.is_none(), "{:?}", r.error);
        let e = r.sim_energy_per_step.expect("sim energy");
        let m = r.sim_makespan.expect("sim makespan");
        assert!(e.is_finite() && e > 0.0, "energy/step {e}");
        assert!(m.is_finite() && m > 0.0, "makespan {m}");
        assert_eq!(r.sim_dropped, Some(0.0), "fault-free cell drops nothing");
        // deterministic: a rerun reproduces the aggregates bit for bit
        let again = run_grid(&spec);
        assert_eq!(again[0].sim_energy_per_step.unwrap().to_bits(), e.to_bits());
        assert_eq!(again[0].sim_makespan.unwrap().to_bits(), m.to_bits());
    }

    #[test]
    fn json_config_parses_sim_fields() {
        let doc = Json::parse(
            r#"{"scale": 0.05, "sim_steps": 50, "sim_seeds": [3, 4], "sim_rate_scales": [0.5, 1.0]}"#,
        )
        .unwrap();
        let spec = GridSpec::from_json(&doc).unwrap();
        assert_eq!(spec.sim_steps, 50);
        assert_eq!(spec.sim_seeds, vec![3, 4]);
        assert_eq!(spec.sim_rate_scales, vec![0.5, 1.0]);
        for bad in [
            r#"{"sim_rate_scales": [0.0]}"#,
            r#"{"sim_rate_scales": ["fast"]}"#,
            r#"{"sim_seeds": ["a"]}"#,
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(GridSpec::from_json(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn fault_axis_multiplies_cells() {
        let mut spec = tiny_spec();
        spec.partitioners = vec![StageSpec::new("sequential")];
        spec.fault_rates = vec![0.0, 0.05];
        let rows = run_grid(&spec);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].fault_rate, 0.0);
        assert_eq!(rows[1].fault_rate, 0.05);
        for r in &rows {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.elp.is_finite());
        }
        // rate 0.0 samples an all-healthy mask — bit-identical metrics to
        // the fault-free pass (the zero-cost-default guarantee end to end)
        spec.fault_rates = vec![];
        let plain = run_grid(&spec);
        assert_eq!(plain.len(), 1);
        assert_eq!(plain[0].energy.to_bits(), rows[0].energy.to_bits());
        assert_eq!(plain[0].partitions, rows[0].partitions);
    }

    #[test]
    fn json_config_parses_fault_rates() {
        let doc = Json::parse(r#"{"scale": 0.05, "fault_rates": [0.0, 0.1]}"#).unwrap();
        let spec = GridSpec::from_json(&doc).unwrap();
        assert_eq!(spec.fault_rates, vec![0.0, 0.1]);
        for bad in [
            r#"{"fault_rates": [1.5]}"#,
            r#"{"fault_rates": ["high"]}"#,
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(GridSpec::from_json(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn unknown_stage_in_grid_yields_error_row() {
        let mut spec = tiny_spec();
        spec.partitioners = vec![StageSpec::new("no-such-stage")];
        let rows = run_grid(&spec);
        assert_eq!(rows.len(), 1);
        assert!(rows[0].error.as_deref().unwrap().contains("no-such-stage"));
    }

    #[test]
    fn threaded_matches_sequential() {
        let mut spec = tiny_spec();
        spec.networks = vec!["lenet".into(), "16k_rand".into()];
        spec.scale = 0.05;
        let seq = run_grid(&spec);
        spec.threads = 2;
        let par = run_grid(&spec);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.network, b.network);
            assert_eq!(a.partitions, b.partitions);
            assert!((a.connectivity - b.connectivity).abs() < 1e-9);
        }
    }
}
