//! Time-budgeted ensemble mapping (paper §V-B2 closing remark: "running
//! an ensemble of different techniques on a time limit — then selecting
//! the best final mapping — is practicable").
//!
//! Given one partitioning, try several placement pipelines inside a wall
//! clock budget and keep the mapping with the lowest ELP.

use super::pipeline::{MapperPipeline, MappingResult, PartitionerKind, PlacerKind, RefinerKind};
use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use crate::mapping::MapError;
use crate::runtime::PjrtRuntime;
use std::time::{Duration, Instant};

/// Ensemble outcome: the winner plus the per-candidate scoreboard.
pub struct EnsembleResult {
    pub best: MappingResult,
    pub best_combo: (PlacerKind, RefinerKind),
    /// (placer, refiner, elp, wall time) per attempted candidate.
    pub scoreboard: Vec<(PlacerKind, RefinerKind, f64, Duration)>,
    pub budget_exhausted: bool,
}

/// Candidate placement pipelines in increasing expected cost.
pub const CANDIDATES: [(PlacerKind, RefinerKind); 5] = [
    (PlacerKind::Hilbert, RefinerKind::None),
    (PlacerKind::MinDistance, RefinerKind::None),
    (PlacerKind::Spectral, RefinerKind::None),
    (PlacerKind::Hilbert, RefinerKind::ForceDirected),
    (PlacerKind::Spectral, RefinerKind::ForceDirected),
];

/// Run the ensemble: partition once with `partitioner`, then race the
/// placement candidates until `budget` is spent (the current candidate is
/// always allowed to finish).
pub fn run(
    g: &Hypergraph,
    layer_ranges: Option<&[(u32, u32)]>,
    hw: NmhConfig,
    partitioner: PartitionerKind,
    budget: Duration,
    seed: u64,
    runtime: Option<&PjrtRuntime>,
) -> Result<EnsembleResult, MapError> {
    let start = Instant::now();
    let mut best: Option<(MappingResult, (PlacerKind, RefinerKind))> = None;
    let mut scoreboard = Vec::new();
    let mut budget_exhausted = false;

    for &(placer, refiner) in CANDIDATES.iter() {
        if start.elapsed() > budget && best.is_some() {
            budget_exhausted = true;
            break;
        }
        let t0 = Instant::now();
        let res = MapperPipeline::new(hw)
            .partitioner(partitioner)
            .placer(placer)
            .refiner(refiner)
            .seed(seed)
            .run_with(g, layer_ranges, runtime)?;
        let dt = t0.elapsed();
        scoreboard.push((placer, refiner, res.metrics.elp, dt));
        let better = best
            .as_ref()
            .map(|(b, _)| res.metrics.elp < b.metrics.elp)
            .unwrap_or(true);
        if better {
            best = Some((res, (placer, refiner)));
        }
    }
    let (best, best_combo) = best.expect("at least one candidate always runs");
    Ok(EnsembleResult {
        best,
        best_combo,
        scoreboard,
        budget_exhausted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn;

    #[test]
    fn picks_minimum_elp() {
        let net = snn::by_name("lenet", 0.1, 5).unwrap();
        let hw = NmhConfig::small().scaled(0.05);
        let res = run(
            &net.graph,
            net.layer_ranges.as_deref(),
            hw,
            PartitionerKind::Sequential,
            Duration::from_secs(120),
            7,
            None,
        )
        .unwrap();
        assert!(!res.scoreboard.is_empty());
        let min_elp = res
            .scoreboard
            .iter()
            .map(|&(_, _, elp, _)| elp)
            .fold(f64::INFINITY, f64::min);
        assert!((res.best.metrics.elp - min_elp).abs() < 1e-9);
    }

    #[test]
    fn tiny_budget_still_yields_mapping() {
        let net = snn::by_name("lenet", 0.1, 5).unwrap();
        let hw = NmhConfig::small().scaled(0.05);
        let res = run(
            &net.graph,
            net.layer_ranges.as_deref(),
            hw,
            PartitionerKind::SequentialUnordered,
            Duration::ZERO,
            7,
            None,
        )
        .unwrap();
        assert!(res.scoreboard.len() >= 1);
        assert!(res.budget_exhausted || res.scoreboard.len() == CANDIDATES.len());
    }
}
