//! Time-budgeted ensemble mapping (paper §V-B2 closing remark: "running
//! an ensemble of different techniques on a time limit — then selecting
//! the best final mapping — is practicable").
//!
//! Given one partitioning, try several placement pipelines inside a wall
//! clock budget and keep the mapping with the lowest ELP. Candidates are
//! registry stage names, so downstream placers/refiners can race too via
//! [`run_candidates`].

use super::pipeline::{MapperPipeline, MappingResult, PartitionerKind};
use super::registry::StageRegistry;
use super::spec::{PipelineSpec, StageSpec};
use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use crate::mapping::MapError;
use crate::runtime::PjrtRuntime;
use std::time::{Duration, Instant};

/// Ensemble outcome: the winner plus the per-candidate scoreboard.
pub struct EnsembleResult {
    pub best: MappingResult,
    /// (placer, refiner) registry names of the winner.
    pub best_combo: (String, String),
    /// (placer, refiner, elp, wall time) per attempted candidate.
    pub scoreboard: Vec<(String, String, f64, Duration)>,
    pub budget_exhausted: bool,
}

/// Candidate placement pipelines in increasing expected cost.
pub const CANDIDATES: [(&str, &str); 5] = [
    ("hilbert", "none"),
    ("mindist", "none"),
    ("spectral", "none"),
    ("hilbert", "force"),
    ("spectral", "force"),
];

/// Enum-shim entry point (see [`run_named`]).
pub fn run(
    g: &Hypergraph,
    layer_ranges: Option<&[(u32, u32)]>,
    hw: NmhConfig,
    partitioner: PartitionerKind,
    budget: Duration,
    seed: u64,
    runtime: Option<&PjrtRuntime>,
) -> Result<EnsembleResult, MapError> {
    run_named(g, layer_ranges, hw, partitioner.name(), budget, seed, runtime)
}

/// Run the ensemble: partition once with the named partitioner, then
/// race the default [`CANDIDATES`] until `budget` is spent (the current
/// candidate is always allowed to finish).
pub fn run_named(
    g: &Hypergraph,
    layer_ranges: Option<&[(u32, u32)]>,
    hw: NmhConfig,
    partitioner: &str,
    budget: Duration,
    seed: u64,
    runtime: Option<&PjrtRuntime>,
) -> Result<EnsembleResult, MapError> {
    let candidates: Vec<(StageSpec, StageSpec)> = CANDIDATES
        .iter()
        .map(|&(pl, rf)| (StageSpec::new(pl), StageSpec::new(rf)))
        .collect();
    run_candidates(
        g,
        layer_ranges,
        StageRegistry::global(),
        PipelineSpec::new(hw).partitioner(StageSpec::new(partitioner)).seed(seed),
        &candidates,
        budget,
        runtime,
    )
}

/// Fully general ensemble: `base` fixes hw/partitioner/seed/threads and
/// each candidate overrides the (placer, refiner) pair; all stages
/// resolve through `registry`.
pub fn run_candidates(
    g: &Hypergraph,
    layer_ranges: Option<&[(u32, u32)]>,
    registry: &StageRegistry,
    base: PipelineSpec,
    candidates: &[(StageSpec, StageSpec)],
    budget: Duration,
    runtime: Option<&PjrtRuntime>,
) -> Result<EnsembleResult, MapError> {
    assert!(!candidates.is_empty(), "ensemble needs at least one candidate");
    // snn-lint: allow(timing-gate) — budget wall-clock is product semantics: it decides
    // early exit and is surfaced to the caller as `budget_exhausted`
    let start = Instant::now();
    let mut best: Option<(MappingResult, (String, String))> = None;
    let mut scoreboard = Vec::new();
    let mut budget_exhausted = false;

    for (placer, refiner) in candidates.iter() {
        if start.elapsed() > budget && best.is_some() {
            budget_exhausted = true;
            break;
        }
        // snn-lint: allow(timing-gate) — the per-candidate duration lands in the scoreboard
        let t0 = Instant::now();
        let spec = base.clone().placer(placer.clone()).refiner(refiner.clone());
        let res = MapperPipeline::from_spec_with(registry, &spec)?
            .run_with(g, layer_ranges, runtime)?;
        let dt = t0.elapsed();
        scoreboard.push((placer.name.clone(), refiner.name.clone(), res.metrics.elp, dt));
        let better = best
            .as_ref()
            .map(|(b, _)| res.metrics.elp < b.metrics.elp)
            .unwrap_or(true);
        if better {
            best = Some((res, (placer.name.clone(), refiner.name.clone())));
        }
    }
    // snn-lint: allow(unwrap-ban) — the non-empty assert above plus `best.is_some()` gating
    // the budget break guarantee at least one candidate ran to completion
    let (best, best_combo) = best.expect("at least one candidate always runs");
    Ok(EnsembleResult {
        best,
        best_combo,
        scoreboard,
        budget_exhausted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn;

    #[test]
    fn picks_minimum_elp() {
        let net = snn::by_name("lenet", 0.1, 5).unwrap();
        let hw = NmhConfig::small().scaled(0.05);
        let res = run(
            &net.graph,
            net.layer_ranges.as_deref(),
            hw,
            PartitionerKind::Sequential,
            Duration::from_secs(120),
            7,
            None,
        )
        .unwrap();
        assert!(!res.scoreboard.is_empty());
        let min_elp = res
            .scoreboard
            .iter()
            .map(|(_, _, elp, _)| *elp)
            .fold(f64::INFINITY, f64::min);
        assert!((res.best.metrics.elp - min_elp).abs() < 1e-9);
    }

    #[test]
    fn tiny_budget_still_yields_mapping() {
        let net = snn::by_name("lenet", 0.1, 5).unwrap();
        let hw = NmhConfig::small().scaled(0.05);
        let res = run_named(
            &net.graph,
            net.layer_ranges.as_deref(),
            hw,
            "seq-unordered",
            Duration::ZERO,
            7,
            None,
        )
        .unwrap();
        assert!(res.scoreboard.len() >= 1);
        assert!(res.budget_exhausted || res.scoreboard.len() == CANDIDATES.len());
    }

    #[test]
    fn unknown_partitioner_name_errors() {
        let net = snn::by_name("lenet", 0.1, 5).unwrap();
        let hw = NmhConfig::small().scaled(0.05);
        let err = run_named(&net.graph, None, hw, "warp-drive", Duration::ZERO, 7, None)
            .unwrap_err();
        assert!(matches!(err, MapError::BadSpec(_)), "{err}");
    }
}
