//! Report emission: CSV files, markdown tables and the per-figure summary
//! statistics quoted in EXPERIMENTS.md.

use super::experiment::ExperimentRow;
use crate::util::json::Json;
use std::io::Write;
use std::path::Path;

/// RFC-4180-style field escaping: fields containing a comma, quote or
/// newline are wrapped in double quotes with inner quotes doubled;
/// clean fields pass through byte-identical.
pub fn csv_escape(field: &str) -> String {
    if field.contains(&[',', '"', '\n', '\r'][..]) {
        let mut out = String::with_capacity(field.len() + 2);
        out.push('"');
        for c in field.chars() {
            if c == '"' {
                out.push('"');
            }
            out.push(c);
        }
        out.push('"');
        out
    } else {
        field.to_string()
    }
}

/// Inverse of [`csv_escape`] over one line: split on unquoted commas,
/// un-double quotes inside quoted fields.
pub fn csv_split(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => fields.push(std::mem::take(&mut cur)),
                _ => cur.push(c),
            }
        }
    }
    fields.push(cur);
    fields
}

/// Write rows as CSV. The write is atomic (tmp + fsync + rename, the
/// checkpoint subsystem's helper): a killed run never leaves a
/// half-written report behind.
pub fn write_csv(rows: &[ExperimentRow], path: &Path) -> std::io::Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    writeln!(buf, "{}", ExperimentRow::csv_header())?;
    for r in rows {
        writeln!(buf, "{}", r.to_csv())?;
    }
    crate::runtime::checkpoint::atomic_write(path, &buf)
}

/// Render rows as a GitHub-markdown table (the EXPERIMENTS.md format).
pub fn to_markdown(rows: &[ExperimentRow]) -> String {
    let mut s = String::new();
    s.push_str("| network | partitioner | placer+refiner | parts | connectivity | energy (pJ) | latency (ns) | congestion | ELP | t_part (s) | t_place (s) |\n");
    s.push_str("|---|---|---|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        s.push_str(&format!(
            "| {} | {} | {}+{} | {} | {:.3e} | {:.3e} | {:.3e} | {:.3e} | {:.3e} | {:.2} | {:.2} |\n",
            r.network,
            r.partitioner,
            r.placer,
            r.refiner,
            r.partitions,
            r.connectivity,
            r.energy,
            r.latency,
            r.congestion,
            r.elp,
            r.partition_time.as_secs_f64(),
            r.placement_time.as_secs_f64(),
        ));
    }
    s
}

/// JSON dump of the rows (machine-readable archive of a run).
pub fn to_json(rows: &[ExperimentRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("network", Json::Str(r.network.clone())),
                    ("nodes", Json::Num(r.nodes as f64)),
                    ("connections", Json::Num(r.connections as f64)),
                    ("partitioner", Json::Str(r.partitioner.clone())),
                    ("placer", Json::Str(r.placer.clone())),
                    ("refiner", Json::Str(r.refiner.clone())),
                    ("partitions", Json::Num(r.partitions as f64)),
                    ("connectivity", Json::Num(r.connectivity)),
                    ("energy", Json::Num(r.energy)),
                    ("latency", Json::Num(r.latency)),
                    ("congestion", Json::Num(r.congestion)),
                    ("elp", Json::Num(r.elp)),
                    ("sr_arith", Json::Num(r.sr_arith)),
                    ("sr_geo", Json::Num(r.sr_geo)),
                    ("cl_arith", Json::Num(r.cl_arith)),
                    ("cl_geo", Json::Num(r.cl_geo)),
                    ("partition_time_s", Json::Num(r.partition_time.as_secs_f64())),
                    ("placement_time_s", Json::Num(r.placement_time.as_secs_f64())),
                ])
            })
            .collect(),
    )
}

/// Geometric-mean ratio of `metric` between two partitioners across
/// common (network, placer, refiner) cells — the §V-B headline numbers
/// ("overlap reaches 0.52-1.46× of hierarchical", "EdgeMap 8.5× worse").
pub fn ratio_summary(
    rows: &[ExperimentRow],
    partitioner_a: &str,
    partitioner_b: &str,
    metric: impl Fn(&ExperimentRow) -> f64,
) -> Option<f64> {
    let mut logs = Vec::new();
    for a in rows.iter().filter(|r| r.partitioner == partitioner_a && r.error.is_none()) {
        if let Some(b) = rows.iter().find(|r| {
            r.partitioner == partitioner_b
                && r.network == a.network
                && r.placer == a.placer
                && r.refiner == a.refiner
                && r.error.is_none()
        }) {
            let (ma, mb) = (metric(a), metric(b));
            if ma > 0.0 && mb > 0.0 && ma.is_finite() && mb.is_finite() {
                logs.push((ma / mb).ln());
            }
        }
    }
    if logs.is_empty() {
        None
    } else {
        Some((logs.iter().sum::<f64>() / logs.len() as f64).exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn row(net: &str, pk: &str, conn: f64) -> ExperimentRow {
        ExperimentRow {
            network: net.into(),
            nodes: 10,
            connections: 20,
            partitioner: pk.into(),
            placer: "hilbert".into(),
            refiner: "none".into(),
            partitions: 2,
            connectivity: conn,
            energy: 1.0,
            latency: 2.0,
            congestion: 3.0,
            elp: 2.0,
            sr_arith: 1.5,
            sr_geo: 1.2,
            cl_arith: 4.0,
            cl_geo: 3.0,
            partition_time: Duration::from_millis(10),
            placement_time: Duration::from_millis(5),
            error: None,
        }
    }

    #[test]
    fn ratio_summary_geomean() {
        let rows = vec![
            row("a", "overlap", 2.0),
            row("a", "hierarchical", 1.0),
            row("b", "overlap", 8.0),
            row("b", "hierarchical", 1.0),
        ];
        // ratios 2 and 8 -> geomean 4
        let r = ratio_summary(&rows, "overlap", "hierarchical", |r| r.connectivity).unwrap();
        assert!((r - 4.0).abs() < 1e-9);
        assert!(ratio_summary(&rows, "overlap", "missing", |r| r.connectivity).is_none());
    }

    #[test]
    fn markdown_and_json_render() {
        let rows = vec![row("a", "overlap", 2.0)];
        let md = to_markdown(&rows);
        assert!(md.contains("| a | overlap |"));
        let js = to_json(&rows).to_string();
        assert!(js.contains("\"network\":\"a\""));
    }

    #[test]
    fn csv_escape_roundtrips_hostile_fields() {
        for field in [
            "plain",
            "",
            "a,b",
            "say \"hi\"",
            "multi\nline",
            "trailing,comma,\"and quotes\"\r\n",
        ] {
            let line = format!("{},{}", csv_escape(field), csv_escape("tail"));
            let fields = csv_split(&line);
            assert_eq!(fields, vec![field.to_string(), "tail".to_string()], "field={field:?}");
        }
    }

    #[test]
    fn csv_writes_file() {
        let rows = vec![row("a", "overlap", 2.0)];
        let dir = std::env::temp_dir().join("snnmap_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rows.csv");
        write_csv(&rows, &p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("network,"));
        assert_eq!(text.lines().count(), 2);
        // the atomic write leaves no temp file behind
        assert!(!dir.join("rows.csv.tmp").exists());
    }
}
