//! The mapping pipeline: partition → push-forward → place → refine →
//! evaluate, with pluggable algorithms (Table IV) and numeric engines.
//!
//! Stages are trait objects resolved through
//! [`super::registry::StageRegistry`]; a pipeline is built either from a
//! serializable [`super::spec::PipelineSpec`] (`from_spec`) or through
//! the historical `*Kind` enum builders, which remain as thin shims over
//! the registry.

use super::registry::StageRegistry;
use super::spec::PipelineSpec;
use crate::hw::faults::FaultMask;
use crate::hw::NmhConfig;
use crate::hypergraph::quotient::{push_forward, Partitioning};
use crate::hypergraph::Hypergraph;
use crate::mapping::MapError;
use crate::metrics::cost::evaluate_with_threads;
use crate::metrics::properties::{self, Mean};
use crate::metrics::MappingMetrics;
use crate::placement::force::{ForceParams, ForceRefiner, RefineStats};
use crate::placement::Placement;
use crate::runtime::PjrtRuntime;
use crate::stage::{NoRefiner, Partitioner, Placer, Refiner, StageCtx};
use std::time::Duration;

/// Partitioning algorithms (paper Table IV + baselines). Kept as a thin
/// shim over [`StageRegistry`] so enum-based callers stay source-stable;
/// new algorithms register by name and need no variant here.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionerKind {
    /// §IV-A1 multilevel coarsening + FM refinement.
    Hierarchical,
    /// §IV-A2 — the paper's novel overlap-driven heuristic.
    HyperedgeOverlap,
    /// §IV-A3 with ordering (natural for layered nets, Alg. 2 otherwise).
    Sequential,
    /// §IV-A3 without ordering (the [7] baseline).
    SequentialUnordered,
    /// EdgeMap-style graph-based control [15].
    EdgeMap,
    /// One-pass streaming partitioner with lookahead window ([17]-style
    /// extension, mapping/streaming.rs).
    Streaming,
}

impl PartitionerKind {
    pub fn name(&self) -> &'static str {
        match self {
            PartitionerKind::Hierarchical => "hierarchical",
            PartitionerKind::HyperedgeOverlap => "overlap",
            PartitionerKind::Sequential => "sequential",
            PartitionerKind::SequentialUnordered => "seq-unordered",
            PartitionerKind::EdgeMap => "edgemap",
            PartitionerKind::Streaming => "streaming",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "hierarchical" | "hier" => PartitionerKind::Hierarchical,
            "overlap" | "hyperedge-overlap" => PartitionerKind::HyperedgeOverlap,
            "sequential" | "seq" => PartitionerKind::Sequential,
            "seq-unordered" | "unordered" => PartitionerKind::SequentialUnordered,
            "edgemap" => PartitionerKind::EdgeMap,
            "streaming" | "stream" => PartitionerKind::Streaming,
            _ => return None,
        })
    }

    pub const ALL: [PartitionerKind; 6] = [
        PartitionerKind::Hierarchical,
        PartitionerKind::HyperedgeOverlap,
        PartitionerKind::Sequential,
        PartitionerKind::SequentialUnordered,
        PartitionerKind::EdgeMap,
        PartitionerKind::Streaming,
    ];

    /// Instantiate the stage with default parameters. Constructed
    /// directly (not through the registry) so the enum shim is
    /// infallible by construction; `from_spec` round-trip tests pin the
    /// equivalence with the registry's parameter-free constructors.
    pub fn to_stage(&self) -> Box<dyn Partitioner> {
        use crate::mapping::{edgemap, hierarchical, overlap, sequential, streaming};
        match self {
            PartitionerKind::Hierarchical => {
                Box::new(hierarchical::HierarchicalPartitioner::new())
            }
            PartitionerKind::HyperedgeOverlap => Box::new(overlap::OverlapPartitioner::new()),
            PartitionerKind::Sequential => Box::new(sequential::SequentialPartitioner::auto()),
            PartitionerKind::SequentialUnordered => {
                Box::new(sequential::SequentialPartitioner::unordered())
            }
            PartitionerKind::EdgeMap => Box::new(edgemap::EdgeMapPartitioner),
            PartitionerKind::Streaming => Box::new(streaming::StreamingPartitioner::new()),
        }
    }
}

/// Initial/direct placement algorithms (Table IV); shim over the
/// registry like [`PartitionerKind`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacerKind {
    /// §IV-B1 Hilbert space-filling curve.
    Hilbert,
    /// §IV-B2 spectral embedding (native or PJRT engine).
    Spectral,
    /// §IV-C2 minimum-distance direct placement (needs no refiner).
    MinDistance,
}

impl PlacerKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlacerKind::Hilbert => "hilbert",
            PlacerKind::Spectral => "spectral",
            PlacerKind::MinDistance => "mindist",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "hilbert" => PlacerKind::Hilbert,
            "spectral" => PlacerKind::Spectral,
            "mindist" | "min-distance" => PlacerKind::MinDistance,
            _ => return None,
        })
    }

    pub const ALL: [PlacerKind; 3] =
        [PlacerKind::Hilbert, PlacerKind::Spectral, PlacerKind::MinDistance];

    /// Instantiate the stage with default parameters (directly, like
    /// [`PartitionerKind::to_stage`] — infallible by construction).
    pub fn to_stage(&self) -> Box<dyn Placer> {
        use crate::placement::{hilbert, mindist, spectral};
        match self {
            PlacerKind::Hilbert => Box::new(hilbert::HilbertPlacer),
            PlacerKind::Spectral => Box::new(spectral::SpectralPlacer::new()),
            PlacerKind::MinDistance => Box::new(mindist::MinDistPlacer),
        }
    }
}

/// Placement refinement (Table IV); shim over the registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefinerKind {
    None,
    /// §IV-C1 force-directed swap refinement.
    ForceDirected,
}

impl RefinerKind {
    pub fn name(&self) -> &'static str {
        match self {
            RefinerKind::None => "none",
            RefinerKind::ForceDirected => "force",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "none" => RefinerKind::None,
            "force" | "force-directed" => RefinerKind::ForceDirected,
            _ => return None,
        })
    }

    /// Instantiate the stage with default parameters (directly, like
    /// [`PartitionerKind::to_stage`] — infallible by construction).
    pub fn to_stage(&self) -> Box<dyn Refiner> {
        match self {
            RefinerKind::None => Box::new(NoRefiner),
            RefinerKind::ForceDirected => Box::new(ForceRefiner::new()),
        }
    }
}

/// A complete mapping outcome.
pub struct MappingResult {
    pub rho: Partitioning,
    /// Quotient h-graph G_P.
    pub gp: Hypergraph,
    pub placement: Placement,
    pub metrics: MappingMetrics,
    /// Synaptic reuse (arithmetic, geometric) — Eq. 14.
    pub sr: (f64, f64),
    /// Connections locality (arithmetic, geometric) — Eq. 15.
    pub cl: (f64, f64),
    pub partition_time: Duration,
    pub placement_time: Duration,
    pub refine_stats: Option<RefineStats>,
}

impl MappingResult {
    /// Human-readable multi-line report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "partitions            {}\n",
            self.rho.num_parts
        ));
        s.push_str(&format!("connectivity (Eq.7)   {:.4e}\n", self.metrics.connectivity));
        s.push_str(&format!("energy                {:.4e} pJ/step\n", self.metrics.energy));
        s.push_str(&format!("latency               {:.4e} ns/step\n", self.metrics.latency));
        s.push_str(&format!("congestion            {:.4e} spikes/core\n", self.metrics.congestion));
        s.push_str(&format!("ELP                   {:.4e}\n", self.metrics.elp));
        s.push_str(&format!(
            "synaptic reuse        arith {:.3} geo {:.3}\n",
            self.sr.0, self.sr.1
        ));
        s.push_str(&format!(
            "connections locality  arith {:.3} geo {:.3}\n",
            self.cl.0, self.cl.1
        ));
        s.push_str(&format!(
            "time                  partition {:?} placement {:?}\n",
            self.partition_time, self.placement_time
        ));
        if let Some(rs) = &self.refine_stats {
            s.push_str(&format!(
                "refinement            {} sweeps, {} swaps, {} empty-moves, wl {:.3e} -> {:.3e}\n",
                rs.sweeps, rs.swaps, rs.moves_to_empty, rs.initial_wirelength, rs.final_wirelength
            ));
        }
        s
    }
}

/// Configurable mapping pipeline. Stages are boxed trait objects; build
/// one from a [`PipelineSpec`] (`from_spec`), from the enum shims
/// (`partitioner`/`placer`/`refiner`), or inject any custom stage with
/// the `with_*` setters.
pub struct MapperPipeline {
    pub hw: NmhConfig,
    partitioner: Box<dyn Partitioner>,
    placer: Box<dyn Placer>,
    refiner: Box<dyn Refiner>,
    pub seed: u64,
    /// Worker-pool width shared by the parallel stages — the metric
    /// engine, the hierarchical partitioner's two-phase rounds and the
    /// spectral placer's matvec sweeps all receive it through
    /// [`StageCtx::threads`]; defaults to the process-wide
    /// [`crate::util::par`] pool size. Never changes results.
    pub threads: usize,
    /// Crash-safe checkpoint/resume policy, handed to stages through
    /// [`StageCtx::checkpoint`] (DESIGN.md §13). Run-environment, not
    /// part of the spec: results are identical with or without it.
    pub checkpoint: Option<crate::runtime::CheckpointPolicy>,
    /// Hardware fault mask the run must respect (DESIGN.md §15):
    /// partition and validation run against the derated capacities
    /// ([`FaultMask::effective_hw`]), placers skip dead cores through
    /// [`StageCtx::faults`], and a post-placement check rejects any
    /// assignment to a dead core. `None` — and an all-healthy mask —
    /// are bit-identical to the pre-fault pipeline.
    pub faults: Option<FaultMask>,
}

impl MapperPipeline {
    pub fn new(hw: NmhConfig) -> Self {
        MapperPipeline {
            hw,
            partitioner: PartitionerKind::HyperedgeOverlap.to_stage(),
            placer: PlacerKind::Spectral.to_stage(),
            refiner: RefinerKind::ForceDirected.to_stage(),
            seed: 42,
            threads: crate::util::par::max_threads(),
            checkpoint: None,
            faults: None,
        }
    }

    /// Build a pipeline from a serializable spec via the built-in
    /// registry.
    pub fn from_spec(spec: &PipelineSpec) -> Result<Self, MapError> {
        Self::from_spec_with(StageRegistry::global(), spec)
    }

    /// Build a pipeline from a spec via a caller-supplied registry
    /// (downstream algorithms included).
    pub fn from_spec_with(registry: &StageRegistry, spec: &PipelineSpec) -> Result<Self, MapError> {
        let faults = match &spec.faults {
            None => None,
            Some(fs) => Some(fs.realize(&spec.hw).map_err(MapError::BadSpec)?),
        };
        Ok(MapperPipeline {
            hw: spec.hw,
            partitioner: registry.partitioner(&spec.partitioner.name, &spec.partitioner.params)?,
            placer: registry.placer(&spec.placer.name, &spec.placer.params)?,
            refiner: registry.refiner(&spec.refiner.name, &spec.refiner.params)?,
            seed: spec.seed,
            threads: spec.threads.max(1),
            checkpoint: None,
            faults,
        })
    }

    /// Cap the worker-pool width used by the parallel pipeline stages
    /// (1 = fully serial; results are identical either way).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Enum shim: select a built-in partitioner.
    pub fn partitioner(mut self, k: PartitionerKind) -> Self {
        self.partitioner = k.to_stage();
        self
    }

    /// Enum shim: select a built-in placer.
    pub fn placer(mut self, k: PlacerKind) -> Self {
        self.placer = k.to_stage();
        self
    }

    /// Enum shim: select a built-in refiner.
    pub fn refiner(mut self, k: RefinerKind) -> Self {
        self.refiner = k.to_stage();
        self
    }

    /// Inject a custom partitioning stage.
    pub fn with_partitioner(mut self, p: Box<dyn Partitioner>) -> Self {
        self.partitioner = p;
        self
    }

    /// Inject a custom placement stage.
    pub fn with_placer(mut self, p: Box<dyn Placer>) -> Self {
        self.placer = p;
        self
    }

    /// Inject a custom refinement stage.
    pub fn with_refiner(mut self, r: Box<dyn Refiner>) -> Self {
        self.refiner = r;
        self
    }

    /// The pipeline seed, threaded to every stage through
    /// [`StageCtx`] (`--seed` is honored uniformly).
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Enable crash-safe checkpoint/resume for stages that support it
    /// (the hierarchical partitioner; see DESIGN.md §13).
    pub fn with_checkpoint(mut self, policy: crate::runtime::CheckpointPolicy) -> Self {
        self.checkpoint = Some(policy);
        self
    }

    /// Map around hardware faults: dead cores and links are avoided,
    /// derated cores shrink the effective capacities (DESIGN.md §15).
    /// The mask must describe this pipeline's lattice — `run` rejects a
    /// dimension mismatch as `BadSpec`.
    pub fn with_faults(mut self, mask: FaultMask) -> Self {
        self.faults = Some(mask);
        self
    }

    /// Shim: switch to a force-directed refiner with explicit
    /// parameters (the typed form of refiner `params` in a spec).
    ///
    /// This *replaces* the refiner stage, so it supersedes any earlier
    /// `refiner(..)` call — and a later `refiner(..)` call discards
    /// these parameters again. Call it last when combining both.
    pub fn force_params(mut self, p: ForceParams) -> Self {
        self.refiner = Box::new(ForceRefiner { params: p });
        self
    }

    /// Stage names as (partitioner, placer, refiner).
    pub fn stage_names(&self) -> (&str, &str, &str) {
        (self.partitioner.name(), self.placer.name(), self.refiner.name())
    }

    /// Run with the native numeric engine.
    pub fn run(
        &self,
        g: &Hypergraph,
        layer_ranges: Option<&[(u32, u32)]>,
    ) -> Result<MappingResult, MapError> {
        self.run_with(g, layer_ranges, None)
    }

    /// Run; when `runtime` is provided, spectral placement and the
    /// force-field prefilter execute through the AOT PJRT artifacts.
    pub fn run_with(
        &self,
        g: &Hypergraph,
        layer_ranges: Option<&[(u32, u32)]>,
        runtime: Option<&PjrtRuntime>,
    ) -> Result<MappingResult, MapError> {
        let ctx = StageCtx {
            seed: self.seed,
            threads: self.threads,
            layer_ranges,
            runtime,
            checkpoint: self.checkpoint.clone(),
            faults: self.faults.as_ref(),
        };

        // Partitioning and validation see the *derated* capacities so no
        // partition exceeds what a degraded core can actually hold; the
        // lattice geometry (and the evaluation model) keep the physical
        // config. For `None` this is `self.hw` verbatim.
        let eff_hw = match &self.faults {
            Some(m) => {
                m.check_matches(&self.hw).map_err(MapError::BadSpec)?;
                m.effective_hw(&self.hw)
            }
            None => self.hw,
        };

        // ---- partition ----
        let t0 = std::time::Instant::now();
        let rho = self.partitioner.partition(g, &eff_hw, &ctx)?;
        let partition_time = t0.elapsed();
        crate::mapping::validate(g, &rho, &eff_hw)?;
        if let Some(m) = &self.faults {
            // dead cores shrink the lattice below num_cores(); the
            // per-partition validation above can't see that
            let alive = m.alive_count();
            if rho.num_parts > alive {
                return Err(MapError::TooManyPartitions { got: rho.num_parts, limit: alive });
            }
        }

        // ---- quotient ----
        let gp = push_forward(g, &rho).graph;

        // ---- place (+ refine; direct placers skip refinement) ----
        let t1 = std::time::Instant::now();
        let mut placement = self.placer.place(&gp, &self.hw, &ctx)?;
        let refine_stats = if self.placer.is_direct() {
            None
        } else {
            self.refiner.refine(&gp, &self.hw, &mut placement, &ctx)?
        };
        let placement_time = t1.elapsed();
        placement
            .validate(&self.hw)
            .map_err(MapError::ConstraintViolated)?;
        if let Some(m) = &self.faults {
            // defense in depth: every placer honors ctx.faults, but a
            // downstream stage that forgot must fail loudly, not map
            // traffic onto a dead core
            for &(x, y) in &placement.coords {
                if m.is_core_dead(x, y) {
                    return Err(MapError::ConstraintViolated(format!(
                        "placement assigned a partition to dead core ({x},{y})"
                    )));
                }
            }
        }

        // ---- evaluate ----
        let metrics = evaluate_with_threads(&gp, &placement, &self.hw, self.threads);
        let sr = (
            properties::synaptic_reuse(g, &rho, Mean::Arithmetic),
            properties::synaptic_reuse(g, &rho, Mean::Geometric),
        );
        let cl = (
            properties::connections_locality(&gp, &placement, &self.hw, Mean::Arithmetic),
            properties::connections_locality(&gp, &placement, &self.hw, Mean::Geometric),
        );

        Ok(MappingResult {
            rho,
            gp,
            placement,
            metrics,
            sr,
            cl,
            partition_time,
            placement_time,
            refine_stats,
        })
    }

    /// Replay NoC traffic over a completed mapping (DESIGN.md §16),
    /// honoring this pipeline's worker count and fault mask the same
    /// way the mapping stages receive them through [`StageCtx`]. The
    /// report is bit-for-bit identical for every `threads` value.
    pub fn simulate(
        &self,
        res: &MappingResult,
        params: crate::sim::SimParams,
    ) -> crate::sim::SimReport {
        crate::sim::simulate_with_threads(
            &res.gp,
            &res.placement,
            &self.hw,
            params,
            self.faults.as_ref(),
            self.threads,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::StageSpec;
    use crate::snn;
    use crate::util::json::Json;

    fn small_net() -> snn::Network {
        snn::by_name("lenet", 0.12, 3).unwrap()
    }

    fn small_hw() -> NmhConfig {
        NmhConfig::small().scaled(0.05) // force multiple partitions
    }

    #[test]
    fn full_pipeline_all_partitioners() {
        let net = small_net();
        for pk in PartitionerKind::ALL {
            let res = MapperPipeline::new(small_hw())
                .partitioner(pk)
                .placer(PlacerKind::Hilbert)
                .refiner(RefinerKind::None)
                .run(&net.graph, net.layer_ranges.as_deref())
                .unwrap_or_else(|e| panic!("{}: {e}", pk.name()));
            assert!(res.rho.num_parts >= 1, "{}", pk.name());
            assert!(res.metrics.energy > 0.0);
            assert!(res.sr.0 >= 1.0, "{} reuse {}", pk.name(), res.sr.0);
        }
    }

    #[test]
    fn pipeline_simulate_matches_serial_reference() {
        // pipeline.simulate wires self.threads + self.faults through to
        // the simulator; the result must equal the serial oracle bitwise
        let net = small_net();
        let hw = small_hw();
        let mask = crate::hw::faults::FaultMask::healthy(&hw);
        let pipeline = MapperPipeline::new(hw)
            .partitioner(PartitionerKind::Sequential)
            .placer(PlacerKind::Hilbert)
            .refiner(RefinerKind::None)
            .threads(4)
            .with_faults(mask.clone());
        let res = pipeline.run(&net.graph, net.layer_ranges.as_deref()).unwrap();
        let params = crate::sim::SimParams { timesteps: 20, seed: 5, poisson_spikes: true };
        let got = pipeline.simulate(&res, params);
        let want =
            crate::sim::simulate_serial(&res.gp, &res.placement, &pipeline.hw, params, Some(&mask));
        assert_eq!(got.spikes, want.spikes);
        assert_eq!(got.hops, want.hops);
        assert_eq!(got.energy.to_bits(), want.energy.to_bits());
        assert_eq!(got.mean_makespan.to_bits(), want.mean_makespan.to_bits());
    }

    #[test]
    fn full_pipeline_all_placers() {
        let net = small_net();
        for pl in PlacerKind::ALL {
            let res = MapperPipeline::new(small_hw())
                .partitioner(PartitionerKind::Sequential)
                .placer(pl)
                .refiner(RefinerKind::None)
                .run(&net.graph, net.layer_ranges.as_deref())
                .unwrap_or_else(|e| panic!("{}: {e}", pl.name()));
            res.placement.validate(&small_hw()).unwrap();
            assert!(res.metrics.elp > 0.0);
        }
    }

    #[test]
    fn force_refinement_improves_or_preserves() {
        let net = small_net();
        let base = MapperPipeline::new(small_hw())
            .partitioner(PartitionerKind::HyperedgeOverlap)
            .placer(PlacerKind::Hilbert)
            .refiner(RefinerKind::None)
            .run(&net.graph, None)
            .unwrap();
        let refined = MapperPipeline::new(small_hw())
            .partitioner(PartitionerKind::HyperedgeOverlap)
            .placer(PlacerKind::Hilbert)
            .refiner(RefinerKind::ForceDirected)
            .run(&net.graph, None)
            .unwrap();
        assert!(refined.metrics.wirelength <= base.metrics.wirelength + 1e-9);
        let rs = refined.refine_stats.unwrap();
        assert!(rs.final_wirelength <= rs.initial_wirelength + 1e-9);
    }

    #[test]
    fn thread_count_does_not_change_metrics() {
        // the pipeline's pool knob must be unobservable in the output
        // (ordered reduction in the metric engine, DESIGN.md §6)
        let net = small_net();
        let run = |t: usize| {
            MapperPipeline::new(small_hw())
                .partitioner(PartitionerKind::HyperedgeOverlap)
                .placer(PlacerKind::Hilbert)
                .refiner(RefinerKind::None)
                .threads(t)
                .run(&net.graph, None)
                .unwrap()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.rho.assign, parallel.rho.assign);
        assert_eq!(serial.metrics, parallel.metrics);
    }

    #[test]
    fn hierarchical_thread_invariant_through_pipeline() {
        // `.threads(n)` must reach the partitioner's two-phase rounds
        // through StageCtx and be unobservable in the output (DESIGN.md
        // §10). The network must clear the partitioner's parallel
        // dispatch threshold or the t=4 run would be vacuously serial;
        // the spectral placer's parallel matvec has its own equivalence
        // test (quotients here are far below its row threshold).
        let net = snn::by_name("16k_rand", 0.06, 9).unwrap();
        assert!(
            net.graph.num_nodes() >= crate::mapping::hierarchical::PAR_MIN_NODES,
            "test network too small to exercise the parallel rounds"
        );
        let hw = NmhConfig::small().scaled(0.04);
        let run = |t: usize| {
            MapperPipeline::new(hw)
                .partitioner(PartitionerKind::Hierarchical)
                .placer(PlacerKind::Spectral)
                .refiner(RefinerKind::None)
                .threads(t)
                .run(&net.graph, None)
                .unwrap()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.rho.assign, parallel.rho.assign);
        assert_eq!(serial.placement.coords, parallel.placement.coords);
        assert_eq!(serial.metrics, parallel.metrics);
    }

    #[test]
    fn force_overlap_thread_invariant_through_pipeline() {
        // `.threads(n)` must reach the overlap partitioner's frontier
        // scoring and the force refiner's candidate scan through
        // StageCtx and be unobservable in the output (DESIGN.md §11).
        // c_npc pins the partition count above the force refiner's
        // dispatch threshold, so the t=4 run is not vacuously serial.
        let net = snn::by_name("16k_rand", 0.06, 11).unwrap();
        let mut hw = NmhConfig::small();
        hw.c_npc = 8;
        let run = |t: usize| {
            MapperPipeline::new(hw)
                .partitioner(PartitionerKind::HyperedgeOverlap)
                .placer(PlacerKind::Hilbert)
                .refiner(RefinerKind::ForceDirected)
                .threads(t)
                .run(&net.graph, None)
                .unwrap()
        };
        let serial = run(1);
        assert!(
            serial.rho.num_parts >= crate::placement::force::PAR_MIN_PARTS,
            "workload below the force refiner's parallel dispatch threshold ({} parts)",
            serial.rho.num_parts
        );
        assert_eq!(serial.refine_stats.as_ref().unwrap().par_sweeps, 0);
        let parallel = run(4);
        // par_sweeps > 0 proves `.threads(4)` actually reached the
        // refiner through StageCtx — bit-identical outputs alone could
        // not distinguish a silently-serial run (the overlap analogue,
        // OverlapStats.par_growth_steps, is asserted at the unit level
        // in mapping/overlap.rs since the Partitioner trait returns no
        // stats).
        let rs = parallel.refine_stats.as_ref().unwrap();
        assert_eq!(rs.par_sweeps, rs.sweeps, "parallel run was vacuously serial");
        assert_eq!(serial.rho.assign, parallel.rho.assign);
        assert_eq!(serial.placement.coords, parallel.placement.coords);
        assert_eq!(serial.metrics, parallel.metrics);
    }

    #[test]
    fn kind_parsing_roundtrip() {
        for pk in PartitionerKind::ALL {
            assert_eq!(PartitionerKind::parse(pk.name()), Some(pk));
        }
        for pl in PlacerKind::ALL {
            assert_eq!(PlacerKind::parse(pl.name()), Some(pl));
        }
        assert_eq!(RefinerKind::parse("force"), Some(RefinerKind::ForceDirected));
        assert_eq!(PartitionerKind::parse("nope"), None);
    }

    #[test]
    fn report_contains_key_metrics() {
        let net = small_net();
        let res = MapperPipeline::new(small_hw())
            .partitioner(PartitionerKind::Sequential)
            .placer(PlacerKind::Hilbert)
            .refiner(RefinerKind::None)
            .run(&net.graph, net.layer_ranges.as_deref())
            .unwrap();
        let rep = res.report();
        for key in ["partitions", "connectivity", "energy", "ELP", "synaptic reuse"] {
            assert!(rep.contains(key), "missing {key} in report");
        }
    }

    #[test]
    fn spec_reproduces_builder_run_bit_for_bit() {
        // acceptance criterion: a PipelineSpec document fully reproduces
        // the equivalent enum-builder run
        let net = small_net();
        let builder = MapperPipeline::new(small_hw())
            .partitioner(PartitionerKind::HyperedgeOverlap)
            .placer(PlacerKind::Hilbert)
            .refiner(RefinerKind::None)
            .seed(7)
            .run(&net.graph, net.layer_ranges.as_deref())
            .unwrap();
        let mut spec = PipelineSpec::new(small_hw()).seed(7);
        spec.partitioner = StageSpec::new("overlap");
        spec.placer = StageSpec::new("hilbert");
        spec.refiner = StageSpec::new("none");
        // ... and once more through a JSON round trip
        let spec = PipelineSpec::from_json_str(&spec.to_json().to_string()).unwrap();
        let from_spec = MapperPipeline::from_spec(&spec)
            .unwrap()
            .run(&net.graph, net.layer_ranges.as_deref())
            .unwrap();
        assert_eq!(builder.rho.assign, from_spec.rho.assign);
        assert_eq!(builder.metrics, from_spec.metrics);
    }

    #[test]
    fn seed_reaches_randomized_stages_uniformly() {
        // hierarchical derives its seed from StageCtx: pinning the same
        // value via stage params or via the pipeline seed is equivalent
        let net = small_net();
        let via_pipeline = MapperPipeline::new(small_hw())
            .partitioner(PartitionerKind::Hierarchical)
            .placer(PlacerKind::Hilbert)
            .refiner(RefinerKind::None)
            .seed(5)
            .run(&net.graph, None)
            .unwrap();
        let mut spec = PipelineSpec::new(small_hw()).seed(99);
        spec.partitioner = StageSpec::with_params(
            "hierarchical",
            crate::stage::StageParams::empty().set("seed", Json::Num(5.0)),
        );
        spec.placer = StageSpec::new("hilbert");
        spec.refiner = StageSpec::new("none");
        let via_params = MapperPipeline::from_spec(&spec)
            .unwrap()
            .run(&net.graph, None)
            .unwrap();
        assert_eq!(via_pipeline.rho.assign, via_params.rho.assign);
    }

    #[test]
    fn unknown_stage_fails_from_spec() {
        let mut spec = PipelineSpec::new(small_hw());
        spec.partitioner = StageSpec::new("does-not-exist");
        let err = MapperPipeline::from_spec(&spec).unwrap_err();
        assert!(
            matches!(
                &err,
                MapError::UnknownStage { kind: "partitioner", name, .. }
                    if name == "does-not-exist"
            ),
            "{err}"
        );
    }

    #[test]
    fn healthy_fault_mask_is_bit_identical_to_none() {
        // acceptance criterion: an all-healthy FaultMask is a zero-cost
        // default — every output matches the no-mask run bit for bit
        use crate::hw::faults::FaultMask;
        let net = small_net();
        let build = || {
            MapperPipeline::new(small_hw())
                .partitioner(PartitionerKind::HyperedgeOverlap)
                .placer(PlacerKind::Spectral)
                .refiner(RefinerKind::ForceDirected)
                .seed(7)
        };
        let base = build().run(&net.graph, net.layer_ranges.as_deref()).unwrap();
        let masked = build()
            .with_faults(FaultMask::healthy(&small_hw()))
            .run(&net.graph, net.layer_ranges.as_deref())
            .unwrap();
        assert_eq!(base.rho.assign, masked.rho.assign);
        assert_eq!(base.placement.coords, masked.placement.coords);
        assert_eq!(base.metrics, masked.metrics);
    }

    #[test]
    fn faulty_pipeline_avoids_dead_cores_for_every_stage_combo() {
        // acceptance criterion: under a seeded fault mask the mapping
        // avoids 100% of dead cores, whichever algorithms run
        use crate::hw::faults::{FaultMask, FaultRates};
        let net = small_net();
        let hw = small_hw();
        let mask = FaultMask::sample(&hw, &FaultRates::uniform(0.05), 13);
        assert!(mask.dead_core_count() > 0, "seed produced no dead cores");
        for pk in [PartitionerKind::HyperedgeOverlap, PartitionerKind::Sequential] {
            for pl in PlacerKind::ALL {
                let res = MapperPipeline::new(hw)
                    .partitioner(pk)
                    .placer(pl)
                    .refiner(RefinerKind::ForceDirected)
                    .with_faults(mask.clone())
                    .run(&net.graph, net.layer_ranges.as_deref())
                    .unwrap_or_else(|e| panic!("{}+{}: {e}", pk.name(), pl.name()));
                for &(x, y) in &res.placement.coords {
                    assert!(
                        !mask.is_core_dead(x, y),
                        "{}+{} placed a partition on dead core ({x},{y})",
                        pk.name(),
                        pl.name()
                    );
                }
            }
        }
    }

    #[test]
    fn fault_mask_dimension_mismatch_is_bad_spec() {
        use crate::hw::faults::FaultMask;
        let net = small_net();
        let wrong = FaultMask::healthy(&NmhConfig::small()); // unscaled dims
        let err = MapperPipeline::new(small_hw())
            .with_faults(wrong)
            .run(&net.graph, None)
            .unwrap_err();
        assert!(matches!(err, MapError::BadSpec(_)), "{err}");
    }
}
