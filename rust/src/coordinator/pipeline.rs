//! The mapping pipeline: partition → push-forward → place → refine →
//! evaluate, with pluggable algorithms (Table IV) and numeric engines.

use crate::hw::NmhConfig;
use crate::hypergraph::quotient::{push_forward, Partitioning};
use crate::hypergraph::Hypergraph;
use crate::mapping::{self, MapError};
use crate::metrics::cost::evaluate_with_threads;
use crate::metrics::properties::{self, Mean};
use crate::metrics::MappingMetrics;
use crate::placement::force::{self, ForceParams, RefineStats};
use crate::placement::{hilbert, mindist, spectral, Placement};
use crate::runtime::PjrtRuntime;
use std::time::Duration;

/// Partitioning algorithms (paper Table IV + baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionerKind {
    /// §IV-A1 multilevel coarsening + FM refinement.
    Hierarchical,
    /// §IV-A2 — the paper's novel overlap-driven heuristic.
    HyperedgeOverlap,
    /// §IV-A3 with ordering (natural for layered nets, Alg. 2 otherwise).
    Sequential,
    /// §IV-A3 without ordering (the [7] baseline).
    SequentialUnordered,
    /// EdgeMap-style graph-based control [15].
    EdgeMap,
    /// One-pass streaming partitioner with lookahead window ([17]-style
    /// extension, mapping/streaming.rs).
    Streaming,
}

impl PartitionerKind {
    pub fn name(&self) -> &'static str {
        match self {
            PartitionerKind::Hierarchical => "hierarchical",
            PartitionerKind::HyperedgeOverlap => "overlap",
            PartitionerKind::Sequential => "sequential",
            PartitionerKind::SequentialUnordered => "seq-unordered",
            PartitionerKind::EdgeMap => "edgemap",
            PartitionerKind::Streaming => "streaming",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "hierarchical" | "hier" => PartitionerKind::Hierarchical,
            "overlap" | "hyperedge-overlap" => PartitionerKind::HyperedgeOverlap,
            "sequential" | "seq" => PartitionerKind::Sequential,
            "seq-unordered" | "unordered" => PartitionerKind::SequentialUnordered,
            "edgemap" => PartitionerKind::EdgeMap,
            "streaming" | "stream" => PartitionerKind::Streaming,
            _ => return None,
        })
    }

    pub const ALL: [PartitionerKind; 6] = [
        PartitionerKind::Hierarchical,
        PartitionerKind::HyperedgeOverlap,
        PartitionerKind::Sequential,
        PartitionerKind::SequentialUnordered,
        PartitionerKind::EdgeMap,
        PartitionerKind::Streaming,
    ];
}

/// Initial/direct placement algorithms (Table IV).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacerKind {
    /// §IV-B1 Hilbert space-filling curve.
    Hilbert,
    /// §IV-B2 spectral embedding (native or PJRT engine).
    Spectral,
    /// §IV-C2 minimum-distance direct placement (needs no refiner).
    MinDistance,
}

impl PlacerKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlacerKind::Hilbert => "hilbert",
            PlacerKind::Spectral => "spectral",
            PlacerKind::MinDistance => "mindist",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "hilbert" => PlacerKind::Hilbert,
            "spectral" => PlacerKind::Spectral,
            "mindist" | "min-distance" => PlacerKind::MinDistance,
            _ => return None,
        })
    }

    pub const ALL: [PlacerKind; 3] =
        [PlacerKind::Hilbert, PlacerKind::Spectral, PlacerKind::MinDistance];
}

/// Placement refinement (Table IV).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefinerKind {
    None,
    /// §IV-C1 force-directed swap refinement.
    ForceDirected,
}

impl RefinerKind {
    pub fn name(&self) -> &'static str {
        match self {
            RefinerKind::None => "none",
            RefinerKind::ForceDirected => "force",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "none" => RefinerKind::None,
            "force" | "force-directed" => RefinerKind::ForceDirected,
            _ => return None,
        })
    }
}

/// A complete mapping outcome.
pub struct MappingResult {
    pub rho: Partitioning,
    /// Quotient h-graph G_P.
    pub gp: Hypergraph,
    pub placement: Placement,
    pub metrics: MappingMetrics,
    /// Synaptic reuse (arithmetic, geometric) — Eq. 14.
    pub sr: (f64, f64),
    /// Connections locality (arithmetic, geometric) — Eq. 15.
    pub cl: (f64, f64),
    pub partition_time: Duration,
    pub placement_time: Duration,
    pub refine_stats: Option<RefineStats>,
}

impl MappingResult {
    /// Human-readable multi-line report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "partitions            {}\n",
            self.rho.num_parts
        ));
        s.push_str(&format!("connectivity (Eq.7)   {:.4e}\n", self.metrics.connectivity));
        s.push_str(&format!("energy                {:.4e} pJ/step\n", self.metrics.energy));
        s.push_str(&format!("latency               {:.4e} ns/step\n", self.metrics.latency));
        s.push_str(&format!("congestion            {:.4e} spikes/core\n", self.metrics.congestion));
        s.push_str(&format!("ELP                   {:.4e}\n", self.metrics.elp));
        s.push_str(&format!(
            "synaptic reuse        arith {:.3} geo {:.3}\n",
            self.sr.0, self.sr.1
        ));
        s.push_str(&format!(
            "connections locality  arith {:.3} geo {:.3}\n",
            self.cl.0, self.cl.1
        ));
        s.push_str(&format!(
            "time                  partition {:?} placement {:?}\n",
            self.partition_time, self.placement_time
        ));
        if let Some(rs) = &self.refine_stats {
            s.push_str(&format!(
                "refinement            {} sweeps, {} swaps, {} empty-moves, wl {:.3e} -> {:.3e}\n",
                rs.sweeps, rs.swaps, rs.moves_to_empty, rs.initial_wirelength, rs.final_wirelength
            ));
        }
        s
    }
}

/// Configurable mapping pipeline (builder-style).
pub struct MapperPipeline {
    pub hw: NmhConfig,
    pub partitioner: PartitionerKind,
    pub placer: PlacerKind,
    pub refiner: RefinerKind,
    pub force_params: ForceParams,
    pub hier_params: mapping::hierarchical::HierParams,
    pub seed: u64,
    /// Worker-pool width shared by the parallel stages (metric engine);
    /// defaults to the process-wide [`crate::util::par`] pool size.
    pub threads: usize,
}

impl MapperPipeline {
    pub fn new(hw: NmhConfig) -> Self {
        MapperPipeline {
            hw,
            partitioner: PartitionerKind::HyperedgeOverlap,
            placer: PlacerKind::Spectral,
            refiner: RefinerKind::ForceDirected,
            force_params: ForceParams::default(),
            hier_params: mapping::hierarchical::HierParams::default(),
            seed: 42,
            threads: crate::util::par::max_threads(),
        }
    }

    /// Cap the worker-pool width used by the parallel pipeline stages
    /// (1 = fully serial; results are identical either way).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    pub fn partitioner(mut self, k: PartitionerKind) -> Self {
        self.partitioner = k;
        self
    }

    pub fn placer(mut self, k: PlacerKind) -> Self {
        self.placer = k;
        self
    }

    pub fn refiner(mut self, k: RefinerKind) -> Self {
        self.refiner = k;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self.hier_params.seed = s;
        self
    }

    pub fn force_params(mut self, p: ForceParams) -> Self {
        self.force_params = p;
        self
    }

    /// Run with the native numeric engine.
    pub fn run(
        &self,
        g: &Hypergraph,
        layer_ranges: Option<&[(u32, u32)]>,
    ) -> Result<MappingResult, MapError> {
        self.run_with(g, layer_ranges, None)
    }

    /// Run; when `runtime` is provided, spectral placement and the
    /// force-field prefilter execute through the AOT PJRT artifacts.
    pub fn run_with(
        &self,
        g: &Hypergraph,
        layer_ranges: Option<&[(u32, u32)]>,
        runtime: Option<&PjrtRuntime>,
    ) -> Result<MappingResult, MapError> {
        // ---- partition ----
        let t0 = std::time::Instant::now();
        let rho = self.partition(g, layer_ranges)?;
        let partition_time = t0.elapsed();
        mapping::validate(g, &rho, &self.hw)?;

        // ---- quotient ----
        let gp = push_forward(g, &rho).graph;

        // ---- place (+ refine) ----
        let t1 = std::time::Instant::now();
        let (mut placement, mut refine_stats) = match self.placer {
            PlacerKind::Hilbert => (hilbert::place(&gp, &self.hw), None),
            PlacerKind::MinDistance => (mindist::place(&gp, &self.hw), None),
            PlacerKind::Spectral => {
                let pl = match runtime {
                    Some(rt) => spectral::place_with_engine(
                        &gp,
                        &self.hw,
                        &crate::runtime::SpectralEngine { runtime: rt },
                    ),
                    None => spectral::place(&gp, &self.hw),
                };
                (pl, None)
            }
        };
        if self.refiner == RefinerKind::ForceDirected && self.placer != PlacerKind::MinDistance {
            // Open a PJRT force-field session once (weight matrix stays
            // resident); each sweep's batch evaluation then only ships the
            // (N, 2) coordinates.
            let session = runtime
                .filter(|rt| gp.num_nodes() <= rt.force_capacity())
                .and_then(|rt| {
                    let w = crate::runtime::dense_flow_matrix(&gp);
                    rt.force_session(&w, gp.num_nodes()).ok()
                });
            let batch = session
                .as_ref()
                .map(|s| move |coords: &[(u16, u16)]| s.eval(coords).ok());
            let stats = match &batch {
                Some(b) => force::refine(&gp, &self.hw, &mut placement, self.force_params, Some(b)),
                None => force::refine(&gp, &self.hw, &mut placement, self.force_params, None),
            };
            refine_stats = Some(stats);
        }
        let placement_time = t1.elapsed();
        placement
            .validate(&self.hw)
            .map_err(MapError::ConstraintViolated)?;

        // ---- evaluate ----
        let metrics = evaluate_with_threads(&gp, &placement, &self.hw, self.threads);
        let sr = (
            properties::synaptic_reuse(g, &rho, Mean::Arithmetic),
            properties::synaptic_reuse(g, &rho, Mean::Geometric),
        );
        let cl = (
            properties::connections_locality(&gp, &placement, &self.hw, Mean::Arithmetic),
            properties::connections_locality(&gp, &placement, &self.hw, Mean::Geometric),
        );

        Ok(MappingResult {
            rho,
            gp,
            placement,
            metrics,
            sr,
            cl,
            partition_time,
            placement_time,
            refine_stats,
        })
    }

    fn partition(
        &self,
        g: &Hypergraph,
        layer_ranges: Option<&[(u32, u32)]>,
    ) -> Result<Partitioning, MapError> {
        use mapping::sequential::SeqOrder;
        match self.partitioner {
            PartitionerKind::Hierarchical => {
                mapping::hierarchical::partition(g, &self.hw, self.hier_params)
            }
            PartitionerKind::HyperedgeOverlap => mapping::overlap::partition(g, &self.hw),
            PartitionerKind::Sequential => {
                // layered nets: natural ids are already layer-major
                let order = if layer_ranges.is_some() { SeqOrder::Natural } else { SeqOrder::Greedy };
                mapping::sequential::partition(g, &self.hw, order)
            }
            PartitionerKind::SequentialUnordered => {
                mapping::sequential::partition(g, &self.hw, SeqOrder::Natural)
            }
            PartitionerKind::EdgeMap => mapping::edgemap::partition(g, &self.hw),
            PartitionerKind::Streaming => {
                mapping::streaming::partition(g, &self.hw, Default::default())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn;

    fn small_net() -> snn::Network {
        snn::by_name("lenet", 0.12, 3).unwrap()
    }

    fn small_hw() -> NmhConfig {
        NmhConfig::small().scaled(0.05) // force multiple partitions
    }

    #[test]
    fn full_pipeline_all_partitioners() {
        let net = small_net();
        for pk in PartitionerKind::ALL {
            let res = MapperPipeline::new(small_hw())
                .partitioner(pk)
                .placer(PlacerKind::Hilbert)
                .refiner(RefinerKind::None)
                .run(&net.graph, net.layer_ranges.as_deref())
                .unwrap_or_else(|e| panic!("{}: {e}", pk.name()));
            assert!(res.rho.num_parts >= 1, "{}", pk.name());
            assert!(res.metrics.energy > 0.0);
            assert!(res.sr.0 >= 1.0, "{} reuse {}", pk.name(), res.sr.0);
        }
    }

    #[test]
    fn full_pipeline_all_placers() {
        let net = small_net();
        for pl in PlacerKind::ALL {
            let res = MapperPipeline::new(small_hw())
                .partitioner(PartitionerKind::Sequential)
                .placer(pl)
                .refiner(RefinerKind::None)
                .run(&net.graph, net.layer_ranges.as_deref())
                .unwrap_or_else(|e| panic!("{}: {e}", pl.name()));
            res.placement.validate(&small_hw()).unwrap();
            assert!(res.metrics.elp > 0.0);
        }
    }

    #[test]
    fn force_refinement_improves_or_preserves() {
        let net = small_net();
        let base = MapperPipeline::new(small_hw())
            .partitioner(PartitionerKind::HyperedgeOverlap)
            .placer(PlacerKind::Hilbert)
            .refiner(RefinerKind::None)
            .run(&net.graph, None)
            .unwrap();
        let refined = MapperPipeline::new(small_hw())
            .partitioner(PartitionerKind::HyperedgeOverlap)
            .placer(PlacerKind::Hilbert)
            .refiner(RefinerKind::ForceDirected)
            .run(&net.graph, None)
            .unwrap();
        assert!(refined.metrics.wirelength <= base.metrics.wirelength + 1e-9);
        let rs = refined.refine_stats.unwrap();
        assert!(rs.final_wirelength <= rs.initial_wirelength + 1e-9);
    }

    #[test]
    fn thread_count_does_not_change_metrics() {
        // the pipeline's pool knob must be unobservable in the output
        // (ordered reduction in the metric engine, DESIGN.md §6)
        let net = small_net();
        let run = |t: usize| {
            MapperPipeline::new(small_hw())
                .partitioner(PartitionerKind::HyperedgeOverlap)
                .placer(PlacerKind::Hilbert)
                .refiner(RefinerKind::None)
                .threads(t)
                .run(&net.graph, None)
                .unwrap()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.rho.assign, parallel.rho.assign);
        assert_eq!(serial.metrics, parallel.metrics);
    }

    #[test]
    fn kind_parsing_roundtrip() {
        for pk in PartitionerKind::ALL {
            assert_eq!(PartitionerKind::parse(pk.name()), Some(pk));
        }
        for pl in PlacerKind::ALL {
            assert_eq!(PlacerKind::parse(pl.name()), Some(pl));
        }
        assert_eq!(RefinerKind::parse("force"), Some(RefinerKind::ForceDirected));
        assert_eq!(PartitionerKind::parse("nope"), None);
    }

    #[test]
    fn report_contains_key_metrics() {
        let net = small_net();
        let res = MapperPipeline::new(small_hw())
            .partitioner(PartitionerKind::Sequential)
            .placer(PlacerKind::Hilbert)
            .refiner(RefinerKind::None)
            .run(&net.graph, net.layer_ranges.as_deref())
            .unwrap();
        let rep = res.report();
        for key in ["partitions", "connectivity", "energy", "ELP", "synaptic reuse"] {
            assert!(rep.contains(key), "missing {key} in report");
        }
    }
}
