//! The stage registry: string names → boxed stage constructors.
//!
//! [`StageRegistry::builtin`] pre-registers all nine built-in algorithms
//! (six partitioners, three placers) plus the two refiners; downstream
//! code registers additional algorithms with `register_*` and resolves
//! them through the same lookup the pipeline, grid runner, ensemble,
//! multichip mapper and CLI use — adding an algorithm is one
//! registration, not five `match` edits.

use crate::mapping::{edgemap, hierarchical, overlap, sequential, streaming, MapError};
use crate::placement::{force, hilbert, mindist, spectral};
use crate::stage::{NoRefiner, Partitioner, Placer, Refiner, StageParams};
use std::collections::BTreeMap;

/// Constructor: parse stage parameters into a ready partitioner.
pub type PartitionerCtor =
    Box<dyn Fn(&StageParams) -> Result<Box<dyn Partitioner>, String> + Send + Sync>;
/// Constructor: parse stage parameters into a ready placer.
pub type PlacerCtor = Box<dyn Fn(&StageParams) -> Result<Box<dyn Placer>, String> + Send + Sync>;
/// Constructor: parse stage parameters into a ready refiner.
pub type RefinerCtor = Box<dyn Fn(&StageParams) -> Result<Box<dyn Refiner>, String> + Send + Sync>;

/// Maps stage names to constructors. Names are case-sensitive; aliases
/// (historical CLI spellings) resolve to their canonical entry.
pub struct StageRegistry {
    partitioners: BTreeMap<String, PartitionerCtor>,
    placers: BTreeMap<String, PlacerCtor>,
    refiners: BTreeMap<String, RefinerCtor>,
    aliases: BTreeMap<String, String>,
}

impl Default for StageRegistry {
    fn default() -> Self {
        StageRegistry::builtin()
    }
}

impl StageRegistry {
    /// The process-wide built-in registry (built once, shared) — what the
    /// enum shims and `from_spec` resolve against. Use [`Self::builtin`]
    /// when you need an owned registry to extend with `register_*`.
    pub fn global() -> &'static StageRegistry {
        static GLOBAL: std::sync::OnceLock<StageRegistry> = std::sync::OnceLock::new();
        GLOBAL.get_or_init(StageRegistry::builtin)
    }

    /// A registry with no stages (building block for tests / sandboxes).
    pub fn empty() -> StageRegistry {
        StageRegistry {
            partitioners: BTreeMap::new(),
            placers: BTreeMap::new(),
            refiners: BTreeMap::new(),
            aliases: BTreeMap::new(),
        }
    }

    /// All built-in algorithms (paper Table IV + baselines), under the
    /// same canonical names the `*Kind` enums report.
    pub fn builtin() -> StageRegistry {
        let mut r = StageRegistry::empty();
        r.register_partitioner(
            "hierarchical",
            Box::new(|p: &StageParams| -> Result<Box<dyn Partitioner>, String> {
                Ok(Box::new(hierarchical::HierarchicalPartitioner::from_params(p)?))
            }),
        );
        r.register_partitioner(
            "overlap",
            Box::new(|p: &StageParams| -> Result<Box<dyn Partitioner>, String> {
                Ok(Box::new(overlap::OverlapPartitioner::from_params(p)?))
            }),
        );
        r.register_partitioner(
            "sequential",
            Box::new(|p: &StageParams| -> Result<Box<dyn Partitioner>, String> {
                Ok(Box::new(sequential::SequentialPartitioner::from_params(p)?))
            }),
        );
        r.register_partitioner(
            "seq-unordered",
            Box::new(|p: &StageParams| -> Result<Box<dyn Partitioner>, String> {
                Ok(Box::new(sequential::SequentialPartitioner::from_params_unordered(p)?))
            }),
        );
        r.register_partitioner(
            "edgemap",
            Box::new(|p: &StageParams| -> Result<Box<dyn Partitioner>, String> {
                Ok(Box::new(edgemap::EdgeMapPartitioner::from_params(p)?))
            }),
        );
        r.register_partitioner(
            "streaming",
            Box::new(|p: &StageParams| -> Result<Box<dyn Partitioner>, String> {
                Ok(Box::new(streaming::StreamingPartitioner::from_params(p)?))
            }),
        );
        r.register_placer(
            "hilbert",
            Box::new(|p: &StageParams| -> Result<Box<dyn Placer>, String> {
                Ok(Box::new(hilbert::HilbertPlacer::from_params(p)?))
            }),
        );
        r.register_placer(
            "spectral",
            Box::new(|p: &StageParams| -> Result<Box<dyn Placer>, String> {
                Ok(Box::new(spectral::SpectralPlacer::from_params(p)?))
            }),
        );
        r.register_placer(
            "mindist",
            Box::new(|p: &StageParams| -> Result<Box<dyn Placer>, String> {
                Ok(Box::new(mindist::MinDistPlacer::from_params(p)?))
            }),
        );
        r.register_refiner(
            "none",
            Box::new(|p: &StageParams| -> Result<Box<dyn Refiner>, String> {
                p.check_known(&[])?;
                Ok(Box::new(NoRefiner))
            }),
        );
        r.register_refiner(
            "force",
            Box::new(|p: &StageParams| -> Result<Box<dyn Refiner>, String> {
                Ok(Box::new(force::ForceRefiner::from_params(p)?))
            }),
        );
        // historical CLI spellings
        r.alias("hier", "hierarchical");
        r.alias("hyperedge-overlap", "overlap");
        r.alias("seq", "sequential");
        r.alias("unordered", "seq-unordered");
        r.alias("stream", "streaming");
        r.alias("min-distance", "mindist");
        r.alias("force-directed", "force");
        r
    }

    pub fn register_partitioner(&mut self, name: &str, ctor: PartitionerCtor) {
        self.partitioners.insert(name.to_string(), ctor);
    }

    pub fn register_placer(&mut self, name: &str, ctor: PlacerCtor) {
        self.placers.insert(name.to_string(), ctor);
    }

    pub fn register_refiner(&mut self, name: &str, ctor: RefinerCtor) {
        self.refiners.insert(name.to_string(), ctor);
    }

    /// Register `alias` as an alternate spelling of `canonical`.
    pub fn alias(&mut self, alias: &str, canonical: &str) {
        self.aliases.insert(alias.to_string(), canonical.to_string());
    }

    fn resolve<'n>(&'n self, name: &'n str) -> &'n str {
        self.aliases.get(name).map(|s| s.as_str()).unwrap_or(name)
    }

    /// Instantiate a partitioner by name.
    pub fn partitioner(
        &self,
        name: &str,
        params: &StageParams,
    ) -> Result<Box<dyn Partitioner>, MapError> {
        let ctor = self.partitioners.get(self.resolve(name)).ok_or_else(|| {
            MapError::UnknownStage {
                kind: "partitioner",
                name: name.to_string(),
                known: self.partitioner_names(),
            }
        })?;
        ctor(params).map_err(|e| MapError::BadSpec(format!("partitioner '{name}': {e}")))
    }

    /// Instantiate a placer by name.
    pub fn placer(&self, name: &str, params: &StageParams) -> Result<Box<dyn Placer>, MapError> {
        let ctor = self.placers.get(self.resolve(name)).ok_or_else(|| {
            MapError::UnknownStage {
                kind: "placer",
                name: name.to_string(),
                known: self.placer_names(),
            }
        })?;
        ctor(params).map_err(|e| MapError::BadSpec(format!("placer '{name}': {e}")))
    }

    /// Instantiate a refiner by name.
    pub fn refiner(&self, name: &str, params: &StageParams) -> Result<Box<dyn Refiner>, MapError> {
        let ctor = self.refiners.get(self.resolve(name)).ok_or_else(|| {
            MapError::UnknownStage {
                kind: "refiner",
                name: name.to_string(),
                known: self.refiner_names(),
            }
        })?;
        ctor(params).map_err(|e| MapError::BadSpec(format!("refiner '{name}': {e}")))
    }

    /// Canonical partitioner names (sorted, aliases excluded).
    pub fn partitioner_names(&self) -> Vec<String> {
        self.partitioners.keys().cloned().collect()
    }

    /// Canonical placer names (sorted, aliases excluded).
    pub fn placer_names(&self) -> Vec<String> {
        self.placers.keys().cloned().collect()
    }

    /// Canonical refiner names (sorted, aliases excluded).
    pub fn refiner_names(&self) -> Vec<String> {
        self.refiners.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn all_nine_builtin_algorithms_resolve() {
        let r = StageRegistry::builtin();
        let partitioners =
            ["hierarchical", "overlap", "sequential", "seq-unordered", "edgemap", "streaming"];
        let placers = ["hilbert", "spectral", "mindist"];
        assert_eq!(partitioners.len() + placers.len(), 9);
        for name in partitioners {
            let stage = r.partitioner(name, &StageParams::empty()).unwrap();
            assert_eq!(stage.name(), name);
        }
        for name in placers {
            let stage = r.placer(name, &StageParams::empty()).unwrap();
            assert_eq!(stage.name(), name);
        }
        for name in ["none", "force"] {
            let stage = r.refiner(name, &StageParams::empty()).unwrap();
            assert_eq!(stage.name(), name);
        }
        assert_eq!(r.partitioner_names().len(), 6);
        assert_eq!(r.placer_names().len(), 3);
        assert_eq!(r.refiner_names().len(), 2);
    }

    #[test]
    fn aliases_resolve_to_canonical_names() {
        let r = StageRegistry::builtin();
        assert_eq!(r.partitioner("hier", &StageParams::empty()).unwrap().name(), "hierarchical");
        assert_eq!(r.placer("min-distance", &StageParams::empty()).unwrap().name(), "mindist");
        assert_eq!(r.refiner("force-directed", &StageParams::empty()).unwrap().name(), "force");
    }

    #[test]
    fn unknown_names_and_bad_params_error() {
        use crate::mapping::MapError;
        let r = StageRegistry::builtin();
        // unknown names surface as the dedicated UnknownStage variant,
        // with the stage kind and the known-name list attached
        let err = r.partitioner("nope", &StageParams::empty()).unwrap_err();
        assert!(
            matches!(
                &err,
                MapError::UnknownStage { kind: "partitioner", name, known }
                    if name == "nope" && known.contains(&"overlap".to_string())
            ),
            "{err}"
        );
        assert!(matches!(
            r.placer("nope", &StageParams::empty()),
            Err(MapError::UnknownStage { kind: "placer", .. })
        ));
        assert!(matches!(
            r.refiner("nope", &StageParams::empty()),
            Err(MapError::UnknownStage { kind: "refiner", .. })
        ));
        // bad parameters for a *known* stage stay BadSpec
        let p = StageParams::empty().set("typo", Json::Num(1.0));
        assert!(matches!(r.partitioner("overlap", &p), Err(MapError::BadSpec(_))));
        // wrong type
        let p = StageParams::empty().set("window", Json::Str("big".into()));
        assert!(r.partitioner("streaming", &p).is_err());
        // out-of-range value
        let p = StageParams::empty().set("window", Json::Num(0.0));
        assert!(r.partitioner("streaming", &p).is_err());
    }

    #[test]
    fn params_reach_the_stage() {
        let r = StageRegistry::builtin();
        let p = StageParams::empty().set("order", Json::Str("greedy".into()));
        let stage = r.partitioner("sequential", &p).unwrap();
        assert_eq!(stage.name(), "sequential");
        let p = StageParams::empty().set("max_sweeps", Json::Num(3.0));
        assert!(r.refiner("force", &p).is_ok());
        assert!(r.refiner("none", &p).is_err(), "'none' accepts no params");
    }
}
