//! L3 coordinator: the user-facing pipeline, the stage registry and
//! serializable pipeline specs, the experiment grid runner, the
//! time-budgeted ensemble mode, and report emitters.

pub mod ensemble;
pub mod experiment;
pub mod pipeline;
pub mod registry;
pub mod report;
pub mod spec;

pub use pipeline::{MapperPipeline, MappingResult, PartitionerKind, PlacerKind, RefinerKind};
pub use registry::StageRegistry;
pub use spec::{PipelineSpec, StageSpec};
