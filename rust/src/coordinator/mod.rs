//! L3 coordinator: the user-facing pipeline, the experiment grid runner,
//! the time-budgeted ensemble mode, and report emitters.

pub mod ensemble;
pub mod experiment;
pub mod pipeline;
pub mod report;

pub use pipeline::{MapperPipeline, MappingResult, PartitionerKind, PlacerKind, RefinerKind};
