//! Hierarchical-multicast cost model (paper §III-A: connections locality
//! "seamlessly creates an opportunity for the hierarchical multicasting
//! of spikes, on architectures that implement such a feature [4]").
//!
//! Under unicast (Table I), an h-edge pays per destination core. A
//! multicast NoC instead forwards one copy along a distribution tree. We
//! approximate the rectilinear Steiner tree with Prim's minimum spanning
//! tree under Manhattan distance (a ≤1.5x overestimate of RSMT), and also
//! report the half-perimeter lower bound. The tighter an h-edge's
//! locality (Eq. 15), the bigger the multicast saving — this model makes
//! that argument quantitative.

use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use crate::placement::Placement;

/// Multicast evaluation of one mapping.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MulticastMetrics {
    /// Σ_e w(e) · MST length of {γ(s)} ∪ γ(D) — links traversed per step.
    pub tree_energy: f64,
    /// Unicast link traversals for the same mapping (Σ_e w Σ_d dist).
    pub unicast_energy: f64,
    /// Σ_e w(e) · HPWL(e): the multicast lower bound.
    pub hpwl_bound: f64,
    /// tree_energy / unicast_energy (≤ 1; lower = multicast helps more).
    pub saving_ratio: f64,
}

/// Evaluate multicast vs unicast spike movement for a placed mapping.
/// Energies are in pJ using the Table II per-hop constants.
pub fn evaluate_multicast(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
) -> MulticastMetrics {
    let per_hop = hw.costs.e_r + hw.costs.e_t;
    let mut m = MulticastMetrics::default();
    let mut pts: Vec<(u16, u16)> = Vec::new();
    for e in gp.edge_ids() {
        let w = gp.weight(e) as f64;
        let s = placement.coords[gp.source(e) as usize];
        pts.clear();
        pts.push(s);
        let mut unicast = 0.0;
        for &d in gp.dsts(e) {
            let c = placement.coords[d as usize];
            unicast += NmhConfig::manhattan(s, c) as f64;
            if !pts.contains(&c) {
                pts.push(c);
            }
        }
        m.unicast_energy += w * unicast * per_hop;
        m.tree_energy += w * mst_length(&pts) as f64 * per_hop;
        m.hpwl_bound += w * hpwl(&pts) as f64 * per_hop;
    }
    m.saving_ratio = if m.unicast_energy > 0.0 {
        m.tree_energy / m.unicast_energy
    } else {
        1.0
    };
    m
}

/// Manhattan-metric minimum spanning tree length (Prim, O(k²)).
pub fn mst_length(pts: &[(u16, u16)]) -> u64 {
    let k = pts.len();
    if k <= 1 {
        return 0;
    }
    let mut in_tree = vec![false; k];
    let mut best = vec![u32::MAX; k];
    in_tree[0] = true;
    for j in 1..k {
        best[j] = NmhConfig::manhattan(pts[0], pts[j]);
    }
    let mut total = 0u64;
    for _ in 1..k {
        let mut pick = usize::MAX;
        let mut pick_d = u32::MAX;
        for j in 0..k {
            if !in_tree[j] && best[j] < pick_d {
                pick_d = best[j];
                pick = j;
            }
        }
        total += pick_d as u64;
        in_tree[pick] = true;
        for j in 0..k {
            if !in_tree[j] {
                let d = NmhConfig::manhattan(pts[pick], pts[j]);
                if d < best[j] {
                    best[j] = d;
                }
            }
        }
    }
    total
}

/// Half-perimeter wirelength of the bounding box — the classic lower
/// bound on any rectilinear Steiner tree spanning `pts`.
pub fn hpwl(pts: &[(u16, u16)]) -> u32 {
    if pts.len() <= 1 {
        return 0;
    }
    let (mut x0, mut x1, mut y0, mut y1) = (u16::MAX, 0u16, u16::MAX, 0u16);
    for &(x, y) in pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    (x1 - x0) as u32 + (y1 - y0) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;

    #[test]
    fn mst_simple_shapes() {
        assert_eq!(mst_length(&[(0, 0)]), 0);
        assert_eq!(mst_length(&[(0, 0), (3, 0)]), 3);
        // L-shape: (0,0)-(3,0)-(3,4) = 3 + 4
        assert_eq!(mst_length(&[(0, 0), (3, 0), (3, 4)]), 7);
        // square corners, side 2: any spanning tree = 3 sides = 6
        assert_eq!(mst_length(&[(0, 0), (2, 0), (0, 2), (2, 2)]), 6);
    }

    #[test]
    fn hpwl_lower_bounds_mst() {
        let mut rng = crate::util::rng::Pcg64::seeded(3);
        for _ in 0..200 {
            let k = rng.range(2, 10);
            let pts: Vec<(u16, u16)> =
                (0..k).map(|_| (rng.below(30) as u16, rng.below(30) as u16)).collect();
            assert!(hpwl(&pts) as u64 <= mst_length(&pts), "pts={pts:?}");
        }
    }

    #[test]
    fn multicast_never_worse_than_unicast() {
        let mut rng = crate::util::rng::Pcg64::seeded(5);
        let n = 40;
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let dsts: Vec<u32> = (0..5).map(|_| rng.below(n) as u32).filter(|&d| d != s).collect();
            if !dsts.is_empty() {
                b.add_edge(s, dsts, rng.next_f32() + 0.01);
            }
        }
        let gp = b.build();
        let hw = NmhConfig::small();
        let pl = crate::placement::hilbert::place(&gp, &hw);
        let m = evaluate_multicast(&gp, &pl, &hw);
        assert!(m.tree_energy <= m.unicast_energy + 1e-9);
        assert!(m.hpwl_bound <= m.tree_energy + 1e-9);
        assert!(m.saving_ratio <= 1.0 && m.saving_ratio > 0.0);
    }

    #[test]
    fn tight_locality_saves_more() {
        // one h-edge to 4 dsts: clustered vs scattered placements
        let mut b = HypergraphBuilder::new(5);
        b.add_edge(0, vec![1, 2, 3, 4], 1.0);
        let gp = b.build();
        let hw = NmhConfig::small();
        let near = Placement {
            coords: vec![(10, 10), (11, 10), (10, 11), (11, 11), (12, 10)],
        };
        let far = Placement {
            coords: vec![(0, 0), (60, 0), (0, 60), (60, 60), (30, 30)],
        };
        let mn = evaluate_multicast(&gp, &near, &hw);
        let mf = evaluate_multicast(&gp, &far, &hw);
        // scattered destinations benefit less (trunk sharing is minimal)
        assert!(mn.saving_ratio < mf.saving_ratio);
    }
}
