//! τ(h, h_s, h_d): probability of a spike being routed through core `h`
//! when travelling from `h_s` to `h_d` (Table I, after [7]).
//!
//! Model: the NoC delivers along a uniformly random monotone (shortest)
//! lattice path inside Rect(h_s, h_d). The probability of passing through
//! `h` is then
//!
//! ```text
//! τ = C(a1+b1, a1) · C(a2+b2, a2) / C(A+B, A)
//! ```
//!
//! with (a1,b1) the |Δx|,|Δy| from h_s to h, (a2,b2) from h to h_d, and
//! (A,B) from h_s to h_d; τ = 0 outside the rectangle.

/// Pascal-triangle binomial table C(n, k) for n ≤ MAX_N (f64; the largest
/// needed value C(126,63) ≈ 4.5e36 is exactly representable ratios-wise).
pub struct Binomial {
    max_n: usize,
    table: Vec<f64>,
}

impl Binomial {
    /// Table covering paths across a `width` × `height` lattice.
    pub fn for_lattice(width: usize, height: usize) -> Self {
        let max_n = width + height; // |Δx|+|Δy| ≤ (w-1)+(h-1) < w+h
        let mut table = vec![0.0f64; (max_n + 1) * (max_n + 1)];
        for n in 0..=max_n {
            table[n * (max_n + 1)] = 1.0;
            for k in 1..=n {
                let prev = (n - 1) * (max_n + 1);
                table[n * (max_n + 1) + k] =
                    table[prev + k - 1] + if k <= n - 1 { table[prev + k] } else { 0.0 };
            }
        }
        Binomial { max_n, table }
    }

    #[inline]
    pub fn c(&self, n: usize, k: usize) -> f64 {
        debug_assert!(n <= self.max_n && k <= n, "C({n},{k}) out of table");
        self.table[n * (self.max_n + 1) + k]
    }
}

/// τ(h, h_s, h_d) under uniform random shortest-path routing.
pub fn tau(bin: &Binomial, h: (u16, u16), hs: (u16, u16), hd: (u16, u16)) -> f64 {
    let (hx, hy) = (h.0 as i32, h.1 as i32);
    let (sx, sy) = (hs.0 as i32, hs.1 as i32);
    let (dx, dy) = (hd.0 as i32, hd.1 as i32);
    // h must lie in the closed rectangle spanned by hs, hd
    if hx < sx.min(dx) || hx > sx.max(dx) || hy < sy.min(dy) || hy > sy.max(dy) {
        return 0.0;
    }
    let a1 = (hx - sx).unsigned_abs() as usize;
    let b1 = (hy - sy).unsigned_abs() as usize;
    let a2 = (dx - hx).unsigned_abs() as usize;
    let b2 = (dy - hy).unsigned_abs() as usize;
    let a = (dx - sx).unsigned_abs() as usize;
    let b = (dy - sy).unsigned_abs() as usize;
    let total = bin.c(a + b, a);
    if total == 0.0 {
        return 0.0;
    }
    bin.c(a1 + b1, a1) * bin.c(a2 + b2, a2) / total
}

/// Iterate the closed rectangle Rect(h1, h2) (Table I).
pub fn rect(h1: (u16, u16), h2: (u16, u16)) -> impl Iterator<Item = (u16, u16)> {
    let x0 = h1.0.min(h2.0);
    let x1 = h1.0.max(h2.0);
    let y0 = h1.1.min(h2.1);
    let y1 = h1.1.max(h2.1);
    (y0..=y1).flat_map(move |y| (x0..=x1).map(move |x| (x, y)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bin() -> Binomial {
        Binomial::for_lattice(64, 64)
    }

    #[test]
    fn binomial_values() {
        let b = bin();
        assert_eq!(b.c(0, 0), 1.0);
        assert_eq!(b.c(5, 2), 10.0);
        assert_eq!(b.c(10, 0), 1.0);
        assert_eq!(b.c(10, 10), 1.0);
        assert_eq!(b.c(6, 3), 20.0);
    }

    #[test]
    fn tau_endpoints_are_certain() {
        let b = bin();
        let hs = (2, 3);
        let hd = (7, 9);
        assert!((tau(&b, hs, hs, hd) - 1.0).abs() < 1e-12);
        assert!((tau(&b, hd, hs, hd) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tau_zero_outside_rect() {
        let b = bin();
        assert_eq!(tau(&b, (0, 0), (2, 2), (5, 5)), 0.0);
        assert_eq!(tau(&b, (6, 2), (2, 2), (5, 5)), 0.0);
    }

    #[test]
    fn tau_antidiagonal_slices_sum_to_one() {
        // every shortest path crosses each "anti-diagonal" of the rect
        // exactly once: Σ_{h: dist(hs,h)=t} τ(h) = 1 for each t
        let b = bin();
        let hs = (1u16, 2u16);
        let hd = (6u16, 8u16);
        let total_dist = 5 + 6;
        for t in 0..=total_dist {
            let mut sum = 0.0;
            for h in rect(hs, hd) {
                let d = (h.0 as i32 - hs.0 as i32).abs() + (h.1 as i32 - hs.1 as i32).abs();
                if d == t {
                    sum += tau(&b, h, hs, hd);
                }
            }
            assert!((sum - 1.0).abs() < 1e-9, "slice t={t} sums to {sum}");
        }
    }

    #[test]
    fn tau_symmetric_under_reversal() {
        let b = bin();
        let hs = (3, 1);
        let hd = (9, 7);
        for h in rect(hs, hd) {
            let fwd = tau(&b, h, hs, hd);
            let back = tau(&b, h, hd, hs);
            assert!((fwd - back).abs() < 1e-12);
        }
    }

    #[test]
    fn degenerate_line_route() {
        let b = bin();
        // same row: every rect cell is on the single path
        for x in 2..=6u16 {
            assert!((tau(&b, (x, 4), (2, 4), (6, 4)) - 1.0).abs() < 1e-12);
        }
        // same cell
        assert!((tau(&b, (5, 5), (5, 5), (5, 5)) - 1.0).abs() < 1e-12);
    }
}
