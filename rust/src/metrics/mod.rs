//! Post-layout mapping performance metrics (paper Table I, adapted to
//! hypergraphs from [7]): energy, latency, interconnect congestion, the
//! Energy-Latency Product compound indicator, plus the §V-C property
//! measures (synaptic reuse, connections locality) and rank statistics.

pub mod cost;
pub mod multicast;
pub mod properties;
pub mod stats;
pub mod tau;

pub use cost::{evaluate, evaluate_serial, evaluate_with_threads, MappingMetrics};
