//! Table I cost model.
//!
//! For every quotient h-edge copy (source partition s → destination
//! partition d, spike frequency w):
//!   energy  += w · (‖γ(s)−γ(d)‖ · (E_R + E_T) + E_R)
//!   latency += w · (‖γ(s)−γ(d)‖ · (L_R + L_T) + L_R)
//! Congestion is the maximum expected per-core traffic under random
//! shortest-path routing: max_h Σ_{(s,d)} w · τ(h, γ(s), γ(d)).
//!
//! Spike replication is inherent: the quotient graph has already collapsed
//! per-neuron destinations into distinct partitions, so each core pays for
//! at most one copy per axon — the correction hypergraphs bring over [7]'s
//! edge-wise accounting (§III-B).

use super::tau::{rect, tau, Binomial};
use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use crate::placement::Placement;

/// Evaluated mapping metrics (Table I + compound indicators).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MappingMetrics {
    /// Total spike-movement energy, pJ per timestep (expected).
    pub energy: f64,
    /// Total spike-movement latency, ns per timestep (expected, serial).
    pub latency: f64,
    /// Max expected per-core traffic (spikes/timestep through a router).
    pub congestion: f64,
    /// Energy-Latency Product (paper's compound indicator).
    pub elp: f64,
    /// Eq. 7 connectivity of the partitioning.
    pub connectivity: f64,
    /// Weighted Manhattan wirelength (refiners' objective).
    pub wirelength: f64,
    pub num_partitions: usize,
    /// Mean spike hop distance (wirelength / total copies weight).
    pub mean_hops: f64,
}

impl MappingMetrics {
    pub fn to_row(&self) -> String {
        format!(
            "energy={:.4e}pJ latency={:.4e}ns congestion={:.4e} elp={:.4e} conn={:.4e} parts={}",
            self.energy, self.latency, self.congestion, self.elp, self.connectivity,
            self.num_partitions
        )
    }
}

/// Evaluate a complete mapping: quotient h-graph `gp` + placement γ.
pub fn evaluate(gp: &Hypergraph, placement: &Placement, hw: &NmhConfig) -> MappingMetrics {
    assert_eq!(gp.num_nodes(), placement.len());
    let costs = hw.costs;
    let mut energy = 0.0f64;
    let mut latency = 0.0f64;
    let mut wirelength = 0.0f64;
    let mut copies_weight = 0.0f64;
    let mut connectivity = 0.0f64;

    // Aggregate directed partition-pair flows for the congestion pass.
    let mut flows: std::collections::HashMap<(u32, u32), f64> = std::collections::HashMap::new();

    for e in gp.edge_ids() {
        let s = gp.source(e);
        let w = gp.weight(e) as f64;
        let sc = placement.coords[s as usize];
        connectivity += w * gp.cardinality(e) as f64;
        for &d in gp.dsts(e) {
            let dc = placement.coords[d as usize];
            let dist = NmhConfig::manhattan(sc, dc) as f64;
            energy += w * (dist * (costs.e_r + costs.e_t) + costs.e_r);
            latency += w * (dist * (costs.l_r + costs.l_t) + costs.l_r);
            wirelength += w * dist;
            copies_weight += w;
            if d != s {
                *flows.entry((s, d)).or_insert(0.0) += w;
            }
        }
    }

    // Congestion: expected traffic per core under random shortest paths.
    let bin = Binomial::for_lattice(hw.width, hw.height);
    let mut core_traffic = vec![0.0f64; hw.num_cores()];
    for (&(s, d), &w) in flows.iter() {
        let sc = placement.coords[s as usize];
        let dc = placement.coords[d as usize];
        for h in rect(sc, dc) {
            let t = tau(&bin, h, sc, dc);
            if t > 0.0 {
                core_traffic[hw.index(h.0, h.1)] += w * t;
            }
        }
    }
    let congestion = core_traffic.iter().cloned().fold(0.0, f64::max);

    MappingMetrics {
        energy,
        latency,
        congestion,
        elp: energy * latency,
        connectivity,
        wirelength,
        num_partitions: gp.num_nodes(),
        mean_hops: if copies_weight > 0.0 { wirelength / copies_weight } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;

    fn hw() -> NmhConfig {
        NmhConfig::small()
    }

    #[test]
    fn hand_computed_two_partitions() {
        // one h-edge: partition 0 -> {1}, w = 2, distance 3
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 2.0);
        let gp = b.build();
        let pl = Placement { coords: vec![(0, 0), (3, 0)] };
        let m = evaluate(&gp, &pl, &hw());
        let c = hw().costs;
        assert!((m.energy - 2.0 * (3.0 * (c.e_r + c.e_t) + c.e_r)).abs() < 1e-9);
        assert!((m.latency - 2.0 * (3.0 * (c.l_r + c.l_t) + c.l_r)).abs() < 1e-9);
        assert!((m.elp - m.energy * m.latency).abs() < 1e-9);
        assert!((m.wirelength - 6.0).abs() < 1e-9);
        assert!((m.mean_hops - 3.0).abs() < 1e-9);
        // all 2 units of traffic pass through every core of the line
        assert!((m.congestion - 2.0).abs() < 1e-9);
    }

    #[test]
    fn colocated_destination_costs_router_only() {
        // self-delivery inside a core: distance 0 still pays one E_R
        let mut b = HypergraphBuilder::new(1);
        b.add_edge(0, vec![0], 1.0);
        let gp = b.build();
        let pl = Placement { coords: vec![(5, 5)] };
        let m = evaluate(&gp, &pl, &hw());
        assert!((m.energy - hw().costs.e_r).abs() < 1e-9);
        assert_eq!(m.congestion, 0.0); // no inter-core flow
    }

    #[test]
    fn replication_cheaper_than_split() {
        // h-edge reaching 4 neurons: in one partition = 1 copy; in 4 = 4
        let mut merged_b = HypergraphBuilder::new(2);
        merged_b.add_edge(0, vec![1], 1.0); // quotient with all dsts merged
        let merged = merged_b.build();
        let mut split_b = HypergraphBuilder::new(5);
        split_b.add_edge(0, vec![1, 2, 3, 4], 1.0); // 4 separate partitions
        let split = split_b.build();
        let pm = Placement { coords: vec![(0, 0), (1, 0)] };
        let ps = Placement {
            coords: vec![(0, 0), (1, 0), (1, 1), (2, 0), (2, 1)],
        };
        let m_merged = evaluate(&merged, &pm, &hw());
        let m_split = evaluate(&split, &ps, &hw());
        assert!(m_merged.energy < m_split.energy / 2.0);
    }

    #[test]
    fn congestion_peaks_between_hot_pair() {
        // heavy flow between (0,0) and (10,0) dominates a light side flow
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1], 10.0);
        b.add_edge(2, vec![3], 0.1);
        let gp = b.build();
        let pl = Placement {
            coords: vec![(0, 0), (10, 0), (0, 20), (1, 20)],
        };
        let m = evaluate(&gp, &pl, &hw());
        // single-row route: all 10 units cross every core in the row
        assert!((m.congestion - 10.0).abs() < 1e-9);
    }

    #[test]
    fn energy_decreases_with_distance() {
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 1.0);
        let gp = b.build();
        let near = evaluate(&gp, &Placement { coords: vec![(0, 0), (1, 0)] }, &hw());
        let far = evaluate(&gp, &Placement { coords: vec![(0, 0), (20, 20)] }, &hw());
        assert!(near.energy < far.energy);
        assert!(near.elp < far.elp);
    }
}
