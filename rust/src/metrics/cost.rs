//! Table I cost model.
//!
//! For every quotient h-edge copy (source partition s → destination
//! partition d, spike frequency w):
//!   energy  += w · (‖γ(s)−γ(d)‖ · (E_R + E_T) + E_R)
//!   latency += w · (‖γ(s)−γ(d)‖ · (L_R + L_T) + L_R)
//! Congestion is the maximum expected per-core traffic under random
//! shortest-path routing: max_h Σ_{(s,d)} w · τ(h, γ(s), γ(d)).
//!
//! Spike replication is inherent: the quotient graph has already collapsed
//! per-neuron destinations into distinct partitions, so each core pays for
//! at most one copy per axon — the correction hypergraphs bring over [7]'s
//! edge-wise accounting (§III-B).
//!
//! # Execution model (DESIGN.md §6)
//!
//! The h-edge sweep and the congestion pass both run as fixed-size chunked
//! folds over [`crate::util::par`]: per-chunk accumulators are merged in
//! ascending chunk order, so the floating-point merge tree is identical
//! for any worker count and `evaluate` is bit-for-bit deterministic —
//! `evaluate_with_threads(.., 1)` equals `evaluate_with_threads(.., k)`
//! exactly. Directed partition-pair flows are aggregated through a sorted
//! flat `Vec` (stable sort keeps duplicate-key weight sums in edge order)
//! instead of a `HashMap`, which both removes per-edge rehashing and fixes
//! the run-to-run nondeterminism of iterating a randomly-seeded map.

use super::tau::{rect, tau, Binomial};
use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use crate::placement::Placement;
use crate::util::par;

/// H-edges folded per chunk. Fixed (never derived from the worker count)
/// so the reduction tree — and thus every f64 sum — is thread-invariant.
const EDGE_CHUNK: usize = 1024;
/// Aggregated flows folded per chunk of the congestion pass.
const FLOW_CHUNK: usize = 512;

/// Evaluated mapping metrics (Table I + compound indicators).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MappingMetrics {
    /// Total spike-movement energy, pJ per timestep (expected).
    pub energy: f64,
    /// Total spike-movement latency, ns per timestep (expected, serial).
    pub latency: f64,
    /// Max expected per-core traffic (spikes/timestep through a router).
    pub congestion: f64,
    /// Energy-Latency Product (paper's compound indicator).
    pub elp: f64,
    /// Eq. 7 connectivity of the partitioning.
    pub connectivity: f64,
    /// Weighted Manhattan wirelength (refiners' objective).
    pub wirelength: f64,
    pub num_partitions: usize,
    /// Mean spike hop distance (wirelength / total copies weight).
    pub mean_hops: f64,
}

impl MappingMetrics {
    pub fn to_row(&self) -> String {
        format!(
            "energy={:.4e}pJ latency={:.4e}ns congestion={:.4e} elp={:.4e} conn={:.4e} parts={}",
            self.energy, self.latency, self.congestion, self.elp, self.connectivity,
            self.num_partitions
        )
    }
}

/// Per-chunk accumulator of the h-edge sweep.
#[derive(Default)]
struct EdgeAcc {
    energy: f64,
    latency: f64,
    wirelength: f64,
    copies_weight: f64,
    connectivity: f64,
    /// Raw inter-partition copies `(s, d, w)` in edge order (unaggregated).
    flows: Vec<(u32, u32, f64)>,
}

/// Evaluate a complete mapping: quotient h-graph `gp` + placement γ.
/// Parallel over the default worker pool; see [`evaluate_with_threads`].
pub fn evaluate(gp: &Hypergraph, placement: &Placement, hw: &NmhConfig) -> MappingMetrics {
    evaluate_with_threads(gp, placement, hw, par::max_threads())
}

/// Single-threaded reference evaluation. Same chunk structure, same merge
/// order, no worker threads — the parallel path must equal this exactly.
pub fn evaluate_serial(gp: &Hypergraph, placement: &Placement, hw: &NmhConfig) -> MappingMetrics {
    evaluate_with_threads(gp, placement, hw, 1)
}

/// Evaluate on an explicit worker count (the coordinator threads its pool
/// size through here; 1 = inline serial execution).
pub fn evaluate_with_threads(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    threads: usize,
) -> MappingMetrics {
    assert_eq!(gp.num_nodes(), placement.len());
    let costs = hw.costs;
    let coords = &placement.coords;

    // ---- chunked h-edge sweep (energy / latency / wirelength / flows) ----
    // snn-lint: allow(float-merge-order) — §6 discipline: chunk boundaries are fixed by
    // EDGE_CHUNK (never by thread count) and chunk partials merge serially in chunk-id
    // order, so the f64 reduction tree is identical for every thread count
    let acc = par::chunked_fold(
        gp.num_edges(),
        EDGE_CHUNK,
        threads,
        |r| {
            let mut a = EdgeAcc::default();
            for e in r {
                let e = e as u32;
                let s = gp.source(e);
                let w = gp.weight(e) as f64;
                let sc = coords[s as usize];
                a.connectivity += w * gp.cardinality(e) as f64;
                for &d in gp.dsts(e) {
                    let dc = coords[d as usize];
                    let dist = NmhConfig::manhattan(sc, dc) as f64;
                    a.energy += w * (dist * (costs.e_r + costs.e_t) + costs.e_r);
                    a.latency += w * (dist * (costs.l_r + costs.l_t) + costs.l_r);
                    a.wirelength += w * dist;
                    a.copies_weight += w;
                    if d != s {
                        a.flows.push((s, d, w));
                    }
                }
            }
            a
        },
        |mut a, mut b| {
            a.energy += b.energy;
            a.latency += b.latency;
            a.wirelength += b.wirelength;
            a.copies_weight += b.copies_weight;
            a.connectivity += b.connectivity;
            a.flows.append(&mut b.flows);
            a
        },
    )
    .unwrap_or_default();

    // ---- aggregate directed partition-pair flows (flat, sorted) ----
    // Stable sort: duplicate (s, d) keys keep their edge order, so the
    // per-pair weight sums are reduction-order deterministic too.
    let mut raw = acc.flows;
    raw.sort_by_key(|&(s, d, _)| (s, d));
    let mut flows: Vec<(u32, u32, f64)> = Vec::with_capacity(raw.len());
    for (s, d, w) in raw {
        match flows.last_mut() {
            Some(last) if last.0 == s && last.1 == d => last.2 += w,
            _ => flows.push((s, d, w)),
        }
    }

    // ---- congestion: parallel per-core traffic accumulation ----
    let bin = Binomial::for_lattice(hw.width, hw.height);
    // snn-lint: allow(float-merge-order) — §6 discipline: fixed FLOW_CHUNK chunking and
    // in-order serial merge of the per-chunk traffic vectors keep the per-core f64 sums
    // bit-identical across thread counts
    let core_traffic = par::chunked_fold(
        flows.len(),
        FLOW_CHUNK,
        threads,
        |r| {
            let mut traffic = vec![0.0f64; hw.num_cores()];
            for &(s, d, w) in &flows[r] {
                let sc = coords[s as usize];
                let dc = coords[d as usize];
                for h in rect(sc, dc) {
                    let t = tau(&bin, h, sc, dc);
                    if t > 0.0 {
                        traffic[hw.index(h.0, h.1)] += w * t;
                    }
                }
            }
            traffic
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(&b) {
                *x += *y;
            }
            a
        },
    );
    let congestion = core_traffic
        .map(|t| t.into_iter().fold(0.0, f64::max))
        .unwrap_or(0.0);

    MappingMetrics {
        energy: acc.energy,
        latency: acc.latency,
        congestion,
        elp: acc.energy * acc.latency,
        connectivity: acc.connectivity,
        wirelength: acc.wirelength,
        num_partitions: gp.num_nodes(),
        mean_hops: if acc.copies_weight > 0.0 {
            acc.wirelength / acc.copies_weight
        } else {
            0.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;
    use crate::util::rng::Pcg64;

    fn hw() -> NmhConfig {
        NmhConfig::small()
    }

    #[test]
    fn hand_computed_two_partitions() {
        // one h-edge: partition 0 -> {1}, w = 2, distance 3
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 2.0);
        let gp = b.build();
        let pl = Placement { coords: vec![(0, 0), (3, 0)] };
        let m = evaluate(&gp, &pl, &hw());
        let c = hw().costs;
        assert!((m.energy - 2.0 * (3.0 * (c.e_r + c.e_t) + c.e_r)).abs() < 1e-9);
        assert!((m.latency - 2.0 * (3.0 * (c.l_r + c.l_t) + c.l_r)).abs() < 1e-9);
        assert!((m.elp - m.energy * m.latency).abs() < 1e-9);
        assert!((m.wirelength - 6.0).abs() < 1e-9);
        assert!((m.mean_hops - 3.0).abs() < 1e-9);
        // all 2 units of traffic pass through every core of the line
        assert!((m.congestion - 2.0).abs() < 1e-9);
    }

    #[test]
    fn colocated_destination_costs_router_only() {
        // self-delivery inside a core: distance 0 still pays one E_R
        let mut b = HypergraphBuilder::new(1);
        b.add_edge(0, vec![0], 1.0);
        let gp = b.build();
        let pl = Placement { coords: vec![(5, 5)] };
        let m = evaluate(&gp, &pl, &hw());
        assert!((m.energy - hw().costs.e_r).abs() < 1e-9);
        assert_eq!(m.congestion, 0.0); // no inter-core flow
    }

    #[test]
    fn replication_cheaper_than_split() {
        // h-edge reaching 4 neurons: in one partition = 1 copy; in 4 = 4
        let mut merged_b = HypergraphBuilder::new(2);
        merged_b.add_edge(0, vec![1], 1.0); // quotient with all dsts merged
        let merged = merged_b.build();
        let mut split_b = HypergraphBuilder::new(5);
        split_b.add_edge(0, vec![1, 2, 3, 4], 1.0); // 4 separate partitions
        let split = split_b.build();
        let pm = Placement { coords: vec![(0, 0), (1, 0)] };
        let ps = Placement {
            coords: vec![(0, 0), (1, 0), (1, 1), (2, 0), (2, 1)],
        };
        let m_merged = evaluate(&merged, &pm, &hw());
        let m_split = evaluate(&split, &ps, &hw());
        assert!(m_merged.energy < m_split.energy / 2.0);
    }

    #[test]
    fn congestion_peaks_between_hot_pair() {
        // heavy flow between (0,0) and (10,0) dominates a light side flow
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1], 10.0);
        b.add_edge(2, vec![3], 0.1);
        let gp = b.build();
        let pl = Placement {
            coords: vec![(0, 0), (10, 0), (0, 20), (1, 20)],
        };
        let m = evaluate(&gp, &pl, &hw());
        // single-row route: all 10 units cross every core in the row
        assert!((m.congestion - 10.0).abs() < 1e-9);
    }

    #[test]
    fn energy_decreases_with_distance() {
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 1.0);
        let gp = b.build();
        let near = evaluate(&gp, &Placement { coords: vec![(0, 0), (1, 0)] }, &hw());
        let far = evaluate(&gp, &Placement { coords: vec![(0, 0), (20, 20)] }, &hw());
        assert!(near.energy < far.energy);
        assert!(near.elp < far.elp);
    }

    /// Seeded random quotient-like graph (multi-outbound) + placement.
    fn random_case(parts: usize, edges: usize, seed: u64) -> (Hypergraph, Placement) {
        let mut rng = Pcg64::seeded(seed);
        let mut b = HypergraphBuilder::new(parts);
        for _ in 0..edges {
            let s = rng.below(parts) as u32;
            let k = rng.range(1, 9);
            let dsts: Vec<u32> = (0..k).map(|_| rng.below(parts) as u32).collect();
            b.add_edge(s, dsts, rng.next_f32() * 4.0 + 0.01);
        }
        let g = b.build();
        // distinct coords on an 8-wide strip of the lattice
        let coords: Vec<(u16, u16)> = (0..parts)
            .map(|p| ((p % 8) as u16, (p / 8) as u16))
            .collect();
        (g, Placement { coords })
    }

    #[test]
    fn parallel_equals_serial_exactly() {
        // the ordered reduction must make the worker count unobservable,
        // down to the last ulp of every metric
        let (g, pl) = random_case(96, 700, 91);
        let serial = evaluate_serial(&g, &pl, &hw());
        for threads in [2, 3, 8] {
            let par = evaluate_with_threads(&g, &pl, &hw(), threads);
            assert_eq!(serial, par, "threads={threads} diverged from serial");
            assert_eq!(serial.energy.to_bits(), par.energy.to_bits());
            assert_eq!(serial.latency.to_bits(), par.latency.to_bits());
            assert_eq!(serial.congestion.to_bits(), par.congestion.to_bits());
            assert_eq!(serial.wirelength.to_bits(), par.wirelength.to_bits());
        }
        // and the default entry point is that same deterministic value
        assert_eq!(serial, evaluate(&g, &pl, &hw()));
    }

    /// All monotone (shortest) lattice paths from `s` to `d`.
    fn all_shortest_paths(s: (u16, u16), d: (u16, u16)) -> Vec<Vec<(u16, u16)>> {
        fn go(
            cur: (i32, i32),
            d: (i32, i32),
            path: &mut Vec<(u16, u16)>,
            out: &mut Vec<Vec<(u16, u16)>>,
        ) {
            path.push((cur.0 as u16, cur.1 as u16));
            if cur == d {
                out.push(path.clone());
            } else {
                let sx = (d.0 - cur.0).signum();
                let sy = (d.1 - cur.1).signum();
                if sx != 0 {
                    go((cur.0 + sx, cur.1), d, path, out);
                }
                if sy != 0 {
                    go((cur.0, cur.1 + sy), d, path, out);
                }
            }
            path.pop();
        }
        let mut out = Vec::new();
        let mut path = Vec::new();
        go(
            (s.0 as i32, s.1 as i32),
            (d.0 as i32, d.1 as i32),
            &mut path,
            &mut out,
        );
        out
    }

    #[test]
    fn congestion_matches_brute_force_path_enumeration() {
        // distinct (s, d) partition pairs on a small patch of the lattice;
        // expected per-core traffic under uniform random shortest-path
        // routing is reproduced by literally enumerating every path
        let mut b = HypergraphBuilder::new(5);
        b.add_edge(0, vec![1, 2], 1.5); // (0,0) -> (3,2), (0,0) -> (2,3)
        b.add_edge(3, vec![4], 2.0); //    (1,1) -> (3,3)
        b.add_edge(2, vec![0], 0.7); //    (2,3) -> (0,0)
        let gp = b.build();
        let coords: Vec<(u16, u16)> = vec![(0, 0), (3, 2), (2, 3), (1, 1), (3, 3)];
        let pl = Placement { coords: coords.clone() };
        let hw = hw();

        let mut traffic = vec![0.0f64; hw.num_cores()];
        for e in gp.edge_ids() {
            let s = gp.source(e);
            let w = gp.weight(e) as f64;
            for &d in gp.dsts(e) {
                if d == s {
                    continue;
                }
                let paths = all_shortest_paths(coords[s as usize], coords[d as usize]);
                let p_path = w / paths.len() as f64;
                for path in &paths {
                    for &(x, y) in path {
                        traffic[hw.index(x, y)] += p_path;
                    }
                }
            }
        }
        let brute_max = traffic.iter().cloned().fold(0.0, f64::max);

        let m = evaluate(&gp, &pl, &hw);
        assert!(
            (m.congestion - brute_max).abs() < 1e-9,
            "tau-based {} vs brute-force {}",
            m.congestion,
            brute_max
        );

        // cross-check the whole per-core field, not just the max
        let bin = Binomial::for_lattice(hw.width, hw.height);
        for (idx, &t_brute) in traffic.iter().enumerate() {
            if t_brute == 0.0 {
                continue;
            }
            let h = hw.coord(idx);
            let mut t_tau = 0.0;
            for e in gp.edge_ids() {
                let s = gp.source(e);
                let w = gp.weight(e) as f64;
                for &d in gp.dsts(e) {
                    if d != s {
                        t_tau += w * tau(&bin, h, coords[s as usize], coords[d as usize]);
                    }
                }
            }
            assert!(
                (t_tau - t_brute).abs() < 1e-9,
                "core {h:?}: tau {t_tau} vs brute {t_brute}"
            );
        }
    }
}
