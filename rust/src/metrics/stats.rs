//! Rank statistics for Fig. 11: Spearman's ρ over z-score-standardized
//! per-network samples (the paper standardizes both metrics per h-graph
//! because quality/property scales differ wildly across networks).

/// Spearman rank correlation coefficient of paired samples.
/// Returns None for fewer than 2 pairs or zero variance.
pub fn spearman(xs: &[f64], ys: &[f64]) -> Option<f64> {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return None;
    }
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

/// Pearson correlation of two equal-length samples.
pub fn pearson(xs: &[f64], ys: &[f64]) -> Option<f64> {
    let n = xs.len() as f64;
    if xs.len() < 2 {
        return None;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return None;
    }
    Some(sxy / (sxx * syy).sqrt())
}

/// Fractional ranks (average rank for ties), 1-based.
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| crate::util::cmp_non_nan(&xs[a], &xs[b]));
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            out[k] = avg_rank;
        }
        i = j + 1;
    }
    out
}

/// Z-score standardization (mean 0, sd 1); constant samples map to 0.
pub fn zscore(xs: &[f64]) -> Vec<f64> {
    let n = xs.len() as f64;
    if xs.is_empty() {
        return vec![];
    }
    let m = xs.iter().sum::<f64>() / n;
    let sd = (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n).sqrt();
    if sd <= 0.0 {
        return vec![0.0; xs.len()];
    }
    xs.iter().map(|x| (x - m) / sd).collect()
}

/// Pool per-group samples with per-group standardization, then compute
/// Spearman on the pooled standardized values (the Fig. 11 methodology).
pub fn grouped_spearman(groups: &[(Vec<f64>, Vec<f64>)]) -> Option<f64> {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (gx, gy) in groups {
        xs.extend(zscore(gx));
        ys.extend(zscore(gy));
    }
    spearman(&xs, &ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_with_ties() {
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn spearman_perfect_monotone() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let inc = [10.0, 20.0, 25.0, 100.0];
        let dec = [5.0, 4.0, 3.0, -10.0];
        assert!((spearman(&xs, &inc).unwrap() - 1.0).abs() < 1e-12);
        assert!((spearman(&xs, &dec).unwrap() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_independent_near_zero() {
        let mut rng = crate::util::rng::Pcg64::seeded(2);
        let xs: Vec<f64> = (0..2000).map(|_| rng.next_f64()).collect();
        let ys: Vec<f64> = (0..2000).map(|_| rng.next_f64()).collect();
        let rho = spearman(&xs, &ys).unwrap();
        assert!(rho.abs() < 0.06, "rho={rho}");
    }

    #[test]
    fn degenerate_inputs() {
        assert!(spearman(&[1.0], &[2.0]).is_none());
        assert!(spearman(&[1.0, 1.0], &[2.0, 3.0]).is_none()); // zero variance
        assert_eq!(zscore(&[5.0, 5.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn zscore_moments() {
        let z = zscore(&[1.0, 2.0, 3.0, 4.0]);
        let m: f64 = z.iter().sum::<f64>() / 4.0;
        let v: f64 = z.iter().map(|x| x * x).sum::<f64>() / 4.0;
        assert!(m.abs() < 1e-12 && (v - 1.0).abs() < 1e-12);
    }

    #[test]
    fn grouped_standardization_removes_scale() {
        // group A has values 100x group B, but within-group the relation
        // is identical: pooled spearman stays ~1
        let a = (vec![100.0, 200.0, 300.0], vec![1000.0, 2000.0, 3000.0]);
        let b = (vec![1.0, 2.0, 3.0], vec![10.0, 20.0, 30.0]);
        let rho = grouped_spearman(&[a, b]).unwrap();
        assert!(rho > 0.95, "rho={rho}");
    }
}
