//! Mapping-property measures (paper §V-C): synaptic reuse SR (Eq. 14) and
//! connections locality CL (Eq. 15), each reported with arithmetic and
//! geometric means — the quantities whose Spearman correlation with
//! connectivity/ELP Fig. 11 establishes.

use crate::hw::NmhConfig;
use crate::hypergraph::quotient::Partitioning;
use crate::hypergraph::Hypergraph;
use crate::placement::Placement;
use crate::util::{geometric_mean, mean};

/// Aggregation used over per-partition / per-h-edge values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mean {
    Arithmetic,
    Geometric,
    Max,
}

/// Synaptic reuse (Eq. 14): per partition, total inbound synapses over
/// distinct inbound axons — how many times each received spike is reused
/// inside the core. ≥ 1; higher is better.
pub fn synaptic_reuse(g: &Hypergraph, rho: &Partitioning, agg: Mean) -> f64 {
    let ratios = synaptic_reuse_per_partition(g, rho);
    match agg {
        Mean::Arithmetic => mean(&ratios),
        Mean::Geometric => geometric_mean(&ratios, 1e-12),
        Mean::Max => ratios.iter().cloned().fold(0.0, f64::max),
    }
}

/// The per-partition reuse ratios behind Eq. 14 (empty partitions and
/// partitions with no inbound axons are skipped).
pub fn synaptic_reuse_per_partition(g: &Hypergraph, rho: &Partitioning) -> Vec<f64> {
    let p = rho.num_parts;
    let mut synapses = vec![0u64; p];
    let mut axons = vec![0u64; p];
    let mut stamp = vec![u32::MAX; p];
    for e in g.edge_ids() {
        for &d in g.dsts(e) {
            let pd = rho.assign[d as usize] as usize;
            synapses[pd] += 1;
            if stamp[pd] != e {
                stamp[pd] = e;
                axons[pd] += 1;
            }
        }
    }
    (0..p)
        .filter(|&i| axons[i] > 0)
        .map(|i| synapses[i] as f64 / axons[i] as f64)
        .collect()
}

/// Connections locality (Eq. 15): per quotient h-edge, the number of
/// lattice points enclosed by the convex hull of the cores it connects
/// (source + destinations). Lower is better (tighter footprint).
pub fn connections_locality(
    gp: &Hypergraph,
    placement: &Placement,
    hw: &NmhConfig,
    agg: Mean,
) -> f64 {
    let vals = locality_per_hedge(gp, placement, hw);
    match agg {
        Mean::Arithmetic => mean(&vals),
        Mean::Geometric => geometric_mean(&vals, 1e-12),
        Mean::Max => vals.iter().cloned().fold(0.0, f64::max),
    }
}

/// Per-h-edge hull footprints behind Eq. 15.
pub fn locality_per_hedge(gp: &Hypergraph, placement: &Placement, _hw: &NmhConfig) -> Vec<f64> {
    let mut out = Vec::with_capacity(gp.num_edges());
    let mut pts: Vec<(i64, i64)> = Vec::new();
    for e in gp.edge_ids() {
        pts.clear();
        let s = placement.coords[gp.source(e) as usize];
        pts.push((s.0 as i64, s.1 as i64));
        for &d in gp.dsts(e) {
            let c = placement.coords[d as usize];
            pts.push((c.0 as i64, c.1 as i64));
        }
        pts.sort_unstable();
        pts.dedup();
        out.push(lattice_points_in_hull(&pts) as f64);
    }
    out
}

/// Number of integer lattice points inside (or on) the convex hull of
/// `pts` (pre-sorted, deduplicated). Handles degenerate hulls: a single
/// point counts 1; a segment counts gcd(Δx, Δy) + 1.
pub fn lattice_points_in_hull(pts: &[(i64, i64)]) -> usize {
    match pts.len() {
        0 => return 0,
        1 => return 1,
        _ => {}
    }
    let hull = convex_hull(pts);
    if hull.len() == 1 {
        return 1;
    }
    if hull.len() == 2 {
        // collinear input: the hull is the longest segment; count every
        // lattice point on any input point's segment span — all inputs are
        // collinear so points on the extreme segment cover them
        let (a, b) = (hull[0], hull[1]);
        return (gcd((b.0 - a.0).abs(), (b.1 - a.1).abs()) + 1) as usize;
    }
    // Interior + boundary count by Pick-style scanline: for each y in the
    // bbox, intersect the polygon with the horizontal line and count the
    // integer x in [xmin_y, xmax_y].
    // snn-lint: allow(unwrap-ban) — hull has >= 3 vertices here: len 0/1/2 returned earlier
    let ymin = hull.iter().map(|p| p.1).min().unwrap();
    // snn-lint: allow(unwrap-ban) — hull has >= 3 vertices here: len 0/1/2 returned earlier
    let ymax = hull.iter().map(|p| p.1).max().unwrap();
    let mut count = 0usize;
    for y in ymin..=ymax {
        let mut xlo = f64::INFINITY;
        let mut xhi = f64::NEG_INFINITY;
        let n = hull.len();
        for i in 0..n {
            let a = hull[i];
            let b = hull[(i + 1) % n];
            let (y0, y1) = (a.1.min(b.1), a.1.max(b.1));
            if y < y0 || y > y1 {
                continue;
            }
            if a.1 == b.1 {
                // horizontal edge on this scanline
                xlo = xlo.min(a.0.min(b.0) as f64);
                xhi = xhi.max(a.0.max(b.0) as f64);
            } else {
                let t = (y - a.1) as f64 / (b.1 - a.1) as f64;
                let x = a.0 as f64 + t * (b.0 - a.0) as f64;
                xlo = xlo.min(x);
                xhi = xhi.max(x);
            }
        }
        if xlo.is_finite() && xhi >= xlo {
            let lo = (xlo - 1e-9).ceil() as i64;
            let hi = (xhi + 1e-9).floor() as i64;
            if hi >= lo {
                count += (hi - lo + 1) as usize;
            }
        }
    }
    count
}

/// Andrew's monotone-chain convex hull (returns CCW, no duplicate last
/// point; collinear inputs collapse to the 2 extreme points).
pub fn convex_hull(pts: &[(i64, i64)]) -> Vec<(i64, i64)> {
    let n = pts.len();
    if n <= 2 {
        return pts.to_vec();
    }
    let cross = |o: (i64, i64), a: (i64, i64), b: (i64, i64)| -> i64 {
        (a.0 - o.0) * (b.1 - o.1) - (a.1 - o.1) * (b.0 - o.0)
    };
    let mut hull: Vec<(i64, i64)> = Vec::with_capacity(2 * n);
    for &p in pts.iter() {
        while hull.len() >= 2 && cross(hull[hull.len() - 2], hull[hull.len() - 1], p) <= 0 {
            hull.pop();
        }
        hull.push(p);
    }
    let lower_len = hull.len() + 1;
    for &p in pts.iter().rev() {
        while hull.len() >= lower_len && cross(hull[hull.len() - 2], hull[hull.len() - 1], p) <= 0 {
            hull.pop();
        }
        hull.push(p);
    }
    hull.pop();
    if hull.is_empty() {
        // all points identical (dedup'd earlier, but be safe)
        return vec![pts[0]];
    }
    // collinear inputs produce a degenerate 2-point chain repeated: dedup
    hull.dedup();
    if hull.len() > 2 {
        hull
    } else {
        hull
    }
}

fn gcd(a: i64, b: i64) -> i64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;

    #[test]
    fn reuse_reflects_colocation() {
        // one axon to 4 neurons: together = 4 synapses / 1 axon = 4
        let mut b = HypergraphBuilder::new(5);
        b.add_edge(0, vec![1, 2, 3, 4], 1.0);
        let g = b.build();
        let together = Partitioning::new(vec![0, 1, 1, 1, 1], 2);
        let split = Partitioning::new(vec![0, 1, 2, 3, 4], 5);
        assert!(
            (synaptic_reuse(&g, &together, Mean::Arithmetic) - 4.0).abs() < 1e-9
        );
        assert!((synaptic_reuse(&g, &split, Mean::Arithmetic) - 1.0).abs() < 1e-9);
        assert!((synaptic_reuse(&g, &together, Mean::Max) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn geometric_mean_penalizes_uneven_reuse() {
        // partition A reuse 4, partition B reuse 1:
        // arith = 2.5, geo = 2 — geo punishes the low-overlap partition
        let mut b = HypergraphBuilder::new(7);
        b.add_edge(0, vec![1, 2, 3, 4], 1.0);
        b.add_edge(5, vec![6], 1.0);
        let g = b.build();
        let rho = Partitioning::new(vec![0, 1, 1, 1, 1, 0, 2], 3);
        let a = synaptic_reuse(&g, &rho, Mean::Arithmetic);
        let ge = synaptic_reuse(&g, &rho, Mean::Geometric);
        assert!((a - 2.5).abs() < 1e-9, "a={a}");
        assert!((ge - 2.0).abs() < 1e-9, "geo={ge}");
    }

    #[test]
    fn hull_counts_simple_shapes() {
        // unit square: 4 lattice points
        assert_eq!(
            lattice_points_in_hull(&[(0, 0), (0, 1), (1, 0), (1, 1)]),
            4
        );
        // 2x2 square: 9
        assert_eq!(lattice_points_in_hull(&[(0, 0), (0, 2), (2, 0), (2, 2)]), 9);
        // single point
        assert_eq!(lattice_points_in_hull(&[(3, 3)]), 1);
        // horizontal segment 0..4
        assert_eq!(lattice_points_in_hull(&[(0, 0), (2, 0), (4, 0)]), 5);
        // diagonal segment (0,0)-(3,3): 4 points
        assert_eq!(lattice_points_in_hull(&[(0, 0), (3, 3)]), 4);
        // right triangle (0,0),(2,0),(0,2): 6 points
        assert_eq!(lattice_points_in_hull(&[(0, 0), (2, 0), (0, 2)]), 6);
    }

    #[test]
    fn hull_matches_bruteforce_on_random_sets() {
        let mut rng = crate::util::rng::Pcg64::seeded(6);
        for _ in 0..50 {
            let k = rng.range(3, 8);
            let mut pts: Vec<(i64, i64)> = (0..k)
                .map(|_| (rng.below(10) as i64, rng.below(10) as i64))
                .collect();
            pts.sort_unstable();
            pts.dedup();
            let got = lattice_points_in_hull(&pts);
            // brute force: point-in-hull test over the bbox
            let hull = convex_hull(&pts);
            let want = brute_count(&hull, &pts);
            assert_eq!(got, want, "pts={pts:?}");
        }
    }

    fn brute_count(hull: &[(i64, i64)], pts: &[(i64, i64)]) -> usize {
        if hull.len() == 1 {
            return 1;
        }
        if hull.len() == 2 {
            return (super::gcd(
                (hull[1].0 - hull[0].0).abs(),
                (hull[1].1 - hull[0].1).abs(),
            ) + 1) as usize;
        }
        let xmin = pts.iter().map(|p| p.0).min().unwrap();
        let xmax = pts.iter().map(|p| p.0).max().unwrap();
        let ymin = pts.iter().map(|p| p.1).min().unwrap();
        let ymax = pts.iter().map(|p| p.1).max().unwrap();
        let mut count = 0;
        for x in xmin..=xmax {
            for y in ymin..=ymax {
                // inside CCW hull: all cross products >= 0
                let inside = (0..hull.len()).all(|i| {
                    let a = hull[i];
                    let b = hull[(i + 1) % hull.len()];
                    (b.0 - a.0) * (y - a.1) - (b.1 - a.1) * (x - a.0) >= 0
                });
                if inside {
                    count += 1;
                }
            }
        }
        count
    }

    #[test]
    fn locality_tight_vs_spread() {
        let mut b = HypergraphBuilder::new(4);
        b.add_edge(0, vec![1, 2, 3], 1.0);
        let gp = b.build();
        let hw = crate::hw::NmhConfig::small();
        let tight = Placement { coords: vec![(0, 0), (1, 0), (0, 1), (1, 1)] };
        let spread = Placement { coords: vec![(0, 0), (20, 0), (0, 20), (20, 20)] };
        let cl_tight = connections_locality(&gp, &tight, &hw, Mean::Arithmetic);
        let cl_spread = connections_locality(&gp, &spread, &hw, Mean::Arithmetic);
        assert!((cl_tight - 4.0).abs() < 1e-9);
        assert!(cl_spread > 100.0, "spread CL {cl_spread}");
    }
}
