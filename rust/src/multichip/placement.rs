//! Chip-aware two-level placement.
//!
//! Level 1: assign quotient-graph partitions to chips by running the
//! hyperedge-overlap partitioner *again* on the quotient h-graph, with
//! per-"core" capacity = cores-per-chip and the chip count as the lattice
//! bound — exactly the paper's insight recursing one level up: chips
//! replicate spikes too (one copy per chip), so chip assignment is the
//! same synaptic-reuse problem.
//!
//! Level 2: within each chip, place its partitions with any registered
//! [`Placer`] on the chip-local lattice (optionally refined by any
//! [`Refiner`]), then translate into global coordinates.

use super::MultiChipConfig;
use crate::hypergraph::quotient::Partitioning;
use crate::hypergraph::{Hypergraph, HypergraphBuilder};
use crate::mapping::{self, MapError};
use crate::placement::Placement;
use crate::stage::{Placer, Refiner, StageCtx};

/// Chip-aware placement of a quotient h-graph onto the chip array.
/// Level-2 placement/refinement are pluggable stage trait objects (use
/// e.g. `StageRegistry::builtin().placer("spectral", ...)`). Returns the
/// global placement plus the chip assignment.
pub fn place(
    gp: &Hypergraph,
    mc: &MultiChipConfig,
    local: &dyn Placer,
    local_refiner: Option<&dyn Refiner>,
    ctx: &StageCtx,
) -> Result<(Placement, Partitioning), MapError> {
    let p = gp.num_nodes();
    if p > mc.num_cores() {
        return Err(MapError::TooManyPartitions { got: p, limit: mc.num_cores() });
    }
    // ---- level 1: partitions -> chips (overlap heuristic, recursed) ----
    // Two candidate fill targets, judged by actual boundary-cut weight:
    // * packed  (c_npc = cores/chip): as few chips as possible — optimal
    //   when the whole workload fits one chip (zero off-chip traffic);
    // * balanced (c_npc ≈ p/chips): keeps the overlap heuristic aligned
    //   with community boundaries when the workload must span chips.
    let chips = mc.chips_x * mc.chips_y;
    let level1 = |target: usize| -> Result<Partitioning, MapError> {
        let mut chip_hw = mc.chip;
        chip_hw.c_npc = target;
        chip_hw.c_apc = usize::MAX >> 1; // chip-level axon queues are off-chip
        chip_hw.c_spc = usize::MAX >> 1; //   links, modeled by cost not capacity
        chip_hw.width = mc.chips_x;
        chip_hw.height = mc.chips_y;
        let rho = mapping::overlap::partition(gp, &chip_hw)?;
        Ok(balance_chips(gp, rho, chips, mc.chip.num_cores()))
    };
    let packed = level1(mc.chip.num_cores())?;
    let balanced = level1(crate::util::div_ceil(p, chips).clamp(1, mc.chip.num_cores()))?;
    let chip_assign = if boundary_cut(gp, &packed) <= boundary_cut(gp, &balanced) {
        packed
    } else {
        balanced
    };

    // ---- level 2: per-chip local placement ----
    let mut coords = vec![(u16::MAX, u16::MAX); p];
    for chip in 0..chips {
        let members: Vec<u32> =
            (0..p as u32).filter(|&v| chip_assign.assign[v as usize] == chip as u32).collect();
        if members.is_empty() {
            continue;
        }
        // induced sub-h-graph over this chip's partitions
        let mut local_id = vec![u32::MAX; p];
        for (i, &v) in members.iter().enumerate() {
            local_id[v as usize] = i as u32;
        }
        let mut b = HypergraphBuilder::new(members.len());
        let mut dsts: Vec<u32> = Vec::new();
        for e in gp.edge_ids() {
            let ls = local_id[gp.source(e) as usize];
            if ls == u32::MAX {
                continue;
            }
            dsts.clear();
            dsts.extend(
                gp.dsts(e).iter().filter_map(|&d| {
                    let l = local_id[d as usize];
                    (l != u32::MAX).then_some(l)
                }),
            );
            if !dsts.is_empty() {
                b.add_edge(ls, std::mem::take(&mut dsts), gp.weight(e));
                dsts = Vec::new();
            }
        }
        let sub = b.build();
        let mut pl = local.place(&sub, &mc.chip, ctx)?;
        // same stage contract as the single-chip pipeline: direct
        // placers already descend the objective and skip refinement
        if !local.is_direct() {
            if let Some(refiner) = local_refiner {
                refiner.refine(&sub, &mc.chip, &mut pl, ctx)?;
            }
        }
        // translate into global coordinates
        let ox = (chip % mc.chips_x) as u16 * mc.chip.width as u16;
        let oy = (chip / mc.chips_x) as u16 * mc.chip.height as u16;
        for (i, &v) in members.iter().enumerate() {
            let (x, y) = pl.coords[i];
            coords[v as usize] = (x + ox, y + oy);
        }
    }
    let placement = Placement { coords };
    placement
        .validate(&mc.global_lattice())
        .map_err(MapError::ConstraintViolated)?;
    Ok((placement, chip_assign))
}

/// Spike-frequency weight crossing chip groups (the level-1 objective).
fn boundary_cut(gp: &Hypergraph, rho: &Partitioning) -> f64 {
    let mut cut = 0.0;
    for e in gp.edge_ids() {
        let s = rho.assign[gp.source(e) as usize];
        if gp.dsts(e).iter().any(|&d| rho.assign[d as usize] != s) {
            cut += gp.weight(e) as f64;
        }
    }
    cut
}

/// The chip-level partitioner may open fewer groups than chips or
/// overfill one: rebalance greedily by spilling the lowest-affinity
/// members of overfull chips into the emptiest chip.
fn balance_chips(
    gp: &Hypergraph,
    rho: Partitioning,
    chips: usize,
    capacity: usize,
) -> Partitioning {
    let mut assign = rho.assign;
    let mut load = vec![0usize; chips];
    for &c in &assign {
        load[c as usize] += 1;
    }
    loop {
        let Some(over) = (0..chips).find(|&c| load[c] > capacity) else { break };
        // snn-lint: allow(unwrap-ban) — chips >= 1 is validated by the chip-grid config,
        // so the range is never empty
        let under = (0..chips).min_by_key(|&c| load[c]).unwrap();
        // spill the member with the least inbound weight (cheapest to move)
        let victim = (0..assign.len() as u32)
            .filter(|&v| assign[v as usize] == over as u32)
            .min_by(|&a, &b| {
                crate::util::cmp_non_nan(&gp.inbound_weight(a), &gp.inbound_weight(b))
            })
            // snn-lint: allow(unwrap-ban) — `over` was selected by load > capacity >= 0,
            // so at least one node is assigned to it
            .expect("overfull chip has members");
        assign[victim as usize] = under as u32;
        load[over] -= 1;
        load[under] += 1;
    }
    Partitioning::new(assign, chips)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::NmhConfig;
    use crate::multichip::metrics::evaluate;
    use crate::placement::force::ForceRefiner;
    use crate::placement::hilbert::{self, HilbertPlacer};
    use crate::util::rng::Pcg64;

    fn clustered_quotient(k: usize, size: usize, seed: u64) -> Hypergraph {
        let n = k * size;
        let mut rng = Pcg64::seeded(seed);
        let mut b = HypergraphBuilder::new(n);
        for s in 0..n as u32 {
            let c = s as usize / size;
            let dsts: Vec<u32> = (0..4)
                .map(|_| (c * size + rng.below(size)) as u32)
                .filter(|&d| d != s)
                .collect();
            if !dsts.is_empty() {
                b.add_edge(s, dsts, rng.next_f32() + 0.1);
            }
        }
        b.build()
    }

    fn tiny_array() -> MultiChipConfig {
        let mut chip = NmhConfig::small();
        chip.width = 8;
        chip.height = 8;
        MultiChipConfig {
            chip,
            chips_x: 2,
            chips_y: 2,
            off_chip_energy_factor: 10.0,
            off_chip_latency_factor: 10.0,
        }
    }

    #[test]
    fn placement_valid_and_within_chips() {
        let gp = clustered_quotient(4, 30, 3);
        let mc = tiny_array();
        let (pl, chips) = place(&gp, &mc, &HilbertPlacer, None, &StageCtx::new(42)).unwrap();
        pl.validate(&mc.global_lattice()).unwrap();
        // every node's global coordinate must land on its assigned chip
        for v in 0..gp.num_nodes() {
            let chip = chips.assign[v];
            let got = mc.chip_of(pl.coords[v]);
            assert_eq!((got.1 as usize * mc.chips_x + got.0 as usize) as u32, chip);
        }
    }

    #[test]
    fn chip_aware_beats_chip_oblivious_on_clusters() {
        // 4 clusters on 4 chips: chip-aware placement should keep each
        // cluster on one chip; a global Hilbert walk will split them
        let gp = clustered_quotient(4, 40, 7);
        let mc = tiny_array();
        let (aware, _) =
            place(&gp, &mc, &HilbertPlacer, Some(&ForceRefiner::new()), &StageCtx::new(42))
                .unwrap();
        let oblivious = hilbert::place(&gp, &mc.global_lattice());
        let ma = evaluate(&gp, &aware, &mc);
        let mo = evaluate(&gp, &oblivious, &mc);
        assert!(
            ma.off_chip_hops < mo.off_chip_hops,
            "aware {} vs oblivious {}",
            ma.off_chip_hops,
            mo.off_chip_hops
        );
        assert!(ma.energy < mo.energy);
    }

    #[test]
    fn respects_chip_capacity() {
        // more partitions than one chip can hold: must spread
        let gp = clustered_quotient(1, 100, 9); // one giant cluster
        let mc = tiny_array(); // 64 cores per chip
        let (pl, chips) = place(&gp, &mc, &HilbertPlacer, None, &StageCtx::new(42)).unwrap();
        pl.validate(&mc.global_lattice()).unwrap();
        let mut load = vec![0usize; 4];
        for &c in &chips.assign {
            load[c as usize] += 1;
        }
        assert!(load.iter().all(|&l| l <= 64), "load={load:?}");
        assert!(load.iter().filter(|&&l| l > 0).count() >= 2);
    }

    #[test]
    fn too_many_partitions_rejected() {
        let gp = clustered_quotient(1, 300, 1);
        let mc = tiny_array(); // 256 cores total
        assert!(matches!(
            place(&gp, &mc, &HilbertPlacer, None, &StageCtx::new(42)),
            Err(MapError::TooManyPartitions { .. })
        ));
    }
}
