//! Multi-chip cost model: Table I generalized with per-hop-class costs.
//!
//! A spike copy's route (XY) decomposes into on-chip hops and
//! boundary-crossing hops; the latter are scaled by the off-chip factors.

use super::MultiChipConfig;
use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use crate::placement::Placement;

/// Multi-chip mapping metrics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MultiChipMetrics {
    pub energy: f64,
    pub latency: f64,
    pub elp: f64,
    /// Total spike-frequency weight crossing chip boundaries per step.
    pub boundary_traffic: f64,
    /// On-chip hop count (weighted).
    pub on_chip_hops: f64,
    /// Off-chip hop count (weighted).
    pub off_chip_hops: f64,
}

/// Evaluate a placed quotient h-graph on the chip array.
pub fn evaluate(gp: &Hypergraph, placement: &Placement, mc: &MultiChipConfig) -> MultiChipMetrics {
    let costs = mc.chip.costs;
    let mut m = MultiChipMetrics::default();
    for e in gp.edge_ids() {
        let w = gp.weight(e) as f64;
        let s = placement.coords[gp.source(e) as usize];
        for &d in gp.dsts(e) {
            let c = placement.coords[d as usize];
            let dist = NmhConfig::manhattan(s, c) as f64;
            let crossings = mc.boundary_crossings(s, c) as f64;
            let on_chip = dist - crossings;
            m.on_chip_hops += w * on_chip;
            m.off_chip_hops += w * crossings;
            if crossings > 0.0 {
                m.boundary_traffic += w;
            }
            m.energy += w
                * (on_chip * (costs.e_r + costs.e_t)
                    + crossings * (costs.e_r + costs.e_t) * mc.off_chip_energy_factor
                    + costs.e_r);
            m.latency += w
                * (on_chip * (costs.l_r + costs.l_t)
                    + crossings * (costs.l_r + costs.l_t) * mc.off_chip_latency_factor
                    + costs.l_r);
        }
    }
    m.elp = m.energy * m.latency;
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;

    #[test]
    fn on_chip_route_matches_single_chip_model() {
        let mc = MultiChipConfig::quad_small();
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 2.0);
        let gp = b.build();
        let pl = Placement { coords: vec![(0, 0), (3, 0)] };
        let m = evaluate(&gp, &pl, &mc);
        let single = crate::metrics::evaluate(&gp, &pl, &mc.chip);
        assert!((m.energy - single.energy).abs() < 1e-9);
        assert_eq!(m.off_chip_hops, 0.0);
    }

    #[test]
    fn boundary_crossing_pays_the_factor() {
        let mc = MultiChipConfig::quad_small();
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 1.0);
        let gp = b.build();
        // (63,0) -> (64,0): one hop, crossing the chip boundary
        let pl = Placement { coords: vec![(63, 0), (64, 0)] };
        let m = evaluate(&gp, &pl, &mc);
        let c = mc.chip.costs;
        let want = (c.e_r + c.e_t) * 10.0 + c.e_r;
        assert!((m.energy - want).abs() < 1e-9, "{} vs {want}", m.energy);
        assert_eq!(m.off_chip_hops, 1.0);
        assert_eq!(m.boundary_traffic, 1.0);
    }

    #[test]
    fn mixed_route_decomposes() {
        let mc = MultiChipConfig::quad_small();
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![1], 1.0);
        let gp = b.build();
        // (60,0) -> (70, 5): dist = 10 + 5 = 15, crossings = 1
        let pl = Placement { coords: vec![(60, 0), (70, 5)] };
        let m = evaluate(&gp, &pl, &mc);
        assert_eq!(m.on_chip_hops, 14.0);
        assert_eq!(m.off_chip_hops, 1.0);
    }
}
