//! Multi-chip mapping extension (paper §VI future work: "the multi-chip
//! generalization of the mapping problem").
//!
//! NMH systems scale by tiling chips into a higher-order mesh (§II-B);
//! off-chip links are slower and costlier than the on-chip NoC. This
//! module models a `chips_x × chips_y` array of identical chips as one
//! global lattice whose hop costs depend on whether a hop crosses a chip
//! boundary, and provides a **chip-aware two-level placement**: the
//! quotient h-graph is first partitioned across chips (minimizing
//! boundary-crossing weight with the same overlap heuristics used for
//! cores), then each chip's share is placed locally.

pub mod metrics;
pub mod placement;

use crate::hw::NmhConfig;

/// A 2D array of identical chips.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MultiChipConfig {
    /// Per-chip lattice + per-core constraints + on-chip hop costs.
    pub chip: NmhConfig,
    pub chips_x: usize,
    pub chips_y: usize,
    /// Energy multiplier for a hop crossing a chip boundary.
    pub off_chip_energy_factor: f64,
    /// Latency multiplier for a boundary-crossing hop.
    pub off_chip_latency_factor: f64,
}

impl MultiChipConfig {
    /// A 2x2 array of "small" chips with 10x costlier off-chip hops
    /// (SerDes-class penalty).
    pub fn quad_small() -> Self {
        MultiChipConfig {
            chip: NmhConfig::small(),
            chips_x: 2,
            chips_y: 2,
            off_chip_energy_factor: 10.0,
            off_chip_latency_factor: 10.0,
        }
    }

    /// The global lattice seen by placement: all chips tiled.
    pub fn global_lattice(&self) -> NmhConfig {
        let mut hw = self.chip;
        hw.width = self.chip.width * self.chips_x;
        hw.height = self.chip.height * self.chips_y;
        hw
    }

    /// Total core count across chips.
    pub fn num_cores(&self) -> usize {
        self.global_lattice().num_cores()
    }

    /// Chip index of a global coordinate.
    #[inline]
    pub fn chip_of(&self, c: (u16, u16)) -> (u16, u16) {
        (
            c.0 / self.chip.width as u16,
            c.1 / self.chip.height as u16,
        )
    }

    /// Number of chip-boundary crossings on an XY route between two
    /// global coordinates (x-boundaries crossed + y-boundaries crossed).
    pub fn boundary_crossings(&self, a: (u16, u16), b: (u16, u16)) -> u32 {
        let (ca, cb) = (self.chip_of(a), self.chip_of(b));
        (ca.0 as i32 - cb.0 as i32).unsigned_abs() + (ca.1 as i32 - cb.1 as i32).unsigned_abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_lattice_dimensions() {
        let mc = MultiChipConfig::quad_small();
        let g = mc.global_lattice();
        assert_eq!((g.width, g.height), (128, 128));
        assert_eq!(mc.num_cores(), 128 * 128);
    }

    #[test]
    fn chip_of_and_crossings() {
        let mc = MultiChipConfig::quad_small();
        assert_eq!(mc.chip_of((0, 0)), (0, 0));
        assert_eq!(mc.chip_of((63, 63)), (0, 0));
        assert_eq!(mc.chip_of((64, 0)), (1, 0));
        assert_eq!(mc.chip_of((127, 127)), (1, 1));
        assert_eq!(mc.boundary_crossings((0, 0), (63, 63)), 0);
        assert_eq!(mc.boundary_crossings((63, 0), (64, 0)), 1);
        assert_eq!(mc.boundary_crossings((0, 0), (127, 127)), 2);
    }
}
