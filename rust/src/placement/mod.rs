//! Placement: γ — the injective assignment of partitions to lattice cores
//! (paper §III, §IV-B/C).
//!
//! * [`hilbert`] — discrete Hilbert space-filling-curve initial placement.
//! * [`spectral`] — Laplacian-eigenmode initial placement (the paper's
//!   proposal), with native or PJRT eigensolver engines.
//! * [`force`] — force-directed refinement (potential Eq. 12 / Eq. 13).
//! * [`mindist`] — TrueNorth-style minimum-distance direct placement.

pub mod eigen;
pub mod force;
pub mod gridfind;
pub mod hilbert;
pub mod mindist;
pub mod spectral;

use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use std::collections::HashMap;

/// A placement γ: partitions → core coordinates.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    /// `coords[p]` = (x, y) of partition p's core.
    pub coords: Vec<(u16, u16)>,
}

impl Placement {
    /// Injectivity + bounds check.
    pub fn validate(&self, hw: &NmhConfig) -> Result<(), String> {
        let mut used = vec![false; hw.num_cores()];
        for (p, &(x, y)) in self.coords.iter().enumerate() {
            if !hw.contains(x as i32, y as i32) {
                return Err(format!("partition {p} at ({x},{y}) outside lattice"));
            }
            let idx = hw.index(x, y);
            if used[idx] {
                return Err(format!("core ({x},{y}) assigned twice"));
            }
            used[idx] = true;
        }
        Ok(())
    }

    /// Total spike-frequency-weighted Manhattan wirelength over a
    /// partitioned h-graph: Σ_e Σ_d w(e)·‖γ(s)−γ(d)‖ — the quantity both
    /// refiners descend (before the per-spike router constants of Tab. I).
    pub fn wirelength(&self, gp: &Hypergraph) -> f64 {
        let mut total = 0.0;
        for e in gp.edge_ids() {
            let s = self.coords[gp.source(e) as usize];
            let w = gp.weight(e) as f64;
            for &d in gp.dsts(e) {
                total += w * NmhConfig::manhattan(s, self.coords[d as usize]) as f64;
            }
        }
        total
    }

    /// Number of partitions placed.
    pub fn len(&self) -> usize {
        self.coords.len()
    }

    pub fn is_empty(&self) -> bool {
        self.coords.is_empty()
    }
}

/// Symmetric partition-to-partition weight adjacency used by the
/// refiners: partition `p`'s neighbor list is [`Self::neighbors`]`(p)` =
/// (q, w) pairs with `w` the total spike frequency of h-edges linking p
/// and q in either direction (source→dest pairs of the quotient graph;
/// self-pairs excluded — their clamped distance is constant).
///
/// The layout is CSR-style flat (`off` + one `nbrs` arena) rather than a
/// `Vec<Vec<..>>`: the force refiner's parallel propose workers share it
/// read-only, and a flat arena gives them per-call-allocation-free,
/// cache-dense neighbor scans (DESIGN.md §11).
pub struct PartitionAdjacency {
    /// CSR offsets: partition p's pairs live in
    /// `nbrs[off[p] as usize .. off[p + 1] as usize]`.
    pub off: Vec<u32>,
    /// Flat (neighbor, weight) pairs, sorted by neighbor id per row.
    pub nbrs: Vec<(u32, f64)>,
    /// total adjacent weight per partition (wdeg in Eq. 8's sense,
    /// restricted to source-destination pairs)
    pub wdeg: Vec<f64>,
}

impl PartitionAdjacency {
    /// Build from a quotient h-graph (pairs = (source, each destination)).
    pub fn build(gp: &Hypergraph) -> Self {
        let n = gp.num_nodes();
        let mut map: HashMap<(u32, u32), f64> = HashMap::new();
        for e in gp.edge_ids() {
            let s = gp.source(e);
            let w = gp.weight(e) as f64;
            for &d in gp.dsts(e) {
                if d == s {
                    continue;
                }
                let key = if s < d { (s, d) } else { (d, s) };
                *map.entry(key).or_insert(0.0) += w;
            }
        }
        let mut off = vec![0u32; n + 1];
        for &(a, b) in map.keys() {
            off[a as usize + 1] += 1;
            off[b as usize + 1] += 1;
        }
        for p in 0..n {
            off[p + 1] += off[p];
        }
        let mut nbrs = vec![(0u32, 0f64); off[n] as usize];
        let mut cursor: Vec<u32> = off[..n].to_vec();
        for (&(a, b), &w) in &map {
            nbrs[cursor[a as usize] as usize] = (b, w);
            cursor[a as usize] += 1;
            nbrs[cursor[b as usize] as usize] = (a, w);
            cursor[b as usize] += 1;
        }
        // Per-row fill order above follows HashMap iteration; sorting by
        // the (unique) neighbor id restores determinism (§4), and wdeg is
        // then summed in sorted order so its f64 merge tree is stable too.
        let mut wdeg = vec![0.0; n];
        for p in 0..n {
            let row = &mut nbrs[off[p] as usize..off[p + 1] as usize];
            row.sort_by_key(|&(q, _)| q);
            wdeg[p] = row.iter().map(|&(_, w)| w).sum();
        }
        PartitionAdjacency { off, nbrs, wdeg }
    }

    /// The (q, w) pairs of partition `p`, sorted by q.
    #[inline]
    pub fn neighbors(&self, p: u32) -> &[(u32, f64)] {
        &self.nbrs[self.off[p as usize] as usize..self.off[p as usize + 1] as usize]
    }

    pub fn len(&self) -> usize {
        self.off.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Heap footprint of the flat layout (refiner scratch accounting).
    pub fn memory_bytes(&self) -> usize {
        self.off.len() * std::mem::size_of::<u32>()
            + self.nbrs.len() * std::mem::size_of::<(u32, f64)>()
            + self.wdeg.len() * std::mem::size_of::<f64>()
    }

    /// Potential of partition p at position `c` (Eq. 12 with the paper's
    /// max(‖·‖, 1) clamp), counting both inbound and outbound pulls.
    pub fn potential_at(&self, p: u32, c: (i32, i32), coords: &[(u16, u16)]) -> f64 {
        let mut pot = 0.0;
        for &(q, w) in self.neighbors(p) {
            let qc = coords[q as usize];
            let dist = (c.0 - qc.0 as i32).abs() + (c.1 - qc.1 as i32).abs();
            pot += w * (dist.max(1)) as f64;
        }
        pot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;

    fn quotient_like() -> Hypergraph {
        // partitions: 0 -> {1,2} (w 2), 1 -> {2} (w 1), 2 -> {0} (w .5)
        let mut b = HypergraphBuilder::new(3);
        b.add_edge(0, vec![1, 2], 2.0);
        b.add_edge(1, vec![2], 1.0);
        b.add_edge(2, vec![0], 0.5);
        b.build()
    }

    #[test]
    fn placement_validation() {
        let hw = NmhConfig::small();
        let good = Placement { coords: vec![(0, 0), (1, 0), (0, 1)] };
        good.validate(&hw).unwrap();
        let dup = Placement { coords: vec![(0, 0), (0, 0)] };
        assert!(dup.validate(&hw).is_err());
        let oob = Placement { coords: vec![(64, 0)] };
        assert!(oob.validate(&hw).is_err());
    }

    #[test]
    fn wirelength_hand_computed() {
        let gp = quotient_like();
        let pl = Placement { coords: vec![(0, 0), (1, 0), (2, 0)] };
        // e0: 2*(d(0,1)+d(0,2)) = 2*(1+2)=6 ; e1: 1*d(1,2)=1 ; e2: .5*d(2,0)=1
        assert!((pl.wirelength(&gp) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn adjacency_symmetric_and_aggregated() {
        let gp = quotient_like();
        let adj = PartitionAdjacency::build(&gp);
        // pair (0,1): w 2 ; pair (0,2): w 2 + 0.5 ; pair (1,2): w 1
        let get = |a: u32, b: u32| {
            adj.neighbors(a).iter().find(|&&(q, _)| q == b).map(|&(_, w)| w).unwrap()
        };
        assert!((get(0, 1) - 2.0).abs() < 1e-9);
        assert!((get(0, 2) - 2.5).abs() < 1e-9);
        assert!((get(1, 0) - 2.0).abs() < 1e-9);
        assert!((get(2, 1) - 1.0).abs() < 1e-9);
        assert!((adj.wdeg[0] - 4.5).abs() < 1e-9);
    }

    #[test]
    fn potential_clamps_colocation() {
        let gp = quotient_like();
        let adj = PartitionAdjacency::build(&gp);
        let coords = vec![(0, 0), (0, 0), (5, 0)];
        // p0 at (0,0): to q1 dist 0 -> clamped 1 (w 2) ; to q2 dist 5 (w 2.5)
        let pot = adj.potential_at(0, (0, 0), &coords);
        assert!((pot - (2.0 * 1.0 + 2.5 * 5.0)).abs() < 1e-9);
    }

    #[test]
    fn self_loops_excluded() {
        let mut b = HypergraphBuilder::new(2);
        b.add_edge(0, vec![0, 1], 3.0);
        let gp = b.build();
        let adj = PartitionAdjacency::build(&gp);
        assert_eq!(adj.neighbors(0).len(), 1); // only (0,1), no self pair
        assert!((adj.neighbors(0)[0].1 - 3.0).abs() < 1e-9);
    }
}
