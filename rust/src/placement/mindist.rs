//! Minimum-distance placement (paper §IV-C2, TrueNorth's scheme [11],
//! generalized + improved).
//!
//! Input partitions (no inbound h-edges) are spread evenly over a centered
//! sub-grid; every other partition is then placed — in topological order
//! when the quotient is acyclic, else Alg. 2's greedy order — on the core
//! minimizing its total spike-frequency-weighted Manhattan distance to
//! already-placed connected partitions. Candidate cores are restricted to
//! the frontier around the occupied region (the paper's scalability
//! improvement over scanning all |H| cores).

use super::{PartitionAdjacency, Placement};
use crate::hw::faults::FaultMask;
use crate::hw::NmhConfig;
use crate::hypergraph::Hypergraph;
use crate::mapping::{ordering, MapError};
use std::collections::BTreeSet;

/// Minimum-distance placement of the quotient h-graph `gp`.
pub fn place(gp: &Hypergraph, hw: &NmhConfig) -> Placement {
    place_threads(gp, hw, 1)
}

/// [`place`] with a worker budget for the Alg. 2 ordering pass (fed from
/// [`crate::stage::StageCtx::threads`] by [`MinDistPlacer`]).
/// Performance knob only — the order, and hence the placement, is
/// bit-for-bit thread-invariant.
// snn-lint: allow(parallel-serial-pairing) — worker-budget wrapper over the ordering pass;
// the frontier walk itself is serial, and the ordering owns the serial twin + tests
pub fn place_threads(gp: &Hypergraph, hw: &NmhConfig, threads: usize) -> Placement {
    assert!(gp.num_nodes() <= hw.num_cores(), "more partitions than cores");
    // with no mask the asserted bound rules out every error path, so the
    // fallback placement is unreachable
    place_masked(gp, hw, threads, None).unwrap_or(Placement { coords: Vec::new() })
}

/// [`place_threads`] under an optional hardware fault mask (DESIGN.md
/// §15): dead cores are pre-marked occupied — never spread onto, never
/// entering the frontier — and the capacity bound counts alive cores
/// only. `faults: None` is bit-identical to [`place_threads`].
pub fn place_masked(
    gp: &Hypergraph,
    hw: &NmhConfig,
    threads: usize,
    faults: Option<&FaultMask>,
) -> Result<Placement, MapError> {
    let n = gp.num_nodes();
    let alive = match faults {
        Some(m) => m.alive_count(),
        None => hw.num_cores(),
    };
    if n > alive {
        return Err(MapError::TooManyPartitions { got: n, limit: alive });
    }
    if n == 0 {
        return Ok(Placement { coords: vec![] });
    }
    let adj = PartitionAdjacency::build(gp);
    let order = ordering::auto_order_threads(gp, threads);

    // Input partitions: no inbound h-edges.
    let inputs: Vec<u32> = (0..n as u32).filter(|&p| gp.inbound(p).is_empty()).collect();

    let mut coords = vec![(u16::MAX, u16::MAX); n];
    let mut used = vec![false; hw.num_cores()];
    if let Some(m) = faults {
        // dead cores look permanently occupied to the whole sweep
        for (i, u) in used.iter_mut().enumerate() {
            if m.core_dead_idx(i) {
                *u = true;
            }
        }
    }
    // frontier: empty cores adjacent to used cores
    let mut frontier: BTreeSet<usize> = BTreeSet::new();

    // --- spread input partitions over a centered, evenly spaced grid ---
    let spread = spread_grid(inputs.len().max(1), hw, faults);
    for (i, &p) in inputs.iter().enumerate() {
        let (x, y) = spread[i];
        place_one(p, (x, y), hw, &mut coords, &mut used, &mut frontier);
    }
    // networks with no pure input partition: seed the first node centrally
    if inputs.is_empty() {
        let p = order[0];
        let center = ((hw.width / 2) as u16, (hw.height / 2) as u16);
        let c = if matches!(faults, Some(m) if m.is_core_dead(center.0, center.1)) {
            let mut gf = super::gridfind::GridFinder::with_faults(hw, faults);
            gf.take_nearest(center.0 as f64, center.1 as f64).ok_or_else(|| {
                MapError::NodeUnmappable {
                    node: p,
                    reason: "no alive core for the seed partition".to_string(),
                }
            })?
        } else {
            center
        };
        place_one(p, c, hw, &mut coords, &mut used, &mut frontier);
    }

    // --- main sweep ---
    for &p in &order {
        if coords[p as usize] != (u16::MAX, u16::MAX) {
            continue;
        }
        // total weighted distance to placed neighbors from candidate c
        let neighbors: Vec<(u32, f64)> = adj
            .neighbors(p)
            .iter()
            .filter(|&&(q, _)| coords[q as usize] != (u16::MAX, u16::MAX))
            .copied()
            .collect();
        let best = if neighbors.is_empty() {
            // unconnected to anything placed: any frontier core works;
            // pick the first (deterministic)
            frontier.iter().next().copied()
        } else {
            let mut best: Option<(f64, usize)> = None;
            for &cell in frontier.iter() {
                let (x, y) = hw.coord(cell);
                let mut cost = 0.0;
                for &(q, w) in &neighbors {
                    cost += w * NmhConfig::manhattan((x, y), coords[q as usize]) as f64;
                }
                if best.map(|(bc, bcell)| (cost, cell) < (bc, bcell)).unwrap_or(true) {
                    best = Some((cost, cell));
                }
            }
            best.map(|(_, cell)| cell)
        };
        let cell = match best {
            Some(c) => c,
            // frontier exhausted (isolated islands): first free alive core
            // (one exists while unplaced partitions remain, by the
            // n <= alive bound at fn entry — the error is defensive)
            None => used.iter().position(|&u| !u).ok_or_else(|| MapError::NodeUnmappable {
                node: p,
                reason: "no free alive core left".to_string(),
            })?,
        };
        let (x, y) = hw.coord(cell);
        place_one(p, (x, y), hw, &mut coords, &mut used, &mut frontier);
    }

    Ok(Placement { coords })
}

/// Claim `c` for partition `p` and update the frontier.
fn place_one(
    p: u32,
    c: (u16, u16),
    hw: &NmhConfig,
    coords: &mut [(u16, u16)],
    used: &mut [bool],
    frontier: &mut BTreeSet<usize>,
) {
    let idx = hw.index(c.0, c.1);
    debug_assert!(!used[idx]);
    used[idx] = true;
    coords[p as usize] = c;
    frontier.remove(&idx);
    for (dx, dy) in [(1i32, 0i32), (-1, 0), (0, 1), (0, -1)] {
        let nx = c.0 as i32 + dx;
        let ny = c.1 as i32 + dy;
        if hw.contains(nx, ny) {
            let ni = hw.index(nx as u16, ny as u16);
            if !used[ni] {
                frontier.insert(ni);
            }
        }
    }
}

/// Evenly spaced k positions on a centered sub-grid (the TrueNorth input
/// spreading rule: "spread out as much as possible while remaining
/// centered and evenly spaced between themselves and the borders").
/// Positions landing on dead cores are nudged to the nearest alive one.
fn spread_grid(k: usize, hw: &NmhConfig, faults: Option<&FaultMask>) -> Vec<(u16, u16)> {
    let cols = (k as f64).sqrt().ceil() as usize;
    let rows = crate::util::div_ceil(k, cols);
    let mut out = Vec::with_capacity(k);
    for i in 0..k {
        let r = i / cols;
        let c = i % cols;
        // fractional positions (c+1)/(cols+1), (r+1)/(rows+1) of the lattice
        let x = ((c + 1) as f64 / (cols + 1) as f64 * hw.width as f64).round() as i64;
        let y = ((r + 1) as f64 / (rows + 1) as f64 * hw.height as f64).round() as i64;
        let x = x.clamp(0, hw.width as i64 - 1) as u16;
        let y = y.clamp(0, hw.height as i64 - 1) as u16;
        out.push((x, y));
    }
    // de-collide (tiny lattices, dead cores): nudge to free alive cells
    let mut seen = std::collections::HashSet::new();
    let mut gf = super::gridfind::GridFinder::with_faults(hw, faults);
    for c in out.iter_mut() {
        if !seen.insert(*c) || gf.is_used(c.0, c.1) {
            // snn-lint: allow(unwrap-ban) — at most n <= alive cells are ever taken
            // (checked by every caller), so take_nearest always finds a free cell
            *c = gf.take_nearest(c.0 as f64, c.1 as f64).expect("lattice full");
        } else {
            gf.take(c.0, c.1);
        }
        seen.insert(*c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::HypergraphBuilder;

    fn layered_quotient() -> Hypergraph {
        // 2 inputs -> 4 mids -> 2 outs
        let mut b = HypergraphBuilder::new(8);
        b.add_edge(0, vec![2, 3], 1.0);
        b.add_edge(1, vec![4, 5], 1.0);
        b.add_edge(2, vec![6], 2.0);
        b.add_edge(3, vec![6], 1.0);
        b.add_edge(4, vec![7], 2.0);
        b.add_edge(5, vec![7], 1.0);
        b.build()
    }

    #[test]
    fn valid_and_all_placed() {
        let gp = layered_quotient();
        let hw = NmhConfig::small();
        let pl = place(&gp, &hw);
        pl.validate(&hw).unwrap();
        assert_eq!(pl.len(), 8);
    }

    #[test]
    fn children_land_near_parents() {
        let gp = layered_quotient();
        let hw = NmhConfig::small();
        let pl = place(&gp, &hw);
        // mid partitions sit close to their input
        for (parent, child) in [(0u32, 2u32), (1, 4)] {
            let d = NmhConfig::manhattan(pl.coords[parent as usize], pl.coords[child as usize]);
            assert!(d <= 3, "partition {child} at distance {d} from {parent}");
        }
    }

    #[test]
    fn inputs_spread_apart() {
        let gp = layered_quotient();
        let hw = NmhConfig::small();
        let pl = place(&gp, &hw);
        let d = NmhConfig::manhattan(pl.coords[0], pl.coords[1]);
        assert!(d >= 10, "inputs should spread, got distance {d}");
    }

    #[test]
    fn cyclic_quotient_still_places() {
        let mut b = HypergraphBuilder::new(5);
        for i in 0..5u32 {
            b.add_edge(i, vec![(i + 1) % 5], 1.0);
        }
        let gp = b.build();
        let hw = NmhConfig::small();
        let pl = place(&gp, &hw);
        pl.validate(&hw).unwrap();
        // ring should stay tight
        assert!(pl.wirelength(&gp) <= 10.0, "wl={}", pl.wirelength(&gp));
    }

    #[test]
    fn spread_grid_even_and_centered() {
        let hw = NmhConfig::small();
        let pts = spread_grid(4, &hw, None);
        assert_eq!(pts.len(), 4);
        // 2x2 arrangement at thirds of the lattice: x in {21,43}, y likewise
        for &(x, y) in &pts {
            assert!(x > 10 && x < 54, "x={x}");
            assert!(y > 10 && y < 54, "y={y}");
        }
        let set: std::collections::HashSet<_> = pts.iter().collect();
        assert_eq!(set.len(), 4);
    }

    #[test]
    fn masked_none_is_bit_identical_and_dead_cores_avoided() {
        let gp = layered_quotient();
        let hw = NmhConfig::small();
        let plain = place_threads(&gp, &hw, 1);
        let masked_none = place_masked(&gp, &hw, 1, None).unwrap();
        assert_eq!(plain.coords, masked_none.coords);
        // kill the cells the unmasked run chose: the masked run must
        // route around every one of them and stay valid
        let mut mask = FaultMask::healthy(&hw);
        for &(x, y) in &plain.coords {
            mask.kill_core(x, y);
        }
        let pl = place_masked(&gp, &hw, 1, Some(&mask)).unwrap();
        pl.validate(&hw).unwrap();
        for &(x, y) in &pl.coords {
            assert!(!mask.is_core_dead(x, y), "placed on dead core ({x},{y})");
        }
    }

    #[test]
    fn masked_rejects_more_partitions_than_alive_cores() {
        let mut hw = NmhConfig::small();
        hw.width = 3;
        hw.height = 3;
        let mut b = HypergraphBuilder::new(9);
        for i in 0..8u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        let gp = b.build();
        let mut mask = FaultMask::healthy(&hw);
        mask.kill_core(1, 1);
        let err = place_masked(&gp, &hw, 1, Some(&mask)).unwrap_err();
        assert!(
            matches!(err, MapError::TooManyPartitions { got: 9, limit: 8 }),
            "{err}"
        );
    }

    #[test]
    fn full_tiny_lattice() {
        let mut hw = NmhConfig::small();
        hw.width = 3;
        hw.height = 3;
        let mut b = HypergraphBuilder::new(9);
        for i in 0..8u32 {
            b.add_edge(i, vec![i + 1], 1.0);
        }
        let gp = b.build();
        let pl = place(&gp, &hw);
        pl.validate(&hw).unwrap();
        assert_eq!(pl.len(), 9);
    }
}

/// [`crate::stage::Placer`] over TrueNorth-style minimum-distance direct
/// placement (registry name "mindist"). A *direct* placer: it already
/// descends the wirelength objective, so the pipeline skips refinement.
#[derive(Clone, Copy, Debug, Default)]
pub struct MinDistPlacer;

impl MinDistPlacer {
    pub fn from_params(p: &crate::stage::StageParams) -> Result<Self, String> {
        p.check_known(&[])?;
        Ok(MinDistPlacer)
    }
}

impl crate::stage::Placer for MinDistPlacer {
    fn name(&self) -> &str {
        "mindist"
    }

    fn place(
        &self,
        gp: &Hypergraph,
        hw: &NmhConfig,
        ctx: &crate::stage::StageCtx,
    ) -> Result<Placement, crate::mapping::MapError> {
        place_masked(gp, hw, ctx.threads.max(1), ctx.faults)
    }

    fn is_direct(&self) -> bool {
        true
    }
}
