//! Nearest-free-core search over the lattice.
//!
//! The spectral placement discretizes a continuous embedding onto integer
//! cores without collisions; the paper uses a KD-tree over available grid
//! points. On a bounded lattice an expanding-ring search is exact and
//! allocation-free: scan Chebyshev rings outward, track the best Euclidean
//! candidate, and stop once the ring radius exceeds the best distance
//! (Euclidean ≥ Chebyshev guarantees optimality).

use crate::hw::NmhConfig;

/// Occupancy-tracking nearest-free-core finder.
pub struct GridFinder {
    width: i32,
    height: i32,
    used: Vec<bool>,
    free_count: usize,
}

impl GridFinder {
    pub fn new(hw: &NmhConfig) -> Self {
        GridFinder {
            width: hw.width as i32,
            height: hw.height as i32,
            used: vec![false; hw.num_cores()],
            free_count: hw.num_cores(),
        }
    }

    /// Like [`Self::new`] but with dead cores pre-marked occupied, so
    /// every `take_nearest` transparently lands on an alive core — the
    /// single masking primitive shared by the spectral discretization
    /// and the minimum-distance input spreading (zero per-placer fault
    /// logic). `faults: None` is exactly [`Self::new`].
    pub fn with_faults(hw: &NmhConfig, faults: Option<&crate::hw::faults::FaultMask>) -> Self {
        let mut gf = GridFinder::new(hw);
        if let Some(m) = faults {
            for (i, u) in gf.used.iter_mut().enumerate() {
                if m.core_dead_idx(i) {
                    *u = true;
                    gf.free_count -= 1;
                }
            }
        }
        gf
    }

    #[inline]
    fn idx(&self, x: i32, y: i32) -> usize {
        (y * self.width + x) as usize
    }

    pub fn free_count(&self) -> usize {
        self.free_count
    }

    pub fn is_used(&self, x: u16, y: u16) -> bool {
        self.used[self.idx(x as i32, y as i32)]
    }

    /// Mark a core as occupied (panics if already taken).
    pub fn take(&mut self, x: u16, y: u16) {
        let i = self.idx(x as i32, y as i32);
        assert!(!self.used[i], "core ({x},{y}) taken twice");
        self.used[i] = true;
        self.free_count -= 1;
    }

    /// Release a core.
    pub fn release(&mut self, x: u16, y: u16) {
        let i = self.idx(x as i32, y as i32);
        assert!(self.used[i], "core ({x},{y}) not taken");
        self.used[i] = false;
        self.free_count += 1;
    }

    /// Claim the free core nearest (Euclidean) to the continuous target
    /// `(tx, ty)`; ties broken towards smaller (y, x). Returns None when
    /// the lattice is full.
    pub fn take_nearest(&mut self, tx: f64, ty: f64) -> Option<(u16, u16)> {
        if self.free_count == 0 {
            return None;
        }
        let cx = (tx.round() as i32).clamp(0, self.width - 1);
        let cy = (ty.round() as i32).clamp(0, self.height - 1);
        let mut best: Option<(f64, i32, i32)> = None;
        let max_ring = self.width.max(self.height);
        for r in 0..=max_ring {
            if let Some((bd, _, _)) = best {
                // any cell on ring r is at Euclidean distance >= r - 1 from
                // the (possibly off-center) target; stop when provably done
                if bd <= (r - 1).max(0) as f64 {
                    break;
                }
            }
            let (x0, x1) = (cx - r, cx + r);
            let (y0, y1) = (cy - r, cy + r);
            let visit = |x: i32, y: i32, best: &mut Option<(f64, i32, i32)>| {
                if x < 0 || y < 0 || x >= self.width || y >= self.height {
                    return;
                }
                if self.used[(y * self.width + x) as usize] {
                    return;
                }
                let dx = x as f64 - tx;
                let dy = y as f64 - ty;
                let d = (dx * dx + dy * dy).sqrt();
                let better = match *best {
                    None => true,
                    Some((bd, bx, by)) => {
                        d < bd - 1e-12 || ((d - bd).abs() <= 1e-12 && (y, x) < (by, bx))
                    }
                };
                if better {
                    *best = Some((d, x, y));
                }
            };
            if r == 0 {
                visit(cx, cy, &mut best);
            } else {
                for x in x0..=x1 {
                    visit(x, y0, &mut best);
                    visit(x, y1, &mut best);
                }
                for y in (y0 + 1)..y1 {
                    visit(x0, y, &mut best);
                    visit(x1, y, &mut best);
                }
            }
        }
        let (_, x, y) = best?;
        self.take(x as u16, y as u16);
        Some((x as u16, y as u16))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw8() -> NmhConfig {
        let mut hw = NmhConfig::small();
        hw.width = 8;
        hw.height = 8;
        hw
    }

    #[test]
    fn takes_exact_cell_when_free() {
        let hw = hw8();
        let mut gf = GridFinder::new(&hw);
        assert_eq!(gf.take_nearest(3.2, 4.1), Some((3, 4)));
        assert!(gf.is_used(3, 4));
    }

    #[test]
    fn falls_to_nearest_when_occupied() {
        let hw = hw8();
        let mut gf = GridFinder::new(&hw);
        gf.take(3, 4);
        let got = gf.take_nearest(3.0, 4.0).unwrap();
        assert_eq!(NmhConfig::manhattan(got, (3, 4)), 1);
    }

    #[test]
    fn nearest_matches_bruteforce() {
        let hw = hw8();
        let mut rng = crate::util::rng::Pcg64::seeded(4);
        let mut gf = GridFinder::new(&hw);
        let mut used = vec![false; 64];
        for _ in 0..60 {
            let tx = rng.next_f64() * 7.0;
            let ty = rng.next_f64() * 7.0;
            // brute-force best
            let mut want: Option<(f64, i32, i32)> = None;
            for y in 0..8i32 {
                for x in 0..8i32 {
                    if used[(y * 8 + x) as usize] {
                        continue;
                    }
                    let d = ((x as f64 - tx).powi(2) + (y as f64 - ty).powi(2)).sqrt();
                    let better = match want {
                        None => true,
                        Some((bd, bx, by)) => {
                            d < bd - 1e-12 || ((d - bd).abs() <= 1e-12 && (y, x) < (by, bx))
                        }
                    };
                    if better {
                        want = Some((d, x, y));
                    }
                }
            }
            let got = gf.take_nearest(tx, ty).unwrap();
            let (_, wx, wy) = want.unwrap();
            assert_eq!(got, (wx as u16, wy as u16), "target ({tx},{ty})");
            used[(wy * 8 + wx) as usize] = true;
        }
    }

    #[test]
    fn exhausts_lattice() {
        let hw = hw8();
        let mut gf = GridFinder::new(&hw);
        for _ in 0..64 {
            assert!(gf.take_nearest(4.0, 4.0).is_some());
        }
        assert_eq!(gf.take_nearest(4.0, 4.0), None);
        assert_eq!(gf.free_count(), 0);
    }

    #[test]
    fn masked_constructor_skips_dead_cores() {
        let hw = hw8();
        let mut mask = crate::hw::faults::FaultMask::healthy(&hw);
        mask.kill_core(4, 4);
        mask.kill_core(3, 4);
        let mut gf = GridFinder::with_faults(&hw, Some(&mask));
        assert_eq!(gf.free_count(), 62);
        let got = gf.take_nearest(4.0, 4.0).unwrap();
        assert_ne!(got, (4, 4));
        assert_ne!(got, (3, 4));
        assert_eq!(NmhConfig::manhattan(got, (4, 4)), 1);
        // None delegates to the unmasked constructor exactly
        let gf = GridFinder::with_faults(&hw, None);
        assert_eq!(gf.free_count(), 64);
    }

    #[test]
    fn release_reopens() {
        let hw = hw8();
        let mut gf = GridFinder::new(&hw);
        gf.take(0, 0);
        gf.release(0, 0);
        assert_eq!(gf.take_nearest(0.0, 0.0), Some((0, 0)));
    }
}
